"""Mixture-of-Experts FFN with GShard/Switch-style capacity dispatch.

TPU-idiomatic design (see DESIGN.md §7): experts are NOT sharded across a
mesh axis (8 and 60 do not divide 16); instead every expert's FFN weights are
tensor-sharded over ``model`` (logical axis "mlp") and tokens are dispatched
with capacity-factor one-hot einsums, grouped per sequence so the dispatch
tensors stay small.  Routing therefore lowers to dense matmuls and reuses the
same collectives as a dense FFN.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.module import Module, Param, stack_specs
from repro.nn.layers import GLUMLP, Linear


class MoE(Module):
    def __init__(self, dim: int, hidden: int, n_experts: int, top_k: int, *,
                 n_shared: int = 0, shared_hidden: Optional[int] = None,
                 capacity_factor: float = 1.25, act: str = "silu",
                 group_size: int = 512, router_dtype=jnp.float32,
                 dtype=jnp.float32):
        self.dim, self.hidden = dim, hidden
        self.n_experts, self.top_k = n_experts, top_k
        self.capacity_factor = capacity_factor
        self.group_size = group_size
        self.router = Linear(dim, n_experts, axes=("embed", "expert_dim"),
                             bias=False, dtype=router_dtype)
        self.expert = GLUMLP(dim, hidden, act=act, bias=False, dtype=dtype)
        self.n_shared = n_shared
        if n_shared:
            self.shared = GLUMLP(dim, (shared_hidden or hidden) * n_shared,
                                 act=act, bias=False, dtype=dtype)
            # qwen2-moe: shared-expert gate (sigmoid) on the shared branch
            self.shared_gate = Linear(dim, 1, axes=("embed", None),
                                      bias=False, dtype=dtype)

    def spec(self):
        s = {"router": self.router.spec(),
             "experts": stack_specs(self.expert.spec(), self.n_experts, "expert")}
        if self.n_shared:
            s["shared"] = self.shared.spec()
            s["shared_gate"] = self.shared_gate.spec()
        return s

    def capacity(self, group: int) -> int:
        c = int(group * self.top_k / self.n_experts * self.capacity_factor)
        return max(4, -(-c // 4) * 4)   # round up to multiple of 4

    def __call__(self, p, x):
        """x: (B, S, d) -> (y, aux) where aux carries the load-balance loss.

        Tokens are routed within GROUPS of ``group_size`` (GShard-style), so
        the dispatch/combine one-hots stay (G, E, C)-sized regardless of the
        global token count — essential for 60-expert configs at 4k sequence.
        """
        B0, S0, d = x.shape
        G = min(self.group_size, B0 * S0)
        total = B0 * S0
        pad = -total % G
        xf = x.reshape(total, d)
        if pad:
            xf = jnp.concatenate([xf, jnp.zeros((pad, d), x.dtype)], 0)
        x = xf.reshape(-1, G, d)                 # (n_groups, G, d) as (B, S, d)
        B, S = x.shape[0], x.shape[1]
        E, k = self.n_experts, self.top_k
        C = self.capacity(S)

        logits = self.router(p["router"], x.astype(self.router.dtype))   # (B,S,E)
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

        # top-k gates, renormalized over the selected experts
        top_p, top_i = jax.lax.top_k(probs, k)                           # (B,S,k)
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

        # position of each (token, choice) in its expert's buffer
        onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)             # (B,S,k,E)
        flat = onehot.reshape(B, S * k, E)
        pos = jnp.cumsum(flat, axis=1) - flat                            # (B,S*k,E)
        pos = pos.reshape(B, S, k, E)
        in_cap = pos < C
        gates = top_p[..., None] * onehot * in_cap                       # (B,S,k,E)

        # dispatch/combine tensors (B, S, E, C)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C,
                                dtype=jnp.float32)                       # (B,S,k,E,C)
        combine = jnp.einsum("bske,bskec->bsec", gates, pos_oh)
        dispatch = (combine > 0).astype(x.dtype)

        xin = jnp.einsum("bsec,bsd->becd", dispatch, x)                  # (B,E,C,d)
        yexp = jax.vmap(self.expert, in_axes=(0, 1), out_axes=1)(
            p["experts"], xin)                                           # (B,E,C,d)
        y = jnp.einsum("bsec,becd->bsd", combine.astype(x.dtype), yexp)

        if self.n_shared:
            g = jax.nn.sigmoid(self.shared_gate(p["shared_gate"], x))
            y = y + g * self.shared(p["shared"], x)

        # Switch-style load-balance loss
        frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))               # (E,)
        frac_probs = jnp.mean(probs, axis=(0, 1))                        # (E,)
        aux = {"lb_loss": E * jnp.sum(frac_tokens * frac_probs),
               "router_overflow": 1.0 - jnp.mean(in_cap)}
        y = y.reshape(-1, d)[:total].reshape(B0, S0, d)
        return y, aux
