"""Multi-head / grouped-query attention with causal, sliding-window,
ring-buffer cache, and cross-attention call modes.

Sharding-aware formulation: Q projections are stored and computed natively
as (d_model, kv_heads, q_per_kv, head_dim) — 5-D activations — so tensor
parallelism can shard whichever axis divides the mesh (kv_heads for MHA-ish
archs, head_dim for kv=8 GQA archs on a 16-wide model axis).  Merged-head
reshapes of sharded tensors (which break GSPMD propagation) never occur
inside the model.  See distributed/sharding.py::attention_axis.

The math lives in :func:`attend5` (5-D) with :func:`attend` as the 4-D
wrapper used by kernels/refs/tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.module import Module, Param, fan_in_init, ones_init
from repro.nn.rope import apply_rope

NEG_INF = -1e30


def attend5(q, k, v, *, q_pos=None, k_pos=None, causal=True, window=None,
            k_valid=None, scale=None):
    """q: (B, S, K, G, D); k/v: (B, T, K, D).  -> (B, S, K, G, D).

    q_pos/k_pos: (B, S)/(B, T) absolute positions (or 1-D broadcastable);
    k_valid: (B, T) mask for unwritten cache slots.
    """
    B, S, K, G, D = q.shape
    T = k.shape[1]
    scale = scale if scale is not None else D ** -0.5

    if q_pos is None:
        q_pos = jnp.arange(S)
    if k_pos is None:
        k_pos = jnp.arange(T)
    q_pos = jnp.broadcast_to(q_pos, (B, S)) if q_pos.ndim == 1 else q_pos
    k_pos = jnp.broadcast_to(k_pos, (B, T)) if k_pos.ndim == 1 else k_pos

    logits = jnp.einsum("bskgd,btkd->bkgst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale

    mask = jnp.ones((B, S, T), dtype=bool)
    if causal:
        mask &= k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        mask &= k_pos[:, None, :] > q_pos[:, :, None] - window
    if k_valid is not None:
        kv = jnp.broadcast_to(k_valid, (B, T)) if k_valid.ndim == 1 else k_valid
        mask &= kv[:, None, :]
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)

    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def attend(q, k, v, *, q_pos=None, k_pos=None, causal=True, window=None,
           k_valid=None, scale=None):
    """4-D wrapper: q (B, S, H, D), kv-head of query h is h // (H/K)."""
    B, S, H, D = q.shape
    K = k.shape[2]
    q5 = q.reshape(B, S, K, H // K, D)
    out = attend5(q5, k, v, q_pos=q_pos, k_pos=k_pos, causal=causal,
                  window=window, k_valid=k_valid, scale=scale)
    return out.reshape(B, S, H, D)


def attend_blocked(q, k, v, *, q_pos=None, k_pos=None, causal=True,
                   window=None, k_valid=None, scale=None, bq: int = 256):
    """Memory-tiled attention: lax.scan over query blocks so the (S, T)
    score matrix never materializes (S*T can be 32k x 32k in prefill).
    Numerically identical to :func:`attend5`.  q is 5-D."""
    B, S, K, G, D = q.shape
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    elif q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos, (B, S))
    bq = min(bq, S)
    pad = -S % bq
    if pad:
        qp = jnp.pad(q, ((0, 0), (0, pad)) + ((0, 0),) * 3)
        pp = jnp.pad(q_pos, ((0, 0), (0, pad)))
    else:
        qp, pp = q, q_pos
    nq = qp.shape[1] // bq
    qs = jnp.moveaxis(qp.reshape(B, nq, bq, K, G, D), 1, 0)
    ps = jnp.moveaxis(pp.reshape(B, nq, bq), 1, 0)

    def body(_, inp):
        qb, pb = inp
        ob = attend5(qb, k, v, q_pos=pb, k_pos=k_pos, causal=causal,
                     window=window, k_valid=k_valid, scale=scale)
        return (), ob

    # flash-style recompute: never save per-block scores/probs for backward
    # (they are O(bq * T * heads) fp32 per block — the dominant training-
    # memory term at 4k-32k sequence; recomputing costs ~30% extra attention
    # flops in bwd).  See EXPERIMENTS.md §Perf iteration 1.
    body = jax.checkpoint(body, prevent_cse=False)
    _, out = jax.lax.scan(body, (), (qs, ps))
    out = jnp.moveaxis(out, 0, 1).reshape(B, nq * bq, K, G, D)
    return out[:, :S]


# score matrices larger than this (elements) switch to the blocked path
_BLOCKED_THRESHOLD = 4 * 1024 * 1024


@dataclasses.dataclass
class KVCache:
    """Ring-buffer KV cache.  ``size`` slots; slot for absolute position p is
    p % size.  For full-attention archs size == max_len (no wrap); for
    sliding-window archs size == window (the paper's "rotate-replace"
    optimization generalized: overwrite the oldest token, rotate the mask).
    """
    k: jax.Array          # (B, size, K, D)
    v: jax.Array          # (B, size, K, D)
    pos: jax.Array        # (B,) int32 — number of tokens written so far

    @property
    def size(self):
        return self.k.shape[1]

    @staticmethod
    def zeros(batch, size, n_kv, head_dim, dtype=jnp.bfloat16):
        shape = (batch, size, n_kv, head_dim)
        return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                       pos=jnp.zeros((batch,), jnp.int32))

    def slot_positions(self):
        """Absolute position currently held by each slot, and validity."""
        B, size = self.k.shape[0], self.size
        slots = jnp.arange(size)[None, :]                       # (1, size)
        n = self.pos[:, None]                                   # (B, 1)
        # slot s holds the largest p < n with p % size == s  (if any)
        last = n - 1 - (n - 1 - slots) % size
        valid = (slots < n) & (last >= 0)
        return jnp.where(valid, last, 0), valid

    def update(self, k_new, v_new):
        """Append one token per sequence (k_new: (B, 1, K, D)).

        Scatter-based in-place write: O(1) HBM traffic per token.  (A
        one-hot multiply would read+write the ENTIRE cache each step —
        §Perf iteration 4.)"""
        b = jnp.arange(self.k.shape[0])
        slot = self.pos % self.size
        return KVCache(
            k=self.k.at[b, slot].set(k_new[:, 0].astype(self.k.dtype)),
            v=self.v.at[b, slot].set(v_new[:, 0].astype(self.v.dtype)),
            pos=self.pos + 1)


jax.tree_util.register_dataclass(KVCache, data_fields=["k", "v", "pos"], meta_fields=[])


class Attention(Module):
    """GQA attention with optional qk-norm, bias, RoPE, sliding window."""

    def __init__(self, dim: int, n_heads: int, n_kv: int, head_dim: Optional[int] = None,
                 *, bias: bool = False, qk_norm: bool = False, rope: bool = True,
                 rope_theta: float = 10000.0, window: Optional[int] = None,
                 causal: bool = True, dtype=jnp.float32, impl: str = "xla"):
        assert n_heads % n_kv == 0
        self.dim, self.n_heads, self.n_kv = dim, n_heads, n_kv
        self.q_per_kv = n_heads // n_kv
        self.head_dim = head_dim or dim // n_heads
        self.bias, self.qk_norm = bias, qk_norm
        self.rope, self.rope_theta, self.window = rope, rope_theta, window
        self.causal, self.dtype, self.impl = causal, dtype, impl

    def spec(self):
        D, K, G, hd = self.dim, self.n_kv, self.q_per_kv, self.head_dim
        dt = self.dtype
        s = {
            "wq": Param((D, K, G, hd), dt,
                        ("embed", "kv_heads", "q_per_kv", "head_dim"),
                        fan_in_init(0)),
            "wk": Param((D, K, hd), dt, ("embed", "kv_heads", "head_dim"),
                        fan_in_init(0)),
            "wv": Param((D, K, hd), dt, ("embed", "kv_heads", "head_dim"),
                        fan_in_init(0)),
            "wo": Param((K, G, hd, D), dt,
                        ("kv_heads", "q_per_kv", "head_dim", "embed"),
                        fan_in_init(0)),
        }
        if self.bias:
            z = lambda k, sh, d: jnp.zeros(sh, d)
            s["bq"] = Param((K, G, hd), dt, ("kv_heads", "q_per_kv", "head_dim"), z)
            s["bk"] = Param((K, hd), dt, ("kv_heads", "head_dim"), z)
            s["bv"] = Param((K, hd), dt, ("kv_heads", "head_dim"), z)
        if self.qk_norm:
            s["q_norm"] = Param((hd,), dt, ("head_dim",), ones_init)
            s["k_norm"] = Param((hd,), dt, ("head_dim",), ones_init)
        return s

    # -- projections --------------------------------------------------------
    def _rms(self, x, scale):
        xf = x.astype(jnp.float32)
        y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), -1, keepdims=True) + 1e-6)
        return (y * scale.astype(jnp.float32)).astype(x.dtype)

    def qkv(self, p, x, positions):
        """Project x -> (q (B,S,K,G,D), k (B,S,K,D), v) with qk-norm/RoPE."""
        q = jnp.einsum("bsd,dkgh->bskgh", x, p["wq"])
        k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
        v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
        if self.bias:
            q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
        if self.qk_norm:
            q, k = self._rms(q, p["q_norm"]), self._rms(k, p["k_norm"])
        if self.rope:
            q = apply_rope(q, positions, self.rope_theta)
            k = apply_rope(k, positions, self.rope_theta)
        return q, k, v

    def out(self, p, o):
        return jnp.einsum("bskgh,kghd->bsd", o, p["wo"])

    def _attend(self, q, k, v, **kw):
        S, T = q.shape[1], k.shape[1]
        if S * T > _BLOCKED_THRESHOLD:
            return attend_blocked(q, k, v, **kw)
        return attend5(q, k, v, **kw)

    # -- call modes ----------------------------------------------------------
    def __call__(self, p, x, *, positions=None, return_kv: bool = False):
        """Full-sequence self-attention (train / prefill)."""
        B, S, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        q, k, v = self.qkv(p, x, positions)
        if self.impl == "pallas":
            from repro.kernels import ops as kops
            q4 = q.reshape(B, S, self.n_heads, self.head_dim)
            o = kops.flash_attention(q4, k, v, causal=self.causal,
                                     window=self.window)
            o = o.reshape(q.shape)
        else:
            from repro.distributed.sharding import seq_parallel_attention
            o = seq_parallel_attention(
                q, k, v, positions, causal=self.causal, window=self.window,
                attend_fn=self._attend)
            if o is None:
                o = self._attend(q, k, v, q_pos=positions, k_pos=positions,
                                 causal=self.causal, window=self.window)
        y = self.out(p, o)
        return (y, (k, v)) if return_kv else y

    def decode(self, p, x, cache: KVCache, positions):
        """One-token decode: x (B, 1, d); positions (B, 1) absolute."""
        q, k, v = self.qkv(p, x, positions)
        cache = cache.update(k, v)
        k_pos, k_valid = cache.slot_positions()
        o = attend5(q, cache.k, cache.v, q_pos=positions, k_pos=k_pos,
                    causal=True, window=self.window, k_valid=k_valid)
        return self.out(p, o), cache

    def cross(self, p, x, k_ctx, v_ctx, *, positions=None, k_pos=None,
              self_attend: bool = True, rotate_replace: bool = False,
              gather_idx=None):
        """Cross-attention of x against an external KV (DCAT crossing /
        whisper decoder cross-attn).

        self_attend: x's own KV is appended (DCAT eq. 4 concatenation).
        rotate_replace: instead of concatenating, overwrite the OLDEST
        context slots with x's KV and rotate the positions (paper §4.1's
        fixed-length-256 optimization — no concat, shapes stay 2^k-aligned).
        gather_idx: (B,) Ψ⁻¹ index — k_ctx/v_ctx are then the DEDUPLICATED
        (B_u, L, K, D) context.  On the Pallas path the gather is fused into
        the kernel's BlockSpec index_map (never materialized in HBM); the
        XLA path materializes the gather.
        """
        B, S, _ = x.shape
        L = k_ctx.shape[1]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(L, L + S), (B, S))
        q, k, v = self.qkv(p, x, positions)

        if (self.impl == "pallas" and gather_idx is not None
                and not rotate_replace and self_attend and k_pos is None):
            from repro.kernels import ops as kops
            q4 = q.reshape(B, S, self.n_heads, self.head_dim)
            o = kops.dcat_cross_attention(q4, k_ctx, v_ctx, k, v, gather_idx)
            return self.out(p, o.reshape(q.shape))

        if gather_idx is not None:
            k_ctx = jnp.take(k_ctx, gather_idx, axis=0)
            v_ctx = jnp.take(v_ctx, gather_idx, axis=0)
        ctx_pos = (jnp.broadcast_to(jnp.arange(L), (B, L))
                   if k_pos is None else jnp.broadcast_to(k_pos, (B, L)))
        if rotate_replace:
            k_full = jax.lax.dynamic_update_slice_in_dim(k_ctx, k, 0, axis=1)
            v_full = jax.lax.dynamic_update_slice_in_dim(v_ctx, v, 0, axis=1)
            kp = jax.lax.dynamic_update_slice_in_dim(ctx_pos, positions, 0, axis=1)
        elif self_attend:
            k_full = jnp.concatenate([k_ctx, k], axis=1)
            v_full = jnp.concatenate([v_ctx, v], axis=1)
            kp = jnp.concatenate([ctx_pos, positions], axis=1)
        else:
            k_full, v_full, kp = k_ctx, v_ctx, ctx_pos
        o = self._attend(q, k_full, v_full, q_pos=positions, k_pos=kp,
                         causal=self.causal, window=self.window)
        return self.out(p, o)
