"""Basic layers: Linear, Embedding, LayerNorm/RMSNorm, MLPs."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.nn.module import Module, Param, fan_in_init, normal_init, ones_init, zeros_init


class Linear(Module):
    """y = x @ w (+ b).  ``axes`` are the logical names of (in, out) dims."""

    def __init__(self, in_dim: int, out_dim: int, *, axes=("embed", "mlp"),
                 bias: bool = False, dtype=jnp.float32, init=None):
        self.in_dim, self.out_dim = in_dim, out_dim
        self.axes, self.bias, self.dtype = tuple(axes), bias, dtype
        self.w_init = init or fan_in_init(axis=0)

    def spec(self):
        s = {"w": Param((self.in_dim, self.out_dim), self.dtype, self.axes, self.w_init)}
        if self.bias:
            s["b"] = Param((self.out_dim,), self.dtype, (self.axes[1],), zeros_init)
        return s

    def __call__(self, p, x):
        y = jnp.einsum("...i,io->...o", x, p["w"])
        if self.bias:
            y = y + p["b"].astype(y.dtype)
        return y


class Embedding(Module):
    def __init__(self, vocab: int, dim: int, *, axes=("vocab", "embed"),
                 dtype=jnp.float32, init=None, pad_rows_to: int = 1):
        self.vocab, self.dim = vocab, dim
        # pad rows so odd vocabularies (50280, 51865) stay shardable over the
        # 16-wide model axis; padded logit columns are masked at the head
        self.rows = -(-vocab // pad_rows_to) * pad_rows_to
        self.axes, self.dtype = tuple(axes), dtype
        self.w_init = init or normal_init(0.02)

    def spec(self):
        return {"table": Param((self.rows, self.dim), self.dtype, self.axes, self.w_init)}

    def __call__(self, p, ids):
        return jnp.take(p["table"], ids, axis=0)

    def attend(self, p, x):
        """Logits against the table (weight tying)."""
        return jnp.einsum("...d,vd->...v", x, p["table"])


class RMSNorm(Module):
    def __init__(self, dim: int, *, eps: float = 1e-6, axes=("embed",), dtype=jnp.float32):
        self.dim, self.eps, self.axes, self.dtype = dim, eps, tuple(axes), dtype

    def spec(self):
        return {"scale": Param((self.dim,), self.dtype, self.axes, ones_init)}

    def __call__(self, p, x):
        dtype = x.dtype
        x = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
        y = x * jax.lax.rsqrt(var + self.eps)
        return (y * p["scale"].astype(jnp.float32)).astype(dtype)


class LayerNorm(Module):
    def __init__(self, dim: int, *, eps: float = 1e-5, axes=("embed",),
                 bias: bool = True, dtype=jnp.float32):
        self.dim, self.eps, self.axes = dim, eps, tuple(axes)
        self.bias, self.dtype = bias, dtype

    def spec(self):
        s = {"scale": Param((self.dim,), self.dtype, self.axes, ones_init)}
        if self.bias:
            s["bias"] = Param((self.dim,), self.dtype, self.axes, zeros_init)
        return s

    def __call__(self, p, x):
        dtype = x.dtype
        x = x.astype(jnp.float32)
        mu = jnp.mean(x, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
        y = (x - mu) * jax.lax.rsqrt(var + self.eps)
        y = y * p["scale"].astype(jnp.float32)
        if self.bias:
            y = y + p["bias"].astype(jnp.float32)
        return y.astype(dtype)


def l2_normalize(x, axis=-1, eps=1e-6):
    return x * jax.lax.rsqrt(jnp.sum(jnp.square(x), axis=axis, keepdims=True) + eps)


_ACT = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu": jax.nn.relu,
    "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
}


class MLP(Module):
    """Standard 2-layer MLP (GPT-2 / whisper style)."""

    def __init__(self, dim: int, hidden: int, *, act: str = "gelu", bias: bool = True,
                 dtype=jnp.float32):
        self.up = Linear(dim, hidden, axes=("embed", "mlp"), bias=bias, dtype=dtype)
        self.down = Linear(hidden, dim, axes=("mlp", "embed"), bias=bias, dtype=dtype)
        self.act = _ACT[act]

    def spec(self):
        return {"up": self.up.spec(), "down": self.down.spec()}

    def __call__(self, p, x):
        return self.down(p["down"], self.act(self.up(p["up"], x)))


class GLUMLP(Module):
    """Gated MLP (llama / qwen / mixtral expert style): down(act(gate(x)) * up(x))."""

    def __init__(self, dim: int, hidden: int, *, act: str = "silu", bias: bool = False,
                 dtype=jnp.float32):
        self.gate = Linear(dim, hidden, axes=("embed", "mlp"), bias=bias, dtype=dtype)
        self.up = Linear(dim, hidden, axes=("embed", "mlp"), bias=bias, dtype=dtype)
        self.down = Linear(hidden, dim, axes=("mlp", "embed"), bias=bias, dtype=dtype)
        self.act = _ACT[act]

    def spec(self):
        return {"gate": self.gate.spec(), "up": self.up.spec(), "down": self.down.spec()}

    def __call__(self, p, x):
        return self.down(p["down"], self.act(self.gate(p["gate"], x)) * self.up(p["up"], x))


class PointwiseMLPNorm(Module):
    """PinFM's phi_in / phi_out / psi: pointwise MLP followed by l2 norm."""

    def __init__(self, in_dim: int, out_dim: int, hidden: Optional[int] = None,
                 *, act: str = "gelu", dtype=jnp.float32, l2: bool = True):
        hidden = hidden or max(in_dim, out_dim)
        self.fc1 = Linear(in_dim, hidden, axes=("embed", "mlp"), bias=True, dtype=dtype)
        self.fc2 = Linear(hidden, out_dim, axes=("mlp", "embed"), bias=True, dtype=dtype)
        self.act = _ACT[act]
        self.l2 = l2

    def spec(self):
        return {"fc1": self.fc1.spec(), "fc2": self.fc2.spec()}

    def __call__(self, p, x):
        y = self.fc2(p["fc2"], self.act(self.fc1(p["fc1"], x)))
        return l2_normalize(y) if self.l2 else y
