"""Functional module system: parameter pytrees with logical sharding axes.

Design: a model is described by a tree of :class:`Param` specs (shape, dtype,
logical axes, initializer).  From the spec tree we can derive, without ever
allocating memory:

  * ``abstract(spec)``       -> jax.ShapeDtypeStruct tree (for .lower())
  * ``logical_axes(spec)``   -> tree of logical-axis-name tuples
  * ``partition_specs(...)`` -> jax.sharding.PartitionSpec tree via a policy

and with a PRNG key we can materialize real parameters for small models:

  * ``init(spec, key)``      -> tree of jnp arrays

Every layer is a :class:`Module`: ``.spec()`` returns its Param tree and
``__call__(params, *args)`` is a pure function of that tree.  Composite
modules nest children specs under their own keys.  There is no tracing or
metaclass magic; everything is a plain pytree, which keeps pjit/shard_map
and scan-over-layers straightforward.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Param spec
# ---------------------------------------------------------------------------

InitFn = Callable[[jax.Array, Sequence[int], Any], jax.Array]


def zeros_init(key, shape, dtype):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    del key
    return jnp.ones(shape, dtype)


def normal_init(stddev: float = 0.02) -> InitFn:
    def f(key, shape, dtype):
        return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    return f


def fan_in_init(axis: int = 0) -> InitFn:
    """LeCun-normal style init: stddev = 1/sqrt(fan_in)."""
    def f(key, shape, dtype):
        fan_in = shape[axis] if shape else 1
        std = 1.0 / np.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, shape, jnp.float32)).astype(dtype)
    return f


@dataclasses.dataclass(frozen=True)
class Param:
    """Declarative spec for one parameter tensor."""
    shape: tuple
    dtype: Any
    axes: tuple            # logical axis names, len == len(shape); None entries ok
    init: InitFn = dataclasses.field(default_factory=lambda: fan_in_init())

    def __post_init__(self):
        if len(self.axes) != len(self.shape):
            raise ValueError(f"axes {self.axes} rank != shape {self.shape} rank")


def is_param(x) -> bool:
    return isinstance(x, Param)


# ---------------------------------------------------------------------------
# Tree utilities over Param specs
# ---------------------------------------------------------------------------

def _map_params(fn, spec):
    return jax.tree.map(fn, spec, is_leaf=is_param)


def abstract(spec):
    """ShapeDtypeStruct tree for jit(...).lower() without allocation."""
    return _map_params(lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), spec)


def logical_axes(spec):
    return _map_params(lambda p: p.axes, spec)


def param_count(spec) -> int:
    leaves = jax.tree.leaves(spec, is_leaf=is_param)
    return int(sum(int(np.prod(p.shape)) for p in leaves))


def param_bytes(spec) -> int:
    leaves = jax.tree.leaves(spec, is_leaf=is_param)
    return int(sum(int(np.prod(p.shape)) * jnp.dtype(p.dtype).itemsize for p in leaves))


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def init(spec, key: jax.Array):
    """Materialize parameters.  Each leaf gets a key derived from its path,
    so adding/removing parameters does not perturb unrelated leaves."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(spec, is_leaf=is_param)
    leaves = []
    for path, p in flat:
        h = int.from_bytes(
            hashlib.blake2s(_path_str(path).encode(), digest_size=4).digest(), "big")
        leaves.append(p.init(jax.random.fold_in(key, h), p.shape, p.dtype))
    return jax.tree.unflatten(treedef, leaves)


# ---------------------------------------------------------------------------
# Partition specs from logical axes
# ---------------------------------------------------------------------------

def partition_specs(spec, policy: dict):
    """Map each Param's logical axes through ``policy`` (logical -> mesh axis
    name, or None, or a tuple of mesh axes).  Unknown logical names -> None.
    """
    from jax.sharding import PartitionSpec as P

    def one(p: Param):
        return P(*[policy.get(a) for a in p.axes])
    return _map_params(one, spec)


def named_sharding_tree(spec, mesh, policy: dict):
    from jax.sharding import NamedSharding
    pspecs = partition_specs(spec, policy)
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))


# ---------------------------------------------------------------------------
# Module base class
# ---------------------------------------------------------------------------

class Module:
    """Base class: stateless, config in __init__, params passed to __call__."""

    def spec(self):
        raise NotImplementedError

    def init(self, key: jax.Array):
        return init(self.spec(), key)

    def abstract(self):
        return abstract(self.spec())

    def param_count(self) -> int:
        return param_count(self.spec())


def stack_specs(spec, n: int, axis_name: str = "layers"):
    """Turn a single-layer Param tree into an n-layer stacked tree (leading
    ``layers`` axis) for use with jax.lax.scan over layers."""
    def one(p: Param):
        base = p.init

        def stacked_init(key, shape, dtype):
            keys = jax.random.split(key, shape[0])
            return jax.vmap(lambda k: base(k, shape[1:], dtype))(keys)

        return Param((n, *p.shape), p.dtype, (axis_name, *p.axes), stacked_init)
    return _map_params(one, spec)
