"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * r_t),  r_t = sigmoid(W_a x_t),
i_t = sigmoid(W_x x_t)

Sequence mode uses an associative scan over the linear recurrence
(h_t = a_t h_{t-1} + b_t); decode mode is a single fused step.  The carried
state is the DCAT "context" analogue for hybrid archs (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.module import Module, Param, fan_in_init, zeros_init
from repro.nn.layers import Linear, _ACT

_C = 8.0  # Griffin's fixed recurrence-sharpness constant


def linear_scan(a, b):
    """Associative scan for h_t = a_t h_{t-1} + b_t over axis 1 (seq)."""
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    return jax.lax.associative_scan(combine, (a, b), axis=1)[1]


@dataclasses.dataclass
class RecurrentState:
    h: jax.Array       # (B, width) RG-LRU hidden state
    conv: jax.Array    # (B, conv_width-1, width) trailing conv inputs


jax.tree_util.register_dataclass(RecurrentState, data_fields=["h", "conv"],
                                 meta_fields=[])


class RGLRU(Module):
    def __init__(self, width: int, dtype=jnp.float32):
        self.width, self.dtype = width, dtype

    def spec(self):
        w, dt = self.width, self.dtype
        return {
            "lam": Param((w,), dt, ("state",),
                         lambda k, s, d: jnp.full(s, 0.65, d)),   # a ~ .9-.99 region
            "wa": Param((w, w), dt, ("embed", "state"), fan_in_init(0)),
            "wx": Param((w, w), dt, ("embed", "state"), fan_in_init(0)),
            "ba": Param((w,), dt, ("state",), zeros_init),
            "bx": Param((w,), dt, ("state",), zeros_init),
        }

    def gates(self, p, x):
        r = jax.nn.sigmoid(x @ p["wa"] + p["ba"])
        i = jax.nn.sigmoid(x @ p["wx"] + p["bx"])
        log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r.astype(jnp.float32)
        a = jnp.exp(log_a)
        gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
            * (i.astype(jnp.float32) * x.astype(jnp.float32))
        return a, gated

    def __call__(self, p, x, h0: Optional[jax.Array] = None):
        """x: (B, S, width).  Returns (y, h_last)."""
        a, b = self.gates(p, x)
        if h0 is not None:
            b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
        h = linear_scan(a, b)
        return h.astype(x.dtype), h[:, -1]

    def step(self, p, x, h):
        """x: (B, 1, width); h: (B, width)."""
        a, b = self.gates(p, x)
        h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
        return h_new.astype(x.dtype)[:, None], h_new


class CausalConv1D(Module):
    """Depthwise causal conv (width w_conv), with decode-state support."""

    def __init__(self, width: int, kernel: int = 4, dtype=jnp.float32):
        self.width, self.kernel, self.dtype = width, kernel, dtype

    def spec(self):
        return {"w": Param((self.kernel, self.width), self.dtype,
                           (None, "state"), fan_in_init(0)),
                "b": Param((self.width,), self.dtype, ("state",), zeros_init)}

    def __call__(self, p, x, prefix: Optional[jax.Array] = None):
        """x: (B, S, width); prefix: (B, kernel-1, width) carried inputs."""
        B, S, W = x.shape
        if prefix is None:
            prefix = jnp.zeros((B, self.kernel - 1, W), x.dtype)
        xp = jnp.concatenate([prefix, x], axis=1)
        y = sum(xp[:, i:i + S] * p["w"][i] for i in range(self.kernel))
        return y + p["b"], xp[:, -(self.kernel - 1):]


class RecurrentBlock(Module):
    """Griffin recurrent block: two branches (gate: linear+GeLU; recurrent:
    linear -> causal conv -> RG-LRU), merged multiplicatively."""

    def __init__(self, dim: int, width: Optional[int] = None, *, conv_kernel: int = 4,
                 dtype=jnp.float32):
        self.dim = dim
        self.width = width or dim
        self.gate_proj = Linear(dim, self.width, axes=("embed", "state"), dtype=dtype)
        self.rec_proj = Linear(dim, self.width, axes=("embed", "state"), dtype=dtype)
        self.conv = CausalConv1D(self.width, conv_kernel, dtype=dtype)
        self.lru = RGLRU(self.width, dtype=dtype)
        self.out_proj = Linear(self.width, dim, axes=("state", "embed"), dtype=dtype)
        self.act = _ACT["gelu"]

    def spec(self):
        return {"gate": self.gate_proj.spec(), "rec": self.rec_proj.spec(),
                "conv": self.conv.spec(), "lru": self.lru.spec(),
                "out": self.out_proj.spec()}

    def init_state(self, batch: int, dtype=jnp.float32) -> RecurrentState:
        return RecurrentState(
            h=jnp.zeros((batch, self.width), dtype),
            conv=jnp.zeros((batch, self.conv.kernel - 1, self.width), dtype))

    def __call__(self, p, x, state: Optional[RecurrentState] = None):
        g = self.act(self.gate_proj(p["gate"], x))
        r = self.rec_proj(p["rec"], x)
        conv_prefix = state.conv if state is not None else None
        r, conv_carry = self.conv(p["conv"], r, conv_prefix)
        h0 = state.h if state is not None else None
        r, h_last = self.lru(p["lru"], r, h0)
        y = self.out_proj(p["out"], g * r)
        return y, RecurrentState(h=h_last, conv=conv_carry)

    def step(self, p, x, state: RecurrentState):
        g = self.act(self.gate_proj(p["gate"], x))
        r = self.rec_proj(p["rec"], x)
        r, conv_carry = self.conv(p["conv"], r, state.conv)
        r, h_new = self.lru.step(p["lru"], r, state.h)
        y = self.out_proj(p["out"], g * r)
        return y, RecurrentState(h=h_new, conv=conv_carry)
