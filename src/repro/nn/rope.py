"""Rotary position embeddings (RoPE), half-rotation convention."""
from __future__ import annotations

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float = 10000.0):
    exp = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exp)          # (head_dim/2,)


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (batch, seq, *head_axes, head_dim) — any number of head axes
    (1 for (B,S,K,D) keys, 2 for (B,S,K,G,D) queries); positions: (batch,
    seq) or (seq,) int32.  Split-halves (rotate_half) convention.
    """
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                        # (hd/2,)
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions, x.shape[:2])
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, seq, hd/2)
    n_head_axes = x.ndim - angles.ndim
    for _ in range(n_head_axes):
        angles = angles[..., None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
