"""HSTU-style block (Zhai et al., ICML 2024, arXiv:2402.17152) — the
alternative backbone the PinFM paper reports trying with results similar to
GPT2 (§3.1: "We also tried HSTU architecture and got similar results").

Pointwise aggregated attention: no softmax; SiLU-gated linear attention
normalized by context length, with a learned elementwise gate U:

    U, V, Q, K = split( SiLU( f1(norm(x)) ) )
    A_ij       = SiLU( Q_i · K_j / sqrt(d) ) / n_i          (j <= i)
    Y          = A @ V
    out        = x + f2( norm2(Y) * U )

Because aggregation is a causal sum (not a normalized softmax), the DCAT
context/crossing split and ring-buffer decode reuse the same KV machinery
as standard attention.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.module import Module, Param, fan_in_init
from repro.nn.layers import RMSNorm
from repro.nn.rope import apply_rope


def hstu_attend(q, k, v, *, q_pos=None, k_pos=None, k_valid=None,
                window=None, n_ctx=None):
    """q: (B, S, H, D); k/v: (B, T, H, D).  SiLU attention, causal.

    n_ctx: normalizer per query (defaults to q_pos+1 — the number of
    attendable positions)."""
    B, S, H, D = q.shape
    T = k.shape[1]
    if q_pos is None:
        q_pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    if k_pos is None:
        k_pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    q_pos = jnp.broadcast_to(q_pos, (B, S)) if q_pos.ndim == 1 else q_pos
    k_pos = jnp.broadcast_to(k_pos, (B, T)) if k_pos.ndim == 1 else k_pos
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    mask = k_pos[:, None, :] <= q_pos[:, :, None]
    if window is not None:
        mask &= k_pos[:, None, :] > q_pos[:, :, None] - window
    if k_valid is not None:
        kv = jnp.broadcast_to(k_valid, (B, T)) if k_valid.ndim == 1 else k_valid
        mask &= kv[:, None, :]
    a = jax.nn.silu(s) * mask[:, None].astype(jnp.float32)
    if n_ctx is None:
        n_ctx = (q_pos + 1).astype(jnp.float32)
    a = a / n_ctx[:, None, :, None]
    y = jnp.einsum("bhst,bthd->bshd", a, v.astype(jnp.float32))
    return y.astype(q.dtype)


class HSTUBlock(Module):
    def __init__(self, dim: int, n_heads: int, head_dim: Optional[int] = None,
                 *, rope: bool = False, rope_theta: float = 10000.0,
                 dtype=jnp.float32):
        self.dim, self.n_heads = dim, n_heads
        self.head_dim = head_dim or dim // n_heads
        self.rope, self.rope_theta = rope, rope_theta
        self.dtype = dtype
        self.norm1 = RMSNorm(dim, dtype=dtype)
        self.norm2 = RMSNorm(n_heads * self.head_dim, dtype=dtype)

    def spec(self):
        D, H, hd = self.dim, self.n_heads, self.head_dim
        dt = self.dtype
        return {
            "norm1": self.norm1.spec(),
            "norm2": self.norm2.spec(),
            # u, v, q, k projections fused conceptually; stored separately so
            # each keeps clean (embed -> heads x head_dim) sharding axes
            "wu": Param((D, H, hd), dt, ("embed", "heads", "head_dim"),
                        fan_in_init(0)),
            "wv": Param((D, H, hd), dt, ("embed", "heads", "head_dim"),
                        fan_in_init(0)),
            "wq": Param((D, H, hd), dt, ("embed", "heads", "head_dim"),
                        fan_in_init(0)),
            "wk": Param((D, H, hd), dt, ("embed", "heads", "head_dim"),
                        fan_in_init(0)),
            "wo": Param((H, hd, D), dt, ("heads", "head_dim", "embed"),
                        fan_in_init(0)),
        }

    def _uvqk(self, p, x, positions):
        h = self.norm1(p["norm1"], x)
        proj = lambda w: jax.nn.silu(jnp.einsum("bsd,dhk->bshk", h, p[w]))
        u, v, q, k = proj("wu"), proj("wv"), proj("wq"), proj("wk")
        if self.rope:
            q = apply_rope(q, positions, self.rope_theta)
            k = apply_rope(k, positions, self.rope_theta)
        return u, v, q, k

    def _out(self, p, y, u):
        B, S = y.shape[0], y.shape[1]
        flat = y.reshape(B, S, -1)
        g = self.norm2(p["norm2"], flat).reshape(y.shape) * u
        return jnp.einsum("bshk,hkd->bsd", g, p["wo"])

    def fwd(self, p, x, positions, return_ctx: bool = False):
        u, v, q, k = self._uvqk(p, x, positions)
        y = hstu_attend(q, k, v, q_pos=positions, k_pos=positions)
        out = x + self._out(p, y, u)
        return (out, (k, v)) if return_ctx else (out, None)

    def cross(self, p, x, ctx, positions, *, ctx_pos=None, gather_idx=None,
              self_attend: bool = True):
        """DCAT crossing for HSTU: candidates silu-attend to Ψ⁻¹(context KV)
        plus their own KV."""
        k_ctx, v_ctx = ctx
        if gather_idx is not None:
            k_ctx = jnp.take(k_ctx, gather_idx, axis=0)
            v_ctx = jnp.take(v_ctx, gather_idx, axis=0)
        B, S, _ = x.shape
        L = k_ctx.shape[1]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(L, L + S), (B, S))
        u, v, q, k = self._uvqk(p, x, positions)
        if self_attend:
            k_full = jnp.concatenate([k_ctx, k], 1)
            v_full = jnp.concatenate([v_ctx, v], 1)
            kp = jnp.concatenate(
                [jnp.broadcast_to(jnp.arange(L), (B, L)) if ctx_pos is None
                 else jnp.broadcast_to(ctx_pos, (B, L)), positions], 1)
        else:
            k_full, v_full = k_ctx, v_ctx
            kp = (jnp.broadcast_to(jnp.arange(L), (B, L)) if ctx_pos is None
                  else jnp.broadcast_to(ctx_pos, (B, L)))
        y = hstu_attend(q, k_full, v_full, q_pos=positions, k_pos=kp)
        return x + self._out(p, y, u)

    def step(self, p, x, cache, positions):
        from repro.nn.attention import KVCache
        u, v, q, k = self._uvqk(p, x, positions)
        cache = cache.update(k, v)
        k_pos, k_valid = cache.slot_positions()
        y = hstu_attend(q, cache.k, cache.v, q_pos=positions, k_pos=k_pos,
                        k_valid=k_valid)
        return x + self._out(p, y, u), cache
