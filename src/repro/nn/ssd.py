"""Mamba-2 SSD (state-space duality, arXiv:2405.21060), chunked algorithm.

Per head:  h_t = exp(A*dt_t) * h_{t-1} + dt_t * B_t x_t^T ;  y_t = C_t h_t + D x_t

The chunked form (quadratic intra-chunk "attention" + linear inter-chunk
state pass) is the TPU-friendly formulation: both pieces are MXU matmuls,
and the inter-chunk scan carries only (H, N, P) states.  The carried state
doubles as the DCAT context analogue for SSM archs (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.nn.module import Module, Param, fan_in_init, zeros_init, ones_init
from repro.nn.layers import Linear
from repro.nn.recurrent import CausalConv1D


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int = 64, h0=None):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,) negative; Bm/Cm: (B,S,G,N), H%G==0.

    Returns (y: (B,S,H,P), h_last: (B,H,N,P)).

    Sequential ``lax.scan`` over chunks: each step does the quadratic
    intra-chunk piece (MXU matmuls over (Q, Q)) and one state update, so peak
    memory is O(B*H*(Q^2 + N*P)) regardless of sequence length — this is
    what lets ``prefill_32k``/``long_500k`` lower without materializing all
    chunks at once.
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0, f"seq {S} % chunk {chunk} != 0"
    Nc, Q = S // chunk, chunk
    rep = H // G
    Af = A.astype(jnp.float32)
    mask = jnp.tril(jnp.ones((Q, Q), bool))

    # chunked views, chunk axis leading for scan
    xr = x.reshape(Bsz, Nc, Q, H, P).transpose(1, 0, 2, 3, 4)
    dtr = dt.reshape(Bsz, Nc, Q, H).transpose(1, 0, 2, 3)
    Br = Bm.reshape(Bsz, Nc, Q, G, N).transpose(1, 0, 2, 3, 4)
    Cr = Cm.reshape(Bsz, Nc, Q, G, N).transpose(1, 0, 2, 3, 4)

    h_init = (jnp.zeros((Bsz, H, N, P), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))

    def body(h, inp):
        xc, dtc, bc, cc = inp                          # (B,Q,H,P) (B,Q,H) ...
        xc = xc.astype(jnp.float32)
        dtc = dtc.astype(jnp.float32)
        bc = jnp.repeat(bc.astype(jnp.float32), rep, axis=2)   # (B,Q,H,N)
        cc = jnp.repeat(cc.astype(jnp.float32), rep, axis=2)
        la = Af * dtc                                  # (B,Q,H)
        cs = jnp.cumsum(la, axis=1)                    # inclusive
        ci = cs.transpose(0, 2, 1)                     # (B,H,Q)
        scores = jnp.einsum("bihn,bjhn->bhij", cc, bc)
        diff = ci[..., :, None] - ci[..., None, :]
        # double-where: exp(diff) overflows to inf in the masked j>i region
        # (diff up to +|A|*dt*Q), and grad-of-where would propagate NaN from
        # the dead branch — clamp the argument inside the mask first
        diff = jnp.where(mask, diff, 0.0)
        M = jnp.where(mask, scores * jnp.exp(diff), 0.0)
        bx = xc * dtc[..., None]
        y_intra = jnp.einsum("bhij,bjhp->bihp", M, bx)
        y_inter = jnp.einsum("bihn,bhnp->bihp",
                             cc * jnp.exp(cs)[..., None], h)
        to_end = jnp.exp(cs[:, -1:, :] - cs)
        s_c = jnp.einsum("bjhn,bjhp->bhnp", bc * to_end[..., None], bx)
        h_new = h * jnp.exp(cs[:, -1, :])[..., None, None] + s_c
        return h_new, (y_intra + y_inter).astype(x.dtype)

    h_last, ys = jax.lax.scan(body, h_init, (xr, dtr, Br, Cr))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, h_last


def ssd_step(x, dt, A, Bm, Cm, h):
    """One decode step.  x: (B,H,P); dt: (B,H); Bm/Cm: (B,G,N); h: (B,H,N,P)."""
    H, G = x.shape[1], Bm.shape[1]
    rep = H // G
    Br = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)
    Cr = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    a = jnp.exp(A.astype(jnp.float32) * dt.astype(jnp.float32))     # (B,H)
    bx = dt.astype(jnp.float32)[..., None] * x.astype(jnp.float32)  # (B,H,P)
    h_new = h * a[..., None, None] + jnp.einsum("bhn,bhp->bhnp", Br, bx)
    y = jnp.einsum("bhn,bhnp->bhp", Cr, h_new)
    return y.astype(x.dtype), h_new


@dataclasses.dataclass
class SSDState:
    h: jax.Array       # (B, H, N, P)
    conv: jax.Array    # (B, kernel-1, conv_dim)


jax.tree_util.register_dataclass(SSDState, data_fields=["h", "conv"], meta_fields=[])


class Mamba2Block(Module):
    """Full Mamba-2 mixer block (in_proj -> conv -> SSD -> gated norm -> out).

    Sharding note: z/x/B/C/dt use SEPARATE projections (the reference fused
    in_proj + split would slice a tensor-sharded dim off shard boundaries).
    x/z are sharded on "state" (d_inner, model axis); B/C/dt are small and
    replicated.  The (d_inner) -> (heads, head_dim) reshape is
    shard-boundary-aligned because d_inner/16 is a multiple of head_dim for
    the assigned config (5120/16 = 320 = 5*64)."""

    def __init__(self, dim: int, *, expand: int = 2, head_dim: int = 64,
                 d_state: int = 128, n_groups: int = 1, conv_kernel: int = 4,
                 chunk: int = 64, dtype=jnp.float32):
        self.dim = dim
        self.d_inner = expand * dim
        self.head_dim, self.d_state, self.n_groups = head_dim, d_state, n_groups
        self.n_heads = self.d_inner // head_dim
        self.chunk = chunk
        self.bc_dim = n_groups * d_state
        self.dtype = dtype
        self.z_proj = Linear(dim, self.d_inner, axes=("embed", "state"), dtype=dtype)
        self.x_proj = Linear(dim, self.d_inner, axes=("embed", "state"), dtype=dtype)
        self.b_proj = Linear(dim, self.bc_dim, axes=("embed", None), dtype=dtype)
        self.c_proj = Linear(dim, self.bc_dim, axes=("embed", None), dtype=dtype)
        self.dt_proj = Linear(dim, self.n_heads, axes=("embed", None), dtype=dtype)
        self.conv_x = CausalConv1D(self.d_inner, conv_kernel, dtype=dtype)
        self.conv_b = CausalConv1D(self.bc_dim, conv_kernel, dtype=dtype)
        self.conv_c = CausalConv1D(self.bc_dim, conv_kernel, dtype=dtype)
        self.out_proj = Linear(self.d_inner, dim, axes=("state", "embed"), dtype=dtype)

    def spec(self):
        H, dt = self.n_heads, self.dtype
        return {
            "z_proj": self.z_proj.spec(), "x_proj": self.x_proj.spec(),
            "b_proj": self.b_proj.spec(), "c_proj": self.c_proj.spec(),
            "dt_proj": self.dt_proj.spec(),
            "conv_x": self.conv_x.spec(), "conv_b": self.conv_b.spec(),
            "conv_c": self.conv_c.spec(),
            "out_proj": self.out_proj.spec(),
            "A_log": Param((H,), dt, ("heads",),
                           lambda k, s, d: jnp.log(jnp.linspace(1.0, 16.0, s[0])).astype(d)),
            "dt_bias": Param((H,), dt, ("heads",), zeros_init),
            "D": Param((H,), dt, ("heads",), ones_init),
            "norm": Param((self.d_inner,), dt, ("state",), ones_init),
        }

    def init_state(self, batch: int, dtype=jnp.float32) -> SSDState:
        k = self.conv_x.kernel - 1
        return SSDState(
            h=jnp.zeros((batch, self.n_heads, self.d_state, self.head_dim), jnp.float32),
            conv=jnp.zeros((batch, k, self.d_inner + 2 * self.bc_dim), dtype))

    def _split(self, p, x, conv_prefix):
        z = self.z_proj(p["z_proj"], x)
        xs = self.x_proj(p["x_proj"], x)
        Bm = self.b_proj(p["b_proj"], x)
        Cm = self.c_proj(p["c_proj"], x)
        dt = self.dt_proj(p["dt_proj"], x)
        if conv_prefix is not None:
            px, pb, pc = jnp.split(
                conv_prefix, [self.d_inner, self.d_inner + self.bc_dim], -1)
        else:
            px = pb = pc = None
        xs, cx = self.conv_x(p["conv_x"], xs, px)
        Bm, cb = self.conv_b(p["conv_b"], Bm, pb)
        Cm, cc = self.conv_c(p["conv_c"], Cm, pc)
        xs, Bm, Cm = jax.nn.silu(xs), jax.nn.silu(Bm), jax.nn.silu(Cm)
        dt = jax.nn.softplus(dt + p["dt_bias"])
        conv_carry = jnp.concatenate([cx, cb, cc], axis=-1)
        return z, xs, Bm, Cm, dt, conv_carry

    def _finish(self, p, y, xs_heads, z):
        y = y + p["D"][..., None] * xs_heads            # D skip, per head
        y = y.reshape(*y.shape[:-2], self.d_inner)
        # gated RMSNorm (mamba2's norm_before_gate=False path)
        g = y * jax.nn.silu(z)
        gf = g.astype(jnp.float32)
        g = (gf * jax.lax.rsqrt(jnp.mean(jnp.square(gf), -1, keepdims=True) + 1e-6)
             * p["norm"].astype(jnp.float32)).astype(y.dtype)
        return self.out_proj(p["out_proj"], g)

    def __call__(self, p, x, state: Optional[SSDState] = None):
        B, S, _ = x.shape
        prefix = state.conv if state is not None else None
        z, xs, Bm, Cm, dt, conv_carry = self._split(p, x, prefix)
        xh = xs.reshape(B, S, self.n_heads, self.head_dim)
        Bm = Bm.reshape(B, S, self.n_groups, self.d_state)
        Cm = Cm.reshape(B, S, self.n_groups, self.d_state)
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        h0 = state.h if state is not None else None
        y, h_last = ssd_chunked(xh, dt, A, Bm, Cm, chunk=self.chunk, h0=h0)
        out = self._finish(p, y, xh, z)
        return out, SSDState(h=h_last, conv=conv_carry)

    def step(self, p, x, state: SSDState):
        """x: (B, 1, dim)."""
        B = x.shape[0]
        z, xs, Bm, Cm, dt, conv_carry = self._split(p, x, state.conv)
        xh = xs.reshape(B, self.n_heads, self.head_dim)
        y, h_new = ssd_step(xh, dt[:, 0], -jnp.exp(p["A_log"].astype(jnp.float32)),
                            Bm.reshape(B, self.n_groups, self.d_state),
                            Cm.reshape(B, self.n_groups, self.d_state), state.h)
        out = self._finish(p, y[:, None], xh[:, None], z)
        return out, SSDState(h=h_new, conv=conv_carry)
