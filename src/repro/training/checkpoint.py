"""Flat-npz checkpointing: param/optimizer pytrees -> one .npz + a JSON
manifest of tree paths.  Single-host (this container); the save path is
sharding-oblivious (device_get gathers addressable shards)."""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    leaves = []
    for _, v in flat:
        a = np.asarray(jax.device_get(v))
        if a.dtype.kind == "V":      # ml_dtypes (bfloat16 etc.): store as f32
            a = np.asarray(jax.device_get(v)).astype(np.float32)
        leaves.append(a)
    return paths, leaves, treedef


def save_checkpoint(path: str, tree, step: int | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    paths, leaves, _ = _flatten(tree)
    arrays = {f"a{i}": leaf for i, leaf in enumerate(leaves)}
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    manifest = {"paths": paths, "step": step}
    with open(path.removesuffix(".npz") + ".json", "w") as f:
        json.dump(manifest, f)


def load_checkpoint(path: str, like_tree) -> Any:
    """Restore into the structure of ``like_tree`` (paths must match)."""
    base = path.removesuffix(".npz")
    with open(base + ".json") as f:
        manifest = json.load(f)
    data = np.load(base + ".npz")
    paths, _, treedef = _flatten(like_tree)
    if paths != manifest["paths"]:
        missing = set(manifest["paths"]) ^ set(paths)
        raise ValueError(f"checkpoint tree mismatch: {sorted(missing)[:5]}...")
    leaves = [data[f"a{i}"] for i in range(len(paths))]
    like_leaves = jax.tree.leaves(like_tree)
    import jax.numpy as jnp
    leaves = [jnp.asarray(l, dtype=ll.dtype) for l, ll in
              zip(leaves, like_leaves)]
    return jax.tree.unflatten(treedef, leaves)
