"""AdamW optimizer + LR schedules, built on raw pytrees (no optax).

Supports per-subtree LR multipliers, needed for PinFM fine-tuning where the
pretrained module runs at ~1/10 of the ranking-model LR (paper §3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # map from top-level param-tree key -> lr multiplier (e.g. {"pinfm": 0.1})
    lr_mults: Optional[dict] = None
    schedule: str = "cosine"         # constant | linear | cosine
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def make_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
        if cfg.schedule == "constant":
            decay = 1.0
        elif cfg.schedule == "linear":
            frac = jnp.clip((step - cfg.warmup_steps)
                            / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
            decay = 1.0 - (1.0 - cfg.min_lr_ratio) * frac
        else:  # cosine
            frac = jnp.clip((step - cfg.warmup_steps)
                            / max(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
            decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac))
        return cfg.lr * warm * decay
    return sched


def adamw_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def _lr_mult_tree(params, lr_mults):
    if not lr_mults:
        return jax.tree.map(lambda _: 1.0, params)
    return {k: jax.tree.map(lambda _: float(lr_mults.get(k, 1.0)), v)
            for k, v in params.items()}


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    sched = make_schedule(cfg)
    step = state["step"] + 1
    lr = sched(step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    mults = _lr_mult_tree(params, cfg.lr_mults)

    def upd(p, g, m, v, mult):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh, vh = m / bc1, v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:      # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * mult * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    flat_mu = jax.tree.leaves(mults)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v, mu in zip(flat_p, flat_g, flat_m, flat_v, flat_mu):
        a, b, c = upd(p, g, m, v, mu)
        new_p.append(a); new_m.append(b); new_v.append(c)
    new_params = jax.tree.unflatten(treedef, new_p)
    new_state = {"m": jax.tree.unflatten(treedef, new_m),
                 "v": jax.tree.unflatten(treedef, new_v), "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
