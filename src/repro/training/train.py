"""Generic pjit training loop.

``make_train_step`` builds a donated, jitted (params, opt_state, batch) ->
(params, opt_state, metrics) step for any model exposing
``loss(params, batch) -> (scalar, metrics)``; ``loss_fn`` may be overridden
(e.g. the fine-tuning ranking loss threads an rng).  With a mesh, param and
batch shardings come from the logical-axis policy (distributed/sharding.py).
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import (batch_axes, data_pspec, make_policy,
                                        param_shardings)
from repro.training.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(loss_fn: Callable, opt_cfg: AdamWConfig, *,
                    has_rng: bool = False):
    """loss_fn(params, batch[, rng]) -> (loss, metrics)."""

    def step(params, opt_state, batch, rng=None):
        if has_rng:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch, rng)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_m = adamw_update(opt_cfg, params, grads,
                                                opt_state)
        out = {"loss": loss, **opt_m}
        if isinstance(metrics, dict):
            out.update({k: v for k, v in metrics.items()
                        if jnp.ndim(v) == 0})
        return params, opt_state, out

    return step


def jit_train_step(step, mesh=None, param_spec_tree=None, policy=None):
    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1))
    pshard = param_shardings(param_spec_tree, mesh, policy)
    from jax.sharding import NamedSharding, PartitionSpec as P
    rep = NamedSharding(mesh, P())
    return jax.jit(step, donate_argnums=(0, 1),
                   in_shardings=(pshard, None, None, None),
                   out_shardings=(pshard, None, None))


def train_loop(step_fn, params, opt_state, batches: Iterator[dict],
               *, rng: Optional[jax.Array] = None, log_every: int = 10,
               log_fn=print):
    """Runs the loop; returns (params, opt_state, history)."""
    history = []
    t0 = time.time()
    for i, batch in enumerate(batches):
        batch = jax.tree.map(jnp.asarray, batch)
        args = (params, opt_state, batch)
        if rng is not None:
            args = args + (jax.random.fold_in(rng, i),)
        params, opt_state, metrics = step_fn(*args)
        history.append({k: float(v) for k, v in metrics.items()})
        if log_every and (i % log_every == 0):
            dt = time.time() - t0
            log_fn(f"step {i:5d}  loss {history[-1]['loss']:.4f}  "
                   f"({dt:.1f}s)")
    return params, opt_state, history


def init_train_state(model, opt_cfg: AdamWConfig, key):
    params = model.init(key)
    return params, adamw_init(params)
