"""Pixtral-12B [hf:mistralai/Pixtral-12B-2409]: VLM; pixtral-ViT frontend is
STUBBED (precomputed patch embeddings) per the brief — this config is the
mistral-nemo language decoder: 40L d_model=5120 32H (kv=8) d_ff=14336."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=131072,
    act="silu", norm="rmsnorm", mlp_type="glu",
    qkv_bias=False, qk_norm=False, rope=True, rope_theta=1_000_000.0,
    tie_embeddings=False, max_seq=131072,
    frontend="patch", frontend_dim=1024, n_patches=1024,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp_fsdp",
    microbatches=4,
    source="hf:mistralai/Pixtral-12B-2409 (decoder dims)",
))
