"""Assigned-architecture configs (plus PinFM's own).  Importing this package
registers every config; ``repro.models.config.get_config(name)`` resolves.
"""
from repro.models.config import ModelConfig, register, get_config, list_configs

from repro.configs import (  # noqa: F401
    command_r_plus_104b,
    qwen3_4b,
    qwen3_8b,
    qwen1_5_0_5b,
    mixtral_8x7b,
    recurrentgemma_2b,
    mamba2_2_7b,
    qwen2_moe_a2_7b,
    pixtral_12b,
    whisper_base,
    pinfm_20b,
    pinfm_hstu,
)

ASSIGNED = [
    "command-r-plus-104b", "qwen3-4b", "qwen1.5-0.5b", "mixtral-8x7b",
    "recurrentgemma-2b", "mamba2-2.7b", "qwen3-8b", "qwen2-moe-a2.7b",
    "pixtral-12b", "whisper-base",
]


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced variant of the same family: <=3 layers, d_model<=512,
    <=4 experts — runnable on CPU for smoke tests."""
    kw = dict(
        n_layers=max(2, len(cfg.pattern)),
        d_model=256,
        n_heads=4,
        n_kv=min(cfg.n_kv, 4) if cfg.n_kv > 1 else 1,
        head_dim=64,
        d_ff=512,
        vocab=512,
        max_seq=512,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        ssm_chunk=16,
    )
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=2, moe_d_ff=128,
                  n_shared=min(cfg.n_shared, 2),
                  shared_d_ff=128 if cfg.n_shared else None)
    if cfg.lru_width:
        kw.update(lru_width=256)
    if cfg.frontend:
        kw.update(frontend_dim=64, n_patches=16)
    if cfg.encoder_layers:
        kw.update(encoder_layers=2)
    if cfg.window:
        kw.update(window=64)
    return cfg.replace(**kw)
