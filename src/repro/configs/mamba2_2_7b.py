"""Mamba2-2.7B [arXiv:2405.21060]: attention-free SSD (state-space duality),
64L d_model=2560, ssm_state=128, vocab=50280."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=80, n_kv=1, head_dim=64,
    d_ff=0, vocab=50280,
    norm="rmsnorm", rope=False, tie_embeddings=True, max_seq=1_048_576,
    pattern=("ssm",), ssm_expand=2, ssm_head_dim=64, ssm_state=128,
    ssm_groups=1, ssm_chunk=256,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp",
    microbatches=4,
    source="arXiv:2405.21060 (Mamba-2, SSD)",
))
