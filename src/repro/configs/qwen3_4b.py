"""Qwen3-4B [hf:Qwen/Qwen3-8B family]: dense GQA + qk-norm,
36L d_model=2560 32H (kv=8) d_ff=9728 vocab=151936."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv=8, head_dim=128,
    d_ff=9728, vocab=151936,
    act="silu", norm="rmsnorm", mlp_type="glu",
    qkv_bias=False, qk_norm=True, rope=True, rope_theta=1_000_000.0,
    tie_embeddings=True, max_seq=131072,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp",
    microbatches=2,
    source="hf:Qwen/Qwen3-8B model card (4B sibling dims)",
))
