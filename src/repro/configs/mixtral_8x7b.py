"""Mixtral 8x7B [arXiv:2401.04088]: MoE 8 experts top-2, sliding-window attn,
32L d_model=4096 32H (kv=8) expert d_ff=14336 vocab=32000."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=14336, vocab=32000,
    act="silu", norm="rmsnorm", mlp_type="glu",
    qkv_bias=False, qk_norm=False, rope=True, rope_theta=1_000_000.0,
    window=4096, tie_embeddings=False, max_seq=131072,
    pattern=("moe",), n_experts=8, top_k=2, capacity_factor=1.25,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp_fsdp",
    microbatches=4,
    source="arXiv:2401.04088 (Mixtral of Experts)",
))
