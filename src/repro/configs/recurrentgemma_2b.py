"""RecurrentGemma-2B [arXiv:2402.19427 Griffin]: RG-LRU + local attention 1:2,
26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv=1, head_dim=256,
    d_ff=7680, vocab=256000,
    act="gelu_tanh", norm="rmsnorm", mlp_type="glu",
    qkv_bias=False, qk_norm=False, rope=True, rope_theta=10_000.0,
    window=2048, embed_scale=True, tie_embeddings=True, max_seq=1_048_576,
    pattern=("rec", "rec", "attn"), lru_width=2560,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp",
    microbatches=2,
    source="arXiv:2402.19427 (Griffin / RecurrentGemma-2B)",
))
