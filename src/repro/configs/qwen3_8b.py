"""Qwen3-8B [hf:Qwen/Qwen3-8B]: dense GQA + qk-norm,
36L d_model=4096 32H (kv=8) d_ff=12288 vocab=151936."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv=8, head_dim=128,
    d_ff=12288, vocab=151936,
    act="silu", norm="rmsnorm", mlp_type="glu",
    qkv_bias=False, qk_norm=True, rope=True, rope_theta=1_000_000.0,
    tie_embeddings=True, max_seq=131072,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp_fsdp",
    microbatches=4,
    source="hf:Qwen/Qwen3-8B",
))
