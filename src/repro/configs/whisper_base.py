"""Whisper-base [arXiv:2212.04356]: enc-dec; mel+conv frontend STUBBED
(precomputed frame embeddings).  6L enc + 6L dec, d_model=512 8H d_ff=2048
vocab=51865.  long_500k is skipped for this arch (DESIGN.md §6)."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, encoder_layers=6, d_model=512, n_heads=8, n_kv=8, head_dim=64,
    d_ff=2048, vocab=51865,
    act="gelu", norm="layernorm", mlp_type="mlp",
    qkv_bias=True, qk_norm=False, rope=False, pos_emb="learned",
    tie_embeddings=True, max_seq=448,
    frontend="audio", frontend_dim=512,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="none", sharding="tp",
    microbatches=4,
    source="arXiv:2212.04356 (Whisper base)",
))
