"""Qwen2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B]: 60 routed experts top-4 +
4 shared, 24L d_model=2048 16H (kv=16) expert d_ff=1408 vocab=151936."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv=16, head_dim=128,
    d_ff=1408, vocab=151936,
    act="silu", norm="rmsnorm", mlp_type="glu",
    qkv_bias=True, qk_norm=False, rope=True, rope_theta=1_000_000.0,
    tie_embeddings=False, max_seq=32768,
    pattern=("moe",), n_experts=60, top_k=4, n_shared=4,
    moe_d_ff=1408, shared_d_ff=1408, capacity_factor=1.25,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp_fsdp",
    microbatches=4,
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
))
