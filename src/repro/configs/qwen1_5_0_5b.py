"""Qwen1.5-0.5B [hf:Qwen/Qwen1.5-0.5B]: dense with QKV bias,
24L d_model=1024 16H (kv=16) d_ff=2816 vocab=151936."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, head_dim=64,
    d_ff=2816, vocab=151936,
    act="silu", norm="rmsnorm", mlp_type="glu",
    qkv_bias=True, qk_norm=False, rope=True, rope_theta=1_000_000.0,
    tie_embeddings=True, max_seq=32768,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp",
    source="hf:Qwen/Qwen1.5-0.5B",
))
