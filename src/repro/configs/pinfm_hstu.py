"""PinFM with the HSTU backbone (paper §3.1: "We also tried HSTU
architecture and got similar results with GPT2") [arXiv:2402.17152]."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pinfm-hstu", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, head_dim=64,
    d_ff=0, vocab=0,
    norm="rmsnorm", rope=True, pos_emb=None,
    tie_embeddings=True, max_seq=16000,
    pattern=("hstu",),
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp", microbatches=4,
    source="PinFM §3.1 alternative backbone; HSTU arXiv:2402.17152",
))
