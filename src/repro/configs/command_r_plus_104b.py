"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-v01 family]: dense GQA,
no biases, 64L d_model=12288 96H (kv=8) d_ff=33792 vocab=256000."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv=8, head_dim=128,
    d_ff=33792, vocab=256000,
    act="silu", norm="layernorm", mlp_type="glu",
    qkv_bias=False, qk_norm=False, rope=True, rope_theta=75_000_000.0,
    tie_embeddings=True, max_seq=131072,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp_fsdp",
    microbatches=8,
    source="hf:CohereForAI/c4ai-command-r-v01 (scaled to R+ 104B dims)",
))
