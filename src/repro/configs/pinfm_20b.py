"""PinFM's own backbone (paper §3.1): GPT2-architecture Pre-LN decoder.
The 20B+ parameters are dominated by the 8 x 80M x 32 hashed id-embedding
tables (20.5B); the transformer itself is GPT2-medium-scale.  Sequence
length is capped at 256 during fine-tuning/serving (paper §4.1)."""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="pinfm-20b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv=16, head_dim=64,
    d_ff=4096, vocab=0,            # id vocabulary lives in the hashed tables
    act="gelu", norm="layernorm", mlp_type="mlp",
    qkv_bias=True, qk_norm=False, rope=False, pos_emb="learned",
    tie_embeddings=True, max_seq=16000,
    param_dtype="bfloat16", compute_dtype="bfloat16",
    remat="full", sharding="tp", microbatches=4,
    source="PinFM paper §3.1/§4 (GPT2 Pre-LN; 8x80Mx32 tables)",
))
