"""ModelConfig: one dataclass that describes every supported architecture
family (dense / moe / hybrid / ssm / vlm / audio).  Configs for the assigned
architectures live in ``repro.configs.<id>`` and are registered here.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

_DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


@dataclasses.dataclass
class ModelConfig:
    name: str = "model"
    family: str = "dense"              # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv: int = 12
    head_dim: Optional[int] = None
    d_ff: int = 3072
    vocab: int = 32000
    act: str = "silu"
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    mlp_type: str = "glu"              # glu | mlp
    qkv_bias: bool = False
    qk_norm: bool = False
    rope: bool = True
    rope_theta: float = 10000.0
    pos_emb: Optional[str] = None      # None | "learned"
    window: Optional[int] = None       # sliding-window attention size
    embed_scale: bool = False          # gemma-style sqrt(d) embedding scaling
    tie_embeddings: bool = True
    max_seq: int = 8192

    # -- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 2
    n_shared: int = 0
    moe_d_ff: Optional[int] = None     # per-expert hidden (defaults to d_ff)
    shared_d_ff: Optional[int] = None
    capacity_factor: float = 1.25

    # -- hybrid / ssm --------------------------------------------------------
    pattern: Tuple[str, ...] = ("attn",)   # repeating block-kind unit
    lru_width: Optional[int] = None
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_state: int = 128
    ssm_groups: int = 1
    ssm_chunk: int = 64

    # -- stub frontends (the one permitted stub: modality encoders) ---------
    frontend: Optional[str] = None     # None | "patch" | "audio"
    frontend_dim: int = 1024           # dim of precomputed patch/frame embeds
    n_patches: int = 1024              # VLM: patches per image in train shapes

    # -- enc-dec (whisper) ----------------------------------------------------
    encoder_layers: int = 0

    # -- numerics / execution -------------------------------------------------
    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    remat: str = "none"                # none | full
    attn_impl: str = "xla"             # xla | pallas
    sharding: str = "tp"               # tp | tp_fsdp
    microbatches: int = 1              # gradient-accumulation steps per batch
    # long-context variant: for pure full-attention archs, long_500k runs only
    # with a sliding-window override (DESIGN.md §6)
    long_context_window: Optional[int] = 4096
    source: str = ""                   # citation for the config

    def pdtype(self):
        return _DTYPES[self.param_dtype]

    def cdtype(self):
        return _DTYPES[self.compute_dtype]

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def block_kinds(self) -> Tuple[str, ...]:
        """Expand the repeating pattern to n_layers block kinds."""
        reps = -(-self.n_layers // len(self.pattern))
        return (self.pattern * reps)[: self.n_layers]

    def scan_groups(self):
        """[(unit_kinds, repeats)] — maximal homogeneous runs of the pattern
        for lax.scan over layers; a partial trailing unit becomes its own
        group (e.g. recurrentgemma 26 = 8 x (rec,rec,attn) + (rec,rec))."""
        kinds = self.block_kinds()
        u = len(self.pattern)
        full = len(kinds) // u
        groups = []
        if full:
            groups.append((tuple(self.pattern), full))
        rem = kinds[full * u:]
        if rem:
            groups.append((tuple(rem), 1))
        return groups

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    import repro.configs  # noqa: F401  (populates the registry)
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    import repro.configs  # noqa: F401
    return sorted(_REGISTRY)
