"""Encoder-decoder transformer (whisper-style).

The conv/mel frontend is stubbed per the brief: inputs are precomputed frame
embeddings (B, frames, d_model).  The encoder output is the DCAT "context"
for enc-dec archs: computed once per unique audio, cross-attended by every
decode step (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_residual
from repro.models.config import ModelConfig
from repro.nn.module import Module, stack_specs
from repro.nn.layers import Embedding, LayerNorm, MLP
from repro.nn.attention import (Attention, KVCache, attend5, attend_blocked,
                                _BLOCKED_THRESHOLD)


def sinusoid_pos(seq: int, dim: int, dtype=jnp.float32):
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    i = jnp.arange(dim // 2)[None, :].astype(jnp.float32)
    angles = pos / jnp.power(10000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], -1).astype(dtype)


class EncBlock(Module):
    def __init__(self, cfg: ModelConfig):
        dtype = cfg.pdtype()
        self.attn = Attention(cfg.d_model, cfg.n_heads, cfg.n_kv, bias=True,
                              rope=False, causal=False, dtype=dtype)
        self.mlp = MLP(cfg.d_model, cfg.d_ff, act="gelu", bias=True, dtype=dtype)
        self.norm1 = LayerNorm(cfg.d_model, dtype=dtype)
        self.norm2 = LayerNorm(cfg.d_model, dtype=dtype)

    def spec(self):
        return {"attn": self.attn.spec(), "mlp": self.mlp.spec(),
                "norm1": self.norm1.spec(), "norm2": self.norm2.spec()}

    def __call__(self, p, x):
        x = x + self.attn(p["attn"], self.norm1(p["norm1"], x))
        return x + self.mlp(p["mlp"], self.norm2(p["norm2"], x))


class DecBlock(Module):
    def __init__(self, cfg: ModelConfig):
        dtype = cfg.pdtype()
        self.self_attn = Attention(cfg.d_model, cfg.n_heads, cfg.n_kv, bias=True,
                                   rope=False, causal=True, dtype=dtype)
        self.cross_attn = Attention(cfg.d_model, cfg.n_heads, cfg.n_kv, bias=True,
                                    rope=False, causal=False, dtype=dtype)
        self.mlp = MLP(cfg.d_model, cfg.d_ff, act="gelu", bias=True, dtype=dtype)
        self.norm1 = LayerNorm(cfg.d_model, dtype=dtype)
        self.normx = LayerNorm(cfg.d_model, dtype=dtype)
        self.norm2 = LayerNorm(cfg.d_model, dtype=dtype)

    def spec(self):
        return {"self_attn": self.self_attn.spec(),
                "cross_attn": self.cross_attn.spec(), "mlp": self.mlp.spec(),
                "norm1": self.norm1.spec(), "normx": self.normx.spec(),
                "norm2": self.norm2.spec()}

    def cross_kv(self, p, enc_out):
        pc = p["cross_attn"]
        k = jnp.einsum("bsd,dkh->bskh", enc_out, pc["wk"]) + pc["bk"]
        v = jnp.einsum("bsd,dkh->bskh", enc_out, pc["wv"]) + pc["bv"]
        return k, v

    def _cross(self, p, x, k, v):
        pc = p["cross_attn"]
        q = jnp.einsum("bsd,dkgh->bskgh", x, pc["wq"]) + pc["bq"]
        if q.shape[1] * k.shape[1] > _BLOCKED_THRESHOLD:
            o = attend_blocked(q, k, v, causal=False)
        else:
            o = attend5(q, k, v, causal=False)
        return jnp.einsum("bskgh,kghd->bsd", o, pc["wo"])

    def fwd(self, p, x, enc_out, positions):
        x = x + self.self_attn(p["self_attn"], self.norm1(p["norm1"], x),
                               positions=positions)
        k, v = self.cross_kv(p, enc_out)
        x = x + self._cross(p, self.normx(p["normx"], x), k, v)
        return x + self.mlp(p["mlp"], self.norm2(p["norm2"], x))

    def step(self, p, x, cache, positions):
        """cache: {"kv": KVCache, "xk": (B,T,H,D), "xv": (B,T,H,D)}."""
        h = self.norm1(p["norm1"], x)
        y, kv = self.self_attn.decode(p["self_attn"], h, cache["kv"], positions)
        x = x + y
        x = x + self._cross(p, self.normx(p["normx"], x), cache["xk"], cache["xv"])
        x = x + self.mlp(p["mlp"], self.norm2(p["norm2"], x))
        return x, {"kv": kv, "xk": cache["xk"], "xv": cache["xv"]}


class EncDecLM(Module):
    def __init__(self, cfg: ModelConfig):
        assert cfg.encoder_layers > 0
        self.cfg = cfg
        dtype = cfg.pdtype()
        self.embed = Embedding(cfg.vocab, cfg.d_model, dtype=dtype,
                               pad_rows_to=16)
        self.pos_embed = Embedding(cfg.max_seq, cfg.d_model, axes=(None, "embed"),
                                   dtype=dtype)
        self.enc_block = EncBlock(cfg)
        self.dec_block = DecBlock(cfg)
        self.enc_norm = LayerNorm(cfg.d_model, dtype=dtype)
        self.dec_norm = LayerNorm(cfg.d_model, dtype=dtype)

    def spec(self):
        return {
            "embed": self.embed.spec(),
            "pos_embed": self.pos_embed.spec(),
            "encoder": stack_specs(self.enc_block.spec(), self.cfg.encoder_layers),
            "decoder": stack_specs(self.dec_block.spec(), self.cfg.n_layers),
            "enc_norm": self.enc_norm.spec(),
            "dec_norm": self.dec_norm.spec(),
        }

    def encode(self, p, frames):
        """frames: (B, T, d_model) — post-conv-stub frame embeddings."""
        cfg = self.cfg
        x = frames.astype(cfg.cdtype())
        x = x + sinusoid_pos(x.shape[1], cfg.d_model, x.dtype)[None]
        x = constrain_residual(x, model_on_last=False)  # see sharding.py

        def body(h, lp):
            return constrain_residual(self.enc_block(lp, h)), None
        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, p["encoder"], length=cfg.encoder_layers)
        return self.enc_norm(p["enc_norm"], x)

    def decode_fwd(self, p, tokens, enc_out, positions=None):
        cfg = self.cfg
        B, S = tokens.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = self.embed(p["embed"], tokens).astype(cfg.cdtype())
        x = x + self.pos_embed(p["pos_embed"],
                               positions[0] % cfg.max_seq).astype(x.dtype)[None]
        x = constrain_residual(x, model_on_last=False)  # see sharding.py

        def body(h, lp):
            return constrain_residual(
                self.dec_block.fwd(lp, h, enc_out, positions)), None
        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, p["decoder"], length=cfg.n_layers)
        return self.embed.attend(p["embed"], self.dec_norm(p["dec_norm"], x))

    def forward(self, p, batch):
        enc_out = self.encode(p, batch["frames"])
        return self.decode_fwd(p, batch["tokens"], enc_out), jnp.zeros((), jnp.float32)

    def loss(self, p, batch):
        logits, _ = self.forward(p, batch)
        logits = logits.astype(jnp.float32)
        labels = batch["labels"]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        nll = jnp.mean(logz - gold)
        return nll, {"nll": nll}

    # -- decode ----------------------------------------------------------------
    def init_caches(self, p_or_abstract, batch: int, size: int, enc_len: int,
                    dtype=None):
        """Zero caches; the cross KV is filled by :meth:`prefill_cross`."""
        cfg = self.cfg
        dtype = dtype or cfg.cdtype()
        L, H, D = cfg.n_layers, cfg.n_kv, cfg.resolved_head_dim
        kv = KVCache.zeros(batch, size, H, D, dtype)
        kv = jax.tree.map(lambda a: jnp.broadcast_to(a, (L, *a.shape)), kv)
        xk = jnp.zeros((L, batch, enc_len, H, D), dtype)
        return {"kv": kv, "xk": xk, "xv": xk}

    def abstract_caches(self, batch, size, enc_len, dtype=None):
        return jax.eval_shape(
            lambda: self.init_caches(None, batch, size, enc_len, dtype))

    def prefill_cross(self, p, enc_out, caches):
        def body(_, lp):
            k, v = self.dec_block.cross_kv(lp, enc_out)
            return (), (k, v)
        _, (xk, xv) = jax.lax.scan(body, (), p["decoder"], length=self.cfg.n_layers)
        return {"kv": caches["kv"], "xk": xk.astype(caches["xk"].dtype),
                "xv": xv.astype(caches["xv"].dtype)}

    def decode_step(self, p, tokens, caches, positions):
        cfg = self.cfg
        x = self.embed(p["embed"], tokens).astype(cfg.cdtype())
        x = x + self.pos_embed(p["pos_embed"], positions % cfg.max_seq).astype(x.dtype)

        def body(h, xs):
            lp, c = xs
            h, c2 = self.dec_block.step(lp, h, c, positions)
            return h, c2
        x, caches = jax.lax.scan(body, x, (p["decoder"], caches),
                                 length=cfg.n_layers)
        return self.embed.attend(p["embed"], self.dec_norm(p["dec_norm"], x)), caches
