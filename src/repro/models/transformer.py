"""Generic decoder stack covering dense / moe / hybrid / ssm / vlm families.

Layers are stacked per homogeneous pattern group and iterated with
``jax.lax.scan`` (compile-time control for 64-layer archs; remat at scan
boundaries).  The stack is split into:

  * :class:`TransformerBody` — the blocks + final norm, operating on embedded
    inputs.  PinFM uses the body directly (its "vocabulary" lives in hashed
    id-embedding tables, not a token embedding).
  * :class:`TransformerLM` — token embedding + body + LM head.

Every block kind supports three call modes (DESIGN.md §5):

  fwd(p, x, positions, return_ctx)   full sequence; optionally emits the DCAT
                                     context (KV for attention kinds, the
                                     recurrent state for rec/ssm kinds)
  cross(p, x, ctx, positions)        DCAT crossing: candidate tokens attend
                                     to / continue from a provided context
  step(p, x, cache, positions)       one-token decode against a cache
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain_residual
from repro.models.config import ModelConfig
from repro.nn.module import Module, stack_specs
from repro.nn.layers import Embedding, GLUMLP, LayerNorm, Linear, MLP, RMSNorm
from repro.nn.attention import Attention, KVCache
from repro.nn.moe import MoE
from repro.nn.recurrent import RecurrentBlock
from repro.nn.ssd import Mamba2Block


def _make_norm(cfg: ModelConfig, dtype):
    if cfg.norm == "rmsnorm":
        return RMSNorm(cfg.d_model, dtype=dtype)
    return LayerNorm(cfg.d_model, dtype=dtype)


class Block(Module):
    """One residual block of a given kind ('attn' | 'moe' | 'rec' | 'ssm')."""

    def __init__(self, cfg: ModelConfig, kind: str):
        self.cfg, self.kind = cfg, kind
        dtype = cfg.pdtype()
        self.norm1 = _make_norm(cfg, dtype)
        if kind in ("attn", "moe"):
            self.attn = Attention(
                cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.resolved_head_dim,
                bias=cfg.qkv_bias, qk_norm=cfg.qk_norm, rope=cfg.rope,
                rope_theta=cfg.rope_theta, window=cfg.window, causal=True,
                dtype=dtype, impl=cfg.attn_impl)
        elif kind == "rec":
            self.rec = RecurrentBlock(cfg.d_model, cfg.lru_width, dtype=dtype)
        elif kind == "ssm":
            self.ssm = Mamba2Block(
                cfg.d_model, expand=cfg.ssm_expand, head_dim=cfg.ssm_head_dim,
                d_state=cfg.ssm_state, n_groups=cfg.ssm_groups,
                chunk=cfg.ssm_chunk, dtype=dtype)
        elif kind == "hstu":
            from repro.nn.hstu import HSTUBlock
            self.hstu = HSTUBlock(cfg.d_model, cfg.n_heads,
                                  cfg.resolved_head_dim, rope=cfg.rope,
                                  rope_theta=cfg.rope_theta, dtype=dtype)
        else:
            raise ValueError(kind)

        if kind == "moe":
            self.ffn = MoE(cfg.d_model, cfg.moe_d_ff or cfg.d_ff, cfg.n_experts,
                           cfg.top_k, n_shared=cfg.n_shared,
                           shared_hidden=cfg.shared_d_ff,
                           capacity_factor=cfg.capacity_factor, act=cfg.act,
                           dtype=dtype)
        elif kind in ("attn", "rec"):
            mk = GLUMLP if cfg.mlp_type == "glu" else MLP
            kw = {} if cfg.mlp_type == "glu" else {"bias": True}
            self.ffn = mk(cfg.d_model, cfg.d_ff, act=cfg.act, dtype=dtype, **kw)
        else:
            self.ffn = None   # mamba2 / hstu: single-mixer blocks
        if self.ffn is not None:
            self.norm2 = _make_norm(cfg, dtype)

    def spec(self):
        if self.kind == "hstu":
            return {"hstu": self.hstu.spec()}
        s = {"norm1": self.norm1.spec()}
        if self.kind in ("attn", "moe"):
            s["attn"] = self.attn.spec()
        elif self.kind == "rec":
            s["rec"] = self.rec.spec()
        else:
            s["ssm"] = self.ssm.spec()
        if self.ffn is not None:
            s["ffn"] = self.ffn.spec()
            s["norm2"] = self.norm2.spec()
        return s

    def _ffn(self, p, x):
        aux = jnp.zeros((), jnp.float32)
        if self.ffn is not None:
            h = self.norm2(p["norm2"], x)
            if self.kind == "moe":
                y, moe_aux = self.ffn(p["ffn"], h)
                aux = moe_aux["lb_loss"]
            else:
                y = self.ffn(p["ffn"], h)
            x = x + y
        return x, aux

    # -- full-sequence ---------------------------------------------------------
    def fwd(self, p, x, positions, return_ctx: bool = False):
        """-> (x, aux, ctx).  ctx is the DCAT context: (k, v) for attention
        kinds, the recurrent/ssm state for rec/ssm kinds."""
        if self.kind == "hstu":
            x, ctx = self.hstu.fwd(p["hstu"], x, positions,
                                   return_ctx=return_ctx)
            return x, jnp.zeros((), jnp.float32), ctx
        h = self.norm1(p["norm1"], x)
        ctx = None
        if self.kind in ("attn", "moe"):
            if return_ctx:
                y, kv = self.attn(p["attn"], h, positions=positions, return_kv=True)
                ctx = kv
            else:
                y = self.attn(p["attn"], h, positions=positions)
            x = x + y
        elif self.kind == "rec":
            y, state = self.rec(p["rec"], h)
            ctx = state
            x = x + y
        else:
            y, state = self.ssm(p["ssm"], h)
            ctx = state
            x = x + y
        x, aux = self._ffn(p, x)
        return x, aux, ctx

    # -- DCAT crossing -----------------------------------------------------------
    def cross(self, p, x, ctx, positions, *, self_attend: bool = True,
              ctx_pos=None, rotate_replace: bool = False, gather_idx=None):
        """Candidate tokens x attend to / continue from a context ctx."""
        if self.kind == "hstu":
            y = self.hstu.cross(p["hstu"], x, ctx, positions, ctx_pos=ctx_pos,
                                gather_idx=gather_idx,
                                self_attend=self_attend or rotate_replace)
            return y, jnp.zeros((), jnp.float32)
        h = self.norm1(p["norm1"], x)
        if self.kind in ("attn", "moe"):
            k_ctx, v_ctx = ctx
            y = self.attn.cross(p["attn"], h, k_ctx, v_ctx, positions=positions,
                                k_pos=ctx_pos, self_attend=self_attend,
                                rotate_replace=rotate_replace,
                                gather_idx=gather_idx)
            x = x + y
        elif self.kind == "rec":
            y, _ = self.rec(p["rec"], h, ctx)
            x = x + y
        else:
            y, _ = self.ssm(p["ssm"], h, ctx)
            x = x + y
        x, aux = self._ffn(p, x)
        return x, aux

    # -- decode ---------------------------------------------------------------
    def init_cache(self, batch: int, size: int, dtype):
        cfg = self.cfg
        if self.kind in ("attn", "moe"):
            size = min(size, cfg.window) if cfg.window else size
            return KVCache.zeros(batch, size, cfg.n_kv, cfg.resolved_head_dim, dtype)
        if self.kind == "hstu":
            return KVCache.zeros(batch, size, cfg.n_heads,
                                 cfg.resolved_head_dim, dtype)
        if self.kind == "rec":
            return self.rec.init_state(batch, dtype)
        return self.ssm.init_state(batch, dtype)

    def step(self, p, x, cache, positions):
        if self.kind == "hstu":
            return self.hstu.step(p["hstu"], x, cache, positions)
        h = self.norm1(p["norm1"], x)
        if self.kind in ("attn", "moe"):
            y, cache = self.attn.decode(p["attn"], h, cache, positions)
        elif self.kind == "rec":
            y, cache = self.rec.step(p["rec"], h, cache)
        else:
            y, cache = self.ssm.step(p["ssm"], h, cache)
        x = x + y
        x, _ = self._ffn(p, x)
        return x, cache


class TransformerBody(Module):
    """Pattern-grouped block stack + final norm, scanned over layers."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = [tuple(Block(cfg, k) for k in unit)
                       for unit, _ in cfg.scan_groups()]
        self.repeats = [r for _, r in cfg.scan_groups()]
        self.final_norm = _make_norm(cfg, cfg.pdtype())

    def spec(self):
        return {
            "groups": [
                {"blocks": [stack_specs(b.spec(), r) for b in unit]}
                for unit, r in zip(self.groups, self.repeats)],
            "final_norm": self.final_norm.spec(),
        }

    def forward(self, p, x, positions, *, collect_ctx: bool = False,
                final_norm: bool = True, skip_last_self_attn: bool = False):
        """-> (y, aux, ctxs).  ctxs: list-per-group of tuple-per-unit-position
        of stacked contexts (leading dim = repeats), or None.

        skip_last_self_attn (paper §4.1, serving): the LAST layer's context
        output x_u^(L) feeds only the loss, so at serving we compute just its
        K/V projection and skip its attention + FFN.  Requires collect_ctx
        and a trailing attention-kind layer.
        """
        aux_total = jnp.zeros((), jnp.float32)
        ctxs = [] if collect_ctx else None
        skip = (skip_last_self_attn and collect_ctx
                and len(self.groups[-1]) == 1
                and self.groups[-1][0].kind in ("attn", "moe"))
        n_groups = len(self.groups)
        for gi, (unit, gp, reps) in enumerate(
                zip(self.groups, p["groups"], self.repeats)):
            last_group = gi == n_groups - 1
            scan_reps = reps - 1 if (skip and last_group) else reps
            blocks = tuple(gp["blocks"])
            if skip and last_group:
                scan_blocks = jax.tree.map(lambda a: a[:-1], blocks)
            else:
                scan_blocks = blocks

            def body(carry, layer_params):
                h, aux = carry
                outs = []
                for blk, bp in zip(unit, layer_params):
                    h, a, ctx = blk.fwd(bp, h, positions, return_ctx=collect_ctx)
                    aux = aux + a
                    outs.append(ctx)
                h = constrain_residual(h)
                return (h, aux), tuple(outs) if collect_ctx else None
            if self.cfg.remat == "full":
                body = jax.checkpoint(body, prevent_cse=False)
            if scan_reps > 0:
                (x, aux_total), ys = jax.lax.scan(
                    body, (x, aux_total), scan_blocks, length=scan_reps)
            else:
                ys = None
            if skip and last_group:
                blk = unit[0]
                bp_last = jax.tree.map(lambda a: a[-1], blocks[0])
                h = blk.norm1(bp_last["norm1"], x)
                _, k, v = blk.attn.qkv(bp_last["attn"], h, positions)
                kv_last = jax.tree.map(lambda a: a[None], (k, v))
                ys = ((kv_last,) if ys is None else
                      jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0),
                                   ys, (kv_last,)))
            if collect_ctx:
                ctxs.append(ys)
        if final_norm:
            x = self.final_norm(p["final_norm"], x)
        return x, aux_total, ctxs

    def cross(self, p, x, ctxs, positions, *, self_attend: bool = True,
              ctx_pos=None, final_norm: bool = True, gather_idx=None,
              rotate_replace: bool = False):
        """DCAT crossing: run candidate tokens through every layer, each layer
        attending to / continuing from its stored context.

        gather_idx: (B_c,) int — the paper's Ψ⁻¹: per-layer broadcast of the
        deduplicated context to the candidate batch, performed INSIDE the
        layer scan so the un-deduplicated KV never exists for all layers at
        once.
        """
        aux_total = jnp.zeros((), jnp.float32)
        for unit, gp, reps, gc in zip(self.groups, p["groups"], self.repeats,
                                      ctxs):
            def body(carry, xs):
                h, aux = carry
                layer_params, layer_ctx = xs
                for blk, bp, c in zip(unit, layer_params, layer_ctx):
                    gidx = gather_idx
                    if gather_idx is not None and blk.kind not in ("attn", "moe"):
                        # rec/ssm states: Ψ⁻¹ materializes the (small) state
                        c = jax.tree.map(lambda a: jnp.take(a, gather_idx,
                                                            axis=0), c)
                        gidx = None
                    h, a = blk.cross(bp, h, c, positions,
                                     self_attend=self_attend, ctx_pos=ctx_pos,
                                     rotate_replace=rotate_replace,
                                     gather_idx=gidx)
                    aux = aux + a
                return (h, aux), None
            (x, aux_total), _ = jax.lax.scan(
                body, (x, aux_total), (tuple(gp["blocks"]), tuple(gc)),
                length=reps)
        if final_norm:
            x = self.final_norm(p["final_norm"], x)
        return x, aux_total

    def decode(self, p, x, caches, positions, *, final_norm: bool = True):
        new_caches = []
        for unit, gp, reps, gc in zip(self.groups, p["groups"], self.repeats,
                                      caches):
            def body(h, xs):
                layer_params, layer_caches = xs
                outs = []
                for blk, bp, c in zip(unit, layer_params, layer_caches):
                    h, c2 = blk.step(bp, h, c, positions)
                    outs.append(c2)
                return h, tuple(outs)
            x, cout = jax.lax.scan(body, x, (tuple(gp["blocks"]), tuple(gc)),
                                   length=reps)
            new_caches.append(cout)
        if final_norm:
            x = self.final_norm(p["final_norm"], x)
        return x, new_caches

    def init_caches(self, batch: int, size: int, dtype=None):
        dtype = dtype or self.cfg.cdtype()
        caches = []
        for unit, reps in zip(self.groups, self.repeats):
            unit_caches = []
            for blk in unit:
                one = blk.init_cache(batch, size, dtype)
                unit_caches.append(jax.tree.map(
                    lambda a: jnp.broadcast_to(a, (reps, *a.shape)), one))
            caches.append(tuple(unit_caches))
        return caches

    def abstract_caches(self, batch: int, size: int, dtype=None):
        return jax.eval_shape(lambda: self.init_caches(batch, size, dtype))


class TransformerLM(Module):
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        dtype = cfg.pdtype()
        self.embed = Embedding(cfg.vocab, cfg.d_model, dtype=dtype,
                               pad_rows_to=16)
        self.body = TransformerBody(cfg)
        if not cfg.tie_embeddings:
            self.lm_head = Linear(cfg.d_model, cfg.vocab,
                                  axes=("embed", "vocab"), dtype=dtype)
        if cfg.frontend == "patch":
            self.projector = Linear(cfg.frontend_dim, cfg.d_model,
                                    axes=(None, "embed"), dtype=dtype)
        if cfg.pos_emb == "learned":
            self.pos_embed = Embedding(cfg.max_seq, cfg.d_model,
                                       axes=(None, "embed"), dtype=dtype)

    def spec(self):
        cfg = self.cfg
        s = {"embed": self.embed.spec(), "body": self.body.spec()}
        if not cfg.tie_embeddings:
            s["lm_head"] = self.lm_head.spec()
        if cfg.frontend == "patch":
            s["projector"] = self.projector.spec()
        if cfg.pos_emb == "learned":
            s["pos_embed"] = self.pos_embed.spec()
        return s

    # -- embedding / head ------------------------------------------------------
    def embed_inputs(self, p, tokens, embeds=None, positions=None):
        cfg = self.cfg
        x = self.embed(p["embed"], tokens).astype(cfg.cdtype())
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, cfg.cdtype())
        if embeds is not None:
            pe = self.projector(p["projector"], embeds.astype(cfg.cdtype()))
            x = jnp.concatenate([pe, x], axis=1)
        if cfg.pos_emb == "learned":
            if positions is None:
                positions = jnp.arange(x.shape[1])[None]
            x = x + self.pos_embed(
                p["pos_embed"], positions % cfg.max_seq).astype(x.dtype)
        # explicit reshard boundary: keeps the residual-stream model-axis
        # constraint from propagating INTO the embedding gather (XLA SPMD
        # mis-partitions gathers of replicated tables, e.g. vocab % 16 != 0)
        return constrain_residual(x, model_on_last=False)

    def logits(self, p, x):
        if self.cfg.tie_embeddings:
            lg = self.embed.attend(p["embed"], x)
            if self.embed.rows != self.cfg.vocab:   # mask padded columns
                mask = jnp.arange(self.embed.rows) < self.cfg.vocab
                lg = jnp.where(mask, lg, jnp.asarray(-1e30, lg.dtype))
            return lg
        return self.lm_head(p["lm_head"], x)

    # -- public API -----------------------------------------------------------
    def forward(self, p, tokens, *, embeds=None, positions=None):
        B = tokens.shape[0]
        x = self.embed_inputs(p, tokens, embeds)
        S = x.shape[1]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x, aux, _ = self.body.forward(p["body"], x, positions)
        return self.logits(p, x), aux

    def init_caches(self, batch: int, size: int, dtype=None):
        return self.body.init_caches(batch, size, dtype)

    def abstract_caches(self, batch: int, size: int, dtype=None):
        return self.body.abstract_caches(batch, size, dtype)

    def decode_step(self, p, tokens, caches, positions):
        """tokens: (B, 1); positions: (B, 1) absolute -> (logits, caches)."""
        x = self.embed_inputs(p, tokens, positions=positions)
        x, caches = self.body.decode(p["body"], x, caches, positions)
        return self.logits(p, x), caches

    # -- loss ------------------------------------------------------------------
    def loss(self, p, batch):
        """batch: {tokens (B,S), labels (B,S), [embeds], [mask]} -> scalar."""
        logits, aux = self.forward(p, batch["tokens"], embeds=batch.get("embeds"))
        labels = batch["labels"]
        if batch.get("embeds") is not None:
            logits = logits[:, -labels.shape[1]:]   # frontend tokens: no labels
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        nll = logz - gold
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(nll)
        nll = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        lb = 0.01 * aux / max(len(self.cfg.block_kinds()), 1)
        return nll + lb, {"nll": nll, "lb": lb}
