from repro.data.synthetic import DataConfig, SyntheticActivity
from repro.data.segment import (pack_segments, realtime_sequence,
                                segment_history)
