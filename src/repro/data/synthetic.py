"""Synthetic user-activity stream with planted latent-interest structure.

Pinterest's 2-year activity logs are not available; we generate a stream
with the statistical properties the paper's mechanisms depend on:

  * users have a small set of latent topics; items belong to topics;
    engagement probability is high iff the item matches an interest
    (so sequence models CAN predict future engagements — HIT@3 lifts on this
    data are directional evidence, DESIGN.md §2);
  * item popularity is Zipfian (so id embeddings matter and hash collisions
    hit the tail);
  * action types with a positive subset (save=1, download=2, clickthrough=3,
    click=4, hide=5, impression=0) and surfaces (HF=0, I2I=1, search=2);
  * "fresh" items (cold-start pool) appear with small ages and no history;
  * ranking requests score G candidates per user (the 1:G dedup pattern).

Everything is deterministic given the seed.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

ACTIONS = {"impression": 0, "save": 1, "download": 2, "clickthrough": 3,
           "click": 4, "hide": 5}
POSITIVE_ACTIONS = (1, 2, 3)
N_ACTIONS = 6
N_SURFACES = 3


@dataclasses.dataclass
class DataConfig:
    n_users: int = 2000
    n_items: int = 5000
    n_topics: int = 32
    interests_per_user: int = 3
    seq_len: int = 64             # L: pretraining segment length
    events_per_user: int = 128
    zipf_a: float = 1.2
    p_engage_match: float = 0.55  # P(positive action | topic match)
    p_engage_miss: float = 0.05
    fresh_frac: float = 0.15      # fraction of items in the fresh pool
    seed: int = 0


class SyntheticActivity:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        self.item_topic = rng.randint(0, cfg.n_topics, cfg.n_items)
        # zipf popularity within topic
        self.item_pop = 1.0 / np.arange(1, cfg.n_items + 1) ** cfg.zipf_a
        rng.shuffle(self.item_pop)
        self.user_interests = np.stack([
            rng.choice(cfg.n_topics, cfg.interests_per_user, replace=False)
            for _ in range(cfg.n_users)])
        n_fresh = int(cfg.n_items * cfg.fresh_frac)
        self.fresh_items = np.arange(cfg.n_items - n_fresh, cfg.n_items)
        self.fresh_set = set(self.fresh_items.tolist())
        # topic -> item lists with popularity weights (established items only)
        self.topic_items = []
        established = np.arange(cfg.n_items - n_fresh)
        for t in range(cfg.n_topics):
            items = established[self.item_topic[established] == t]
            if len(items) == 0:
                items = established[:1]
            w = self.item_pop[items]
            self.topic_items.append((items, w / w.sum()))

    # -- event stream --------------------------------------------------------
    def user_events(self, user: int, n: int, rng: np.random.RandomState):
        """-> dict of arrays: ids, actions, surfaces, timestamps."""
        cfg = self.cfg
        interests = self.user_interests[user]
        ids = np.empty(n, np.int64)
        actions = np.empty(n, np.int32)
        surfaces = rng.randint(0, N_SURFACES, n).astype(np.int32)
        t0 = rng.randint(0, 10_000)
        timestamps = t0 + np.cumsum(rng.exponential(30.0, n))
        for i in range(n):
            if rng.rand() < 0.8:   # browse within an interest
                topic = interests[rng.randint(len(interests))]
            else:                  # exploration
                topic = rng.randint(cfg.n_topics)
            items, w = self.topic_items[topic]
            ids[i] = items[rng.choice(len(items), p=w)]
            match = self.item_topic[ids[i]] in interests
            p = cfg.p_engage_match if match else cfg.p_engage_miss
            if rng.rand() < p:
                actions[i] = rng.choice(POSITIVE_ACTIONS,
                                        p=[0.6, 0.15, 0.25])
            else:
                actions[i] = (ACTIONS["hide"] if rng.rand() < 0.05
                              else ACTIONS["impression"])
        return {"ids": ids, "actions": actions, "surfaces": surfaces,
                "timestamps": timestamps.astype(np.float32)}

    # -- pretraining batches ----------------------------------------------------
    def pretrain_batches(self, batch_size: int, n_batches: int,
                         seed: int = 1) -> Iterator[dict]:
        """Non-overlapping length-L segments (paper §3.1 data construction)."""
        cfg = self.cfg
        rng = np.random.RandomState(seed)
        for _ in range(n_batches):
            users = rng.randint(0, cfg.n_users, batch_size)
            out = {k: [] for k in ("ids", "actions", "surfaces")}
            for u in users:
                ev = self.user_events(int(u), cfg.seq_len, rng)
                for k in out:
                    out[k].append(ev[k][:cfg.seq_len])
            yield {
                "ids": np.stack(out["ids"]).astype(np.int32),
                "actions": np.stack(out["actions"]),
                "surfaces": np.stack(out["surfaces"]),
                "valid": np.ones((batch_size, cfg.seq_len), bool),
                "user_id": users.astype(np.int32),
            }

    # -- fine-tuning / ranking batches ------------------------------------------
    def ranking_batches(self, n_requests: int, cands_per_request: int,
                        n_batches: int, seq_len: Optional[int] = None,
                        seed: int = 2,
                        fresh_prob: float = 0.25) -> Iterator[dict]:
        """Each batch: n_requests unique users × G candidates (the paper's
        1:G dedup pattern, already Ψ-deduplicated as the pipeline emits it)."""
        cfg = self.cfg
        L = seq_len or cfg.seq_len
        rng = np.random.RandomState(seed)
        G = cands_per_request
        for _ in range(n_batches):
            users = rng.choice(cfg.n_users, n_requests, replace=False)
            seq = {k: [] for k in ("ids", "actions", "surfaces")}
            for u in users:
                ev = self.user_events(int(u), L, rng)
                for k in seq:
                    seq[k].append(ev[k])
            cand_ids, labels, ages = [], [], []
            for u in users:
                interests = self.user_interests[u]
                for _ in range(G):
                    if rng.rand() < fresh_prob:
                        c = int(rng.choice(self.fresh_items))
                        age = rng.randint(0, 28)
                    else:
                        topic = (interests[rng.randint(len(interests))]
                                 if rng.rand() < 0.5
                                 else rng.randint(cfg.n_topics))
                        items, w = self.topic_items[topic]
                        c = int(items[rng.choice(len(items), p=w)])
                        age = rng.randint(28, 1000)
                    match = self.item_topic[c] in interests
                    p = cfg.p_engage_match if match else cfg.p_engage_miss
                    save = rng.rand() < p
                    click = rng.rand() < min(2 * p, 0.9)
                    hide = (not match) and rng.rand() < 0.08
                    cand_ids.append(c)
                    labels.append([save, click, hide])
                    ages.append(age)
            B_c = n_requests * G
            inv = np.repeat(np.arange(n_requests), G).astype(np.int32)
            cand_ids = np.asarray(cand_ids, np.int32)
            # dense features: noisy topic one-hot-ish summaries
            user_feats = rng.randn(n_requests, 8).astype(np.float32)
            cand_feats = np.stack(
                [self.item_pop[cand_ids],
                 (self.item_topic[cand_ids] % 8).astype(np.float32)],
                axis=1).astype(np.float32)
            cand_feats = np.concatenate(
                [cand_feats, rng.randn(B_c, 6).astype(np.float32)], axis=1)
            gs = self._graphsage(cand_ids, rng)
            yield {
                "seq_ids": np.stack(seq["ids"]).astype(np.int32),
                "seq_actions": np.stack(seq["actions"]),
                "seq_surfaces": np.stack(seq["surfaces"]),
                "seq_valid": np.ones((n_requests, L), bool),
                "seq_user_id": users.astype(np.int32),
                "inverse_idx": inv,
                "cand_ids": cand_ids,
                "cand_feats": cand_feats,
                "user_feats": user_feats,
                "graphsage": gs,
                "cand_age_days": np.asarray(ages, np.float32),
                "labels": np.asarray(labels, np.float32),
            }

    def _graphsage(self, item_ids, rng, dim: int = 16):
        """Stand-in GraphSAGE embeddings: topic-structured + noise, available
        for fresh items too (that is the point of the technique)."""
        topic = self.item_topic[item_ids]
        base = np.zeros((len(item_ids), dim), np.float32)
        base[np.arange(len(item_ids)), topic % dim] = 1.0
        return base + 0.1 * rng.randn(len(item_ids), dim).astype(np.float32)

    def is_fresh(self, item_ids) -> np.ndarray:
        return np.isin(item_ids, self.fresh_items)
