"""Sequence segmentation utilities (paper §3.1 data construction): a user's
full history (up to 16k events, timestamp-ascending) is cut into
NON-OVERLAPPING segments of length L for pretraining; the most recent L_d
events form the downstream real-time sequence."""
from __future__ import annotations

from typing import Dict, Iterator, List

import numpy as np

FIELDS = ("ids", "actions", "surfaces", "timestamps")


def sort_by_time(events: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    order = np.argsort(events["timestamps"], kind="stable")
    return {k: np.asarray(v)[order] for k, v in events.items()}


def segment_history(events: Dict[str, np.ndarray], seg_len: int,
                    *, max_len: int = 16_000,
                    drop_last_partial: bool = False) -> List[dict]:
    """Non-overlapping length-L segments (earliest first).  The final
    partial segment is right-padded and carries a ``valid`` mask."""
    ev = sort_by_time(events)
    n = min(len(ev["ids"]), max_len)
    ev = {k: v[-n:] for k, v in ev.items()}          # keep the most recent
    out = []
    for start in range(0, n, seg_len):
        end = min(start + seg_len, n)
        if end - start < seg_len and drop_last_partial:
            break
        seg = {}
        valid = np.zeros(seg_len, bool)
        valid[: end - start] = True
        for k in FIELDS:
            if k not in ev:
                continue
            buf = np.zeros(seg_len, np.asarray(ev[k]).dtype)
            buf[: end - start] = ev[k][start:end]
            seg[k] = buf
        seg["valid"] = valid
        out.append(seg)
    return out


def realtime_sequence(events: Dict[str, np.ndarray], l_d: int) -> dict:
    """The downstream model's input: the LAST L_d events, left-padded."""
    ev = sort_by_time(events)
    n = min(len(ev["ids"]), l_d)
    seg = {}
    valid = np.zeros(l_d, bool)
    valid[l_d - n:] = True
    for k in FIELDS:
        if k not in ev:
            continue
        buf = np.zeros(l_d, np.asarray(ev[k]).dtype)
        if n:
            buf[l_d - n:] = ev[k][-n:]
        seg[k] = buf
    seg["valid"] = valid
    return seg


def pack_segments(segments: List[dict], batch_size: int) -> Iterator[dict]:
    """Batch segments into fixed-size arrays (trailing remainder dropped)."""
    for i in range(0, len(segments) - batch_size + 1, batch_size):
        chunk = segments[i:i + batch_size]
        yield {k: np.stack([s[k] for s in chunk]) for k in chunk[0]}
