"""Cluster serving tier: N engine workers behind one affinity router.

The single-process ``ServingEngine`` tops out at one host's devices and
one ContextCache; the paper's deployment serves half a billion users.
This package is the tier above the engine:

  * :mod:`~repro.cluster.membership` — rendezvous (HRW) hashing: a pure,
    coordination-free ``key -> worker`` map where membership changes
    move only ~1/N of the key space (cache residency survives joins,
    leaves, and deaths).
  * :mod:`~repro.cluster.worker` — :class:`EngineWorker` (in-process
    thread) and :class:`SubprocessWorker` (spawned child) wrapping one
    engine each behind a coalescing command queue, with a typed
    never-hang failure contract (:class:`WorkerLostError`,
    first-writer-wins :class:`ClusterFuture`).
  * :mod:`~repro.cluster.fanout` — corpus shards as picklable payloads
    (:func:`make_shards`) and the worker-side :class:`ShardScorer`
    running the same exact/IVF executors as the engine, offset into
    global row space.
  * :mod:`~repro.cluster.router` — :class:`ClusterRouter`: the
    ``submit(request) -> future`` front door; rank/generate traffic
    routes to each user's rendezvous owner, retrieval scatter/gathers
    across the worker shards and merges with the retrieval stack's
    lower-index-wins contract — bit-identical to a single engine.

Quickstart (in-process, 2 workers)::

    from repro.cluster import ClusterRouter, EngineWorker, WorkerCore
    workers = {f"w{i}": EngineWorker(f"w{i}", WorkerCore(make_engine()))
               for i in range(2)}
    router = ClusterRouter(workers)
    router.attach_index(index, k=64)     # cluster-sharded retrieval
    router.warmup()
    fut = router.submit(RankRequest(...))     # routed by user affinity
    probs = fut.result()

``examples/serve_cluster.py`` runs the same flow over subprocess
workers; ``benchmarks/bench_cluster.py`` measures aggregate scaling,
affinity hit rate, and drain latency.
"""
from repro.cluster.fanout import (ShardScorer, ShardSpec,
                                  default_slice_rows, make_shards)
from repro.cluster.membership import (Membership, rendezvous_owner,
                                      rendezvous_score)
from repro.cluster.router import ClusterRouter
from repro.cluster.worker import (ClusterFuture, EngineWorker,
                                  SubprocessWorker, WorkerCore,
                                  WorkerLostError)

__all__ = [
    "ClusterRouter",
    "EngineWorker", "SubprocessWorker", "WorkerCore", "ClusterFuture",
    "WorkerLostError",
    "Membership", "rendezvous_owner", "rendezvous_score",
    "ShardSpec", "ShardScorer", "make_shards", "default_slice_rows",
]
