"""Cluster workers: one ServingEngine per worker, behind a command queue.

Two transports share one contract:

  * :class:`EngineWorker` — in-process: a daemon thread owns a
    :class:`WorkerCore` (engine + optional shard scorer) and drains a
    command queue, coalescing adjacent request batches into one engine
    flush (the cluster-level analogue of the scheduler's own
    coalescing).
  * :class:`SubprocessWorker` — same queue machinery, but the core lives
    in a spawned child process and commands travel a ``multiprocessing``
    pipe.  The child builds its OWN engine via a top-level picklable
    factory (models/params/indexes never cross the pipe); requests,
    shard payloads and numpy results do.

Failure contract (mirrors the scheduler's ``ShedError`` discipline —
futures NEVER hang): :meth:`kill` marks the worker dead under the queue
lock, so the loop can never pop another item afterwards, and
:meth:`take_pending` atomically recovers every queued + in-flight
(request, future) pair for the router to re-route to survivors.
Requests are pure, so re-running one elsewhere is safe, and
:class:`ClusterFuture` resolution is FIRST-WRITER-WINS: a dead worker's
late-but-valid result and the re-routed result race harmlessly.
Anything un-re-routable fails with the typed :class:`WorkerLostError`.
Graceful :meth:`close` drains the queue first (the drain path of the
kill-one-worker test).
"""
from __future__ import annotations

import multiprocessing as mp
import threading
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.fanout import ShardScorer, ShardSpec
from repro.serving.scheduler import ShedError


class WorkerLostError(RuntimeError):
    """A worker died (killed, crashed, or closed) with this request
    un-re-routable — the cluster tier's typed never-hang terminal, the
    analogue of the scheduler's ``ShedError``."""

    def __init__(self, worker: str, reason: str = "lost"):
        self.worker = worker
        self.reason = reason
        super().__init__(f"worker {worker!r} lost ({reason})")

    def __reduce__(self):   # default exception pickling would drop fields
        return (WorkerLostError, (self.worker, self.reason))


def _dump_exc(exc: BaseException) -> tuple:
    """Picklable surrogate for an exception crossing the worker pipe —
    typed errors (ShedError, WorkerLostError) reconstruct exactly."""
    if isinstance(exc, ShedError):
        return ("shed", (exc.lane, exc.reason, exc.wait_ms, exc.budget_ms,
                         exc.priority))
    if isinstance(exc, WorkerLostError):
        return ("lost", (exc.worker, exc.reason))
    return ("generic", (type(exc).__name__, str(exc)))


def _load_exc(payload: tuple) -> BaseException:
    kind, a = payload
    if kind == "shed":
        return ShedError(*a)
    if kind == "lost":
        return WorkerLostError(*a)
    name, msg = a
    return RuntimeError(f"{name}: {msg}")


class ClusterFuture:
    """Future for one cluster-routed request.  Unlike the scheduler's
    :class:`~repro.serving.scheduler.Future` (exactly-once by assertion),
    resolution here is FIRST-WRITER-WINS: a re-routed request may be
    resolved by the new owner while the dead owner's stale error/result
    trails in — the first set sticks, later sets are dropped."""

    __slots__ = ("_ev", "_value", "_exc", "_cbs", "_lock")

    def __init__(self):
        self._ev = threading.Event()
        self._value = None
        self._exc: Optional[BaseException] = None
        self._cbs: List[Callable[["ClusterFuture"], None]] = []
        self._lock = threading.Lock()

    def _resolve(self, value, exc) -> bool:
        with self._lock:
            if self._ev.is_set():
                return False
            self._value, self._exc = value, exc
            cbs, self._cbs = self._cbs, []
            self._ev.set()
        for cb in cbs:
            cb(self)
        return True

    def _set(self, value) -> bool:
        return self._resolve(value, None)

    def _set_error(self, exc: BaseException) -> bool:
        return self._resolve(None, exc)

    def done(self) -> bool:
        return self._ev.is_set()

    def add_done_callback(self, cb: Callable[["ClusterFuture"], None]):
        """Run ``cb(self)`` at resolution (immediately if already done) —
        the router chains two-stage rank submission onto retrieval
        completion with this."""
        with self._lock:
            if not self._ev.is_set():
                self._cbs.append(cb)
                return
        cb(self)

    def result(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError("cluster future not resolved in time")
        if self._exc is not None:
            raise self._exc
        return self._value


class WorkerCore:
    """The worker-resident state: one engine, optionally one corpus
    shard.  Every method is an RPC endpoint for :class:`SubprocessWorker`
    (arguments and returns must pickle) and a direct call for
    :class:`EngineWorker`."""

    def __init__(self, engine):
        self.engine = engine
        self.shard: Optional[ShardScorer] = None

    def submit_batch(self, requests: Sequence) -> List[tuple]:
        """Run a request batch through one engine flush.  Per-request
        status tuples — ``("ok", payload)`` / ``("err", surrogate)`` —
        so one shed request doesn't poison its batchmates."""
        futs = self.engine.submit_many(requests)
        self.engine.flush()
        out = []
        for f in futs:
            try:
                out.append(("ok", f.result()))
            except Exception as e:           # noqa: BLE001 — re-raised typed
                out.append(("err", _dump_exc(e)))
        return out

    def encode_users(self, requests: Sequence) -> np.ndarray:
        return self.engine.encode_users(requests)

    def attach_shard(self, spec: ShardSpec) -> None:
        self.shard = ShardScorer(spec)

    def shard_topk(self, route: str, queries: np.ndarray, k: int,
                   off=None, val=None, mask=None) -> Tuple[np.ndarray,
                                                           np.ndarray]:
        assert self.shard is not None, "no shard attached"
        if route == "exact":
            return self.shard.exact_topk(queries, k, mask)
        assert route == "ivf", route
        return self.shard.ivf_topk(queries, off, val, mask, k)

    def warm_shard(self, d_query: int, ks, q_buckets, ivf_slots=()) -> int:
        assert self.shard is not None, "no shard attached"
        return self.shard.warm(d_query, ks, q_buckets, ivf_slots)

    def warmup(self, seq_len: Optional[int] = None) -> dict:
        return self.engine.warmup(seq_len=seq_len)

    def compiles_after_warmup(self) -> int:
        return int(self.engine.registry.compiles_after_warmup)

    def stats(self) -> dict:
        return {"engine": self.engine.stats(),
                "shard": self.shard.stats() if self.shard else None}

    def obs_snapshot(self) -> dict:
        return self.engine.obs.snapshot()

    def ping(self) -> bool:
        return True

    def close(self) -> None:
        self.engine.close()


class _QueueWorker:
    """The shared command-queue half of both worker transports: a daemon
    thread drains batches (coalescing adjacent ones) and control calls
    in submission order.  One condition variable guards the deque, the
    dead flag, and the in-flight handoff — so ``kill`` + ``take_pending``
    is atomic against the loop and no (request, future) pair can slip
    between them."""

    def __init__(self, name: str):
        self.name = name
        self._cv = threading.Condition()
        self._items: deque = deque()
        self._dead = False
        self._dead_reason = "lost"
        self._closing = False
        self._inflight: List[tuple] = []
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=f"cluster-{name}")
        self._thread.start()

    # transport-specific execution of one coalesced batch / control call
    def _exec_batch(self, requests: List) -> List[tuple]:
        raise NotImplementedError

    def _exec_call(self, method: str, args, kwargs):
        raise NotImplementedError

    def _shutdown_transport(self) -> None:
        pass

    # -- public surface ------------------------------------------------------
    def submit_batch(self, pairs: Sequence[Tuple[Any, ClusterFuture]]
                     ) -> bool:
        """Enqueue (request, future) pairs; the worker resolves each
        future from its slot in the coalesced flush.  Returns False —
        with the futures UNTOUCHED — if the worker is dead or closing, so
        the caller re-routes instead of failing."""
        with self._cv:
            if self._dead or self._closing:
                return False
            self._items.append(("batch", list(pairs)))
            self._cv.notify()
        return True

    def call_async(self, method: str, *args, **kwargs) -> ClusterFuture:
        fut = ClusterFuture()
        with self._cv:
            if self._dead or self._closing:
                fut._set_error(WorkerLostError(self.name, self._dead_reason))
                return fut
            self._items.append(("call", method, args, kwargs, fut))
            self._cv.notify()
        return fut

    def call(self, method: str, *args, **kwargs):
        return self.call_async(method, *args, **kwargs).result()

    def healthy(self) -> bool:
        with self._cv:
            return self._thread.is_alive() and not self._dead

    def idle(self) -> bool:
        with self._cv:
            return not self._items and not self._inflight

    def join_idle(self, timeout: float = 60.0) -> bool:
        """Wait until the queue is drained and nothing is in flight."""
        import time
        t0 = time.monotonic()
        while time.monotonic() - t0 < timeout:
            if self.idle():
                return True
            time.sleep(0.002)
        return False

    def kill(self, reason: str = "killed") -> None:
        """Simulated crash: mark dead under the queue lock (the loop can
        never pop another item) and tear down the transport.  Call
        :meth:`take_pending` afterwards to recover queued + in-flight
        requests for re-routing."""
        with self._cv:
            if self._dead:
                return
            self._dead = True
            self._dead_reason = reason
            self._cv.notify()
        self._shutdown_transport()

    def take_pending(self) -> List[Tuple[Any, ClusterFuture]]:
        """Atomically drain every un-resolved (request, future) pair off
        a dead worker: the batch executing at kill time plus everything
        still queued.  Queued control-call futures fail typed (they bind
        to this worker's state and cannot re-route)."""
        out: List[Tuple[Any, ClusterFuture]] = []
        with self._cv:
            assert self._dead, "take_pending on a live worker"
            out.extend((r, f) for r, f in self._inflight if not f.done())
            self._inflight = []
            for item in self._items:
                if item[0] == "batch":
                    out.extend((r, f) for r, f in item[1] if not f.done())
                elif item[0] == "call":
                    item[4]._set_error(
                        WorkerLostError(self.name, self._dead_reason))
            self._items.clear()
        return out

    def close(self, timeout: float = 60.0) -> None:
        """Graceful drain: finish everything queued, then stop.  If the
        drain does not finish within ``timeout``, everything still
        queued or in flight resolves with a typed
        :class:`WorkerLostError` — callers blocked in ``result()`` with
        no timeout must never hang on a close."""
        with self._cv:
            if self._dead:
                return
            self._closing = True
            self._items.append(("close",))
            self._cv.notify()
        self._thread.join(timeout)
        stranded: List[ClusterFuture] = []
        with self._cv:
            self._dead = True
            self._dead_reason = ("close timeout" if self._thread.is_alive()
                                 else "closed")
            # on a clean drain both are empty; on a timeout this is the
            # take_pending sweep, resolved typed instead of re-routed
            # (the caller is tearing the worker down, not re-balancing)
            stranded.extend(f for _, f in self._inflight if not f.done())
            self._inflight = []
            for item in self._items:
                if item[0] == "batch":
                    stranded.extend(f for _, f in item[1] if not f.done())
                elif item[0] == "call":
                    stranded.append(item[4])
            self._items.clear()
        self._shutdown_transport()
        for f in stranded:      # outside the lock: callbacks may re-enter
            f._set_error(WorkerLostError(self.name, self._dead_reason))

    # -- the worker loop -----------------------------------------------------
    def _run(self):
        while True:
            with self._cv:
                while not self._items and not self._dead:
                    self._cv.wait()
                if self._dead:
                    return      # leftovers recovered by take_pending
                item = self._items.popleft()
                if item[0] == "batch":
                    pairs = list(item[1])
                    while self._items and self._items[0][0] == "batch":
                        pairs.extend(self._items.popleft()[1])
                    self._inflight = list(pairs)
            if item[0] == "close":
                try:
                    self._exec_call("close", (), {})
                except Exception:
                    pass
                return
            if item[0] == "call":
                _, method, args, kwargs, fut = item
                try:
                    fut._set(self._exec_call(method, args, kwargs))
                except Exception as e:       # noqa: BLE001 — typed on future
                    with self._cv:
                        dead = self._dead
                    fut._set_error(
                        WorkerLostError(self.name, self._dead_reason)
                        if dead else e)
                continue
            # -- batch ----------------------------------------------------
            try:
                statuses = self._exec_batch([r for r, _ in pairs])
            except Exception as e:           # noqa: BLE001 — typed on futures
                with self._cv:
                    dead = self._dead
                    if not dead:
                        self._inflight = []
                if not dead:                 # genuine engine error
                    for _, f in pairs:
                        f._set_error(e)
                # dead: futures stay in _inflight for take_pending
                continue
            # a completed flush is valid even if we died mid-way —
            # first-writer-wins absorbs any race with a re-routed copy
            for (r, f), (tag, payload) in zip(pairs, statuses):
                if tag == "ok":
                    f._set(payload)
                else:
                    f._set_error(_load_exc(payload))
            with self._cv:
                self._inflight = []


class EngineWorker(_QueueWorker):
    """In-process worker: the core (engine + shard) lives in this process
    and the queue thread calls it directly."""

    def __init__(self, name: str, core: WorkerCore):
        self.core = core
        super().__init__(name)

    def _exec_batch(self, requests):
        return self.core.submit_batch(requests)

    def _exec_call(self, method, args, kwargs):
        return getattr(self.core, method)(*args, **kwargs)


def _subprocess_main(conn, factory, factory_kwargs):
    """Child entry point: build the core locally, serve RPCs until EOF.
    ``factory`` must be a top-level picklable callable -> WorkerCore —
    engines/params/indexes are built in the child, never shipped."""
    try:
        core = factory(**factory_kwargs)
    except Exception as e:                   # noqa: BLE001 — reported typed
        conn.send(("fatal", _dump_exc(e)))
        return
    conn.send(("ready", None))
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            return
        if msg[0] == "__close__":
            try:
                core.close()
            except Exception:
                pass
            conn.send(("ok", None))
            return
        method, args, kwargs = msg
        try:
            conn.send(("ok", getattr(core, method)(*args, **kwargs)))
        except Exception as e:               # noqa: BLE001 — surrogate typed
            conn.send(("err", _dump_exc(e)))


class SubprocessWorker(_QueueWorker):
    """Worker whose core runs in a spawned child process.  The parent
    side keeps the same queue/coalescing machinery; execution is a
    synchronous RPC over a duplex pipe (one outstanding call — the queue
    thread is the only caller).  ``kill()`` terminates the child; the
    resulting pipe EOF surfaces as :class:`WorkerLostError`."""

    def __init__(self, name: str, factory: Callable[..., WorkerCore],
                 factory_kwargs: Optional[Dict[str, Any]] = None,
                 start_timeout: float = 300.0):
        ctx = mp.get_context("spawn")   # never fork a JAX-initialized parent
        self._conn, child = ctx.Pipe(duplex=True)
        self._proc = ctx.Process(
            target=_subprocess_main,
            args=(child, factory, dict(factory_kwargs or {})),
            daemon=True, name=f"cluster-{name}")
        self._proc.start()
        child.close()
        if not self._conn.poll(start_timeout):
            self._proc.terminate()
            raise TimeoutError(f"worker {name!r} failed to start in "
                               f"{start_timeout}s")
        tag, payload = self._conn.recv()
        if tag == "fatal":
            raise _load_exc(payload)
        super().__init__(name)

    def _rpc(self, method, args, kwargs):
        try:
            self._conn.send((method, args, kwargs))
            tag, payload = self._conn.recv()
        except (EOFError, OSError, BrokenPipeError) as e:
            raise WorkerLostError(self.name, f"pipe: {type(e).__name__}")
        if tag == "err":
            raise _load_exc(payload)
        return payload

    def _exec_batch(self, requests):
        return self._rpc("submit_batch", (requests,), {})

    def _exec_call(self, method, args, kwargs):
        if method == "close":
            try:
                self._conn.send(("__close__",))
                if self._conn.poll(10.0):
                    self._conn.recv()
            except (EOFError, OSError, BrokenPipeError):
                pass
            self._proc.join(10.0)
            return None
        return self._rpc(method, args, kwargs)

    def _shutdown_transport(self):
        try:
            self._proc.terminate()
        except Exception:
            pass

    def healthy(self) -> bool:
        return super().healthy() and self._proc.is_alive()
