"""Retrieval fan-out: corpus shards as picklable payloads + the
worker-side shard scorer.

The cluster generalizes :class:`~repro.retrieval.sharded.ShardedRetriever`
from one-process ``shard_map`` to scatter/gather across engine workers.
The split of responsibilities mirrors the mesh retriever exactly:

  * PLANNING stays on the router against the FULL index —
    :func:`~repro.retrieval.sharded.shard_layout` fixes the contiguous-row
    geometry, :func:`~repro.retrieval.sharded.shard_filter_masks` resolves
    per-request filters into shard-local packed bitmasks, and
    :func:`~repro.retrieval.sharded.plan_ivf_shards` clips probed cluster
    slices to each shard's row window.  All id mapping (``item_ids``,
    ``id_rows``) also happens on the router, so a worker never needs the
    id tables or IVF metadata — just its quantized row block.
  * SCORING happens on the worker over its (padded) row block:
    :class:`ShardScorer` runs the same ``fused_topk`` / ``ivf_topk``
    executors the engine uses, with the shard's ``row_offset`` baked in so
    partials come back with GLOBAL row indices.
  * The MERGE is the one host-side contract —
    :func:`~repro.retrieval.scorer.merge_topk`, stable lower-index-wins,
    shards in ascending row order — so the cluster result is bit-identical
    to the single-device scorer (exact) / single-device IVF scorer (ivf).

Shard payloads (:func:`make_shards`) are plain-numpy dataclasses: small
enough to pickle through a ``multiprocessing`` pipe to subprocess workers,
self-contained enough that a re-shard after a worker death is just
``make_shards(index, n_survivors)`` + one ``attach_shard`` per survivor.

Zero-recompile discipline: the scorer ALWAYS passes a pushdown mask
(all-zeros when the request carries no filters), so filtered and
unfiltered traffic share one executor per (k, Q-bucket[, S]) — the same
convention the engine's retrieval executors use.  :meth:`ShardScorer.warm`
precompiles the ladder; ``compiles`` counts builds so tests can pin
post-warmup compiles to zero on every worker.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.retrieval.scorer import chunk_topk, merge_topk, _round_up
from repro.retrieval.sharded import shard_layout


def q_bucket(n: int, *, floor: int = 8) -> int:
    """Next power-of-two query-count bucket (>= ``floor``) — the router
    pads query blocks to these so every shard executor shape is drawn
    from a small warmed ladder."""
    b = floor
    while b < n:
        b *= 2
    return b


def default_slice_rows(ivf) -> int:
    """The IVF slice width the subsystem standardizes on for a given
    coarse quantizer — same formula as the mesh retriever and the engine,
    so router plans and worker executors agree."""
    return int(min(4096, max(32, _round_up(max(ivf.max_cluster_rows(), 1),
                                           32))))


@dataclasses.dataclass
class ShardSpec:
    """One worker's slice of the corpus: quantized rows [lo, lo+rows) of
    the physical (possibly IVF-permuted) layout, zero-padded to the
    common ``rows_per_shard``.  Plain numpy throughout — picklable for
    subprocess workers."""
    shard_id: int
    n_shards: int
    lo: int                      # global row offset of this shard
    rows_per_shard: int
    n_valid: int                 # real (un-padded) rows in this shard
    bits: int
    chunk_rows: int
    block_rows: int
    slice_rows: int              # 0 when the index has no IVF build
    packed: np.ndarray           # (rows_per_shard, W) int32
    scale: np.ndarray            # (rows_per_shard, 1) fp16
    bias: np.ndarray             # (rows_per_shard, 1) fp16


def make_shards(index, n_shards: int, *, chunk_rows: int = 32768,
                block_rows: int = 32) -> List[ShardSpec]:
    """Cut ``index`` into ``n_shards`` contiguous-row payloads with the
    mesh retriever's geometry (:func:`shard_layout`); shard s owns global
    rows [s*rps, (s+1)*rps)."""
    qt = index.qt
    R = qt.packed.shape[0]
    cr, rps = shard_layout(R, n_shards, chunk_rows=chunk_rows,
                           block_rows=block_rows)
    sr = default_slice_rows(index.ivf) if index.ivf is not None else 0
    pk = np.asarray(qt.packed)
    sc = np.asarray(qt.scale, np.float16)
    bs = np.asarray(qt.bias, np.float16)

    def window(a: np.ndarray, lo: int) -> np.ndarray:
        w = a[lo:lo + rps]
        if w.shape[0] < rps:
            w = np.pad(w, ((0, rps - w.shape[0]),) + ((0, 0),) * (a.ndim - 1))
        return np.ascontiguousarray(w)

    return [ShardSpec(shard_id=s, n_shards=n_shards, lo=s * rps,
                      rows_per_shard=rps,
                      n_valid=int(np.clip(index.n_items - s * rps, 0, rps)),
                      bits=index.bits, chunk_rows=cr, block_rows=block_rows,
                      slice_rows=sr, packed=window(pk, s * rps),
                      scale=window(sc, s * rps), bias=window(bs, s * rps))
            for s in range(n_shards)]


class ShardScorer:
    """Device-side scorer for one :class:`ShardSpec` — the worker half of
    the cluster fan-out.  Returns per-shard partial top-ks with GLOBAL
    row indices; the router merges them with ``merge_topk``."""

    def __init__(self, spec: ShardSpec):
        self.spec = spec
        self.packed = jnp.asarray(spec.packed)
        self.scale = jnp.asarray(spec.scale, jnp.float16)
        self.bias = jnp.asarray(spec.bias, jnp.float16)
        # chunked views for the exact route — the same (chunk, base,
        # n_valid) operand protocol as the engine's retrieve executors,
        # so shard partials are bitwise what the engine's chunks produce
        cr = spec.chunk_rows
        self._chunks = [
            (self.packed[cb:cb + cr], self.scale[cb:cb + cr],
             self.bias[cb:cb + cr],
             jnp.asarray(spec.lo + cb, jnp.int32),
             jnp.asarray(min(spec.n_valid - cb, cr), jnp.int32), cb)
            for cb in range(0, spec.rows_per_shard, cr)]
        self._jitted: Dict[tuple, object] = {}
        self.compiles = 0

    def k_local(self, k: int) -> int:
        # a shard can contribute at most its own rows — same clip as the
        # mesh retriever, keeps the merge exact when k > rows_per_shard
        return min(int(k), self.spec.rows_per_shard)

    def _get(self, key, build):
        fn = self._jitted.get(key)
        if fn is None:
            self.compiles += 1
            fn = self._jitted[key] = build()
        return fn

    # -- exact route --------------------------------------------------------
    def _build_exact(self, k: int):
        sp = self.spec
        kc = min(int(k), sp.chunk_rows)

        def fn(q, pk, sc, bs, base, n_valid, mask):
            return chunk_topk(q, pk, sc, bs, base, n_valid, k=kc,
                              bits=sp.bits, mask=mask)

        return jax.jit(fn)

    def exact_topk(self, queries: np.ndarray, k: int,
                   mask: Optional[np.ndarray]) -> Tuple[np.ndarray,
                                                        np.ndarray]:
        """(Q, D) fp32 queries (Q already bucket-padded by the router),
        optional (Q, rows_per_shard/32) shard-local packed mask ->
        (scores (Q, k_local), GLOBAL rows (Q, k_local)) numpy.

        Runs the engine's own single-chunk executor (``chunk_topk``) over
        the shard's chunks and merges host-side — NOT a different fused
        kernel — because bit-identical scores require the identical
        contraction: same dequant-dot, same chunk shape, same Q bucket."""
        Q = queries.shape[0]
        if mask is None:       # always-mask: one executor either way
            mask = np.zeros((Q, self.spec.rows_per_shard // 32), np.int32)
        mask = np.asarray(mask, np.int32)
        fn = self._get(("exact", int(k), Q),
                       lambda: self._build_exact(k))
        q = jnp.asarray(queries, jnp.float32)
        wpc = self.spec.chunk_rows // 32
        parts = [fn(q, pk, sc, bs, base, nv,
                    jnp.asarray(mask[:, cb // 32:cb // 32 + wpc]))
                 for pk, sc, bs, base, nv, cb in self._chunks]
        s, r = merge_topk([p[0] for p in parts], [p[1] for p in parts],
                          self.k_local(k))
        return np.asarray(s), np.asarray(r)

    # -- IVF route -----------------------------------------------------------
    def _build_ivf(self, k: int, S: int):
        from repro.retrieval.ivf import ivf_topk
        sp = self.spec
        sr = sp.slice_rows

        def fn(q, off, val, mask):
            # pad the shard block by one slice so every clipped-slice
            # gather is in-bounds (same trick as the mesh retriever)
            pk = jnp.pad(self.packed, ((0, sr), (0, 0)))
            sc = jnp.pad(self.scale, ((0, sr), (0, 0)))
            bs = jnp.pad(self.bias, ((0, sr), (0, 0)))
            return ivf_topk(q, pk, sc, bs, off, val, mask,
                            k=self.k_local(k), bits=sp.bits, slice_rows=sr,
                            row_offset=sp.lo)

        return jax.jit(fn)

    def ivf_topk(self, queries: np.ndarray, off: np.ndarray,
                 val: np.ndarray, mask: Optional[np.ndarray],
                 k: int) -> Tuple[np.ndarray, np.ndarray]:
        """Shard window of a :func:`plan_ivf_shards` plan: (Q, S)
        offsets/valids in shard-local rows, optional (Q, S, sr/32) mask ->
        (scores, GLOBAL rows), each (Q, k_local) numpy."""
        assert self.spec.slice_rows, "shard built from a non-IVF index"
        Q, S = off.shape
        if mask is None:
            mask = np.zeros((Q, S, self.spec.slice_rows // 32), np.int32)
        fn = self._get(("ivf", int(k), Q, S),
                       lambda: self._build_ivf(k, S))
        s, r = fn(jnp.asarray(queries, jnp.float32),
                  jnp.asarray(off, jnp.int32), jnp.asarray(val, jnp.int32),
                  jnp.asarray(mask, jnp.int32))
        return np.asarray(s), np.asarray(r)

    # -- warmup ---------------------------------------------------------------
    def warm(self, d_query: int, ks, q_buckets, ivf_slots=()) -> int:
        """Precompile the (k, Q[, S]) ladder; returns executors built.
        After this, traffic whose shapes stay on the ladder never
        compiles — ``self.compiles`` is the audit counter."""
        before = self.compiles
        for k in ks:
            for Q in q_buckets:
                z = np.zeros((Q, d_query), np.float32)
                self.exact_topk(z, k, None)
                for S in ivf_slots:
                    off = np.zeros((Q, S), np.int32)
                    self.ivf_topk(z, off, off.copy(), None, k)
        return self.compiles - before

    def stats(self) -> Dict[str, object]:
        return {"shard_id": self.spec.shard_id, "lo": self.spec.lo,
                "rows_per_shard": self.spec.rows_per_shard,
                "n_valid": self.spec.n_valid, "compiles": self.compiles,
                "executors": len(self._jitted)}
