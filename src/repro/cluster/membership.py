"""Cluster membership: rendezvous (HRW) key routing + the live worker set.

The affinity contract of the cluster tier is a pure function: the owner
of a user key is ``argmax over workers of hash(worker, key)`` —
rendezvous / highest-random-weight hashing.  Two properties make it the
right router for a ContextCache-sharded fleet:

  * STABILITY — when a worker joins or leaves, exactly the keys whose
    argmax involves that worker move (an expected 1/N of the keyspace on
    join, the dead worker's 1/N on leave); every other key keeps its
    owner, so its pooled-embedding / ctx-KV cache entry stays hot.  No
    ring, no token table, no coordinated state: any router instance with
    the same live-worker list computes the same owner.
  * DETERMINISM — the hash is ``blake2b`` over (worker name, key bytes),
    so owners agree across processes and across restarts (test
    reproducibility; multi-router deployments route identically).

:class:`Membership` wraps the live set: ordered worker names, alive/dead
marking, and ``owner(key)`` over the alive subset.  It is intentionally
tiny — health checking and re-routing policy live in the
:class:`~repro.cluster.router.ClusterRouter`, which mutates membership
under its own lock.
"""
from __future__ import annotations

import hashlib
from typing import Dict, List, Optional, Sequence


def rendezvous_score(worker: str, key: bytes) -> int:
    """64-bit HRW weight of ``key`` on ``worker`` (deterministic across
    processes — stdlib blake2b, no PYTHONHASHSEED dependence)."""
    h = hashlib.blake2b(digest_size=8)
    h.update(worker.encode("utf-8"))
    h.update(b"\x00")
    h.update(key)
    return int.from_bytes(h.digest(), "big")


def rendezvous_owner(workers: Sequence[str], key: bytes) -> str:
    """The HRW owner of ``key`` among ``workers`` (ties — a 2^-64 event —
    break by name, so the choice is still deterministic)."""
    assert workers, "no workers to route to"
    return max(workers, key=lambda w: (rendezvous_score(w, key), w))


class Membership:
    """The router's view of the worker fleet: insertion-ordered names,
    alive/dead flags, and HRW ownership over the alive subset.  NOT
    internally locked — the owning router serializes mutations."""

    def __init__(self, names: Sequence[str] = ()):
        self._alive: Dict[str, bool] = {}
        for n in names:
            self.add(n)

    def add(self, name: str) -> None:
        if name in self._alive:
            raise ValueError(f"worker {name!r} already a member")
        self._alive[name] = True

    def mark_dead(self, name: str) -> None:
        if name not in self._alive:
            raise KeyError(name)
        self._alive[name] = False

    def remove(self, name: str) -> None:
        self._alive.pop(name)

    def alive(self) -> List[str]:
        return [n for n, ok in self._alive.items() if ok]

    def names(self) -> List[str]:
        return list(self._alive)

    def is_alive(self, name: str) -> bool:
        return self._alive.get(name, False)

    def owner(self, key: bytes) -> str:
        """HRW owner of ``key`` among the ALIVE workers — a dead worker's
        key range re-routes to the survivors automatically (each of its
        keys falls to its second-highest-weight worker)."""
        alive = self.alive()
        if not alive:
            raise RuntimeError("no alive workers in the cluster")
        return rendezvous_owner(alive, key)

    def moved_keys(self, keys: Sequence[bytes],
                   other: "Membership") -> int:
        """How many of ``keys`` route differently here vs ``other`` —
        the rebalance-cost probe the stability tests (and the rebalance
        policy) use."""
        return sum(self.owner(k) != other.owner(k) for k in keys)
