"""ClusterRouter: the cluster tier's front door — ``submit(request) ->
future`` over N engine workers.

Routing by lane:

  * rank / generate — the request's user key (the engine's ``key_fn`` or
    full sequence identity; prompt bytes for generate) picks ONE worker
    by rendezvous hash (:mod:`repro.cluster.membership`), so repeat
    users always land on the worker whose ContextCache / ctx-KV slab
    already holds them.  The worker coalesces adjacent batches into one
    engine flush.
  * retrieve / two_stage with a router-attached corpus — scatter/gather:
    the router dedupes pending requests into unique (user, filter,
    route) rows exactly like the engine's retrieve lane, fetches pooled
    user embeddings FROM EACH USER'S OWNER worker (cache affinity is
    preserved through the fan-out), scatters shard-local top-k calls to
    every worker's corpus shard, and merges the partials with the same
    stable lower-index-wins :func:`~repro.retrieval.scorer.merge_topk`
    the mesh retriever uses — so results are bit-identical to a single
    engine serving the whole corpus.  Two-stage requests then chain a
    ``RankRequest`` on the retrieved candidates back to the user's owner
    (whose cache already holds the pooled embedding), composing a
    ``TwoStageResult`` identical to the engine's fused lane —
    ``score_emb`` is row-wise in the candidates, so decomposing the
    stages across the tier changes nothing numerically.
  * without a router corpus, retrieve / two_stage route to the owner
    worker whole (each worker serves a replicated index its builder
    attached) — the single-engine fused paths, just sharded by user.

Robustness (the ``ShedError`` discipline, one tier up): a worker death
marks it dead in the membership (its key range falls to the survivors
by the rendezvous property), re-routes its queued + in-flight requests,
fails what cannot re-route with the typed
:class:`~repro.cluster.worker.WorkerLostError`, re-shards the corpus
across the survivors, and re-warms the new shard executors.  Futures
never hang.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.fanout import default_slice_rows, make_shards
from repro.cluster.membership import Membership
from repro.cluster.worker import ClusterFuture, WorkerLostError, _QueueWorker
from repro.obs import MetricsRegistry, Observability
from repro.retrieval.scorer import merge_topk
from repro.retrieval.sharded import (plan_ivf_shards, shard_filter_masks,
                                     shard_layout)
from repro.serving.plan import (BucketLadder, GenerateRequest, RankRequest,
                                RetrieveRequest, RetrieveThenRankRequest,
                                TwoStageResult, lane_of, request_key)


class _ReshardRetry(Exception):
    """Internal: a concurrent reshard (join/leave/death) invalidated a
    fan-out group's shard-layout snapshot mid-scatter — retry the group
    against the new layout.  Never escapes the router."""


def _user_key(request, key_fn) -> bytes:
    """The affinity key: the engine cache key for sequence-bearing
    requests, prompt bytes for generate."""
    if isinstance(request, GenerateRequest):
        return np.ascontiguousarray(request.prompts).tobytes()
    return key_fn(request)


class ClusterRouter:
    """Front door over a named set of cluster workers (any mix of
    :class:`~repro.cluster.worker.EngineWorker` and
    :class:`~repro.cluster.worker.SubprocessWorker`).

    Args:
      workers: ``{name: worker}`` — names are the rendezvous identities;
        keep them stable across restarts so ownership (and cache
        residency) is reproducible.
      key_fn: ``request -> bytes`` affinity key override; MUST match the
        ``key_fn`` the worker engines were built with, or affinity
        routing will warm one cache entry while flushes look up another.
      fanout_unique: unique users per fan-out dispatch group (the
        scatter batch width — every shard executor is warmed at exactly
        this query count).
      obs_enabled: router-side metrics (routed/fan-out/death counters);
        worker engines carry their own handles, aggregated by
        :meth:`merged_metrics`.

    ``n_workers`` for a deployment usually comes from the launch mesh:
    ``mesh.shape["data"]`` (``launch/mesh.py``) is the same axis the
    one-process retriever shards over.
    """

    def __init__(self, workers: Dict[str, _QueueWorker], *,
                 key_fn: Optional[Callable] = None,
                 fanout_unique: int = 8,
                 obs: Optional[Observability] = None,
                 obs_enabled: bool = True):
        assert workers, "a cluster needs at least one worker"
        self._workers: Dict[str, _QueueWorker] = dict(workers)
        self._membership = Membership(list(self._workers))
        self._key_fn = key_fn or request_key
        self._cap = int(fanout_unique)
        # the engine's own query bucketing (pow2 ladder) — groups pad to
        # fit(len(group)), NOT flat to the cap, because bit-identical
        # scores need the executor Q the single engine would have used
        self._ladder = BucketLadder(self._cap, 1)
        self._lock = threading.RLock()
        self.obs = obs if obs is not None else Observability(
            enabled=obs_enabled)
        m = self.obs.metrics
        self._m_routed = {ln: m.counter(
            "cluster_requests_total", "requests routed by the cluster "
            "router", lane=ln) for ln in ("rank", "retrieve", "two_stage",
                                          "generate")}
        self._m_groups = m.counter("cluster_fanout_groups_total",
                                   "retrieval fan-out dispatch groups")
        self._m_coalesced = m.counter(
            "cluster_fanout_coalesced_total",
            "fan-out requests deduplicated into an existing unique row")
        self._m_reroutes = m.counter("cluster_reroutes_total",
                                     "requests re-routed off a dead worker")
        self._m_deaths = m.counter("cluster_worker_deaths_total",
                                   "workers lost")
        self._m_alive = m.gauge("cluster_workers_alive",
                                "alive workers in the membership")
        self._m_alive.set(len(self._workers))
        self._m_fan_ms = m.histogram(
            "cluster_fanout_latency_ms",
            "scatter/gather wall time per fan-out group")
        # -- corpus fan-out state (attach_index) --
        self._index = None
        self._retrieve_k = 0
        self._tab = None            # SliceTable of the attached IVF build
        self._ivf_levels: List[int] = []
        self._n_tail = 0
        self._shard_order: List[str] = []   # worker name per ascending shard
        self._rows_per_shard = 0
        self._shard_gen = 0     # bumped by every reshard; fan-out groups
        # snapshot it and retry if it moved mid-scatter (a join/leave
        # would otherwise silently truncate an unfiltered exact top-k)
        # -- fan-out thread --
        self._fan_cv = threading.Condition()
        self._fan_items: deque = deque()
        self._closing = False
        self._fan_thread = threading.Thread(
            target=self._fan_loop, daemon=True, name="cluster-fanout")
        self._fan_thread.start()

    # ======================================================================
    # public surface
    # ======================================================================
    def submit(self, request) -> ClusterFuture:
        """Enqueue one typed request — the engine's ``submit`` contract,
        one tier up.  Returns a :class:`ClusterFuture` that resolves to
        the same payload the owning engine would produce."""
        lane = lane_of(request)
        self._m_routed[lane].inc()
        fut = ClusterFuture()
        if lane in ("retrieve", "two_stage") and self._index is not None:
            with self._fan_cv:
                if self._closing:
                    fut._set_error(WorkerLostError("<router>", "closed"))
                    return fut
                self._fan_items.append((request, fut))
                self._fan_cv.notify()
            return fut
        self._route_to_owner(request, fut)
        return fut

    def submit_many(self, requests: Sequence) -> List[ClusterFuture]:
        return [self.submit(r) for r in requests]

    def flush(self, timeout: float = 120.0) -> None:
        """Wait until the fan-out queue and every worker queue drain."""
        import time
        t0 = time.monotonic()
        while time.monotonic() - t0 < timeout:
            with self._fan_cv:
                fan_idle = not self._fan_items and not self._fan_busy
            if fan_idle and all(w.idle() for w in self._alive_workers()
                                .values()):
                return
            time.sleep(0.002)
        raise TimeoutError("cluster flush did not drain in time")

    def attach_features(self, fn: Callable) -> None:
        """Candidate-feature fetcher for DECOMPOSED two-stage requests
        (``ids -> (n, F_c)``): the router builds each rank stage's
        ``cand_feats`` with it.  Worker engines serving replicated fused
        two-stage keep their own ``attach_features``."""
        self._features_fn = fn

    def attach_index(self, index, *, k: int = 100,
                     chunk_rows: int = 32768, block_rows: int = 32,
                     ivf_nprobe: int = 8, ivf_widen: int = 2) -> None:
        """Attach a corpus for CLUSTER-SHARDED retrieval: each alive
        worker gets one contiguous-row shard
        (:func:`~repro.cluster.fanout.make_shards`); retrieve/two-stage
        traffic then fans out instead of routing whole to an owner.  The
        full index stays router-side for planning (filters, IVF probes)
        and id mapping; only quantized row blocks ship to workers.  An
        IVF-built index additionally serves ``route="ivf"`` — requested
        nprobes round up the same ``ivf_nprobe * 2**j`` level ladder the
        engine uses, so per-request results match a single engine
        attach-for-attach."""
        assert 0 < k <= index.n_items
        with self._lock:
            self._index = index
            self._retrieve_k = int(k)
            self._chunk_rows, self._block_rows = chunk_rows, block_rows
            self._tab, self._ivf_levels, self._n_tail = None, [], 0
            if index.ivf is not None:
                from repro.retrieval.ivf import SliceTable
                ivf = index.ivf
                sr = default_slice_rows(ivf)
                self._tab = SliceTable(ivf, sr)
                C = ivf.n_clusters
                base = int(min(max(1, ivf_nprobe), C))
                self._ivf_levels = sorted(
                    {min(base * 2 ** j, C)
                     for j in range(max(0, ivf_widen) + 1)})
                self._n_tail = len(range(ivf.n_clustered, index.n_items, sr))
            self._reshard_locked(warm=False)

    def warmup(self, *, seq_len: Optional[int] = None) -> Dict[str, dict]:
        """Warm every worker in parallel: the engine's own warmup ladder
        plus (with a router corpus) the shard executors at the fan-out
        query width.  -> {worker: engine warmup telemetry}."""
        with self._lock:
            futs = {n: w.call_async("warmup", seq_len=seq_len)
                    for n, w in self._alive_workers().items()}
        out = {n: f.result() for n, f in futs.items()}
        self._warm_shards()
        return out

    def stats(self) -> dict:
        """Router + per-worker telemetry (worker entries are each
        engine's pinned ``stats()`` dict plus shard-scorer counters)."""
        with self._lock:
            alive = self._alive_workers()
            snap = {
                "workers": {n: ("alive" if self._membership.is_alive(n)
                                else "dead") for n in self._workers},
                "n_alive": len(alive),
                "sharded_corpus": self._index is not None,
                "rows_per_shard": self._rows_per_shard,
                "routed": {ln: c.get() for ln, c in self._m_routed.items()},
                "fanout_groups": self._m_groups.get(),
                "fanout_coalesced": self._m_coalesced.get(),
                "reroutes": self._m_reroutes.get(),
                "deaths": self._m_deaths.get(),
            }
            futs = {n: w.call_async("stats") for n, w in alive.items()}
        per = {}
        for n, f in futs.items():
            try:
                per[n] = f.result()
            except WorkerLostError as e:
                # died between the snapshot and the reply — telemetry for
                # the survivors must stay available during a death window
                per[n] = {"error": str(e)}
        snap["per_worker"] = per
        return snap

    def merged_metrics(self, namespace: str = "repro") -> MetricsRegistry:
        """One cluster-wide :class:`MetricsRegistry`: the router's own
        registry plus every IN-PROCESS worker engine's, each folded in
        under a ``worker`` label (``MetricsRegistry.merge``).  Subprocess
        workers export snapshots instead (``obs_snapshot`` RPC) — merge
        those offline with ``tools/dump_obs.py --merge``."""
        reg = MetricsRegistry(namespace=namespace)
        if isinstance(self.obs.metrics, MetricsRegistry):
            reg.merge(self.obs.metrics, labels={"worker": "router"})
        with self._lock:
            cores = [(n, getattr(w, "core", None))
                     for n, w in self._alive_workers().items()]
        for n, core in cores:
            if core is None:        # subprocess: registry lives remotely
                continue
            m = core.engine.obs.metrics
            if isinstance(m, MetricsRegistry):
                reg.merge(m, labels={"worker": n})
        return reg

    def check_health(self) -> List[str]:
        """Probe every member; handle (and return) the ones found dead."""
        lost = []
        for n, w in list(self._alive_workers().items()):
            if not w.healthy():
                self._on_worker_lost(n, "health check")
                lost.append(n)
        return lost

    def add_worker(self, name: str, worker: _QueueWorker) -> None:
        """Join a worker: it takes over its rendezvous share (~1/N) of
        the key space; with a router corpus the shards re-cut and
        re-warm.  Everyone else's keys — and cache entries — stay put."""
        with self._lock:
            self._membership.add(name)
            self._workers[name] = worker
            self._m_alive.set(len(self._membership.alive()))
            if self._index is not None:
                self._reshard_locked(warm=True)

    def remove_worker(self, name: str) -> None:
        """Graceful leave: stop routing to it, drain its queue, close
        it, re-shard without it."""
        with self._lock:
            self._membership.mark_dead(name)
            self._m_alive.set(len(self._membership.alive()))
            w = self._workers[name]
        w.join_idle()
        w.close()
        with self._lock:
            self._membership.remove(name)
            del self._workers[name]
            if self._index is not None and self._membership.alive():
                self._reshard_locked(warm=True)

    def kill_worker(self, name: str) -> None:
        """Hard-kill a worker (the drain-test hook): simulate a crash,
        then run the death path — re-route its pending requests and
        re-shard."""
        self._workers[name].kill()
        self._on_worker_lost(name, "killed")

    def close(self) -> None:
        with self._fan_cv:
            self._closing = True
            self._fan_cv.notify()
        self._fan_thread.join(30.0)
        for w in self._workers.values():
            w.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # ======================================================================
    # owner routing
    # ======================================================================
    def _alive_workers(self) -> Dict[str, _QueueWorker]:
        return {n: self._workers[n] for n in self._membership.alive()}

    def owner_of(self, request) -> str:
        """The worker this request's user key routes to (exposed for
        affinity tests and traffic shaping)."""
        return self._membership.owner(_user_key(request, self._key_fn))

    def _route_to_owner(self, request, fut: ClusterFuture,
                        retried: bool = False) -> None:
        key = _user_key(request, self._key_fn)
        rerouted = retried      # True once any death forced a re-route
        for _ in range(len(self._workers) + 1):
            with self._lock:
                alive = self._membership.alive()
                if not alive:
                    break
                owner = self._membership.owner(key)
                w = self._workers[owner]
            if w.submit_batch([(request, fut)]):
                if rerouted:    # counted at the successful re-submit, so
                    self._m_reroutes.inc()   # fresh submits that lose the
                return          # death race are counted too
            # lost the race with a death: run the death path and retry
            self._on_worker_lost(owner, "dead at submit")
            rerouted = True
        fut._set_error(WorkerLostError("<cluster>", "no alive workers"))

    # ======================================================================
    # death path
    # ======================================================================
    def _on_worker_lost(self, name: str, reason: str) -> None:
        """Membership out, pending re-routed, corpus re-cut, shard
        executors re-warmed.  Idempotent per worker."""
        with self._lock:
            if not self._membership.is_alive(name):
                return
            self._membership.mark_dead(name)
            self._m_deaths.inc()
            self._m_alive.set(len(self._membership.alive()))
            w = self._workers[name]
        w.kill(reason)
        pending = w.take_pending()
        with self._lock:
            if self._index is not None and self._membership.alive():
                self._reshard_locked(warm=True)
        for r, f in pending:
            lane = lane_of(r)
            if lane in ("retrieve", "two_stage") and self._index is not None:
                self._m_reroutes.inc()
                with self._fan_cv:
                    self._fan_items.append((r, f))
                    self._fan_cv.notify()
            else:   # _route_to_owner counts the re-route on re-submit
                self._route_to_owner(r, f, retried=True)

    def _reshard_locked(self, warm: bool) -> None:
        """Re-cut the corpus across the alive workers (ascending shard =
        alive order, so the merge's lower-index-wins tie-break is the
        global row order) and optionally re-warm the shard executors."""
        self._shard_gen += 1
        alive = self._membership.alive()
        specs = make_shards(self._index, len(alive),
                            chunk_rows=self._chunk_rows,
                            block_rows=self._block_rows)
        _, self._rows_per_shard = shard_layout(
            self._index.qt.packed.shape[0], len(alive),
            chunk_rows=self._chunk_rows, block_rows=self._block_rows)
        futs = [self._workers[n].call_async("attach_shard", spec)
                for n, spec in zip(alive, specs)]
        for f in futs:
            f.result()
        self._shard_order = list(alive)
        if warm:
            self._warm_shards()

    def _ivf_slots(self) -> List[int]:
        return [self._tab.slots(p) + self._n_tail for p in self._ivf_levels]

    def _warm_shards(self) -> None:
        if self._index is None:
            return
        with self._lock:
            names = list(self._shard_order)
            futs = [self._workers[n].call_async(
                        "warm_shard", self._index.dim, [self._retrieve_k],
                        list(self._ladder.sizes()), self._ivf_slots())
                    for n in names]
        for f in futs:
            try:
                f.result()
            except WorkerLostError:
                pass    # its death path will re-shard + re-warm again

    def _ivf_level(self, nprobe: Optional[int]) -> int:
        levels = self._ivf_levels
        if nprobe is None:
            return levels[0]
        for p in levels:
            if p >= nprobe:
                return p
        return levels[-1]

    # ======================================================================
    # retrieval fan-out
    # ======================================================================
    _fan_busy = False

    def _fan_loop(self) -> None:
        while True:
            with self._fan_cv:
                while not self._fan_items and not self._closing:
                    self._fan_cv.wait()
                if self._closing:
                    for r, f in self._fan_items:
                        f._set_error(WorkerLostError("<router>", "closed"))
                    self._fan_items.clear()
                    return
                batch = list(self._fan_items)
                self._fan_items.clear()
                self._fan_busy = True
            try:
                self._fan_process(batch)
            except Exception as e:   # noqa: BLE001 — the loop must survive
                # anything escaping the batch machinery resolves the whole
                # batch typed (first-writer-wins drops already-set futures)
                # so the daemon keeps draining and futures never hang
                for _, f in batch:
                    f._set_error(e)
            finally:
                with self._fan_cv:
                    self._fan_busy = False

    def _fan_process(self, batch: List[tuple]) -> None:
        """Dedupe a drained fan-out batch into unique (user, filter,
        route) rows — the engine's retrieve-lane grouping, router-side —
        then dispatch route-uniform groups of <= fanout_unique."""
        from repro.retrieval.filters import ItemFilter
        uniq: Dict[tuple, int] = {}
        rows: List[dict] = []
        for r, f in batch:
            try:
                filt = ItemFilter(
                    exclude_ids=r.exclude_ids,
                    allow_surfaces=(None if r.allow_surfaces is None
                                    else tuple(r.allow_surfaces)))
                filt = None if filt.is_empty() else filt
                route = getattr(r, "route", "exact")
                conf = (("ivf", self._ivf_level(getattr(r, "nprobe", None)))
                        if route == "ivf" else ("exact", None))
                key = self._key_fn(r)
                fp = filt.fingerprint() if filt is not None else b""
            except Exception as e:   # noqa: BLE001 — malformed request:
                f._set_error(e)      # fail it alone, keep its batchmates
                continue
            u = uniq.setdefault((key, fp, conf), len(rows))
            if u == len(rows):
                rows.append({"req": r, "key": key, "filt": filt,
                             "conf": conf, "members": []})
            else:
                self._m_coalesced.inc()
            rows[u]["members"].append((r, f))
        by_conf: Dict[tuple, List[int]] = {}
        order = []
        for u, row in enumerate(rows):
            if row["conf"] not in by_conf:
                by_conf[row["conf"]] = []
                order.append(row["conf"])
            by_conf[row["conf"]].append(u)
        for conf in order:
            us = by_conf[conf]
            for g0 in range(0, len(us), self._cap):
                group = [rows[u] for u in us[g0:g0 + self._cap]]
                self._fan_group(conf, group)

    def _fan_group(self, conf: tuple, group: List[dict]) -> None:
        """One scatter/gather: owner-affine encode, per-shard top-k,
        lower-index-wins merge, resolve.  A worker death inside the
        group re-shards and retries the group on the survivors; a
        concurrent join/leave reshard retries against the new layout;
        any other error resolves the group's futures typed — no
        exception may escape to the fan-out thread."""
        import time
        t0 = time.monotonic()
        self._m_groups.inc()
        err: Optional[BaseException] = None
        deaths = reshards = 0
        while deaths <= len(self._workers) and reshards <= 16:
            try:
                self._fan_group_once(conf, group)
                self._m_fan_ms.record((time.monotonic() - t0) * 1e3)
                return
            except _ReshardRetry:
                reshards += 1       # operator-rate events; 16 is generous
            except WorkerLostError as e:
                deaths += 1
                err = e
                if e.worker in self._workers:
                    self._on_worker_lost(e.worker, "fan-out")
                if not self._membership.alive():
                    err = WorkerLostError("<cluster>", "no alive workers")
                    break
            except Exception as e:   # noqa: BLE001 — typed on the futures
                err = e              # genuine error (bad request, engine
                break                # bug): fail the group, keep the loop
        if err is None:
            err = WorkerLostError("<cluster>", "fan-out retries exhausted")
        for row in group:
            for _, f in row["members"]:
                f._set_error(err)

    def _fan_group_once(self, conf: tuple, group: List[dict]) -> None:
        index, k = self._index, self._retrieve_k
        cap = self._ladder.fit(len(group))      # the engine's b_q
        with self._lock:
            names = list(self._shard_order)
            workers = dict(self._workers)
            rps = self._rows_per_shard
            gen = self._shard_gen
        n_shards = len(names)
        # -- owner-affine encode (cache residency follows the HRW owner) --
        by_owner: Dict[str, List[int]] = {}
        for j, row in enumerate(group):
            by_owner.setdefault(self._membership.owner(row["key"]),
                                []).append(j)
        emb = np.zeros((len(group), index.dim), np.float32)
        efuts = []
        for owner, idxs in by_owner.items():
            w = workers.get(owner)
            if w is None or not self._membership.is_alive(owner):
                raise WorkerLostError(owner or "<cluster>", "owner gone")
            efuts.append((owner, idxs, w.call_async(
                "encode_users", [group[j]["req"] for j in idxs])))
        for owner, idxs, f in efuts:
            e = np.asarray(f.result(), np.float32)
            for pos, j in enumerate(idxs):
                emb[j] = e[pos]
        q = np.zeros((cap, index.dim), np.float32)
        q[:len(group)] = emb
        filts = [row["filt"] for row in group]
        # -- plan + scatter --
        if conf[0] == "exact":
            masks = shard_filter_masks(index, filts + [None] *
                                       (cap - len(group)), cap,
                                       n_shards, rps)
            sfuts = [workers[n].call_async(
                        "shard_topk", "exact", q, k,
                        mask=None if masks is None else masks[s])
                     for s, n in enumerate(names)]
        else:
            off, val, masks, S = plan_ivf_shards(
                index, self._tab, emb, conf[1], filts, n_shards, rps)
            padq = cap - len(group)

            def padQ(a):
                if a is None or padq == 0:
                    return a
                pad = [(0, 0)] * a.ndim
                pad[1] = (0, padq)
                return np.pad(a, pad)
            off, val, masks = padQ(off), padQ(val), padQ(masks)
            sfuts = [workers[n].call_async(
                        "shard_topk", "ivf", q, k, off=off[s], val=val[s],
                        mask=None if masks is None else masks[s])
                     for s, n in enumerate(names)]
        try:
            parts = [f.result() for f in sfuts]
        except WorkerLostError:
            raise
        except Exception:
            # a reshard racing the scatter can surface as a shard-side
            # error (e.g. filter-mask width vs the re-cut shard) — if the
            # layout moved under us, that is retryable, not terminal
            with self._lock:
                if self._shard_gen != gen:
                    raise _ReshardRetry() from None
            raise
        with self._lock:
            if self._shard_gen != gen:
                # the layout changed mid-scatter: workers may have scored
                # re-cut shards against our old snapshot (an unfiltered
                # exact route would return a silently incomplete top-k) —
                # discard the partials and retry on the new layout
                raise _ReshardRetry()
        # -- gather + merge (ascending shard = ascending global rows) --
        scores, rows_m = merge_topk([p[0] for p in parts],
                                    [p[1] for p in parts], k)
        scores, rows_m = scores[:len(group)], rows_m[:len(group)]
        if scores.shape[-1] < k:     # tiny shards: k > sum of k_locals
            padw = k - scores.shape[-1]
            scores = np.pad(scores, ((0, 0), (0, padw)),
                            constant_values=-np.inf)
            rows_m = np.pad(rows_m, ((0, 0), (0, padw)),
                            constant_values=-1)
        if conf[0] == "ivf":         # unvisited rows have no honest index
            rows_m = np.where(scores == -np.inf, -1, rows_m)
        # -- resolve --
        for j, row in enumerate(group):
            ids_full = index.item_ids(rows_m[j])
            for r, f in row["members"]:
                ids, sc = ids_full[:r.k], scores[j, :r.k]
                if isinstance(r, RetrieveThenRankRequest):
                    self._chain_rank(r, f, ids, sc)
                else:
                    f._set((ids, sc))

    def _chain_rank(self, r: RetrieveThenRankRequest, fut: ClusterFuture,
                    ids: np.ndarray, retr_scores: np.ndarray) -> None:
        """Second stage of a decomposed two-stage request: rank the
        retrieved candidates on the user's owner worker (cache-resident
        pooled embedding) and compose the ``TwoStageResult``."""
        feats_fn = r.cand_feats_fn or getattr(self, "_features_fn", None)
        if feats_fn is None:
            fut._set_error(ValueError(
                "two-stage fan-out needs cand_feats_fn on the request or "
                "router.attach_features()"))
            return
        try:
            feats = np.asarray(feats_fn(ids), np.float32)
        except Exception as e:       # noqa: BLE001 — typed on the future
            fut._set_error(e)
            return
        rank_req = RankRequest(
            seq_ids=r.seq_ids, seq_actions=r.seq_actions,
            seq_surfaces=r.seq_surfaces, cand_ids=np.asarray(ids, np.int64),
            cand_feats=feats, user_feats=r.user_feats, priority=r.priority)
        rank_fut = ClusterFuture()

        def compose(rf: ClusterFuture):
            try:
                probs = rf.result(timeout=0)
            except Exception as e:   # noqa: BLE001 — typed passthrough
                fut._set_error(e)
                return
            fut._set(TwoStageResult(item_ids=ids,
                                    retrieval_scores=retr_scores,
                                    probs=np.asarray(probs)))

        rank_fut.add_done_callback(compose)
        self._route_to_owner(rank_req, rank_fut)
