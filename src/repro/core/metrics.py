"""Ranking metrics.  HIT@3 (paper §5.1): for each recommendation group, how
many of the model's top-3 scored items received the user action.

This module is MODEL-QUALITY metrics (offline evaluation).  Serving
observability — latency histograms, counters, Prometheus export — is a
different subsystem: ``repro/obs/metrics.py`` (package ``repro.obs``).
The two are deliberately separate packages so neither import shadows
the other; grep for ``repro.obs`` when you want per-lane p50/p99, and
here when you want HIT@k."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hit_at_k(scores, labels, *, k: int = 3):
    """scores/labels: (n_groups, group_size).  Returns mean over groups of
    (number of top-k items with label==1) / k."""
    _, idx = jax.lax.top_k(scores, k)
    picked = jnp.take_along_axis(labels.astype(jnp.float32), idx, axis=-1)
    return jnp.mean(jnp.sum(picked, axis=-1) / k)


def grouped_hit_at_k(scores, labels, group_ids, *, k: int = 3,
                     num_groups: int | None = None):
    """Variable-group variant via segment ops; group_ids must be 0..G-1."""
    import numpy as np
    scores = np.asarray(scores); labels = np.asarray(labels)
    group_ids = np.asarray(group_ids)
    hits, total = 0.0, 0
    for g in np.unique(group_ids):
        m = group_ids == g
        s, l = scores[m], labels[m]
        kk = min(k, len(s))
        top = np.argsort(-s)[:kk]
        hits += l[top].sum() / kk
        total += 1
    return hits / max(total, 1)
