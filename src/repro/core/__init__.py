"""PinFM core: the paper's contribution (pretrain model, InfoNCE losses,
DCAT, fine-tune ranking integration)."""
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.core.losses import LossConfig, pinfm_losses
from repro.core.dcat import DCAT, DCATOptions, dedup, dedup_inverse, dedup_stats
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.metrics import hit_at_k
