"""PinFM pretraining model (paper §3.1).

    H = phi_out( M( phi_in( E + V + A ) ) )            (eq. 1)
    z_j = psi( emb(id_j) )

E: hashed-multi-table id embeddings; V: surface embeddings; A: action
embeddings; M: any decoder backbone (GPT2 Pre-LN by default — backbone is
pluggable per DESIGN.md §5); phi_in/phi_out/psi: pointwise MLP + l2 norm.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.embeddings import HashedIDEmbedding
from repro.core.losses import LossConfig, learnable_tau, pinfm_losses
from repro.models.config import ModelConfig, get_config
from repro.models.transformer import TransformerBody
from repro.nn.layers import Embedding, PointwiseMLPNorm
from repro.nn.module import Module, Param


@dataclasses.dataclass
class PinFMConfig:
    backbone: str = "pinfm-20b"
    n_tables: int = 8
    rows: int = 80_000_000
    sub_dim: int = 32
    action_vocab: int = 16
    surface_vocab: int = 8
    seq_len: int = 256            # L: pretraining segment length
    loss: LossConfig = dataclasses.field(default_factory=LossConfig)
    # positive-action ids (paper Table 4 ablates this set)
    pos_actions: Tuple[int, ...] = (1, 2, 3)     # e.g. save, download, clickthrough
    tau_init: float = 0.05

    @property
    def id_dim(self) -> int:
        return self.n_tables * self.sub_dim

    def backbone_config(self) -> ModelConfig:
        return get_config(self.backbone)

    def replace(self, **kw):
        return dataclasses.replace(self, **kw)


class PinFMPretrain(Module):
    def __init__(self, cfg: PinFMConfig,
                 backbone_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.bb = backbone_cfg or cfg.backbone_config()
        dtype = self.bb.pdtype()
        d = self.bb.d_model
        self.id_embed = HashedIDEmbedding(cfg.n_tables, cfg.rows, cfg.sub_dim,
                                          dtype=dtype)
        self.action_embed = Embedding(cfg.action_vocab, cfg.id_dim,
                                      axes=(None, "embed"), dtype=dtype)
        self.surface_embed = Embedding(cfg.surface_vocab, cfg.id_dim,
                                       axes=(None, "embed"), dtype=dtype)
        self.phi_in = PointwiseMLPNorm(cfg.id_dim, d, dtype=dtype, l2=True)
        self.body = TransformerBody(self.bb)
        self.phi_out = PointwiseMLPNorm(d, cfg.id_dim, dtype=dtype, l2=True)
        self.psi = PointwiseMLPNorm(cfg.id_dim, cfg.id_dim, dtype=dtype, l2=True)
        if self.bb.pos_emb == "learned":
            self.pos_embed = Embedding(min(self.bb.max_seq, 16384), d,
                                       axes=(None, "embed"), dtype=dtype)

    def spec(self):
        s = {
            "id_embed": self.id_embed.spec(),
            "action_embed": self.action_embed.spec(),
            "surface_embed": self.surface_embed.spec(),
            "phi_in": self.phi_in.spec(),
            "body": self.body.spec(),
            "phi_out": self.phi_out.spec(),
            "psi": self.psi.spec(),
            "log_tau": Param((), jnp.float32, (),
                             lambda k, sh, d: jnp.asarray(
                                 jnp.log(self.cfg.tau_init), d)),
        }
        if self.bb.pos_emb == "learned":
            s["pos_embed"] = self.pos_embed.spec()
        return s

    # -- encoding -----------------------------------------------------------
    def event_embed(self, p, ids, actions, surfaces):
        """E + V + A -> (B, L, id_dim)."""
        e = self.id_embed(p["id_embed"], ids)
        v = self.surface_embed(p["surface_embed"], surfaces)
        a = self.action_embed(p["action_embed"], actions)
        return e + v + a

    def input_tokens(self, p, ids, actions, surfaces, positions=None):
        x = self.phi_in(p["phi_in"], self.event_embed(p, ids, actions, surfaces))
        x = x.astype(self.bb.cdtype())
        if self.bb.pos_emb == "learned":
            B, L = ids.shape[0], ids.shape[1]
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(L), (B, L))
            cap = self.pos_embed.vocab
            x = x + self.pos_embed(p["pos_embed"], positions % cap).astype(x.dtype)
        return x

    def encode(self, p, ids, actions, surfaces, *, collect_ctx: bool = False,
               positions=None):
        """-> (H: (B, L, id_dim), aux, ctxs)."""
        B, L = ids.shape
        x = self.input_tokens(p, ids, actions, surfaces, positions)
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(L), (B, L))
        y, aux, ctxs = self.body.forward(p["body"], x, positions,
                                         collect_ctx=collect_ctx)
        H = self.phi_out(p["phi_out"], y.astype(jnp.float32))
        return H, aux, ctxs

    def targets(self, p, ids):
        """z = psi(emb(id)) -> (B, L, id_dim)."""
        e = self.id_embed(p["id_embed"], ids)
        return self.psi(p["psi"], e.astype(jnp.float32))

    # -- pretraining loss ------------------------------------------------------
    def pos_action_mask(self, actions):
        m = jnp.zeros_like(actions, dtype=bool)
        for a in self.cfg.pos_actions:
            m |= actions == a
        return m

    def loss(self, p, batch):
        """batch: ids/actions/surfaces (B, L) int32, valid (B, L) bool,
        user_id (B,) int32."""
        H, aux, _ = self.encode(p, batch["ids"], batch["actions"],
                                batch["surfaces"])
        z = self.targets(p, batch["ids"])
        tau = learnable_tau(p["log_tau"], self.cfg.loss)
        pos = self.pos_action_mask(batch["actions"])
        total, metrics = pinfm_losses(
            H, z, pos, batch["valid"].astype(bool), batch["user_id"], tau,
            self.cfg.loss)
        return total, metrics
