"""PinFM fine-tuning: integration into a downstream multi-task ranking model
(paper §3.2, §5.1 Tables 1-3).

The ranking model is a DCN-v2-family multi-task classifier.  PinFM enters as
a *module*: the pretrained transformer + tables encode the (deduplicated)
user activity sequence; depending on the input-sequence variant the candidate
item is fused early (appended to the sequence, scored via DCAT crossing) or
late (pooled user embedding only):

  variant            candidate in sequence   extra features
  ------------------ ----------------------- -------------------------------
  base               yes (early fusion)      y_cand, emb(cand)
  graphsage          yes                     + GraphSAGE summed into cand tok
  graphsage-lt       yes                     + learnable token output
  lite-mean          no  (late fusion)       mean-pool(H_u), emb(cand)
  lite-last          no                      H_u[:, -1], emb(cand)

Cold-start techniques (Table 2): Candidate-Item-Randomization (CIR, 10% of
candidate ids replaced by random ids during training) and Item-age-Dependent
Dropout (IDD, p=0.7 on PinFM outputs for items <7d old, p=0.5 for 7-28d).

Auxiliary losses (paper §3.2): sequence losses (L_ntl/L_mtl) on the module,
ranking losses applied directly to the module output via a small head, and
an MSE loss aligning module-head and final predictions.  The pretrained
module trains at ~1/10 LR (see AdamWConfig.lr_mults).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.dcat import DCAT, DCATOptions
from repro.core.losses import LossConfig, learnable_tau, pinfm_losses
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.nn.layers import Linear, PointwiseMLPNorm, _ACT
from repro.nn.module import Module, Param, fan_in_init, normal_init, zeros_init

VARIANTS = ("base", "graphsage", "graphsage-lt", "lite-mean", "lite-last")


@dataclasses.dataclass
class FinetuneConfig:
    variant: str = "graphsage-lt"
    n_tasks: int = 3                  # e.g. save, click, hide
    user_feat_dim: int = 32
    cand_feat_dim: int = 32
    graphsage_dim: int = 64
    seq_len: int = 128                # L_d — downstream real-time sequence
    hidden: int = 256
    n_cross_layers: int = 3
    # cold start
    cir_prob: float = 0.10
    idd_p_fresh: float = 0.7          # item age < 7d
    idd_p_mid: float = 0.5            # 7d <= age < 28d
    use_cir: bool = True
    use_idd: bool = True
    # aux losses
    use_seq_loss: bool = True         # L_ntl during fine-tuning
    seq_loss: LossConfig = dataclasses.field(
        default_factory=lambda: LossConfig(use_mtl=False, use_ftl=False))
    use_module_head: bool = True      # ranking loss on module outputs + MSE align
    align_weight: float = 0.1
    gs_align_weight: float = 0.01     # align projected GraphSAGE to emb space
    dcat: DCATOptions = dataclasses.field(default_factory=DCATOptions)


class CrossNetwork(Module):
    """DCN-v2 cross layers: x_{l+1} = x0 * (W x_l + b) + x_l."""

    def __init__(self, dim: int, n_layers: int, dtype=jnp.float32):
        self.dim, self.n_layers, self.dtype = dim, n_layers, dtype

    def spec(self):
        return {f"l{i}": {
            "w": Param((self.dim, self.dim), self.dtype, ("embed", "mlp"),
                       fan_in_init(0)),
            "b": Param((self.dim,), self.dtype, ("mlp",), zeros_init)}
            for i in range(self.n_layers)}

    def __call__(self, p, x0):
        x = x0
        for i in range(self.n_layers):
            w, b = p[f"l{i}"]["w"], p[f"l{i}"]["b"]
            x = x0 * (x @ w + b) + x
        return x


class PinFMRankingModel(Module):
    """Downstream ranking model with PinFM integrated as a module.

    Parameter tree is split into {"pinfm": ..., "ranker": ...} so the
    optimizer can apply the 1/10 LR multiplier to the pretrained module.
    """

    def __init__(self, pinfm_cfg: PinFMConfig, cfg: FinetuneConfig):
        assert cfg.variant in VARIANTS
        self.pcfg, self.cfg = pinfm_cfg, cfg
        self.pinfm = PinFMPretrain(pinfm_cfg)
        d_model = self.pinfm.bb.d_model
        id_dim = pinfm_cfg.id_dim
        dtype = self.pinfm.bb.pdtype()
        self.dcat = DCAT(self.pinfm.body, cfg.dcat)
        self.gs_proj = Linear(cfg.graphsage_dim, id_dim, axes=(None, "embed"),
                              dtype=dtype)
        # PinFM feature block: outputs fed into feature crossing
        n_feat = {"base": 2, "graphsage": 2, "graphsage-lt": 3,
                  "lite-mean": 2, "lite-last": 2}[cfg.variant]
        feat_dim = n_feat * id_dim
        in_dim = cfg.user_feat_dim + cfg.cand_feat_dim + feat_dim
        self.in_proj = Linear(in_dim, cfg.hidden, axes=(None, "embed"),
                              bias=True, dtype=dtype)
        self.cross = CrossNetwork(cfg.hidden, cfg.n_cross_layers, dtype=dtype)
        self.mlp_mid = Linear(cfg.hidden, cfg.hidden, axes=("embed", "mlp"),
                              bias=True, dtype=dtype)
        self.heads = Linear(cfg.hidden, cfg.n_tasks, axes=("mlp", None),
                            bias=True, dtype=dtype)
        self.module_head = Linear(feat_dim, cfg.n_tasks, axes=(None, None),
                                  bias=True, dtype=dtype)

    def spec(self):
        return {
            "pinfm": self.pinfm.spec(),
            "ranker": {
                "gs_proj": self.gs_proj.spec(),
                "in_proj": self.in_proj.spec(),
                "cross": self.cross.spec(),
                "mlp_mid": self.mlp_mid.spec(),
                "heads": self.heads.spec(),
                "module_head": self.module_head.spec(),
                "learnable_token": Param(
                    (self.pcfg.id_dim,), self.pinfm.bb.pdtype(), ("embed",),
                    normal_init(0.02)),
            },
        }

    # ------------------------------------------------------------------
    def _candidate_tokens(self, p, cand_ids, graphsage):
        """Build the crossing input sequence for each candidate.
        -> (B_c, S_c, d_model), S_c = 2 for graphsage-lt ([LT, cand]) else 1.
        Also returns the raw candidate event embedding (pre-phi_in) and the
        projected GraphSAGE embedding (for the alignment loss)."""
        cfg = self.cfg
        pf, pr = p["pinfm"], p["ranker"]
        e_c = self.pinfm.id_embed(pf["id_embed"], cand_ids)          # (B_c, id_dim)
        gs_e = None
        if cfg.variant in ("graphsage", "graphsage-lt") and graphsage is not None:
            gs_e = self.gs_proj(pr["gs_proj"], graphsage)
            e_c = e_c + gs_e
        toks = [e_c[:, None, :]]
        if cfg.variant == "graphsage-lt":
            lt = jnp.broadcast_to(pr["learnable_token"],
                                  (e_c.shape[0], 1, self.pcfg.id_dim))
            toks = [lt] + toks                                        # [LT, cand]
        x_c = jnp.concatenate(toks, axis=1)
        x_c = self.pinfm.phi_in(pf["phi_in"], x_c).astype(self.pinfm.bb.cdtype())
        if self.pinfm.bb.pos_emb == "learned":
            L = cfg.seq_len
            S_c = x_c.shape[1]
            pos = jnp.arange(L, L + S_c) % self.pinfm.pos_embed.vocab
            x_c = x_c + self.pinfm.pos_embed(pf["pos_embed"], pos).astype(
                x_c.dtype)[None]
        return x_c, e_c, gs_e

    def encode_context(self, p, seq_ids, seq_actions, seq_surfaces, *,
                       serving: bool = False):
        """Context component only (candidate-independent, so cacheable per
        user — the early-fusion analogue of :meth:`encode_user`):
        deduplicated sequences -> (H_u, ctxs, aux).  ``ctxs`` is the
        per-layer DCAT context (KV / recurrent state) consumed by
        :meth:`candidate_features`; at serving, skip_last_self_attn may
        elide the last layer's hidden output (H_u then only feeds the loss,
        which serving does not use)."""
        pf = p["pinfm"]
        x_u = self.pinfm.input_tokens(pf, seq_ids, seq_actions, seq_surfaces)
        y, aux, ctxs = self.dcat.context(pf["body"], x_u, serving=serving)
        H_u = self.pinfm.phi_out(pf["phi_out"], y.astype(jnp.float32))
        return H_u, ctxs, aux

    @property
    def n_cand_tokens(self) -> int:
        """Candidate-side token count S_c entering the crossing component
        (the learnable token adds one for graphsage-lt) — also the number
        of context slots ``rotate_replace`` overwrites per call, i.e. the
        ``n_new`` of ``ctx_rotate``."""
        return 2 if self.cfg.variant == "graphsage-lt" else 1

    def candidate_features(self, p, batch, ctxs, *, ctx_len: int,
                           cand_ids=None, rotated: bool = False):
        """Crossing component: candidate tokens attend to precomputed
        context ``ctxs`` (early-fusion variants).  -> (features
        (B_c, n_feat*id_dim), e_cand, gs_e).  ``rotated``: ctxs is in the
        pre-rotated fixed-L serving layout (see ``core.dcat.ctx_rotate``)."""
        cfg, pf = self.cfg, p["pinfm"]
        if cand_ids is None:
            cand_ids = batch["cand_ids"]
        x_c, e_c, gs_e = self._candidate_tokens(
            p, cand_ids, batch.get("graphsage"))
        y_c, _ = self.dcat.crossing(pf["body"], x_c, batch["inverse_idx"],
                                    ctxs, ctx_len=ctx_len, rotated=rotated)
        y_c = self.pinfm.phi_out(pf["phi_out"], y_c.astype(jnp.float32))
        feats = [y_c[:, -1], e_c]                                    # cand output
        if cfg.variant == "graphsage-lt":
            feats.insert(1, y_c[:, 0])                               # LT output
        return jnp.concatenate(feats, axis=-1), e_c, gs_e

    def pinfm_features(self, p, batch, *, train: bool = False, rng=None,
                       serving: bool = False, ctxs=None):
        """Run the PinFM module.  batch carries the DEDUPLICATED sequences +
        inverse index (the data pipeline / router performs Ψ on host):

          seq_ids/actions/surfaces: (B_u, L_d); inverse_idx: (B_c,);
          cand_ids: (B_c,); graphsage: (B_c, gs_dim)

        ``ctxs``: optional precomputed context from :meth:`encode_context`
        (early-fusion variants only) — the context transformer is then
        skipped entirely and H_u is returned as None (serving cache path).

        -> (features (B_c, n_feat*id_dim), H_u, aux)."""
        cfg, pcfg = self.cfg, self.pcfg
        pf = p["pinfm"]
        cand_ids = batch["cand_ids"]
        if train and cfg.use_cir and rng is not None:
            # Candidate Item Randomization: 10% random ids (cold-start sim)
            r1, r2 = jax.random.split(rng)
            rand_ids = jax.random.randint(r1, cand_ids.shape, 0, 1 << 30)
            keep = jax.random.uniform(r2, cand_ids.shape) > cfg.cir_prob
            cand_ids = jnp.where(keep, cand_ids, rand_ids)

        lite = cfg.variant in ("lite-mean", "lite-last")
        H_u = None
        aux = jnp.zeros((), jnp.float32)
        if lite:
            H_u, aux, _ = self.pinfm.encode(
                pf, batch["seq_ids"], batch["seq_actions"],
                batch["seq_surfaces"], collect_ctx=False)
        elif ctxs is None:
            H_u, ctxs, aux = self.encode_context(
                p, batch["seq_ids"], batch["seq_actions"],
                batch["seq_surfaces"], serving=serving)

        inv = batch["inverse_idx"]
        if lite:
            pooled = (jnp.mean(H_u, axis=1) if cfg.variant == "lite-mean"
                      else H_u[:, -1])
            user_emb = jnp.take(pooled, inv, axis=0)                 # (B_c, id_dim)
            e_c = self.pinfm.id_embed(pf["id_embed"], cand_ids)
            features = jnp.concatenate([user_emb, e_c], axis=-1)
            gs_e = None
        else:
            ctx_len = (batch["seq_ids"].shape[1] if "seq_ids" in batch
                       else cfg.seq_len)
            features, e_c, gs_e = self.candidate_features(
                p, batch, ctxs, ctx_len=ctx_len, cand_ids=cand_ids)

        # Item-age Dependent Dropout on the module outputs (Table 2 IDD)
        if train and cfg.use_idd and rng is not None and "cand_age_days" in batch:
            age = batch["cand_age_days"]
            pdrop = jnp.where(age < 7, cfg.idd_p_fresh,
                              jnp.where(age < 28, cfg.idd_p_mid, 0.0))
            keep = jax.random.uniform(jax.random.fold_in(rng, 7),
                                      (features.shape[0], 1)) >= pdrop[:, None]
            features = features * keep / jnp.maximum(1 - pdrop[:, None], 1e-3)

        return features, H_u, {"aux": aux, "gs_e": gs_e,
                               "e_cand": e_c if cfg.variant != "lite-mean" else None}

    # -- late-fusion serving split (lite variants) -----------------------------
    def encode_user(self, p, seq_ids, seq_actions, seq_surfaces):
        """Pooled user embedding for lite variants — cacheable across
        requests because it does not depend on candidates (paper §3.2 late
        fusion: 'we can easily cache the output of PinFM')."""
        assert self.cfg.variant in ("lite-mean", "lite-last")
        H_u, _, _ = self.pinfm.encode(p["pinfm"], seq_ids, seq_actions,
                                      seq_surfaces, collect_ctx=False)
        return (jnp.mean(H_u, axis=1) if self.cfg.variant == "lite-mean"
                else H_u[:, -1])

    def _ranker_logits(self, p, batch, feats):
        """Feature crossing + task heads over PinFM features (B_c, F)."""
        pr = p["ranker"]
        user_f = jnp.take(batch["user_feats"], batch["inverse_idx"], axis=0)
        x = jnp.concatenate([user_f, batch["cand_feats"], feats],
                            axis=-1).astype(feats.dtype)
        x = self.in_proj(pr["in_proj"], x)
        x = self.cross(pr["cross"], x)
        x = _ACT["relu"](self.mlp_mid(pr["mlp_mid"], x))
        return self.heads(pr["heads"], x)

    def score_with_user_emb(self, p, user_emb, batch):
        """user_emb: (B_c, id_dim) — already Ψ⁻¹-gathered per candidate."""
        e_c = self.pinfm.id_embed(p["pinfm"]["id_embed"], batch["cand_ids"])
        feats = jnp.concatenate([user_emb, e_c], axis=-1)
        return self._ranker_logits(p, batch, feats)

    # -- early-fusion serving split (context-KV cache path) --------------------
    def score_with_ctxs(self, p, batch, ctxs, *, ctx_len: Optional[int] = None,
                        rotated: bool = False):
        """Early-fusion scoring from a PRECOMPUTED context (the candidate-
        independent half of DCAT, cacheable per user exactly like the lite
        pooled embedding): crossing + feature crossing only, no context
        transformer.  -> task logits (B_c, n_tasks).  ``rotated``: ctxs is
        pre-rotated into the fixed-L ``rotate_replace`` serving layout, so
        the crossing skips the per-call rotation."""
        assert self.cfg.variant not in ("lite-mean", "lite-last")
        feats, _, _ = self.candidate_features(
            p, batch, ctxs,
            ctx_len=self.cfg.seq_len if ctx_len is None else ctx_len,
            rotated=rotated)
        return self._ranker_logits(p, batch, feats)

    def forward(self, p, batch, *, train: bool = False, rng=None,
                serving: bool = False, ctxs=None):
        """-> (task_logits (B_c, n_tasks), module_logits, extras)."""
        feats, H_u, extras = self.pinfm_features(
            p, batch, train=train, rng=rng, serving=serving, ctxs=ctxs)
        logits = self._ranker_logits(p, batch, feats)
        module_logits = self.module_head(p["ranker"]["module_head"], feats)
        extras["H_u"] = H_u
        return logits, module_logits, extras

    # ------------------------------------------------------------------
    def loss(self, p, batch, *, rng=None, train: bool = True):
        cfg = self.cfg
        logits, module_logits, extras = self.forward(p, batch, train=train,
                                                     rng=rng)
        labels = batch["labels"].astype(jnp.float32)                 # (B_c, T)
        bce = _bce(logits, labels)
        metrics = {"bce": bce}
        total = bce

        if cfg.use_module_head:
            m_bce = _bce(module_logits, labels)
            align = jnp.mean(jnp.square(
                jax.nn.sigmoid(module_logits.astype(jnp.float32))
                - jax.lax.stop_gradient(
                    jax.nn.sigmoid(logits.astype(jnp.float32)))))
            total = total + m_bce + cfg.align_weight * align
            metrics.update(module_bce=m_bce, align=align)

        if cfg.use_seq_loss:
            pf = p["pinfm"]
            z = self.pinfm.targets(pf, batch["seq_ids"])
            tau = learnable_tau(pf["log_tau"], cfg.seq_loss)
            pos = self.pinfm.pos_action_mask(batch["seq_actions"])
            valid = batch.get("seq_valid",
                              jnp.ones_like(batch["seq_ids"], bool))
            seq_total, seq_m = pinfm_losses(
                extras["H_u"], z, pos, valid.astype(bool),
                batch["seq_user_id"], tau, cfg.seq_loss)
            total = total + 0.1 * seq_total
            metrics["seq_ntl"] = seq_m.get("ntl", 0.0)

        if cfg.gs_align_weight and extras.get("gs_e") is not None:
            e_id = self.pinfm.id_embed(p["pinfm"]["id_embed"],
                                       batch["cand_ids"])
            ga = jnp.mean(jnp.square(
                extras["gs_e"].astype(jnp.float32)
                - jax.lax.stop_gradient(e_id.astype(jnp.float32))))
            total = total + cfg.gs_align_weight * ga
            metrics["gs_align"] = ga

        metrics["total"] = total
        return total, (metrics, logits)


def _bce(logits, labels):
    lg = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(lg, 0) - lg * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(lg))))
