"""Pretraining-quality evaluation: next-positive-item retrieval.

The InfoNCE objectives train H_i to score the next positively-engaged item's
psi-embedding above in-batch alternatives; recall@k over a candidate corpus
is the standard proxy for pretraining quality (used by the Figure-3
iterations benchmark)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def next_item_recall(model, params, batches, *, k: int = 10,
                     corpus_ids=None) -> dict:
    """Recall@k of the next positively-engaged item against a corpus.

    batches: iterable of pretrain batches; corpus_ids: (C,) candidate items
    (defaults to all ids present in the evaluated batches)."""
    anchors, gold = [], []
    all_ids = []
    for b in batches:
        H, _, _ = model.encode(params, jnp.asarray(b["ids"]),
                               jnp.asarray(b["actions"]),
                               jnp.asarray(b["surfaces"]))
        pos = np.asarray(model.pos_action_mask(jnp.asarray(b["actions"])))
        ids = np.asarray(b["ids"])
        Hn = np.asarray(H)
        B, L = ids.shape
        for bb in range(B):
            for i in range(L - 1):
                if pos[bb, i + 1]:
                    anchors.append(Hn[bb, i])
                    gold.append(ids[bb, i + 1])
        all_ids.append(ids.reshape(-1))
    if not anchors:
        return {"recall": 0.0, "n": 0}
    anchors = np.stack(anchors)
    gold = np.asarray(gold)
    corpus = (np.unique(np.concatenate(all_ids)) if corpus_ids is None
              else np.asarray(corpus_ids))
    z = np.asarray(model.targets(params, jnp.asarray(corpus)))   # (C, D)
    sims = anchors @ z.T                                          # (N, C)
    kk = min(k, sims.shape[1])
    topk = np.argpartition(-sims, kk - 1, axis=1)[:, :kk]
    hit = np.array([gold[i] in corpus[topk[i]] for i in range(len(gold))])
    return {"recall": float(hit.mean()), "n": int(len(gold)),
            "corpus": int(len(corpus)), "k": kk}
