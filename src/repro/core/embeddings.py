"""PinFM hashed id-embedding tables (paper §4.2).

Each item id is looked up in ``n_tables`` sub-tables through independent
universal hashes; the sub-embeddings are concatenated:

    E_i = emb(id_i) = ⊗_{j=0}^{7} emb_j(hash_j(id_i))       (8 x 80M x 32 -> 256)

The 8-way multi-hash mitigates collisions: two ids collide on the full
embedding only if they collide in all 8 tables.  In the production config the
tables hold 8*80M*32 = 20.48B parameters — the bulk of PinFM's "20B+".

Rows are sharded over the full mesh (logical axis "id_vocab").
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.nn.module import Module, Param, normal_init

# odd 32-bit multipliers + offsets (fixed, so checkpoints are stable)
_MULTS = np.array([0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
                   0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09],
                  dtype=np.uint32)
_OFFS = np.array([0x632BE59B, 0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2D,
                  0x165667B5, 0xD3A2646B, 0xFD7046C3, 0xB55A4F0B],
                 dtype=np.uint32)


def multi_hash(ids, n_tables: int, rows: int):
    """ids: int32/uint32 (...,) -> (..., n_tables) int32 row indices."""
    u = ids.astype(jnp.uint32)[..., None]
    mults = jnp.asarray(_MULTS[:n_tables])
    offs = jnp.asarray(_OFFS[:n_tables])
    h = u * mults + offs                    # wraps mod 2^32 (multiplicative hashing)
    h = h ^ (h >> 15)
    return (h % jnp.uint32(rows)).astype(jnp.int32)


class HashedIDEmbedding(Module):
    def __init__(self, n_tables: int = 8, rows: int = 80_000_000,
                 sub_dim: int = 32, dtype=jnp.float32):
        self.n_tables, self.rows, self.sub_dim = n_tables, rows, sub_dim
        self.dim = n_tables * sub_dim
        self.dtype = dtype

    def spec(self):
        return {"tables": Param((self.n_tables, self.rows, self.sub_dim),
                                self.dtype, (None, "id_vocab", None),
                                normal_init(0.02))}

    def __call__(self, p, ids):
        """ids: (...,) int -> (..., n_tables*sub_dim)."""
        idx = multi_hash(ids, self.n_tables, self.rows)       # (..., T)
        # gather per table: vmap over the table axis
        def one(table, rows_idx):
            return jnp.take(table, rows_idx, axis=0)
        gathered = jax.vmap(one, in_axes=(0, -1), out_axes=-2)(p["tables"], idx)
        # gathered: (..., n_tables, sub_dim) -> concat
        return gathered.reshape(*ids.shape, self.dim)
