"""Deduplicated Cross-Attention Transformer — DCAT (paper §4.1).

Key data pattern: unique user sequences ≪ scored candidates (1:1000 serving,
1:10 training).  The transformer is split into

  * **context component** — self-attention over the DEDUPLICATED user-sequence
    batch Ψ(X) (B_u sequences), emitting per-layer KV (attention kinds) or the
    recurrent/SSD state (rec/ssm kinds — our TPU-side generalization for
    attention-free backbones, DESIGN.md §5);
  * **crossing component** — each candidate is a short query sequence that
    attends to Ψ⁻¹(KV_u) ‖ KV_c per layer (eq. 4), where Ψ⁻¹ is a gather by
    unique-row index performed inside the layer scan.

Optimizations from the paper, both implemented:
  * ``rotate_replace`` — keep the sequence length fixed at L (256 in prod):
    overwrite the oldest tokens' KV slots with the candidate KV and rotate
    the position ids instead of concatenating (§4.1 "+25%" trick, part 1);
  * ``skip_last_self_attn`` — at serving, the last layer's context output is
    only used by the loss, so compute just its K/V projection (part 2).

Ψ itself (deduplication) runs OUTSIDE the accelerator graph — in training the
data pipeline emits (unique_sequences, inverse_index); at serving the router
does the same with pointers.  :func:`dedup` is that host-side operation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerBody


# ---------------------------------------------------------------------------
# Ψ — host-side batch deduplication (invertible)
# ---------------------------------------------------------------------------

def dedup(rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Ψ: (B, ...) -> (unique (B_u, ...), inverse (B,)) with
    Ψ⁻¹(u, inv) = u[inv] == rows.  First-occurrence order is preserved."""
    rows = np.asarray(rows)
    flat = rows.reshape(rows.shape[0], -1)
    _, first_idx, inverse = np.unique(
        flat, axis=0, return_index=True, return_inverse=True)
    # re-order unique rows by first occurrence so Ψ is deterministic/stable
    order = np.argsort(first_idx)
    rank = np.empty_like(order)
    rank[order] = np.arange(len(order))
    unique = rows[np.sort(first_idx)]
    return unique, rank[inverse].astype(np.int32)


def dedup_inverse(unique, inverse):
    """Ψ⁻¹ — reference implementation (the production path is the gather
    fused into the crossing layer scan / Pallas kernel)."""
    return jnp.take(jnp.asarray(unique), jnp.asarray(inverse), axis=0)


# ---------------------------------------------------------------------------
# DCAT over a TransformerBody
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DCATOptions:
    rotate_replace: bool = False
    skip_last_self_attn: bool = False


class DCAT:
    """Context/crossing execution over an existing body + params."""

    def __init__(self, body: TransformerBody, opts: Optional[DCATOptions] = None):
        self.body = body
        self.opts = opts or DCATOptions()

    def context(self, p_body, x_u, positions=None, *, serving: bool = False):
        """x_u: (B_u, L, d) deduplicated embedded sequences.
        -> (H_u, aux, ctxs).  At serving, skip_last_self_attn may elide the
        last layer's output (H_u is then not the true last hidden state —
        fine, it is only used by the loss)."""
        B, L = x_u.shape[0], x_u.shape[1]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(L), (B, L))
        skip = serving and self.opts.skip_last_self_attn
        return self.body.forward(p_body, x_u, positions, collect_ctx=True,
                                 skip_last_self_attn=skip)

    def crossing(self, p_body, x_c, inverse_idx, ctxs, *, ctx_len: int,
                 positions=None):
        """x_c: (B_c, S_c, d) embedded candidate tokens; inverse_idx: (B_c,)
        maps each candidate to its unique user row (Ψ⁻¹).
        -> y_c: (B_c, S_c, d) final-normed crossing outputs."""
        B_c, S_c = x_c.shape[0], x_c.shape[1]
        if positions is None:
            positions = jnp.broadcast_to(
                jnp.arange(ctx_len, ctx_len + S_c), (B_c, S_c))
        y, aux = self.body.cross(
            p_body, x_c, ctxs, positions,
            gather_idx=jnp.asarray(inverse_idx),
            self_attend=not self.opts.rotate_replace,
            rotate_replace=self.opts.rotate_replace)
        return y, aux

    # -- reference (paper's baseline): full self-attention over Ψ⁻¹ batch ----
    def reference_scores(self, p_body, x_u, x_c, inverse_idx):
        """Score candidates WITHOUT dedup/DCAT: materialize Ψ⁻¹(X_u), append
        the candidate tokens, run plain causal self-attention, and read the
        outputs at the candidate positions.  DCAT (concat mode) must match
        this exactly — the centerpiece equivalence test."""
        x_full = jnp.concatenate(
            [jnp.take(x_u, jnp.asarray(inverse_idx), axis=0), x_c], axis=1)
        B, S = x_full.shape[0], x_full.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        y, aux, _ = self.body.forward(p_body, x_full, positions)
        return y[:, -x_c.shape[1]:], aux


def dedup_stats(inverse_idx) -> dict:
    """Observability: dedup ratio etc. (paper: 1:10 training, 1:1000 serving)."""
    inverse_idx = np.asarray(inverse_idx)
    b_c = len(inverse_idx)
    b_u = len(np.unique(inverse_idx))
    return {"candidates": b_c, "unique_users": b_u,
            "dedup_ratio": b_c / max(b_u, 1)}
