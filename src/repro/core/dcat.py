"""Deduplicated Cross-Attention Transformer — DCAT (paper §4.1).

Key data pattern: unique user sequences ≪ scored candidates (1:1000 serving,
1:10 training).  The transformer is split into

  * **context component** — self-attention over the DEDUPLICATED user-sequence
    batch Ψ(X) (B_u sequences), emitting per-layer KV (attention kinds) or the
    recurrent/SSD state (rec/ssm kinds — our TPU-side generalization for
    attention-free backbones, DESIGN.md §5);
  * **crossing component** — each candidate is a short query sequence that
    attends to Ψ⁻¹(KV_u) ‖ KV_c per layer (eq. 4), where Ψ⁻¹ is a gather by
    unique-row index performed inside the layer scan.

Optimizations from the paper, both implemented:
  * ``rotate_replace`` — keep the sequence length fixed at L (256 in prod):
    overwrite the oldest tokens' KV slots with the candidate KV and rotate
    the position ids instead of concatenating (§4.1 "+25%" trick, part 1);
  * ``skip_last_self_attn`` — at serving, the last layer's context output is
    only used by the loss, so compute just its K/V projection (part 2).

Ψ itself (deduplication) runs OUTSIDE the accelerator graph — in training the
data pipeline emits (unique_sequences, inverse_index); at serving the router
does the same with pointers.  :func:`dedup` is that host-side operation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerBody


# ---------------------------------------------------------------------------
# Ψ — host-side batch deduplication (invertible)
# ---------------------------------------------------------------------------

def dedup_with_first(
        rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Ψ with provenance: (B, ...) -> (unique (B_u, ...), inverse (B,),
    first_of (B_u,)) where ``first_of[u]`` is the input row index of the
    first occurrence of unique row ``u``.  Fully vectorized — no per-unique
    Python loop; first-occurrence order is preserved."""
    rows = np.asarray(rows)
    flat = rows.reshape(rows.shape[0], -1)
    _, first_idx, inverse = np.unique(
        flat, axis=0, return_index=True, return_inverse=True)
    # re-order unique rows by first occurrence so Ψ is deterministic/stable
    order = np.argsort(first_idx)
    rank = np.empty_like(order)
    rank[order] = np.arange(len(order))
    first_of = np.sort(first_idx).astype(np.int32)
    return rows[first_of], rank[inverse.ravel()].astype(np.int32), first_of


def dedup(rows: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Ψ: (B, ...) -> (unique (B_u, ...), inverse (B,)) with
    Ψ⁻¹(u, inv) = u[inv] == rows.  First-occurrence order is preserved."""
    unique, inverse, _ = dedup_with_first(rows)
    return unique, inverse


def dedup_inverse(unique, inverse):
    """Ψ⁻¹ — reference implementation (the production path is the gather
    fused into the crossing layer scan / Pallas kernel)."""
    return jnp.take(jnp.asarray(unique), jnp.asarray(inverse), axis=0)


# ---------------------------------------------------------------------------
# Context pytree (ctxs) per-user slicing — the serving ContextCache unit
# ---------------------------------------------------------------------------
# ``ctxs`` as emitted by TransformerBody.forward(collect_ctx=True) is a
# list-per-scan-group of tuple-per-unit-position of stacked contexts.  Every
# leaf — attention KV, recurrent state, SSD state — carries the scan-repeats
# axis at 0 and the UNIQUE-USER batch axis at 1, so one user's context is the
# axis-1 slice of every leaf.  These helpers are what lets the engine cache
# early-fusion contexts per user and reassemble arbitrary batches of them.

def ctx_slice(ctxs, i: int):
    """Extract user ``i``'s context as a host-side (numpy-leaf) pytree with
    the batch axis removed: leaf (reps, B_u, ...) -> (reps, ...)."""
    return jax.tree.map(lambda a: np.asarray(a[:, i]), ctxs)


def ctx_slice_batch(ctxs, n: int):
    """Per-user host slices for the first ``n`` batch rows with ONE
    device->host sync: the batched leaves are sliced to ``[:, :n]`` on
    device (padding rows never transfer) and fetched in a single
    ``jax.device_get``, then split into per-user contiguous pytrees.
    Equals ``[ctx_slice(ctxs, i) for i in range(n)]`` bit-for-bit, minus
    the one blocking transfer PER USER PER LEAF that loop pays."""
    host = jax.device_get(jax.tree.map(lambda a: a[:, :n], ctxs))
    return [jax.tree.map(lambda a: np.ascontiguousarray(a[:, i]), host)
            for i in range(n)]


def ctx_pack(user_ctxs: Sequence, b_u: Optional[int] = None):
    """Inverse of :func:`ctx_slice` over a batch: stack per-user context
    pytrees back into a batched pytree with ``b_u`` unique-user rows
    (zero-padded past ``len(user_ctxs)`` so the result fits a shape bucket).
    """
    n = len(user_ctxs)
    assert n > 0, "ctx_pack needs at least one user context"
    b_u = n if b_u is None else b_u
    assert b_u >= n

    def pack(*leaves):
        first = np.asarray(leaves[0])
        out = np.zeros((first.shape[0], b_u, *first.shape[1:]), first.dtype)
        for i, leaf in enumerate(leaves):
            out[:, i] = leaf
        return out

    return jax.tree.map(pack, *user_ctxs)


def ctx_nbytes(ctx) -> int:
    """Approximate memory footprint of one context pytree (host numpy or
    device arrays — device leaves are NOT transferred, their ``nbytes``
    attribute is used directly; non-array leaves such as layout tags count
    as zero)."""
    total = 0
    for l in jax.tree.leaves(ctx):
        if isinstance(l, (str, bytes)):
            continue
        nb = getattr(l, "nbytes", None)
        if nb is None:
            try:
                nb = np.asarray(l).nbytes
            except (TypeError, ValueError):
                nb = 0
        total += int(nb)
    return total


def ctx_rotate(ctxs, n_new: int, ctx_len: int):
    """Pre-rotate a context pytree into the fixed-L ``rotate_replace``
    serving layout: drop the OLDEST ``n_new`` KV slots from every
    attention-KV leaf, so the crossing step can CONCAT the candidate KV
    (restoring length ``ctx_len``) instead of performing the per-call
    in-place rotation (``dynamic_update_slice`` over the full gathered
    context).  Attention results are invariant to key order given explicit
    key positions, so the rotated layout scores the same candidates
    (up to floating-point summation order).

    KV leaves are identified by shape: ``leaf.ndim >= 4`` and
    ``leaf.shape[-3] == ctx_len`` (the (reps, [B,] L, K, D) layout emitted
    by ``TransformerBody.forward(collect_ctx=True)``); recurrent / SSD
    state leaves are returned untouched.  Callers gate on attention-only
    bodies (see ``ServingEngine``) so a state axis can never alias
    ``ctx_len``.  Works on batched ctxs and on per-user ``ctx_slice``
    outputs alike, numpy or device leaves."""
    assert 0 < n_new < ctx_len, (n_new, ctx_len)

    def rot(leaf):
        if getattr(leaf, "ndim", 0) >= 4 and leaf.shape[-3] == ctx_len:
            return leaf[..., n_new:, :, :]
        return leaf

    return jax.tree.map(rot, ctxs)


# ---------------------------------------------------------------------------
# DCAT over a TransformerBody
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DCATOptions:
    rotate_replace: bool = False
    skip_last_self_attn: bool = False


class DCAT:
    """Context/crossing execution over an existing body + params."""

    def __init__(self, body: TransformerBody, opts: Optional[DCATOptions] = None):
        self.body = body
        self.opts = opts or DCATOptions()

    def context(self, p_body, x_u, positions=None, *, serving: bool = False):
        """x_u: (B_u, L, d) deduplicated embedded sequences.
        -> (H_u, aux, ctxs).  At serving, skip_last_self_attn may elide the
        last layer's output (H_u is then not the true last hidden state —
        fine, it is only used by the loss)."""
        B, L = x_u.shape[0], x_u.shape[1]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(L), (B, L))
        skip = serving and self.opts.skip_last_self_attn
        return self.body.forward(p_body, x_u, positions, collect_ctx=True,
                                 skip_last_self_attn=skip)

    def crossing(self, p_body, x_c, inverse_idx, ctxs, *, ctx_len: int,
                 positions=None, rotated: bool = False):
        """x_c: (B_c, S_c, d) embedded candidate tokens; inverse_idx: (B_c,)
        maps each candidate to its unique user row (Ψ⁻¹).
        -> y_c: (B_c, S_c, d) final-normed crossing outputs.

        rotated: ``ctxs`` is already in the :func:`ctx_rotate` fixed-L
        layout (KV length ``ctx_len - S_c``, oldest slots dropped) — the
        candidate KV is concatenated back to length ``ctx_len`` with
        rotated key positions, skipping the per-call in-place rotation.
        Only meaningful under ``rotate_replace=True`` serving; the cached
        engine path pre-rotates once at ContextCache-insert time."""
        B_c, S_c = x_c.shape[0], x_c.shape[1]
        if positions is None:
            positions = jnp.broadcast_to(
                jnp.arange(ctx_len, ctx_len + S_c), (B_c, S_c))
        if rotated:
            assert self.opts.rotate_replace, \
                "rotated ctx layout requires DCATOptions(rotate_replace=True)"
            # surviving slots keep positions [S_c, ctx_len); the concat
            # restores a fixed ctx_len-key attention, same key SET as the
            # in-place rotation (order differs, scores agree numerically)
            y, aux = self.body.cross(
                p_body, x_c, ctxs, positions,
                ctx_pos=jnp.arange(S_c, ctx_len),
                gather_idx=jnp.asarray(inverse_idx),
                self_attend=True, rotate_replace=False)
            return y, aux
        y, aux = self.body.cross(
            p_body, x_c, ctxs, positions,
            gather_idx=jnp.asarray(inverse_idx),
            self_attend=not self.opts.rotate_replace,
            rotate_replace=self.opts.rotate_replace)
        return y, aux

    # -- reference (paper's baseline): full self-attention over Ψ⁻¹ batch ----
    def reference_scores(self, p_body, x_u, x_c, inverse_idx):
        """Score candidates WITHOUT dedup/DCAT: materialize Ψ⁻¹(X_u), append
        the candidate tokens, run plain causal self-attention, and read the
        outputs at the candidate positions.  DCAT (concat mode) must match
        this exactly — the centerpiece equivalence test."""
        x_full = jnp.concatenate(
            [jnp.take(x_u, jnp.asarray(inverse_idx), axis=0), x_c], axis=1)
        B, S = x_full.shape[0], x_full.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        y, aux, _ = self.body.forward(p_body, x_full, positions)
        return y[:, -x_c.shape[1]:], aux


def dedup_stats(inverse_idx) -> dict:
    """Observability: dedup ratio etc. (paper: 1:10 training, 1:1000 serving)."""
    inverse_idx = np.asarray(inverse_idx)
    b_c = len(inverse_idx)
    b_u = len(np.unique(inverse_idx))
    return {"candidates": b_c, "unique_users": b_u,
            "dedup_ratio": b_c / max(b_u, 1)}
