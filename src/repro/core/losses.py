"""PinFM pretraining losses (paper §3.1): sampled-InfoNCE next-token,
multi-token-window, and future-token objectives.

All three share one structure: an anchor user-representation H_i, a positive
target z_j (the psi-projected embedding of a future positively-engaged item),
and in-batch negatives — embeddings of positively-engaged items from OTHER
users (eq. 2: "sampled in-batch excluding items positively engaged by the
same user").

Numerics: similarities are inner products of l2-normalized vectors divided by
a learnable temperature; the denominator is computed as
logaddexp(pos, logsumexp(negs)) so a small tau cannot overflow.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class LossConfig:
    use_ntl: bool = True
    use_mtl: bool = True
    use_ftl: bool = True
    window: int = 16          # L' — multi-token / future-token window
    downstream_len: int = 128  # L_d — anchor position for L_ftl
    mtl_stride: int = 2       # subsample L_mtl pairs (paper: "we also subsample")
    n_negatives: int = 4096   # K — in-batch negative pool size (eq. 2)
    tau_min: float = 0.01


def _neg_logsumexp(H, z, pos_mask, user_ids, tau, n_negatives: int = 0):
    """Per-anchor logsumexp over in-batch negatives.

    H: (B, L, D) anchors; z: (B, L, D) item embeddings (targets pool);
    pos_mask: (B, L) bool — pool entries that are positively-engaged items;
    user_ids: (B,) — exclusion key.
    When n_negatives < B*L the pool is a deterministic stride-subsample (the
    paper samples K in-batch negatives; eq. 2) — required at production batch
    sizes where the full (BL, BL) similarity matrix would not fit.
    Returns (B, L): logsumexp_k sim(H_bi, z_k)/tau over valid negatives.
    """
    B, L, D = H.shape
    BL = B * L
    Hf = H.reshape(BL, D).astype(jnp.float32)
    zf = z.reshape(BL, D).astype(jnp.float32)
    pool_ok = pos_mask.reshape(-1)
    pool_user = jnp.repeat(user_ids, L)
    if 0 < n_negatives < BL:
        idx = (jnp.arange(n_negatives) * (BL // n_negatives)) % BL
        zf, pool_ok, pool_user = zf[idx], pool_ok[idx], pool_user[idx]
    sims = (Hf @ zf.T) / tau                                   # (BL, M)
    anchor_user = jnp.repeat(user_ids, L)
    valid = pool_ok[None, :] & (anchor_user[:, None] != pool_user[None, :])
    sims = jnp.where(valid, sims, NEG_INF)
    return jax.nn.logsumexp(sims, axis=-1).reshape(B, L)


def _pair_sims(H, z, tau):
    """(B, L, L) sims[b, i, j] = H_bi . z_bj / tau (within-user)."""
    return jnp.einsum("bid,bjd->bij", H.astype(jnp.float32),
                      z.astype(jnp.float32)) / tau


def _masked_mean(x, m):
    return jnp.sum(x * m) / jnp.maximum(jnp.sum(m), 1.0)


def pinfm_losses(H, z, pos_mask, valid_mask, user_ids, tau,
                 cfg: LossConfig) -> Tuple[jax.Array, dict]:
    """H: (B, L, D) user reps; z: (B, L, D) psi(emb(id)); pos_mask: (B, L)
    positive-action indicator; valid_mask: (B, L) non-padding; user_ids: (B,).
    """
    B, L, _ = H.shape
    pos = pos_mask & valid_mask
    neg_lse = _neg_logsumexp(H, z, pos, user_ids, tau,
                             cfg.n_negatives)                   # (B, L) per anchor
    sims = _pair_sims(H, z, tau)                                # (B, L, L)

    # pairwise loss for anchor i, target j: -s_ij + logaddexp(s_ij, neg_lse_i)
    def pair_loss(i_j_mask):
        l = -sims + jnp.logaddexp(sims, neg_lse[:, :, None])    # (B, L, L)
        return _masked_mean(l, i_j_mask)

    ii = jnp.arange(L)
    delta = ii[None, :] - ii[:, None]                           # j - i
    anchor_ok = valid_mask[:, :, None]
    target_ok = pos[:, None, :]

    metrics = {}
    total = jnp.zeros((), jnp.float32)

    if cfg.use_ntl:
        m_ntl = (delta == 1) & anchor_ok & target_ok
        l_ntl = pair_loss(m_ntl.astype(jnp.float32))
        metrics["ntl"] = l_ntl
        total = total + l_ntl

    if cfg.use_mtl:
        band = (delta >= 1) & (delta <= cfg.window)
        if cfg.mtl_stride > 1:   # deterministic subsampling of the band
            band = band & ((delta % cfg.mtl_stride) == 1)
        m_mtl = band & anchor_ok & target_ok
        l_mtl = pair_loss(m_mtl.astype(jnp.float32))
        metrics["mtl"] = l_mtl
        total = total + l_mtl

    if cfg.use_ftl:
        ld = min(cfg.downstream_len, L - 1) - 1                 # 0-indexed H_{L_d}
        anchor = jnp.zeros((L,), bool).at[ld].set(True)
        band = (delta >= 1) & (delta <= cfg.window)
        m_ftl = band & anchor[None, :, None] & anchor_ok & target_ok
        l_ftl = pair_loss(m_ftl.astype(jnp.float32))
        metrics["ftl"] = l_ftl
        total = total + l_ftl

    metrics["tau"] = tau
    return total, metrics


def learnable_tau(log_tau, cfg: LossConfig):
    return jnp.maximum(jnp.exp(log_tau.astype(jnp.float32)), cfg.tau_min)
