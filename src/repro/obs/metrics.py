"""Serving metrics: a thread-safe registry of counters, gauges, and
log-spaced-bucket histograms, exporting JSON snapshots and Prometheus
text exposition.

NAMING — every metric is ``<namespace>_<subsystem>_<name>`` (namespace
defaults to ``repro``), e.g. ``repro_serving_flush_latency_ms``; callers
pass the ``<subsystem>_<name>`` part plus optional label kwargs
(``lane="rank"``).  The registry get-or-creates one metric object per
(name, label set) — hot paths hold on to the returned handle instead of
re-looking it up per event.

HISTOGRAMS use FIXED log-spaced buckets (no reservoir sampling, no
decay): ``per_decade`` inclusive upper bounds per factor of 10 between
``lo`` and ``hi``, plus an underflow bucket (<= lo) and an overflow
bucket.  Quantiles are computed exactly from the bucket counts — the
reported pXX is the inclusive upper bound of the bucket holding that
rank, a deterministic value whose error is bounded by the bucket ratio
(~12% at the default 20 buckets/decade), which is what dashboards and
SLO gates want: reproducible numbers, not a sample of them.  Two
histograms with the same bucket layout :meth:`Histogram.merge` by plain
count addition — the multi-host aggregation path needs nothing fancier.

Mutations take a per-metric lock (leaf locks — never held while taking
any other), so an 8-thread record hammer loses no counts; export
(:meth:`MetricsRegistry.snapshot` / :meth:`prometheus_text`) first runs
the registered COLLECTORS (pull-style callbacks that copy engine-side
counters in under their own locks, Prometheus-scrape style), then reads
every metric under its lock.

This module is SERVING observability — not model quality.  Model
evaluation metrics (HIT@3 etc.) live in ``repro/core/metrics.py``; the
two are deliberately separate packages (``repro.obs`` vs ``repro.core``)
so neither import shadows the other.
"""
from __future__ import annotations

import math
import re
import threading
from bisect import bisect_left
from typing import Callable, Dict, List, Optional, Tuple

_NAME_RE = re.compile(r"^[a-z][a-z0-9_]*$")


def _fmt_labels(labels: Tuple[Tuple[str, str], ...]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


def _fmt_num(v) -> str:
    """Prometheus-friendly number: integers stay integral, floats use
    repr (full precision round-trips)."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    f = float(v)
    if math.isinf(f):
        return "+Inf" if f > 0 else "-Inf"
    return repr(f)


class Counter:
    """Monotonically increasing count.  ``set_total`` exists for
    COLLECTORS that mirror an externally-owned cumulative counter (the
    engine's cache hit counts etc.) into the registry at export time."""
    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def inc(self, n=1):
        with self._lock:
            self.value += n

    def set_total(self, v):
        with self._lock:
            self.value = v

    def get(self):
        with self._lock:
            return self.value


class Gauge:
    """Point-in-time value (queue depth, occupancy, bytes resident)."""
    __slots__ = ("_lock", "value")

    def __init__(self):
        self._lock = threading.Lock()
        self.value = 0

    def set(self, v):
        with self._lock:
            self.value = v

    def inc(self, n=1):
        with self._lock:
            self.value += n

    def get(self):
        with self._lock:
            return self.value


class Histogram:
    """Fixed log-spaced-bucket histogram with exact-from-buckets
    quantiles.

    Bucket layout: inclusive upper bounds ``lo * 10**(i / per_decade)``
    for ``i = 0 .. n`` (the first bound is exactly ``lo``, the last is
    the first bound >= ``hi``), plus one overflow bucket above the last
    bound.  ``record(v)`` lands ``v`` in the FIRST bucket whose upper
    bound is >= v (bounds are inclusive: recording a value exactly equal
    to a bound counts in that bound's bucket — pinned by test).

    ``quantile(q)`` returns the inclusive upper bound of the bucket
    containing rank ``ceil(q * count)`` (rank >= 1), i.e. a value
    guaranteed >= at least ``q`` of the recorded samples and tight to one
    bucket width; NaN when empty, the top bound when the rank falls in
    the overflow bucket.  Deterministic — the same recordings always
    report the same pXX.
    """
    __slots__ = ("_lock", "bounds", "counts", "count", "sum")

    def __init__(self, lo: float = 1e-2, hi: float = 1e5,
                 per_decade: int = 20):
        assert lo > 0 and hi > lo and per_decade >= 1
        bounds: List[float] = []
        i = 0
        while True:
            b = lo * 10.0 ** (i / per_decade)
            bounds.append(b)
            if b >= hi:
                break
            i += 1
        self._lock = threading.Lock()
        self.bounds = bounds              # inclusive upper bounds
        self.counts = [0] * (len(bounds) + 1)   # +1 = overflow
        self.count = 0
        self.sum = 0.0

    def record(self, v) -> None:
        v = float(v)
        idx = bisect_left(self.bounds, v)       # first bound >= v
        with self._lock:
            self.counts[idx] += 1
            self.count += 1
            self.sum += v

    def layout(self) -> tuple:
        return (len(self.bounds), self.bounds[0], self.bounds[-1])

    def merge(self, other: "Histogram") -> "Histogram":
        """-> a NEW histogram holding both sides' recordings.  Requires
        identical bucket layouts (multi-host aggregation ships the same
        registry code everywhere, so layouts agree by construction)."""
        if self.layout() != other.layout():
            raise ValueError(f"bucket layout mismatch: {self.layout()} "
                             f"vs {other.layout()}")
        out = Histogram.__new__(Histogram)
        out._lock = threading.Lock()
        out.bounds = self.bounds
        with self._lock:
            a = (list(self.counts), self.count, self.sum)
        with other._lock:
            b = (list(other.counts), other.count, other.sum)
        out.counts = [x + y for x, y in zip(a[0], b[0])]
        out.count = a[1] + b[1]
        out.sum = a[2] + b[2]
        return out

    def _quantile_locked(self, q: float) -> float:
        if self.count == 0:
            return float("nan")
        rank = max(1, math.ceil(q * self.count))
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank:
                return self.bounds[min(i, len(self.bounds) - 1)]
        return self.bounds[-1]      # pragma: no cover - rank <= count

    def quantile(self, q: float) -> float:
        assert 0.0 < q <= 1.0, q
        with self._lock:
            return self._quantile_locked(q)

    def snapshot(self) -> dict:
        """-> JSON-able dict: count/sum/p50/p95/p99 plus the non-empty
        cumulative bucket prefix (le -> cumulative count)."""
        with self._lock:
            counts = list(self.counts)
            total, s = self.count, self.sum
            ps = {f"p{int(q * 100)}": self._quantile_locked(q)
                  for q in (0.5, 0.95, 0.99)}
        buckets, cum = {}, 0
        for b, c in zip(self.bounds, counts):
            cum += c
            if c:
                buckets[_fmt_num(b)] = cum
        return {"count": total, "sum": s, **ps, "buckets": buckets}


class NullMetric:
    """Shared no-op counter/gauge/histogram — the ``enabled=False``
    fast path records into this (every mutator is a constant method)."""
    __slots__ = ()
    value = 0
    count = 0
    sum = 0.0

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def set_total(self, v):
        pass

    def record(self, v):
        pass

    def get(self):
        return 0

    def quantile(self, q):
        return float("nan")


NULL_METRIC = NullMetric()


class MetricsRegistry:
    """Thread-safe get-or-create registry + exporter.

    ``counter/gauge/histogram(name, help=..., **labels)`` return the
    (shared) metric object for that name + label set; the first call
    fixes the metric's type, help string, and (for histograms) bucket
    parameters — later conflicting declarations raise.  Collectors
    registered via :meth:`register_collector` run at the top of every
    export, outside the registry lock, so they may freely take their own
    locks and mutate metrics.
    """

    def __init__(self, namespace: str = "repro"):
        assert _NAME_RE.match(namespace), namespace
        self.namespace = namespace
        self.enabled = True
        self._lock = threading.Lock()
        self._metrics: Dict[tuple, object] = {}   # (name, labels) -> metric
        self._meta: Dict[str, tuple] = {}         # name -> (type, help, params)
        self._collectors: List[Callable] = []

    # -- declaration --------------------------------------------------------
    def _get(self, name: str, typ: str, help_: str, params: tuple,
             labels: dict, factory):
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r} (want "
                             "[a-z][a-z0-9_]*)")
        lk = tuple(sorted((k, str(v)) for k, v in labels.items()))
        with self._lock:
            meta = self._meta.get(name)
            if meta is None:
                self._meta[name] = (typ, help_, params)
            elif meta[0] != typ or meta[2] != params:
                raise ValueError(
                    f"metric {name!r} already declared as {meta[0]}"
                    f"{meta[2]}, conflicting redeclaration as {typ}{params}")
            m = self._metrics.get((name, lk))
            if m is None:
                m = self._metrics[(name, lk)] = factory()
            return m

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(name, "counter", help, (), labels, Counter)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(name, "gauge", help, (), labels, Gauge)

    def histogram(self, name: str, help: str = "", *, lo: float = 1e-2,
                  hi: float = 1e5, per_decade: int = 20,
                  **labels) -> Histogram:
        return self._get(name, "histogram", help, (lo, hi, per_decade),
                         labels, lambda: Histogram(lo, hi, per_decade))

    def register_collector(self, fn: Callable) -> None:
        """``fn()`` is invoked before every export to pull externally-
        owned counters into the registry (scrape-style)."""
        with self._lock:
            self._collectors.append(fn)

    def _collect(self):
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:        # outside the registry lock on purpose
            fn()

    # -- aggregation --------------------------------------------------------
    def merge(self, other: "MetricsRegistry", *,
              labels: Optional[dict] = None) -> "MetricsRegistry":
        """Fold ``other``'s metrics into this registry — the cluster-tier
        aggregation path: a fresh registry absorbs each worker's registry
        under a distinguishing label set (``labels={"worker": name}``),
        yielding one exposition with per-worker series; merging WITHOUT
        extra labels sums same-named series instead (the "all workers"
        rollup).  Counters and gauges add; histograms add bucket counts
        (identical layouts required — :meth:`Histogram.merge`).  Runs
        ``other``'s collectors first so externally-owned counters are
        current.  Export-time aggregation, not a hot path: concurrent
        recordings into ``self`` during a merge may be folded into the
        histogram swap.  -> self."""
        other._collect()
        items, meta = other._items()
        extra = {k: str(v) for k, v in (labels or {}).items()}
        for (name, lk), m in items:
            typ, help_, params = meta[name]
            lab = dict(lk)
            lab.update(extra)
            if typ == "counter":
                self.counter(name, help_, **lab).inc(m.get())
            elif typ == "gauge":
                self.gauge(name, help_, **lab).inc(m.get())
            else:
                h = self.histogram(name, help_, lo=params[0], hi=params[1],
                                   per_decade=params[2], **lab)
                folded = h.merge(m)
                with h._lock:
                    h.counts = folded.counts
                    h.count = folded.count
                    h.sum = folded.sum
        return self

    # -- export -------------------------------------------------------------
    def _items(self):
        with self._lock:
            return sorted(self._metrics.items()), dict(self._meta)

    def snapshot(self) -> dict:
        """-> JSON-able {full_name{labels}: value | histogram dict}."""
        self._collect()
        items, meta = self._items()
        out = {}
        for (name, labels), m in items:
            full = f"{self.namespace}_{name}" + _fmt_labels(labels)
            out[full] = (m.snapshot() if isinstance(m, Histogram)
                         else m.get())
        return out

    def prometheus_text(self) -> str:
        """-> Prometheus text exposition.  Histograms emit the standard
        cumulative ``_bucket``/``_sum``/``_count`` series plus derived
        ``_p50``/``_p99`` gauges (exact-from-buckets, see
        :meth:`Histogram.quantile`) so a raw snapshot file already shows
        the latency distribution without a query engine."""
        self._collect()
        items, meta = self._items()
        by_name: Dict[str, list] = {}
        for (name, labels), m in items:
            by_name.setdefault(name, []).append((labels, m))
        lines = []
        for name in sorted(by_name):
            typ, help_, _ = meta[name]
            full = f"{self.namespace}_{name}"
            if help_:
                lines.append(f"# HELP {full} {help_}")
            lines.append(f"# TYPE {full} {typ}")
            for labels, m in by_name[name]:
                ls = _fmt_labels(labels)
                if typ != "histogram":
                    lines.append(f"{full}{ls} {_fmt_num(m.get())}")
                    continue
                with m._lock:
                    counts = list(m.counts)
                    total, s = m.count, m.sum
                    p50 = m._quantile_locked(0.5) if total else float("nan")
                    p99 = m._quantile_locked(0.99) if total else float("nan")
                cum = 0
                for b, c in zip(m.bounds, counts):
                    cum += c
                    if c:         # non-empty buckets + +Inf carry everything
                        lines.append(
                            f'{full}_bucket{_fmt_labels(labels + (("le", _fmt_num(b)),))} {cum}')
                lines.append(
                    f'{full}_bucket{_fmt_labels(labels + (("le", "+Inf"),))} '
                    f"{total}")
                lines.append(f"{full}_sum{ls} {_fmt_num(s)}")
                lines.append(f"{full}_count{ls} {total}")
                if total:
                    lines.append(f"{full}_p50{ls} {_fmt_num(p50)}")
                    lines.append(f"{full}_p99{ls} {_fmt_num(p99)}")
        return "\n".join(lines) + ("\n" if lines else "")


class NullMetricsRegistry:
    """The ``enabled=False`` registry: every declaration returns the
    shared :data:`NULL_METRIC`, every export is empty, collectors are
    dropped — the hot-loop cost of a disabled engine is one attribute
    load and a constant method call per record site."""

    enabled = False
    namespace = "repro"

    def counter(self, name, help="", **labels):
        return NULL_METRIC

    def gauge(self, name, help="", **labels):
        return NULL_METRIC

    def histogram(self, name, help="", *, lo=1e-2, hi=1e5, per_decade=20,
                  **labels):
        return NULL_METRIC

    def register_collector(self, fn):
        pass

    def snapshot(self):
        return {}

    def prometheus_text(self):
        return ""


NULL_REGISTRY = NullMetricsRegistry()
