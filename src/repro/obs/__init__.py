"""Serving observability: per-request tracing, latency histograms, and
exportable metrics for the serving stack.

Three pieces, one facade:

  * ``obs/metrics.py`` — :class:`MetricsRegistry`: thread-safe counters,
    gauges, and fixed log-spaced-bucket histograms (exact p50/p95/p99
    from buckets), exported as a JSON snapshot or Prometheus text.
  * ``obs/trace.py`` — :class:`Tracer`: a bounded ring buffer of timed
    spans exported as Chrome trace-event JSON (Perfetto-loadable), with
    optional ``jax.profiler.TraceAnnotation`` mirroring so device
    profiles carry the same lane/stage names.
  * :class:`Observability` — the per-engine handle bundling both; the
    ``ServingEngine`` builds one (``obs_enabled=...``) and threads it
    through the scheduler, the flush lanes, and the pipeline stages.

``Observability(enabled=False)`` swaps in shared null implementations
(:data:`~repro.obs.metrics.NULL_REGISTRY`,
:data:`~repro.obs.trace.NULL_TRACER`) whose every method is a constant
no-op — the disabled engine's hot loop pays an attribute load per
record site and nothing else (benchmarked: bench_serving_engine.py
section 5).

Naming: this package is SERVING observability.  Model evaluation
metrics (HIT@3) are ``repro/core/metrics.py`` — different package, no
import shadowing; see each module's docstring.
"""
from __future__ import annotations

import json

from repro.obs.metrics import (NULL_METRIC, NULL_REGISTRY, Counter, Gauge,
                               Histogram, MetricsRegistry,
                               NullMetricsRegistry)
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "Observability",
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "Tracer",
    "NullMetricsRegistry", "NullTracer", "NULL_REGISTRY", "NULL_TRACER",
    "NULL_METRIC",
]


class Observability:
    """One engine's observability handle: ``.metrics`` (a
    :class:`MetricsRegistry` or its null) and ``.tracer`` (a
    :class:`Tracer` or its null), plus the export conveniences the
    tools/examples use.

    Args:
      enabled: False swaps BOTH members for shared no-op singletons —
        the fast path a latency-critical deployment can pin.
      trace_capacity: ring-buffer size of the tracer (newest events
        win).
      annotate: wrap engine executor dispatch and tracer spans in
        ``jax.profiler.TraceAnnotation`` (off by default; only useful
        while capturing a device profile).
      namespace: metric name prefix (default ``repro``).
    """

    def __init__(self, enabled: bool = True, *, trace_capacity: int = 8192,
                 annotate: bool = False, namespace: str = "repro"):
        self.enabled = bool(enabled)
        if self.enabled:
            self.metrics = MetricsRegistry(namespace=namespace)
            self.tracer = Tracer(capacity=trace_capacity, annotate=annotate)
        else:
            self.metrics = NULL_REGISTRY
            self.tracer = NULL_TRACER

    # -- export conveniences ------------------------------------------------
    def snapshot(self) -> dict:
        """-> JSON-able metrics snapshot (runs collectors first)."""
        return self.metrics.snapshot()

    def prometheus_text(self) -> str:
        """-> Prometheus text exposition (runs collectors first)."""
        return self.metrics.prometheus_text()

    def chrome_trace(self) -> dict:
        """-> Chrome trace-event JSON object (Perfetto-loadable)."""
        return self.tracer.chrome_trace()

    def export_trace(self, path: str) -> None:
        self.tracer.export(path)

    def export_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.prometheus_text())

    def export_snapshot(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2, default=str)
