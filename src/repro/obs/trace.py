"""Request tracing: bounded ring buffer of timed spans, exported as
Chrome trace-event JSON (loadable in Perfetto / chrome://tracing).

The tracer records COMPLETE events — (name, category, start, duration,
track, args) tuples appended to a ``deque(maxlen=capacity)`` — so a
long-lived engine holds the most recent window of activity at a fixed
memory bound and export never blocks serving.  Producers that already
measured their timings (the engine's pipeline stages do, for
``PipelineStats``) emit via :meth:`Tracer.event` with the measured
start/duration — no second clock read; code that hasn't uses the
:meth:`Tracer.span` context manager.

Tracks: every span carries a ``tid`` obtained from :meth:`Tracer.tid`
(a stable small int per track name — "requests", "lane:rank", ...), and
the export emits the matching ``thread_name`` metadata events, so the
Perfetto timeline shows one named row per lane with the engine's own
stage names on it.

``annotate=True`` additionally wraps :meth:`span`/:meth:`annotation`
scopes in ``jax.profiler.TraceAnnotation``, so a device profile captured
with ``jax.profiler.trace`` shows the SAME lane/stage names on the
device timeline as this host-side trace — the two line up by name.

The disabled path is :data:`NULL_TRACER`: every method is a constant
no-op (``span`` returns one shared reusable null context manager), which
is what lets the engine leave trace calls inline in its hot loop.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from typing import Dict, Optional


class _NullCtx:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


class _Span:
    """Context manager recording one complete event on exit; optionally
    mirrors itself onto the device timeline via TraceAnnotation."""
    __slots__ = ("tracer", "name", "cat", "tid", "args", "t0", "_ann")

    def __init__(self, tracer, name, cat, tid, args):
        self.tracer, self.name, self.cat = tracer, name, cat
        self.tid, self.args = tid, args
        self._ann = None

    def __enter__(self):
        if self.tracer.annotate:
            self._ann = self.tracer._annotation(self.name)
            self._ann.__enter__()
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter() - self.t0
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self.tracer.event(self.name, self.cat, self.t0, dur,
                          tid=self.tid, args=self.args)
        return False


class Tracer:
    """Bounded in-memory trace sink.

    Args:
      capacity: ring-buffer size in events — the newest ``capacity``
        events are kept, older ones are dropped (``dropped`` counts
        them; the count is exported in the trace metadata).
      annotate: wrap :meth:`span` scopes (and hand out real
        :meth:`annotation` scopes) in ``jax.profiler.TraceAnnotation``
        so device profiles share the host trace's names.  Off by
        default — annotations cost a little even without an active
        profiler session.
    """

    enabled = True

    def __init__(self, capacity: int = 8192, annotate: bool = False):
        self.capacity = int(capacity)
        self.annotate = bool(annotate)
        self._events = deque(maxlen=self.capacity)
        self._appended = 0
        self._epoch = time.perf_counter()
        self._tid_lock = threading.Lock()
        self._tids: Dict[str, int] = {}

    # -- recording ----------------------------------------------------------
    def event(self, name: str, cat: str, t_start: float, dur_s: float,
              *, tid: int = 0, args: Optional[dict] = None) -> None:
        """Record one complete event; times are ``time.perf_counter``
        seconds (the tracer converts to trace microseconds on export).
        deque.append is atomic under the GIL — no lock on the hot path."""
        self._events.append((name, cat, t_start, dur_s, tid, args))
        self._appended += 1

    def instant(self, name: str, cat: str = "serving", *, tid: int = 0,
                args: Optional[dict] = None) -> None:
        """Zero-duration marker (rendered as an instant event)."""
        self._events.append((name, cat, time.perf_counter(), -1.0, tid,
                             args))
        self._appended += 1

    def span(self, name: str, cat: str = "serving", *, tid: int = 0,
             args: Optional[dict] = None) -> _Span:
        """-> context manager timing its body into one complete event."""
        return _Span(self, name, cat, tid, args)

    @staticmethod
    def _annotation(name: str):
        import jax
        return jax.profiler.TraceAnnotation(name)

    def annotation(self, name: str):
        """-> a ``jax.profiler.TraceAnnotation`` scope when ``annotate``
        is set (else a shared no-op) — the engine wraps executor dispatch
        in this so device timelines carry lane/executor names."""
        return self._annotation(name) if self.annotate else _NULL_CTX

    def tid(self, track: str) -> int:
        """Stable small int for a named track (lane, stage group)."""
        with self._tid_lock:
            t = self._tids.get(track)
            if t is None:
                t = self._tids[track] = len(self._tids) + 1
            return t

    @property
    def dropped(self) -> int:
        return max(0, self._appended - len(self._events))

    # -- export -------------------------------------------------------------
    def chrome_trace(self) -> dict:
        """-> Chrome trace-event JSON object (``traceEvents`` +
        ``displayTimeUnit``), Perfetto-loadable.  Timestamps are
        microseconds since the tracer's epoch."""
        events = list(self._events)          # atomic snapshot of the ring
        with self._tid_lock:
            tids = dict(self._tids)
        te = []
        for track, t in sorted(tids.items(), key=lambda kv: kv[1]):
            te.append({"name": "thread_name", "ph": "M", "pid": 1,
                       "tid": t, "args": {"name": track}})
        for name, cat, t0, dur, tid, args in events:
            ev = {"name": name, "cat": cat, "pid": 1, "tid": tid,
                  "ts": (t0 - self._epoch) * 1e6}
            if dur < 0:
                ev["ph"], ev["s"] = "i", "t"
            else:
                ev["ph"], ev["dur"] = "X", dur * 1e6
            if args:
                ev["args"] = args
            te.append(ev)
        return {"displayTimeUnit": "ms", "traceEvents": te,
                "otherData": {"dropped_events": self.dropped,
                              "capacity": self.capacity}}

    def export(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


class NullTracer:
    """The ``enabled=False`` tracer: constant no-ops everywhere."""

    enabled = False
    annotate = False
    capacity = 0
    dropped = 0

    def event(self, name, cat, t_start, dur_s, *, tid=0, args=None):
        pass

    def instant(self, name, cat="serving", *, tid=0, args=None):
        pass

    def span(self, name, cat="serving", *, tid=0, args=None):
        return _NULL_CTX

    def annotation(self, name):
        return _NULL_CTX

    def tid(self, track):
        return 0

    def chrome_trace(self):
        return {"displayTimeUnit": "ms", "traceEvents": [],
                "otherData": {"dropped_events": 0, "capacity": 0}}

    def export(self, path):
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


NULL_TRACER = NullTracer()
