"""Logical-axis -> mesh-axis sharding policies.

Params carry *logical* axis names ("embed", "mlp", "kv_heads", "q_per_kv",
"head_dim", "vocab", "state", "id_vocab", ...).  A policy dict maps those to
mesh axes ("data", "model", "pod").

Tensor parallelism for attention picks ONE of {kv_heads, q_per_kv, head_dim}
— whichever divides the model-axis width — per architecture
(:func:`attention_tp_axis`).  kv_heads gives classic Megatron sharding
(1 all-reduce / layer); head_dim is the fallback for kv=8 GQA archs on a
16-wide model axis (2 all-reduces / layer: after QK^T and after the out
projection).  The 5-D attention formulation (nn/attention.py) makes all
three choices propagate through GSPMD without resharding.

Parameter regimes:
  * ``tp``      — tensor-parallel only; params otherwise replicated.  Right
                  for <=8B archs where per-layer weight all-gathers would
                  cost more than replication saves.
  * ``tp_fsdp`` — additionally shard the "embed" (d_model) dim of large
                  matrices over data (+pod): ZeRO-3-style.  Required for
                  archs whose params+optimizer would not fit HBM otherwise.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


BASE_RULES = {
    "mlp": "model", "vocab": "model", "state": "model", "heads": "model",
    "id_vocab": "model",
    "kv_heads": None, "q_per_kv": None, "head_dim": None,
    "expert": None, "embed": None, "layers": None, "expert_dim": None,
    "embed_sub": None, None: None,
}


def attention_tp_axis(n_kv: int, q_per_kv: int, head_dim: int,
                      tp_width: int) -> Optional[str]:
    """Which attention logical axis to shard over the model mesh axis."""
    if n_kv % tp_width == 0:
        return "kv_heads"
    if q_per_kv % tp_width == 0:
        return "q_per_kv"
    if head_dim % tp_width == 0:
        return "head_dim"
    return None


def make_policy(mode: str = "tp", *, multi_pod: bool = False,
                model_cfg=None, tp_width: int = 16) -> dict:
    rules = dict(BASE_RULES)
    data_axes = ("pod", "data") if multi_pod else "data"
    if model_cfg is not None:
        ax = attention_tp_axis(model_cfg.n_kv,
                               model_cfg.n_heads // model_cfg.n_kv,
                               model_cfg.resolved_head_dim, tp_width)
        if ax:
            rules[ax] = "model"
        if ax == "head_dim":
            # kv=8-style GQA on a 16-wide axis: head_dim sharding is kept
            # for WEIGHT STORAGE, but full-sequence attention runs
            # sequence-parallel (queries sharded over 'model', K/V
            # all-gathered) — head_dim-sharded QK^T would all-reduce every
            # score matrix (§Perf iteration 5: 107 TB -> ~0.4 TB per step
            # for command-r prefill_32k).
            rules["_attn_seq"] = True
        if model_cfg.n_heads % tp_width == 0 and ax != "head_dim":
            rules["heads"] = "model"     # per-head scalars (mamba A/dt/D)
        elif model_cfg.n_heads % tp_width != 0:
            rules["heads"] = None
    if mode == "dp":
        # pure data parallelism over the WHOLE mesh: right for sub-1B
        # backbones (PinFM's transformer) where per-layer TP collectives
        # dwarf the once-per-step gradient all-reduce (§Perf iteration 7)
        for k in list(rules):
            rules[k] = None
        rules["_batch"] = (("pod", "data", "model") if multi_pod
                           else ("data", "model"))
        rules["_residual_model"] = False
        rules["id_vocab"] = rules["_batch"]
        return rules
    if mode == "tp_fsdp":
        rules["embed"] = data_axes
    elif mode != "tp":
        raise ValueError(f"unknown sharding mode {mode!r}")
    # PinFM hashed id tables (20.5B params): shard rows over the FULL mesh —
    # 16-way sharding leaves 10.2 GiB/chip of fp32 Adam moments
    # (§Perf iteration 6)
    rules["id_vocab"] = (("pod", "data", "model") if multi_pod
                         else ("data", "model"))
    rules["_batch"] = data_axes
    return rules


def batch_axes(policy: dict):
    return policy["_batch"]


def clean(policy: dict) -> dict:
    return {k: v for k, v in policy.items() if not str(k).startswith("_")}


def param_pspecs(spec_tree, policy: dict):
    from repro.nn.module import partition_specs
    return partition_specs(spec_tree, clean(policy))


def param_shardings(spec_tree, mesh: Mesh, policy: dict):
    pspecs = param_pspecs(spec_tree, policy)
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspecs,
                        is_leaf=lambda x: isinstance(x, P))


def data_pspec(policy: dict, extra_axes: int = 1) -> P:
    """PartitionSpec for a batch tensor: batch dim sharded, rest replicated."""
    return P(batch_axes(policy), *([None] * extra_axes))


def constrain(x, mesh: Mesh, *axes):
    """Sharding constraint helper for activations inside jit."""
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*axes)))


# ---------------------------------------------------------------------------
# Residual-stream activation sharding (EXPERIMENTS.md §Perf iteration 2).
#
# The layer-scan carry x: (B, S, d_model) is saved once per layer for the
# rematerialized backward — 64 x 1.6 GiB/device for command-r+ if only the
# batch dim is sharded.  Constraining d_model over "model" at layer
# boundaries cuts that 16x; GSPMD turns the tensor-parallel all-reduces into
# equal-byte reduce-scatter + all-gather pairs (Megatron sequence-parallel
# style).  Installed via a context manager so plain CPU tests (no mesh)
# are unaffected.
# ---------------------------------------------------------------------------

import contextlib

_ACT_CTX = None


@contextlib.contextmanager
def activation_constraints(mesh: Mesh, policy: dict):
    global _ACT_CTX
    prev = _ACT_CTX
    _ACT_CTX = (mesh, policy)
    try:
        yield
    finally:
        _ACT_CTX = prev


def seq_parallel_attention(q, k, v, positions, *, causal=True, window=None,
                           attend_fn=None):
    """Sequence-parallel full-sequence attention (§Perf iteration 5).

    q: (B, S, K, G, D); k/v: (B, S, K, D); positions: (B, S).
    Queries are sharded over 'model' along S; K/V are all-gathered once per
    layer (2*B*S*K*D bytes vs all-reducing B*H*S*T score matrices).  Returns
    None when no activation context / mesh is installed or shapes don't
    divide — caller falls back to the plain path.
    """
    if _ACT_CTX is None:
        return None
    mesh, policy = _ACT_CTX
    if not policy.get("_attn_seq") or "model" not in mesh.axis_names:
        return None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    S = q.shape[1]
    if S % sizes["model"] != 0 or S == 1:
        return None
    from jax.experimental.shard_map import shard_map

    batch_ax = policy.get("_batch")
    bw = sizes.get(batch_ax, 1) if not isinstance(batch_ax, tuple) else 0
    if isinstance(batch_ax, tuple):
        bw = 1
        for a in batch_ax:
            bw *= sizes[a]
    dp = batch_ax if q.shape[0] % max(bw, 1) == 0 else None

    def local(q_l, k_l, v_l, pos_l):
        k_f = jax.lax.all_gather(k_l, "model", axis=1, tiled=True)
        v_f = jax.lax.all_gather(v_l, "model", axis=1, tiled=True)
        pos_f = jax.lax.all_gather(pos_l, "model", axis=1, tiled=True)
        return attend_fn(q_l, k_f, v_f, q_pos=pos_l, k_pos=pos_f,
                         causal=causal, window=window)

    qspec = P(dp, "model", None, None, None)
    kspec = P(dp, "model", None, None)
    return shard_map(local, mesh=mesh,
                     in_specs=(qspec, kspec, kspec, P(dp, "model")),
                     out_specs=qspec, check_rep=False)(q, k, v, positions)


def constrain_residual(x, model_on_last: bool = True):
    """Shard (batch -> data[+pod], last dim -> model) where divisible.
    With model_on_last=False only the batch dim is constrained — used right
    after embedding gathers, where forcing a model-sharded output trips an
    XLA SPMD gather-partitioning bug for replicated (vocab%16!=0) tables."""
    if _ACT_CTX is None or x.ndim < 2:
        return x
    mesh, policy = _ACT_CTX
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def width(ax):
        if ax is None:
            return 1
        if isinstance(ax, tuple):
            n = 1
            for a in ax:
                n *= sizes[a]
            return n
        return sizes[ax]

    batch_ax = policy.get("_batch")
    spec = [None] * x.ndim
    if batch_ax and x.shape[0] % width(batch_ax) == 0:
        spec[0] = batch_ax
    if model_on_last and policy.get("_residual_model", True) \
            and "model" in sizes and x.shape[-1] % sizes["model"] == 0:
        spec[-1] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
