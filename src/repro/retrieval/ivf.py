"""IVF-ANN retrieval route: approximate top-k at 10M+ items over the
exact scorer machinery (ROADMAP item 2; PinnerFormer-style pooled-embedding
retrieval under PinFM's items/sec budget).

The exact paths scan the whole corpus; this route trades exactness for
scale with an inverted file (IVF):

  build    k-means centroids over the (dequantized) candidate-tower
           embeddings — Lloyd iterations run as jitted jnp blocks over the
           packed corpus, training on a row sample like faiss — then a
           STABLE permutation lays the corpus out cluster-contiguously.
           The permutation is pure metadata: ``IVFData.row_map`` (permuted
           -> original row) and ``inv_perm`` (original -> permuted) keep
           ``ItemFilter.exclude_ids`` and returned ids in the original id
           space (``ItemIndex.item_ids`` / ``id_rows`` consult them), and
           per-row PTQ makes the permuted table byte-identical row-wise.
  probe    each query routes to its top-``nprobe`` centroids on host (a
           (Q, C) dot against the centroid table — tiny), and the probed
           clusters' rows are visited as fixed-shape ``slice_rows`` slices
           of the permuted corpus: ``ivf_topk`` gathers the slices with
           ``lax.dynamic_slice`` and runs the SAME dequant+dot scoring as
           ``chunk_topk``, so recall loss comes ONLY from cluster pruning
           and is directly measurable against ``retrieval_topk_ref``.
  merge    the per-slice scores stream through the shared bitonic partial
           top-k merge (``kernels.retrieval_topk.bitonic_topk_merge``) —
           the same network the Pallas kernel carries — preserving the
           (score desc, lower row index) tie-break in the PHYSICAL
           (permuted) row space.  Scores are bit-identical to the exact
           oracle on probed rows; at full probe the whole result matches
           the exact paths run on the same permuted index bit-for-bit
           (equal-score ties order by physical row, so against the
           UNPERMUTED oracle the score arrays still match exactly while
           tied ids may legitimately swap).  Slots the probe does
           not fill carry ``valid = 0`` and never contribute, so one
           static (Q, S) shape serves every nprobe <= the attached
           maximum: ``compiles_after_warmup == 0`` holds through the
           warmed executor ladder.

Filters (the PR-3 open question, resolved): masks are PUSHED DOWN into
the probed slices — each visited slice gets its packed row-bitmask window
and excluded rows pin to -inf before selection, exactly like the exact
paths (no post-filter bias *within* the probed set).  What pushdown alone
cannot fix is a filter starving the probed clusters below k survivors;
when a ``recall_floor`` is configured, the scorer then WIDENS nprobe up a
doubling ladder (each level a pre-warmed executor shape) until the fill
fraction — finite slots / k, the recall proxy — reaches the floor or the
ladder ends.  Unfilled tail slots are ``(-inf, -1)`` sentinels: unlike
the exact paths (whose tails carry the lowest excluded row), an IVF tail
row was never *visited*, so no honest row index exists for it.

Rows appended by ``IndexBuilder.append`` after the build live as an
UNCLUSTERED TAIL (rows [n_clustered, n_items) in permuted space, identity
-mapped): they are assigned to their nearest centroid as metadata
(rebuild hint + staleness counter ``ivf_appended_unclustered``) but are
scanned EXACTLY by the existing chunk machinery and merged with the IVF
partial — so freshness never costs recall or a recompile.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.retrieval_topk import _SENTINEL_IDX, bitonic_topk_merge
from repro.retrieval.filters import (as_filter_list, excluded_rows,
                                     pack_bits)
from repro.retrieval.scorer import (_round_up, chunk_topk, merge_topk,
                                    unpack_codes)

MERGES = ("bitonic", "topk")


@dataclasses.dataclass(eq=False)
class IVFData:
    """Coarse-quantizer metadata riding on an :class:`ItemIndex`.

    ``eq=False`` keeps the default identity hash: the index is a
    registered pytree whose meta fields must be hashable for jit keys.

    Clustered rows occupy the permuted prefix [0, n_clustered); cluster c
    owns the contiguous permuted rows [starts[c], starts[c+1]).  Rows
    appended after the build sit in [n_clustered, n_items) (identity
    row_map) — the unclustered tail the scorers scan exactly."""
    centroids: np.ndarray     # (C, D) fp32 routing table
    starts: np.ndarray        # (C + 1,) int64 cluster row boundaries
    row_map: np.ndarray       # (n_items,) int64: permuted row -> original
    inv_perm: np.ndarray      # (n_items,) int64: original row -> permuted
    assignments: np.ndarray   # (n_items,) int32: ORIGINAL row -> cluster
    n_clustered: int

    @property
    def n_clusters(self) -> int:
        return self.centroids.shape[0]

    @property
    def n_items(self) -> int:
        return self.row_map.shape[0]

    @property
    def appended_unclustered(self) -> int:
        """Staleness: rows appended since the last (re)build."""
        return self.n_items - self.n_clustered

    def max_cluster_rows(self) -> int:
        return int(np.max(np.diff(self.starts))) if self.n_clusters else 0


# -- k-means (Lloyd, jnp blocks) ------------------------------------------

def _make_assign(C: int, D: int, block: int):
    """Jitted one-block Lloyd step: nearest centroid per row + weighted
    per-cluster sums/counts (weight 0 parks pad rows in a spare segment).
    argmin ties go to the LOWER cluster index (deterministic builds)."""
    def f(xb, w, c):
        d = 0.5 * jnp.sum(c * c, axis=1)[None, :] - xb @ c.T   # (B, C)
        a = jnp.argmin(d, axis=1).astype(jnp.int32)
        aw = jnp.where(w > 0, a, C)
        sums = jax.ops.segment_sum(xb * w[:, None], aw, num_segments=C + 1)
        cnts = jax.ops.segment_sum(w, aw, num_segments=C + 1)
        return a, sums[:C], cnts[:C]
    return jax.jit(f)


def _blocks(x: np.ndarray, block: int):
    """Yield (padded fp32 block, weight) pairs of static shape."""
    R = x.shape[0]
    for off in range(0, R, block):
        xb = np.asarray(x[off:off + block], np.float32)
        n = xb.shape[0]
        w = np.ones(block, np.float32)
        if n < block:
            xb = np.pad(xb, ((0, block - n), (0, 0)))
            w[n:] = 0.0
        yield xb, w


def kmeans(x, n_clusters: int, *, iters: int = 8, seed: int = 0,
           block_rows: int = 8192):
    """Lloyd k-means over (R, D) fp32 rows -> ((C, D) centroids,
    (R,) int32 assignments to the RETURNED centroids).

    Rows stream through a jitted block step (argmin + segment sums), so
    peak memory is one (block_rows, C) distance tile, not (R, C).  Empty
    clusters keep their previous centroid.  Deterministic in (x, seed)."""
    x = np.asarray(x, np.float32)
    R, D = x.shape
    C = int(min(n_clusters, R))
    assert C > 0
    rng = np.random.default_rng(seed)
    cents = x[np.sort(rng.choice(R, size=C, replace=False))].copy()
    block = int(min(block_rows, _round_up(R, 8)))
    step = _make_assign(C, D, block)
    assign = np.zeros(R, np.int32)
    for it in range(max(1, iters)):
        sums = np.zeros((C, D), np.float64)
        cnts = np.zeros(C, np.float64)
        cj = jnp.asarray(cents)
        pos = 0
        for xb, w in _blocks(x, block):
            a, s, c = step(jnp.asarray(xb), jnp.asarray(w), cj)
            n = int(w.sum())
            assign[pos:pos + n] = np.asarray(a)[:n]
            sums += np.asarray(s, np.float64)
            cnts += np.asarray(c, np.float64)
            pos += n
        if it == iters - 1:
            break        # assignments already match the final centroids
        nz = cnts > 0
        cents[nz] = (sums[nz] / cnts[nz, None]).astype(np.float32)
    return cents, assign


def assign_rows(x, centroids, *, block_rows: int = 8192) -> np.ndarray:
    """Nearest-centroid assignment pass (no centroid update): the append
    path and the final build pass share it.  -> (R,) int32."""
    x = np.asarray(x, np.float32)
    R, D = x.shape
    C = centroids.shape[0]
    block = int(min(block_rows, _round_up(max(R, 1), 8)))
    step = _make_assign(C, D, block)
    cj = jnp.asarray(centroids, jnp.float32)
    out = np.zeros(R, np.int32)
    pos = 0
    for xb, w in _blocks(x, block):
        a, _, _ = step(jnp.asarray(xb), jnp.asarray(w), cj)
        n = int(w.sum())
        out[pos:pos + n] = np.asarray(a)[:n]
        pos += n
    return out


def dequant_rows(qt, start: int, n: int) -> np.ndarray:
    """Dequantize corpus rows [start, start+n) -> (n, D) fp32 numpy —
    the embedding space every scorer path sees (building the quantizer on
    the dequantized table keeps routing consistent with scoring)."""
    pk = jnp.asarray(np.asarray(qt.packed)[start:start + n])
    sc = jnp.asarray(np.asarray(qt.scale)[start:start + n], jnp.float32)
    bs = jnp.asarray(np.asarray(qt.bias)[start:start + n], jnp.float32)
    return np.asarray(unpack_codes(pk, qt.bits) * sc + bs)


def build_ivf(index, n_clusters: int, *, iters: int = 8, seed: int = 0,
              train_rows: int = 131072, block_rows: int = 8192):
    """Cluster an :class:`ItemIndex` -> a NEW index with a
    cluster-contiguous row layout and :class:`IVFData` attached.

    k-means trains on a ``train_rows`` sample (faiss-style — a full-corpus
    Lloyd pass at 10M rows buys nothing), then one assignment pass covers
    every row.  The stable permutation (argsort of assignments) preserves
    original row order within each cluster, so the tie-break contract maps
    cleanly back through ``row_map``.  Rebuilding an already-IVF index
    re-clusters from the ORIGINAL row order (folding any appended tail
    into proper clusters, resetting the staleness counter)."""
    from repro.quant.ptq import QuantizedTable
    from repro.retrieval.index import ItemIndex

    n = index.n_items
    assert 0 < n_clusters
    qt = index.qt
    packed = np.asarray(qt.packed)[:n]
    scale = np.asarray(qt.scale)[:n]
    bias = np.asarray(qt.bias)[:n]
    surfaces = (None if index.surfaces is None
                else np.asarray(index.surfaces)[:n])
    if index.ivf is not None:      # rebuild: undo the previous permutation
        back = np.asarray(index.ivf.inv_perm)
        packed, scale, bias = packed[back], scale[back], bias[back]
        if surfaces is not None:
            surfaces = surfaces[back]
    base_qt = QuantizedTable(packed=jnp.asarray(packed),
                             scale=jnp.asarray(scale),
                             bias=jnp.asarray(bias),
                             bits=qt.bits, dim=qt.dim)

    rng = np.random.default_rng(seed)
    if n > train_rows:
        sample = np.sort(rng.choice(n, size=train_rows, replace=False))
    else:
        sample = np.arange(n)
    train = np.concatenate([
        dequant_rows(base_qt, int(lo), int(hi - lo + 1))[
            sample[(sample >= lo) & (sample <= hi)] - lo]
        for lo, hi in _sample_windows(sample, block_rows)]) \
        if len(sample) else np.zeros((0, qt.dim), np.float32)
    cents, _ = kmeans(train, n_clusters, iters=iters, seed=seed,
                      block_rows=block_rows)
    C = cents.shape[0]

    assign = np.zeros(n, np.int32)
    for off in range(0, n, block_rows):
        m = min(block_rows, n - off)
        assign[off:off + m] = assign_rows(
            dequant_rows(base_qt, off, m), cents, block_rows=block_rows)

    order = np.argsort(assign, kind="stable").astype(np.int64)
    counts = np.bincount(assign, minlength=C).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(counts)])
    inv = np.empty(n, np.int64)
    inv[order] = np.arange(n, dtype=np.int64)
    new_qt = QuantizedTable(packed=jnp.asarray(packed[order]),
                            scale=jnp.asarray(scale[order]),
                            bias=jnp.asarray(bias[order]),
                            bits=qt.bits, dim=qt.dim)
    ivf = IVFData(centroids=cents.astype(np.float32), starts=starts,
                  row_map=order, inv_perm=inv, assignments=assign,
                  n_clustered=n)
    return ItemIndex(qt=new_qt, start_id=index.start_id, n_items=n,
                     surfaces=None if surfaces is None else surfaces[order],
                     ivf=ivf)


def _sample_windows(sample: np.ndarray, block: int):
    """Group sorted sample rows into <= block-wide dequant windows."""
    out = []
    i = 0
    while i < len(sample):
        lo = sample[i]
        j = i
        while j + 1 < len(sample) and sample[j + 1] - lo < block:
            j += 1
        out.append((lo, sample[j]))
        i = j + 1
    return out


def ivf_append(ivf: IVFData, new_rows: np.ndarray) -> IVFData:
    """Extend the IVF metadata for rows appended AFTER the build: each new
    row is assigned to its NEAREST EXISTING centroid (metadata only — no
    re-cluster, no permutation change), and physically lives in the
    identity-mapped unclustered tail that the scorers scan exactly.
    ``appended_unclustered`` grows by len(new_rows); a later
    :func:`build_ivf` rebuild folds the tail into real clusters."""
    n_new = int(np.asarray(new_rows).shape[0])
    n0 = ivf.n_items
    tail = np.arange(n0, n0 + n_new, dtype=np.int64)
    return IVFData(
        centroids=ivf.centroids, starts=ivf.starts,
        row_map=np.concatenate([ivf.row_map, tail]),
        inv_perm=np.concatenate([ivf.inv_perm, tail]),
        assignments=np.concatenate([
            ivf.assignments,
            assign_rows(new_rows, ivf.centroids)]).astype(np.int32),
        n_clustered=ivf.n_clustered)


# -- probing: host-side routing + slice tables ----------------------------

class SliceTable:
    """Per-cluster slice decomposition for one (IVFData, slice_rows):
    cluster c's permuted row span cut into fixed ``slice_rows`` windows —
    (offset, valid) pairs the device scorer gathers.  ``spc`` bounds the
    slices any one cluster contributes, so S = nprobe * spc is a static
    executor shape."""

    def __init__(self, ivf: IVFData, slice_rows: int):
        assert slice_rows % 32 == 0, \
            f"slice_rows={slice_rows} must be a multiple of 32 (packed " \
            "filter-mask words cover 32 rows)"
        self.slice_rows = int(slice_rows)
        offs, vals, ptr = [], [], [0]
        for c in range(ivf.n_clusters):
            a, b = int(ivf.starts[c]), int(ivf.starts[c + 1])
            for o in range(a, b, slice_rows):
                offs.append(o)
                vals.append(min(slice_rows, b - o))
            ptr.append(len(offs))
        self.off = np.asarray(offs, np.int32)
        self.val = np.asarray(vals, np.int32)
        self.ptr = np.asarray(ptr, np.int64)
        self.total = len(offs)
        self.spc = int(max(1, (np.diff(self.ptr).max()
                               if ivf.n_clusters else 1)))

    def slots(self, nprobe: int) -> int:
        """Static slot count S covering any top-``nprobe`` probe."""
        return int(min(max(1, nprobe) * self.spc, max(self.total, 1)))

    def gather(self, clusters: np.ndarray, S: int):
        """(Q, P) probed cluster ids (ascending per query) -> (Q, S)
        offsets/valids; unused slots are (0, 0) and score nothing."""
        Q = clusters.shape[0]
        off = np.zeros((Q, S), np.int32)
        val = np.zeros((Q, S), np.int32)
        for q in range(Q):
            n = 0
            for c in clusters[q]:
                lo, hi = int(self.ptr[c]), int(self.ptr[c + 1])
                m = hi - lo
                if m == 0:
                    continue
                off[q, n:n + m] = self.off[lo:hi]
                val[q, n:n + m] = self.val[lo:hi]
                n += m
            assert n <= S, (n, S)
        return off, val


def ivf_route(centroids: np.ndarray, queries: np.ndarray,
              nprobe: int) -> np.ndarray:
    """Top-``nprobe`` clusters per query by the L2 routing score
    q.c - ||c||^2/2 (argmax == nearest centroid).  Host numpy — the
    (Q, C) product is microscopic next to the corpus scan.  Ties pick the
    lower cluster id; the returned ids are sorted ASCENDING per query so
    gathered slice offsets ascend and the row tie-break is preserved.
    -> (Q, min(nprobe, C)) int."""
    q = np.asarray(queries, np.float32)
    c = np.asarray(centroids, np.float32)
    s = q @ c.T - 0.5 * np.sum(c * c, axis=1)[None, :]
    P = int(min(nprobe, c.shape[0]))
    top = np.argsort(-s, axis=1, kind="stable")[:, :P]
    return np.sort(top, axis=1)


def slice_masks(filters, index, offsets: np.ndarray, valids: np.ndarray,
                slice_rows: int, *, cache: Optional[dict] = None):
    """Filter pushdown: resolve per-query filters into packed bitmask
    windows of the PROBED slices only -> (Q, S, slice_rows/32) int32, or
    None when every filter is empty.  Rows are memoized per (fingerprint,
    slice offset) — pass ``cache`` to share the memo across calls (the
    engine passes its LRU)."""
    if filters is None or all(f is None or f.is_empty() for f in filters):
        return None
    Q, S = offsets.shape
    W = slice_rows // 32
    memo = cache if cache is not None else {}
    out = np.zeros((Q, S, W), np.int32)
    any_set = False
    for qi, f in enumerate(filters):
        if f is None or f.is_empty():
            continue
        fp = f.fingerprint()
        for si in range(S):
            if valids[qi, si] <= 0:
                continue
            key = (fp, "ivf", int(offsets[qi, si]))
            row = memo.get(key)
            if row is None:
                row = pack_bits(excluded_rows(
                    f, index, int(offsets[qi, si]), slice_rows))
                memo[key] = row
            if row.any():
                out[qi, si] = row
                any_set = True
    return out if any_set else None


# -- the device scorer core ----------------------------------------------

def ivf_topk(queries, packed, scale, bias, offsets, valids, mask=None, *,
             k: int, bits: int = 4, slice_rows: int, row_offset=0,
             merge: str = "bitonic"):
    """Score the probed slices of a permuted corpus and return their
    top-k.  Pure jnp, jit-friendly, static in (Q, S, slice_rows, k).

    packed/scale/bias: the PERMUTED corpus, padded by >= slice_rows rows
      so every gather is in-bounds (``lax.dynamic_slice`` clamping would
      silently shift rows — the pad makes clamping unreachable).
    offsets/valids: (Q, S) int32 slice descriptors from
      :meth:`SliceTable.gather`; offsets ascend per query; ``valid = 0``
      slots are inert, so one executor serves every probe width <= S.
    mask: optional (Q, S, slice_rows/32) packed pushdown bitmask.
    row_offset: traced scalar added to returned rows (sharding).

    Scoring is the same dequant-then-dot formula as ``chunk_topk`` — on
    probed rows the two paths see identical fp operands.  Selection
    either streams slices through the shared bitonic merge (default; the
    kernel's own network, O(k + slice_rows) live values) or flattens to
    one ``lax.top_k``; both realize (score desc, row asc), bit-identical.
    Tail slots with no surviving row are ``(-inf, -1)``.

    -> (scores (Q, k) fp32, permuted rows (Q, k) int32, -1 = unfilled).
    """
    assert merge in MERGES, merge
    queries = jnp.asarray(queries, jnp.float32)
    Q, D = queries.shape
    S = offsets.shape[1]
    sr = int(slice_rows)
    offsets = jnp.asarray(offsets, jnp.int32)
    valids = jnp.asarray(valids, jnp.int32)

    def one(o):
        return (jax.lax.dynamic_slice_in_dim(packed, o, sr, 0),
                jax.lax.dynamic_slice_in_dim(scale, o, sr, 0),
                jax.lax.dynamic_slice_in_dim(bias, o, sr, 0))

    pk, sc, bs = jax.vmap(jax.vmap(one))(offsets)     # (Q, S, sr, .)
    deq = (unpack_codes(pk, bits) * sc.astype(jnp.float32)
           + bs.astype(jnp.float32))                  # (Q, S, sr, D)
    s = jnp.einsum("qsrd,qd->qsr", deq, queries,
                   preferred_element_type=jnp.float32)
    local = jnp.arange(sr, dtype=jnp.int32)
    rows = offsets[:, :, None] + local[None, None, :]
    s = jnp.where(local[None, None, :] < valids[:, :, None], s, -jnp.inf)
    if mask is not None:
        mwords = jnp.asarray(mask, jnp.int32)         # (Q, S, sr/32)
        mbits = ((mwords[..., None]
                  >> jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, 32), 3))
                 & 1).reshape(s.shape)
        s = jnp.where(mbits == 1, -jnp.inf, s)

    if merge == "bitonic":
        init = (jnp.full((Q, k), -jnp.inf, jnp.float32),
                jnp.full((Q, k), _SENTINEL_IDX, jnp.int32))

        def body(carry, blk):
            return bitonic_topk_merge(carry[0], carry[1], blk[0], blk[1],
                                      k=k), None

        (top_s, top_r), _ = jax.lax.scan(
            body, init, (jnp.moveaxis(s, 1, 0), jnp.moveaxis(rows, 1, 0)))
    else:
        flat_s = s.reshape(Q, S * sr)
        flat_r = rows.reshape(Q, S * sr)
        if S * sr < k:               # k > survivors even before masking
            padw = k - S * sr
            flat_s = jnp.concatenate(
                [flat_s, jnp.full((Q, padw), -jnp.inf, jnp.float32)], 1)
            flat_r = jnp.concatenate(
                [flat_r, jnp.full((Q, padw), _SENTINEL_IDX, jnp.int32)], 1)
        top_s, idx = jax.lax.top_k(flat_s, k)
        top_r = jnp.take_along_axis(flat_r, idx, axis=1)
    top_r = jnp.where(top_s == -jnp.inf, jnp.int32(-1),
                      top_r + jnp.asarray(row_offset, jnp.int32))
    return top_s, top_r


def pad_for_slices(qt, slice_rows: int):
    """Device-resident permuted corpus padded so every slice gather is
    in-bounds -> (packed, scale (fp16), bias (fp16)) jnp arrays."""
    pad = slice_rows
    packed = jnp.pad(jnp.asarray(qt.packed), ((0, pad), (0, 0)))
    scale = jnp.pad(jnp.asarray(qt.scale, jnp.float16), ((0, pad), (0, 0)))
    bias = jnp.pad(jnp.asarray(qt.bias, jnp.float16), ((0, pad), (0, 0)))
    return packed, scale, bias


# -- standalone scorer (benchmarks / tests / notebooks) -------------------

class IVFScorer:
    """IVF top-k against an IVF-built :class:`ItemIndex` — the standalone
    counterpart of ``CorpusScorer`` for the ANN route (the serving engine
    wires the same pieces through its warmed executor registry instead).

    ``nprobe`` is the base probe width; with ``recall_floor`` set the
    probe widens up a doubling ladder of ``widen`` extra levels whenever a
    query's fill fraction (finite slots / k — the recall proxy) lands
    below the floor.  Appended-but-unclustered rows are scanned exactly
    every call.  Returned rows are PERMUTED corpus rows (-1 sentinels for
    unfilled tails); :meth:`retrieve` maps them to item ids."""

    def __init__(self, index, *, nprobe: int = 8, slice_rows: int = 4096,
                 widen: int = 2, recall_floor: Optional[float] = None,
                 merge: str = "bitonic"):
        if index.ivf is None:
            raise ValueError("IVFScorer needs an IVF-built index — run "
                             "retrieval.ivf.build_ivf(index, n_clusters)")
        assert merge in MERGES, merge
        self.index = index
        self.ivf: IVFData = index.ivf
        self.merge = merge
        self.recall_floor = recall_floor
        sr = int(min(slice_rows,
                     max(32, _round_up(self.ivf.max_cluster_rows(), 32))))
        self.table = SliceTable(self.ivf, sr)
        self.slice_rows = sr
        C = self.ivf.n_clusters
        base = int(min(max(1, nprobe), C))
        lvls = sorted({min(base * 2 ** j, C)
                       for j in range(max(0, widen) + 1)})
        self.nprobe_levels = lvls
        self.nprobe = base
        self.packed, self.scale, self.bias = pad_for_slices(index.qt, sr)
        self.widened = 0
        self._jitted = {}

    def _fn(self, k: int, S: int, masked: bool):
        key = (k, S, masked)
        fn = self._jitted.get(key)
        if fn is None:
            import functools
            fn = self._jitted[key] = jax.jit(functools.partial(
                ivf_topk, k=k, bits=self.index.bits,
                slice_rows=self.slice_rows, merge=self.merge))
        return fn

    def _probe(self, q: np.ndarray, k: int, nprobe: int, filters):
        S = self.table.slots(nprobe)
        clusters = ivf_route(self.ivf.centroids, q, nprobe)
        off, val = self.table.gather(clusters, S)
        mask = slice_masks(filters, self.index, off, val, self.slice_rows)
        fn = self._fn(k, S, mask is not None)
        args = (jnp.asarray(q), self.packed, self.scale, self.bias,
                jnp.asarray(off), jnp.asarray(val))
        if mask is not None:
            args += (jnp.asarray(mask),)
        s, r = fn(*args)
        tel = {"clusters_probed": int(clusters.shape[0] * clusters.shape[1]),
               "rows_scanned": int(val.sum())}
        return np.asarray(s), np.asarray(r), tel

    def _tail_topk(self, q: np.ndarray, k: int, filters):
        """Exact scan of the appended unclustered tail via ``chunk_topk``
        (the same executor body the engine's tail chunks run)."""
        nc, n = self.ivf.n_clustered, self.index.n_items
        rows = n - nc
        ch = _round_up(rows, 32)
        pk = jnp.asarray(np.asarray(self.index.qt.packed)[nc:nc + ch])
        sc = jnp.asarray(np.asarray(self.index.qt.scale)[nc:nc + ch],
                         jnp.float16)
        bs = jnp.asarray(np.asarray(self.index.qt.bias)[nc:nc + ch],
                         jnp.float16)
        if pk.shape[0] < ch:
            pad = ch - pk.shape[0]
            pk = jnp.pad(pk, ((0, pad), (0, 0)))
            sc = jnp.pad(sc, ((0, pad), (0, 0)))
            bs = jnp.pad(bs, ((0, pad), (0, 0)))
        mask = None
        if filters is not None and any(
                f is not None and not f.is_empty() for f in filters):
            mask = jnp.asarray(np.stack(
                [pack_bits(excluded_rows(f, self.index, nc, ch))
                 for f in filters]))
        s, r = chunk_topk(jnp.asarray(q), pk, sc, bs,
                          jnp.asarray(nc, jnp.int32),
                          jnp.asarray(rows, jnp.int32),
                          k=min(k, ch), bits=self.index.bits, mask=mask)
        return np.asarray(s), np.asarray(r)

    def topk(self, queries, k: int, *, filters=None):
        """-> (scores (Q, k) fp32, permuted rows (Q, k) int32; tail slots
        are (-inf, -1)).  ``filters``: one ItemFilter broadcast or a
        per-query sequence, pushed down into the probed slices."""
        assert 0 < k <= self.index.n_items
        q = np.asarray(queries, np.float32)
        assert q.ndim == 2 and q.shape[1] == self.index.dim
        filters = (as_filter_list(filters, q.shape[0])
                   if filters is not None else None)
        lvl = 0
        while True:
            s, r, _ = self._probe(q, k, self.nprobe_levels[lvl], filters)
            if self.ivf.appended_unclustered:
                ts, tr = self._tail_topk(q, k, filters)
                s, r = merge_topk([s, ts], [r, tr], k)
                r = np.where(s == -np.inf, -1, r)
            fill = np.min(np.mean(s > -np.inf, axis=1))
            if (self.recall_floor is None or fill >= self.recall_floor
                    or lvl + 1 >= len(self.nprobe_levels)):
                return s, r
            lvl += 1
            self.widened += 1

    def retrieve(self, queries, k: int, *, filters=None):
        """Like :meth:`topk` but rows map to item ids (-1 = unfilled)."""
        s, r = self.topk(queries, k, filters=filters)
        return s, self.index.item_ids(r)
