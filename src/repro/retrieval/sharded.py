"""ShardedRetriever: corpus top-k over a device mesh.

The packed corpus is split into contiguous row ranges, one per device along
the ``data`` mesh axis (the same axis ``distributed.sharding`` uses for the
row-sharded id tables).  Each shard runs the fused scorer locally over its
rows (queries replicated), producing a per-shard exact top-k with GLOBAL
row indices (shard offset via ``lax.axis_index``); the tiny (n_dev, Q, k)
partials are merged on host with the stable lower-index-wins rule.

Per-device work and memory drop by n_dev; the only cross-device traffic is
the replicated (Q, D) query block in and (Q, k) partials out — no score
matrix, no corpus movement.

With ``route="ivf"`` (an IVF-built index — see ``retrieval.ivf``) each
shard probes only the SHARD-LOCAL portions of the query's top-``nprobe``
clusters: routing runs once on host against the global centroid table,
the probed clusters' slices are clipped to each shard's row range (plus
the appended unclustered tail, which is always visited), and every shard
runs the same ``ivf_topk`` slice-gather scorer over its clipped slices —
shards owning none of the probed rows contribute only sentinel slots.
The host merge is unchanged; unfilled tails come back as (-inf, -1).

The PLANNING half of this module (:func:`shard_layout`,
:func:`shard_filter_masks`, :func:`plan_ivf_shards`) is deliberately
shard_map-free: the same host-side plans drive the one-process mesh
retriever here AND the cluster tier's scatter/gather
(``repro.cluster.fanout``), where each "shard" is a separate engine
worker instead of a mesh device — the merge contract (lower index wins,
shards in row order) is identical on both sides.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.retrieval.filters import as_filter_list, filter_masks
from repro.retrieval.index import ItemIndex
from repro.retrieval.scorer import fused_topk, merge_topk, _round_up


def shard_layout(n_rows: int, n_shards: int, *, chunk_rows: int = 32768,
                 block_rows: int = 32):
    """Contiguous-row shard geometry shared by the mesh retriever and the
    cluster fan-out: every shard holds the same whole number of scan
    chunks (the fused scorer's streaming requirement).
    -> (chunk_rows, rows_per_shard)."""
    per = _round_up(n_rows, n_shards) // n_shards
    cr = min(chunk_rows, _round_up(per, block_rows))
    return cr, _round_up(per, cr)


def shard_filter_masks(index: ItemIndex, filters, n_queries: int,
                       n_shards: int, rows_per_shard: int):
    """-> (n_shards, Q, ceil(rows_per_shard/32)) int32 stacked shard-local
    packed bitmasks (numpy), or None when every filter is empty.  Shard s
    covers rows [s * rows_per_shard, (s+1) * rows_per_shard)."""
    filters = as_filter_list(filters, n_queries)
    ms = [filter_masks(filters, index, row_start=s * rows_per_shard,
                       n_rows=rows_per_shard) for s in range(n_shards)]
    if ms[0] is None:     # emptiness is a global property of `filters`
        return None
    return np.stack([np.asarray(m) for m in ms])


def plan_ivf_shards(index: ItemIndex, tab, queries_np, nprobe: int,
                    filters, n_shards: int, rows_per_shard: int):
    """Host-side IVF probe planning for row-sharded execution: global
    centroid routing, per-shard clipped slice descriptors (+ the appended
    unclustered tail on its owning shards), and per-shard pushdown masks.
    ``tab`` is the index's :class:`~repro.retrieval.ivf.SliceTable`.
    -> (off (n_shards, Q, S), val, masks or None, S).  Used by
    :class:`ShardedRetriever` (one process, shard_map) and by the cluster
    tier's fan-out (one plan window per engine worker)."""
    from repro.retrieval.filters import excluded_rows, pack_bits
    from repro.retrieval.ivf import ivf_route
    ivf = index.ivf
    sr = tab.slice_rows
    rps = rows_per_shard
    Q = queries_np.shape[0]
    clusters = ivf_route(ivf.centroids, queries_np, nprobe)
    nc, n = ivf.n_clustered, index.n_items
    tail = [(o, min(sr, n - o)) for o in range(nc, n, sr)]
    S = tab.slots(clusters.shape[1]) + len(tail)
    off = np.zeros((n_shards, Q, S), np.int32)
    val = np.zeros((n_shards, Q, S), np.int32)
    filts = (as_filter_list(filters, Q)
             if filters is not None else [None] * Q)
    masked = any(f is not None and not f.is_empty() for f in filts)
    masks = (np.zeros((n_shards, Q, S, sr // 32), np.int32)
             if masked else None)
    memo = {}
    for q in range(Q):
        # probed cluster slices (ascending) then the unclustered tail
        # (highest rows) — global row order, so the merge tie-break
        # contract carries over
        gslices = []
        for c in clusters[q]:
            lo, hi = int(tab.ptr[c]), int(tab.ptr[c + 1])
            gslices += [(int(tab.off[i]), int(tab.val[i]))
                        for i in range(lo, hi)]
        gslices += tail
        used = np.zeros(n_shards, np.int32)
        for o, v in gslices:
            s0, s1 = o // rps, (o + v - 1) // rps
            for sh in range(s0, min(s1, n_shards - 1) + 1):
                lo = sh * rps
                a, b = max(o, lo), min(o + v, lo + rps)
                if b <= a:
                    continue
                j = used[sh]
                off[sh, q, j] = a - lo
                val[sh, q, j] = b - a
                if masked and filts[q] is not None:
                    key = (filts[q].fingerprint(), a)
                    row = memo.get(key)
                    if row is None:
                        row = memo[key] = pack_bits(excluded_rows(
                            filts[q], index, a, sr))
                    masks[sh, q, j] = row
                used[sh] = j + 1
    return off, val, masks, S


class ShardedRetriever:
    """Splits an :class:`ItemIndex` across the ``data`` axis of a mesh.

    Per-request :class:`~repro.retrieval.filters.ItemFilter` constraints
    are resolved on host into one packed row bitmask PER SHARD (each in
    shard-local row coordinates), stacked along the ``data`` axis and
    applied inside each shard's fused scorer — excluded rows are pinned to
    -inf before the per-shard top-k, and the stable lower-index-wins merge
    then matches the single-device filtered result exactly."""

    def __init__(self, index: ItemIndex, mesh: Optional[Mesh] = None, *,
                 devices: Optional[Sequence] = None,
                 chunk_rows: int = 32768, block_rows: int = 32):
        if mesh is None:
            devices = list(devices if devices is not None else jax.devices())
            mesh = Mesh(np.asarray(devices), ("data",))
        assert "data" in mesh.axis_names
        self.mesh = mesh
        self.index = index
        self.n_shards = mesh.shape["data"]
        qt = index.qt
        R = qt.packed.shape[0]
        self.block_rows = block_rows
        # every shard must hold the same whole number of scan chunks
        self.chunk_rows, self.rows_per_shard = shard_layout(
            R, self.n_shards, chunk_rows=chunk_rows, block_rows=block_rows)
        pad = self.rows_per_shard * self.n_shards - R
        # committed to the mesh layout once — otherwise every topk() call
        # would reshard (copy) the whole corpus into P("data")
        shard = NamedSharding(self.mesh, P("data", None))
        self.packed = jax.device_put(
            jnp.pad(jnp.asarray(qt.packed), ((0, pad), (0, 0))), shard)
        self.scale = jax.device_put(
            jnp.pad(jnp.asarray(qt.scale, jnp.float16), ((0, pad), (0, 0))),
            shard)
        self.bias = jax.device_put(
            jnp.pad(jnp.asarray(qt.bias, jnp.float16), ((0, pad), (0, 0))),
            shard)
        self._jitted = {}

    def _build(self, k: int, masked: bool):
        rps = self.rows_per_shard
        # a shard can contribute at most its own rows to the global top-k,
        # so clipping the per-shard k keeps the merge exact while letting
        # k exceed rows_per_shard (small shards, large k)
        k_local = min(k, rps)

        def local(q, pk, sc, bs, *m):
            shard = jax.lax.axis_index("data")
            off = shard * rps
            n_valid = jnp.clip(self.index.n_items - off, 0, rps)
            s, r = fused_topk(q, pk, sc, bs, k=k_local, bits=self.index.bits,
                              chunk_rows=self.chunk_rows,
                              block_rows=self.block_rows,
                              n_valid=n_valid, row_offset=off,
                              mask=m[0][0] if m else None)
            return s[None], r[None]               # (1, Q, k_local) per shard

        in_specs = (P(None, None), P("data", None),
                    P("data", None), P("data", None))
        if masked:   # stacked per-shard masks ride the same data axis
            in_specs += (P("data", None, None),)
        fn = shard_map(local, mesh=self.mesh, in_specs=in_specs,
                       out_specs=(P("data", None, None),
                                  P("data", None, None)),
                       check_rep=False)
        return jax.jit(fn)

    # -- IVF route: shard-local cluster probing -----------------------------
    def _ivf_state(self):
        """Lazy (SliceTable, slice_rows) for the attached IVF metadata."""
        if getattr(self, "_ivf_tab", None) is None:
            from repro.retrieval.ivf import SliceTable
            ivf = self.index.ivf
            sr = int(min(4096, max(32, _round_up(
                max(ivf.max_cluster_rows(), 1), 32))))
            self._ivf_tab = SliceTable(ivf, sr)
        return self._ivf_tab

    def _build_ivf(self, k: int, S: int, masked: bool):
        from repro.retrieval.ivf import ivf_topk
        rps = self.rows_per_shard
        tab = self._ivf_state()
        sr = tab.slice_rows
        k_local = min(k, rps)
        bits = self.index.bits

        def local(q, pk, sc, bs, off, val, *m):
            shard = jax.lax.axis_index("data")
            # pad the shard block by one slice so every clipped-slice
            # gather is in-bounds (dynamic_slice clamping would shift rows)
            pk = jnp.pad(pk, ((0, sr), (0, 0)))
            sc = jnp.pad(sc, ((0, sr), (0, 0)))
            bs = jnp.pad(bs, ((0, sr), (0, 0)))
            s, r = ivf_topk(q, pk, sc, bs, off[0], val[0],
                            m[0][0] if m else None, k=k_local, bits=bits,
                            slice_rows=sr, row_offset=shard * rps)
            return s[None], r[None]

        in_specs = (P(None, None), P("data", None), P("data", None),
                    P("data", None), P("data", None, None),
                    P("data", None, None))
        if masked:
            in_specs += (P("data", None, None, None),)
        fn = shard_map(local, mesh=self.mesh, in_specs=in_specs,
                       out_specs=(P("data", None, None),
                                  P("data", None, None)),
                       check_rep=False)
        return jax.jit(fn)

    def _ivf_probe(self, queries_np, nprobe: int, filters):
        """Host-side probe planning — :func:`plan_ivf_shards` with this
        retriever's geometry.  -> (off (n_sh, Q, S), val, masks or None,
        S)."""
        return plan_ivf_shards(self.index, self._ivf_state(), queries_np,
                               nprobe, filters, self.n_shards,
                               self.rows_per_shard)

    def _topk_ivf(self, queries, k: int, *, nprobe: int, filters=None):
        q_np = np.asarray(queries, np.float32)
        off, val, masks, S = self._ivf_probe(q_np, nprobe, filters)
        key = ("ivf", k, S, masks is not None)
        fn = self._jitted.get(key)
        if fn is None:
            fn = self._jitted[key] = self._build_ivf(k, S, masks is not None)
        args = (jnp.asarray(q_np), self.packed, self.scale, self.bias,
                jnp.asarray(off), jnp.asarray(val))
        if masks is not None:
            args += (jnp.asarray(masks),)
        s, r = fn(*args)
        s, r = np.asarray(s), np.asarray(r)             # (n_dev, Q, k_l)
        s, r = merge_topk(list(s), list(r), k)
        if s.shape[-1] < k:     # tiny shards: k > n_dev * k_local survivors
            padw = k - s.shape[-1]
            s = np.pad(s, ((0, 0), (0, padw)), constant_values=-np.inf)
            r = np.pad(r, ((0, 0), (0, padw)), constant_values=-1)
        return s, np.where(s == -np.inf, -1, r)

    def _shard_masks(self, filters, n_queries: int):
        """-> (n_shards, Q, ceil(rows_per_shard/32)) int32 stacked
        shard-local packed bitmasks, or None when every filter is empty."""
        ms = shard_filter_masks(self.index, filters, n_queries,
                                self.n_shards, self.rows_per_shard)
        return None if ms is None else jnp.asarray(ms, jnp.int32)

    def topk(self, queries, k: int, *, filters=None, route: str = "exact",
             nprobe: int = 8):
        """-> (scores (Q, k), rows (Q, k)) — identical to the single-device
        scorer, including index tie-breaks (shards are index-ordered) and
        per-query ``filters`` (a single ItemFilter broadcasts).

        ``route="ivf"`` (needs an IVF-built index) probes only the
        shard-local portions of each query's top-``nprobe`` clusters —
        identical to the single-device :class:`~repro.retrieval.ivf.
        IVFScorer` at the same nprobe; unfilled tails are (-inf, -1)."""
        assert 0 < k <= self.index.n_items
        if route == "ivf":
            if self.index.ivf is None:
                raise ValueError('route="ivf" needs an IVF-built index — '
                                 "run retrieval.ivf.build_ivf first")
            return self._topk_ivf(queries, k, nprobe=nprobe,
                                  filters=filters)
        assert route == "exact", route
        queries = jnp.asarray(queries, jnp.float32)
        masks = (self._shard_masks(filters, queries.shape[0])
                 if filters is not None else None)
        key = (k, masks is not None)
        fn = self._jitted.get(key)
        if fn is None:
            fn = self._jitted[key] = self._build(k, masks is not None)
        args = (queries, self.packed, self.scale, self.bias)
        s, r = fn(*args, masks) if masks is not None else fn(*args)
        s, r = np.asarray(s), np.asarray(r)             # (n_dev, Q, k)
        return merge_topk(list(s), list(r), k)

    def retrieve(self, queries, k: int, *, filters=None,
                 route: str = "exact", nprobe: int = 8):
        """Like :meth:`topk` but maps rows to item ids (numpy)."""
        scores, rows = self.topk(queries, k, filters=filters, route=route,
                                 nprobe=nprobe)
        return scores, self.index.item_ids(rows)
