"""ShardedRetriever: corpus top-k over a device mesh.

The packed corpus is split into contiguous row ranges, one per device along
the ``data`` mesh axis (the same axis ``distributed.sharding`` uses for the
row-sharded id tables).  Each shard runs the fused scorer locally over its
rows (queries replicated), producing a per-shard exact top-k with GLOBAL
row indices (shard offset via ``lax.axis_index``); the tiny (n_dev, Q, k)
partials are merged on host with the stable lower-index-wins rule.

Per-device work and memory drop by n_dev; the only cross-device traffic is
the replicated (Q, D) query block in and (Q, k) partials out — no score
matrix, no corpus movement.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.retrieval.filters import as_filter_list, filter_masks
from repro.retrieval.index import ItemIndex
from repro.retrieval.scorer import fused_topk, merge_topk, _round_up


class ShardedRetriever:
    """Splits an :class:`ItemIndex` across the ``data`` axis of a mesh.

    Per-request :class:`~repro.retrieval.filters.ItemFilter` constraints
    are resolved on host into one packed row bitmask PER SHARD (each in
    shard-local row coordinates), stacked along the ``data`` axis and
    applied inside each shard's fused scorer — excluded rows are pinned to
    -inf before the per-shard top-k, and the stable lower-index-wins merge
    then matches the single-device filtered result exactly."""

    def __init__(self, index: ItemIndex, mesh: Optional[Mesh] = None, *,
                 devices: Optional[Sequence] = None,
                 chunk_rows: int = 32768, block_rows: int = 32):
        if mesh is None:
            devices = list(devices if devices is not None else jax.devices())
            mesh = Mesh(np.asarray(devices), ("data",))
        assert "data" in mesh.axis_names
        self.mesh = mesh
        self.index = index
        self.n_shards = mesh.shape["data"]
        qt = index.qt
        R = qt.packed.shape[0]
        self.block_rows = block_rows
        # every shard must hold the same whole number of scan chunks
        self.chunk_rows = min(chunk_rows, _round_up(
            _round_up(R, self.n_shards) // self.n_shards, block_rows))
        self.rows_per_shard = _round_up(
            _round_up(R, self.n_shards) // self.n_shards, self.chunk_rows)
        pad = self.rows_per_shard * self.n_shards - R
        # committed to the mesh layout once — otherwise every topk() call
        # would reshard (copy) the whole corpus into P("data")
        shard = NamedSharding(self.mesh, P("data", None))
        self.packed = jax.device_put(
            jnp.pad(jnp.asarray(qt.packed), ((0, pad), (0, 0))), shard)
        self.scale = jax.device_put(
            jnp.pad(jnp.asarray(qt.scale, jnp.float16), ((0, pad), (0, 0))),
            shard)
        self.bias = jax.device_put(
            jnp.pad(jnp.asarray(qt.bias, jnp.float16), ((0, pad), (0, 0))),
            shard)
        self._jitted = {}

    def _build(self, k: int, masked: bool):
        rps = self.rows_per_shard
        # a shard can contribute at most its own rows to the global top-k,
        # so clipping the per-shard k keeps the merge exact while letting
        # k exceed rows_per_shard (small shards, large k)
        k_local = min(k, rps)

        def local(q, pk, sc, bs, *m):
            shard = jax.lax.axis_index("data")
            off = shard * rps
            n_valid = jnp.clip(self.index.n_items - off, 0, rps)
            s, r = fused_topk(q, pk, sc, bs, k=k_local, bits=self.index.bits,
                              chunk_rows=self.chunk_rows,
                              block_rows=self.block_rows,
                              n_valid=n_valid, row_offset=off,
                              mask=m[0][0] if m else None)
            return s[None], r[None]               # (1, Q, k_local) per shard

        in_specs = (P(None, None), P("data", None),
                    P("data", None), P("data", None))
        if masked:   # stacked per-shard masks ride the same data axis
            in_specs += (P("data", None, None),)
        fn = shard_map(local, mesh=self.mesh, in_specs=in_specs,
                       out_specs=(P("data", None, None),
                                  P("data", None, None)),
                       check_rep=False)
        return jax.jit(fn)

    def _shard_masks(self, filters, n_queries: int):
        """-> (n_shards, Q, ceil(rows_per_shard/32)) int32 stacked
        shard-local packed bitmasks, or None when every filter is empty."""
        filters = as_filter_list(filters, n_queries)
        rps = self.rows_per_shard
        ms = [filter_masks(filters, self.index, row_start=s * rps,
                           n_rows=rps) for s in range(self.n_shards)]
        if ms[0] is None:     # emptiness is a global property of `filters`
            return None
        return jnp.asarray(np.stack(ms), jnp.int32)

    def topk(self, queries, k: int, *, filters=None):
        """-> (scores (Q, k), rows (Q, k)) — identical to the single-device
        scorer, including index tie-breaks (shards are index-ordered) and
        per-query ``filters`` (a single ItemFilter broadcasts)."""
        assert 0 < k <= self.index.n_items
        queries = jnp.asarray(queries, jnp.float32)
        masks = (self._shard_masks(filters, queries.shape[0])
                 if filters is not None else None)
        key = (k, masks is not None)
        fn = self._jitted.get(key)
        if fn is None:
            fn = self._jitted[key] = self._build(k, masks is not None)
        args = (queries, self.packed, self.scale, self.bias)
        s, r = fn(*args, masks) if masks is not None else fn(*args)
        s, r = np.asarray(s), np.asarray(r)             # (n_dev, Q, k)
        return merge_topk(list(s), list(r), k)

    def retrieve(self, queries, k: int, *, filters=None):
        """Like :meth:`topk` but maps rows to item ids (numpy)."""
        scores, rows = self.topk(queries, k, filters=filters)
        return scores, self.index.item_ids(rows)
