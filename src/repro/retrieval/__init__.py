"""Corpus-scale retrieval subsystem: candidate generation upstream of the
ranking engine (PinnerFormer-style pooled-user-embedding -> corpus
dot-product retrieval over an int4/int8-packed item index).

Module map:

  index.py    ItemIndex — packed item-embedding corpus (int4/int8 codes +
              fp16 scale/bias, optional per-item surface metadata,
              pytree-registered, npz save/load) and IndexBuilder — exports
              candidate-tower embeddings from
              ``PinFMRankingModel._candidate_tokens`` for an id range,
              packs them with ``quant.ptq.quantize_table``, and appends
              new id ranges incrementally (``append``) without
              re-quantizing existing rows.
  filters.py  ItemFilter — per-request retrieval constraints (already-seen
              item ids, surface targeting) and their conversion to packed
              per-row bitmasks (bit 1 = excluded) applied by every scorer
              path as -inf score pins before top-k selection.
  scorer.py   CorpusScorer — exact top-k over the packed corpus with three
              paths: the fused Pallas kernel (``kernels.retrieval_topk``),
              the streaming pure-jnp fused path (scan over cache-resident
              chunks, block-max selection + exact rescore), and the
              brute-force oracle (``kernels.ref.retrieval_topk_ref``).
              Also the shared executor/merge helpers (``chunk_topk``,
              ``merge_topk`` — the ONE host-side partial top-k merge; the
              device-side counterpart is
              ``kernels.retrieval_topk.bitonic_topk_merge``).
  ivf.py      The approximate route: IVF coarse quantizer (k-means over
              the candidate-tower embeddings, ``build_ivf`` permuting the
              corpus cluster-contiguously), host-side probe routing, the
              ``ivf_topk`` slice-gather scorer (exact scoring inside the
              probed clusters, shared bitonic merge), filter pushdown
              with recall-floor nprobe widening, and the standalone
              ``IVFScorer``.  Opt-in: recall loss comes only from cluster
              pruning and is measurable against the exact oracle.
  sharded.py  ShardedRetriever — contiguous corpus row ranges per device
              over the ``data`` mesh axis via ``shard_map``; per-shard
              exact top-k (or shard-clipped IVF probes with
              ``route="ivf"``), stable lower-index-wins merge on host.

Serving integration lives in ``serving.engine``: ``RetrieveRequest`` ->
cached pooled user embedding (``encode_user`` + ContextCache) -> bucketed
corpus-chunk executors in the ExecutorRegistry -> host merge; covered by
``ServingEngine.warmup()`` so steady-state retrieval never recompiles.
"""
from repro.retrieval.filters import (ItemFilter, as_filter_list,
                                     filter_masks, pack_bits, unpack_bits)
from repro.retrieval.index import IndexBuilder, ItemIndex
from repro.retrieval.ivf import (IVFData, IVFScorer, build_ivf, ivf_route,
                                 ivf_topk, kmeans)
from repro.retrieval.scorer import (CorpusScorer, chunk_topk, fused_topk,
                                    merge_topk, unpack_codes)
from repro.retrieval.sharded import ShardedRetriever
