"""ItemIndex + IndexBuilder: the corpus side of the retrieval subsystem.

The index stores the candidate-tower item embeddings of a contiguous item-id
range, packed with the serving PTQ scheme (``quant.ptq.quantize_table``):
int4/int8 codes bitpacked into int32 words + one fp16 scale/bias pair per
row.  At 1M items x 64 dims that is 32 MiB of packed codes instead of
256 MiB fp32 — cheap enough to keep device-resident per shard.

Because quantization is strictly per-row, the corpus is INCREMENTALLY
refreshable: :meth:`IndexBuilder.append` quantizes only the new id range
and concatenates it below the existing rows — already-packed rows are
never re-quantized, so a grown index is byte-identical to the old one on
its original row range (the property that lets ``ServingEngine`` keep its
warmed executors across a refresh).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.ptq import QuantizedTable, dequantize_table, quantize_table


@dataclasses.dataclass
class ItemIndex:
    """Packed item-embedding corpus for ids [start_id, start_id + n_items).

    Without IVF metadata, corpus row r holds item id ``start_id + r``.
    With ``ivf`` attached (``retrieval.ivf.build_ivf``) the rows are laid
    out CLUSTER-CONTIGUOUSLY and the id<->row mapping goes through the
    stable permutation: :meth:`item_ids` maps returned rows back through
    ``ivf.row_map`` and :meth:`id_rows` maps ids (e.g. a filter's
    ``exclude_ids``) forward through ``ivf.inv_perm`` — callers always
    speak original item ids, whatever the physical layout.  ``surfaces``
    is optional per-item metadata ((n_items,) int, host numpy, stored in
    ROW order — permuted alongside the table) consumed by
    surface-targeting :class:`~repro.retrieval.filters.ItemFilter`s."""
    qt: QuantizedTable
    start_id: int
    n_items: int
    surfaces: Optional[np.ndarray] = None
    ivf: Optional["IVFData"] = None    # retrieval.ivf.IVFData

    @property
    def dim(self) -> int:
        return self.qt.dim

    @property
    def bits(self) -> int:
        return self.qt.bits

    @property
    def nbytes(self) -> int:
        return self.qt.nbytes

    def item_ids(self, rows):
        """Map retrieval row indices (any shape) back to item ids.

        On an IVF-permuted index, in-range rows go through ``row_map``;
        negative rows (the IVF route's unfilled-tail sentinel) map to -1;
        rows >= n_items (exact-path padding fills) keep the identity
        mapping, as on an unpermuted index."""
        rows = np.asarray(rows)
        if self.ivf is None:
            return rows + self.start_id
        r = rows.astype(np.int64)
        in_range = (r >= 0) & (r < self.n_items)
        mapped = np.where(in_range,
                          self.ivf.row_map[np.where(in_range, r, 0)], r)
        return np.where(r < 0, -1, mapped + self.start_id)

    def id_rows(self, ids):
        """Map item ids to CORPUS ROWS in the physical layout (through
        ``ivf.inv_perm`` when permuted); ids outside the index id range
        map to -1.  The inverse of :meth:`item_ids` on valid rows."""
        ids = np.asarray(ids, np.int64)
        rows = ids - self.start_id
        ok = (rows >= 0) & (rows < self.n_items)
        if self.ivf is not None:
            rows = np.where(ok, self.ivf.inv_perm[np.where(ok, rows, 0)],
                            rows)
        return np.where(ok, rows, -1)

    def dequantize(self, *, out_dtype=jnp.float32):
        """Whole-corpus fp dequantization (the brute-force serving layout).
        Rows come back in the PHYSICAL (possibly permuted) layout."""
        return dequantize_table(self.qt, out_dtype=out_dtype)

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        """npz snapshot (codes + scale/bias + id range + surfaces + IVF
        metadata when present)."""
        extra = ({"surfaces": np.asarray(self.surfaces)}
                 if self.surfaces is not None else {})
        if self.ivf is not None:
            extra.update(
                ivf_centroids=np.asarray(self.ivf.centroids),
                ivf_starts=np.asarray(self.ivf.starts),
                ivf_row_map=np.asarray(self.ivf.row_map),
                ivf_inv_perm=np.asarray(self.ivf.inv_perm),
                ivf_assignments=np.asarray(self.ivf.assignments),
                ivf_n_clustered=self.ivf.n_clustered)
        np.savez(path,
                 packed=np.asarray(self.qt.packed),
                 scale=np.asarray(self.qt.scale),
                 bias=np.asarray(self.qt.bias),
                 bits=self.qt.bits, dim=self.qt.dim,
                 start_id=self.start_id, n_items=self.n_items, **extra)

    @classmethod
    def load(cls, path: str) -> "ItemIndex":
        with np.load(path) as z:
            qt = QuantizedTable(packed=jnp.asarray(z["packed"]),
                                scale=jnp.asarray(z["scale"]),
                                bias=jnp.asarray(z["bias"]),
                                bits=int(z["bits"]), dim=int(z["dim"]))
            ivf = None
            if "ivf_centroids" in z.files:
                from repro.retrieval.ivf import IVFData
                ivf = IVFData(centroids=z["ivf_centroids"],
                              starts=z["ivf_starts"],
                              row_map=z["ivf_row_map"],
                              inv_perm=z["ivf_inv_perm"],
                              assignments=z["ivf_assignments"],
                              n_clustered=int(z["ivf_n_clustered"]))
            return cls(qt=qt, start_id=int(z["start_id"]),
                       n_items=int(z["n_items"]),
                       surfaces=(z["surfaces"] if "surfaces" in z.files
                                 else None),
                       ivf=ivf)


# ``ivf`` rides as a meta field: host-side metadata (identity-hashed —
# IVFData is eq=False) that must never be traced.
jax.tree_util.register_dataclass(
    ItemIndex, data_fields=["qt", "surfaces"],
    meta_fields=["start_id", "n_items", "ivf"])


class IndexBuilder:
    """Exports candidate-tower item embeddings from a ``PinFMRankingModel``
    and packs them into an :class:`ItemIndex`.

    The item embedding is the candidate event embedding ``e_c`` emitted by
    ``PinFMRankingModel._candidate_tokens`` — exactly the vector the lite
    variants pair with the pooled user embedding at ranking time, so
    user . item dot-product retrieval is consistent with downstream
    scoring.  Ids are embedded in fixed-size batches (one XLA compile)."""

    def __init__(self, model, params, *, batch_size: int = 4096,
                 bits: int = 4):
        self.model, self.params = model, params
        self.batch_size = int(batch_size)
        self.bits = bits

        def embed(p, ids):
            _, e_c, _ = model._candidate_tokens(p, ids, None)
            return e_c.astype(jnp.float32)

        self._embed = jax.jit(embed)

    def item_embeddings(self, ids) -> np.ndarray:
        """-> (len(ids), id_dim) fp32 candidate-tower embeddings."""
        ids = np.asarray(ids, np.int32)
        bs = self.batch_size
        out = []
        for off in range(0, len(ids), bs):
            chunk = ids[off:off + bs]
            n = len(chunk)
            if n < bs:                        # pad the tail to the jit shape
                chunk = np.pad(chunk, (0, bs - n))
            out.append(np.asarray(self._embed(self.params,
                                              jnp.asarray(chunk)))[:n])
        return np.concatenate(out, axis=0)

    def _quantize(self, start_id: int, n_items: int, bits: int):
        emb = self.item_embeddings(start_id + np.arange(n_items))
        return quantize_table(jnp.asarray(emb), bits=bits)

    def build(self, start_id: int = 0, n_items: int = None, *,
              surfaces=None) -> ItemIndex:
        """Embed + quantize ids [start_id, start_id + n_items).  Optional
        ``surfaces`` ((n_items,) int) attaches per-item surface metadata
        for surface-constrained filtering."""
        assert n_items is not None and n_items > 0
        if surfaces is not None:
            surfaces = np.asarray(surfaces)
            assert surfaces.shape == (n_items,), surfaces.shape
        qt = self._quantize(start_id, n_items, self.bits)
        return ItemIndex(qt=qt, start_id=int(start_id), n_items=int(n_items),
                         surfaces=surfaces)

    def append(self, index: ItemIndex, n_new: int, *,
               surfaces=None) -> ItemIndex:
        """Incremental index refresh: embed + quantize ONLY the next
        ``n_new`` ids after ``index`` and append them as new rows.

        Existing packed rows, scales, and biases are reused as-is (per-row
        quantization makes the append exact — the returned index is
        byte-identical to ``index`` on rows [0, index.n_items)), so
        refreshing a corpus costs O(n_new), not O(n_items), and an engine
        holding the old index can re-attach the grown one with zero new
        XLA compiles (see ``ServingEngine.attach_index``).

        ``surfaces`` is required iff ``index`` carries surfaces (the
        metadata must stay aligned with the rows).

        On an IVF-built index the appended rows land in the UNCLUSTERED
        TAIL: they are assigned to their nearest existing centroid
        (metadata only — ``retrieval.ivf.ivf_append``, no re-cluster, no
        permutation change), the id<->row maps extend identically, and
        the IVF scorers scan the tail exactly — so append + IVF retrieve
        still costs zero new compiles.  The ``ivf_appended_unclustered``
        staleness counter (surfaced in ``ServingEngine.stats()``) tracks
        how far the layout has drifted from the clustering; rebuild with
        ``build_ivf`` when it matters."""
        assert n_new > 0
        new_start = index.start_id + index.n_items
        qt_new = self._quantize(new_start, n_new, index.bits)
        qt = QuantizedTable(
            packed=jnp.concatenate([index.qt.packed, qt_new.packed]),
            scale=jnp.concatenate([index.qt.scale, qt_new.scale]),
            bias=jnp.concatenate([index.qt.bias, qt_new.bias]),
            bits=index.bits, dim=index.dim)
        if index.surfaces is not None:
            if surfaces is None:
                raise ValueError("index has surfaces metadata; append() "
                                 "needs surfaces for the new items")
            surfaces = np.concatenate([np.asarray(index.surfaces),
                                       np.asarray(surfaces)])
            assert len(surfaces) == index.n_items + n_new
        elif surfaces is not None:
            raise ValueError("cannot add surfaces on append to an index "
                             "built without them")
        ivf = index.ivf
        if ivf is not None:
            from repro.retrieval.ivf import dequant_rows, ivf_append
            # assign from the DEQUANTIZED new rows — the embedding space
            # the scorers actually search
            ivf = ivf_append(ivf, dequant_rows(qt_new, 0, n_new))
        return ItemIndex(qt=qt, start_id=index.start_id,
                         n_items=index.n_items + n_new, surfaces=surfaces,
                         ivf=ivf)
