"""ItemIndex + IndexBuilder: the corpus side of the retrieval subsystem.

The index stores the candidate-tower item embeddings of a contiguous item-id
range, packed with the serving PTQ scheme (``quant.ptq.quantize_table``):
int4/int8 codes bitpacked into int32 words + one fp16 scale/bias pair per
row.  At 1M items x 64 dims that is 32 MiB of packed codes instead of
256 MiB fp32 — cheap enough to keep device-resident per shard.

Because quantization is strictly per-row, the corpus is INCREMENTALLY
refreshable: :meth:`IndexBuilder.append` quantizes only the new id range
and concatenates it below the existing rows — already-packed rows are
never re-quantized, so a grown index is byte-identical to the old one on
its original row range (the property that lets ``ServingEngine`` keep its
warmed executors across a refresh).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.ptq import QuantizedTable, dequantize_table, quantize_table


@dataclasses.dataclass
class ItemIndex:
    """Packed item-embedding corpus for ids [start_id, start_id + n_items).

    Corpus row r holds item id ``start_id + r`` — retrieval returns row
    indices; :meth:`item_ids` maps them back to ids.  ``surfaces`` is
    optional per-item metadata ((n_items,) int, host numpy) consumed by
    surface-targeting :class:`~repro.retrieval.filters.ItemFilter`s."""
    qt: QuantizedTable
    start_id: int
    n_items: int
    surfaces: Optional[np.ndarray] = None

    @property
    def dim(self) -> int:
        return self.qt.dim

    @property
    def bits(self) -> int:
        return self.qt.bits

    @property
    def nbytes(self) -> int:
        return self.qt.nbytes

    def item_ids(self, rows):
        """Map retrieval row indices (any shape) back to item ids."""
        return np.asarray(rows) + self.start_id

    def dequantize(self, *, out_dtype=jnp.float32):
        """Whole-corpus fp dequantization (the brute-force serving layout)."""
        return dequantize_table(self.qt, out_dtype=out_dtype)

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        """npz snapshot (codes + scale/bias + id range + surfaces)."""
        extra = ({"surfaces": np.asarray(self.surfaces)}
                 if self.surfaces is not None else {})
        np.savez(path,
                 packed=np.asarray(self.qt.packed),
                 scale=np.asarray(self.qt.scale),
                 bias=np.asarray(self.qt.bias),
                 bits=self.qt.bits, dim=self.qt.dim,
                 start_id=self.start_id, n_items=self.n_items, **extra)

    @classmethod
    def load(cls, path: str) -> "ItemIndex":
        with np.load(path) as z:
            qt = QuantizedTable(packed=jnp.asarray(z["packed"]),
                                scale=jnp.asarray(z["scale"]),
                                bias=jnp.asarray(z["bias"]),
                                bits=int(z["bits"]), dim=int(z["dim"]))
            return cls(qt=qt, start_id=int(z["start_id"]),
                       n_items=int(z["n_items"]),
                       surfaces=(z["surfaces"] if "surfaces" in z.files
                                 else None))


jax.tree_util.register_dataclass(
    ItemIndex, data_fields=["qt", "surfaces"],
    meta_fields=["start_id", "n_items"])


class IndexBuilder:
    """Exports candidate-tower item embeddings from a ``PinFMRankingModel``
    and packs them into an :class:`ItemIndex`.

    The item embedding is the candidate event embedding ``e_c`` emitted by
    ``PinFMRankingModel._candidate_tokens`` — exactly the vector the lite
    variants pair with the pooled user embedding at ranking time, so
    user . item dot-product retrieval is consistent with downstream
    scoring.  Ids are embedded in fixed-size batches (one XLA compile)."""

    def __init__(self, model, params, *, batch_size: int = 4096,
                 bits: int = 4):
        self.model, self.params = model, params
        self.batch_size = int(batch_size)
        self.bits = bits

        def embed(p, ids):
            _, e_c, _ = model._candidate_tokens(p, ids, None)
            return e_c.astype(jnp.float32)

        self._embed = jax.jit(embed)

    def item_embeddings(self, ids) -> np.ndarray:
        """-> (len(ids), id_dim) fp32 candidate-tower embeddings."""
        ids = np.asarray(ids, np.int32)
        bs = self.batch_size
        out = []
        for off in range(0, len(ids), bs):
            chunk = ids[off:off + bs]
            n = len(chunk)
            if n < bs:                        # pad the tail to the jit shape
                chunk = np.pad(chunk, (0, bs - n))
            out.append(np.asarray(self._embed(self.params,
                                              jnp.asarray(chunk)))[:n])
        return np.concatenate(out, axis=0)

    def _quantize(self, start_id: int, n_items: int, bits: int):
        emb = self.item_embeddings(start_id + np.arange(n_items))
        return quantize_table(jnp.asarray(emb), bits=bits)

    def build(self, start_id: int = 0, n_items: int = None, *,
              surfaces=None) -> ItemIndex:
        """Embed + quantize ids [start_id, start_id + n_items).  Optional
        ``surfaces`` ((n_items,) int) attaches per-item surface metadata
        for surface-constrained filtering."""
        assert n_items is not None and n_items > 0
        if surfaces is not None:
            surfaces = np.asarray(surfaces)
            assert surfaces.shape == (n_items,), surfaces.shape
        qt = self._quantize(start_id, n_items, self.bits)
        return ItemIndex(qt=qt, start_id=int(start_id), n_items=int(n_items),
                         surfaces=surfaces)

    def append(self, index: ItemIndex, n_new: int, *,
               surfaces=None) -> ItemIndex:
        """Incremental index refresh: embed + quantize ONLY the next
        ``n_new`` ids after ``index`` and append them as new rows.

        Existing packed rows, scales, and biases are reused as-is (per-row
        quantization makes the append exact — the returned index is
        byte-identical to ``index`` on rows [0, index.n_items)), so
        refreshing a corpus costs O(n_new), not O(n_items), and an engine
        holding the old index can re-attach the grown one with zero new
        XLA compiles (see ``ServingEngine.attach_index``).

        ``surfaces`` is required iff ``index`` carries surfaces (the
        metadata must stay aligned with the rows)."""
        assert n_new > 0
        new_start = index.start_id + index.n_items
        qt_new = self._quantize(new_start, n_new, index.bits)
        qt = QuantizedTable(
            packed=jnp.concatenate([index.qt.packed, qt_new.packed]),
            scale=jnp.concatenate([index.qt.scale, qt_new.scale]),
            bias=jnp.concatenate([index.qt.bias, qt_new.bias]),
            bits=index.bits, dim=index.dim)
        if index.surfaces is not None:
            if surfaces is None:
                raise ValueError("index has surfaces metadata; append() "
                                 "needs surfaces for the new items")
            surfaces = np.concatenate([np.asarray(index.surfaces),
                                       np.asarray(surfaces)])
            assert len(surfaces) == index.n_items + n_new
        elif surfaces is not None:
            raise ValueError("cannot add surfaces on append to an index "
                             "built without them")
        return ItemIndex(qt=qt, start_id=index.start_id,
                         n_items=index.n_items + n_new, surfaces=surfaces)
