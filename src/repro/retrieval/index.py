"""ItemIndex + IndexBuilder: the corpus side of the retrieval subsystem.

The index stores the candidate-tower item embeddings of a contiguous item-id
range, packed with the serving PTQ scheme (``quant.ptq.quantize_table``):
int4/int8 codes bitpacked into int32 words + one fp16 scale/bias pair per
row.  At 1M items x 64 dims that is 32 MiB of packed codes instead of
256 MiB fp32 — cheap enough to keep device-resident per shard.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant.ptq import QuantizedTable, dequantize_table, quantize_table


@dataclasses.dataclass
class ItemIndex:
    """Packed item-embedding corpus for ids [start_id, start_id + n_items).

    Corpus row r holds item id ``start_id + r`` — retrieval returns row
    indices; :meth:`item_ids` maps them back to ids."""
    qt: QuantizedTable
    start_id: int
    n_items: int

    @property
    def dim(self) -> int:
        return self.qt.dim

    @property
    def bits(self) -> int:
        return self.qt.bits

    @property
    def nbytes(self) -> int:
        return self.qt.nbytes

    def item_ids(self, rows):
        return np.asarray(rows) + self.start_id

    def dequantize(self, *, out_dtype=jnp.float32):
        """Whole-corpus fp dequantization (the brute-force serving layout)."""
        return dequantize_table(self.qt, out_dtype=out_dtype)

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        np.savez(path,
                 packed=np.asarray(self.qt.packed),
                 scale=np.asarray(self.qt.scale),
                 bias=np.asarray(self.qt.bias),
                 bits=self.qt.bits, dim=self.qt.dim,
                 start_id=self.start_id, n_items=self.n_items)

    @classmethod
    def load(cls, path: str) -> "ItemIndex":
        with np.load(path) as z:
            qt = QuantizedTable(packed=jnp.asarray(z["packed"]),
                                scale=jnp.asarray(z["scale"]),
                                bias=jnp.asarray(z["bias"]),
                                bits=int(z["bits"]), dim=int(z["dim"]))
            return cls(qt=qt, start_id=int(z["start_id"]),
                       n_items=int(z["n_items"]))


jax.tree_util.register_dataclass(
    ItemIndex, data_fields=["qt"], meta_fields=["start_id", "n_items"])


class IndexBuilder:
    """Exports candidate-tower item embeddings from a ``PinFMRankingModel``
    and packs them into an :class:`ItemIndex`.

    The item embedding is the candidate event embedding ``e_c`` emitted by
    ``PinFMRankingModel._candidate_tokens`` — exactly the vector the lite
    variants pair with the pooled user embedding at ranking time, so
    user . item dot-product retrieval is consistent with downstream
    scoring.  Ids are embedded in fixed-size batches (one XLA compile)."""

    def __init__(self, model, params, *, batch_size: int = 4096,
                 bits: int = 4):
        self.model, self.params = model, params
        self.batch_size = int(batch_size)
        self.bits = bits

        def embed(p, ids):
            _, e_c, _ = model._candidate_tokens(p, ids, None)
            return e_c.astype(jnp.float32)

        self._embed = jax.jit(embed)

    def item_embeddings(self, ids) -> np.ndarray:
        """-> (len(ids), id_dim) fp32 candidate-tower embeddings."""
        ids = np.asarray(ids, np.int32)
        bs = self.batch_size
        out = []
        for off in range(0, len(ids), bs):
            chunk = ids[off:off + bs]
            n = len(chunk)
            if n < bs:                        # pad the tail to the jit shape
                chunk = np.pad(chunk, (0, bs - n))
            out.append(np.asarray(self._embed(self.params,
                                              jnp.asarray(chunk)))[:n])
        return np.concatenate(out, axis=0)

    def build(self, start_id: int = 0, n_items: int = None) -> ItemIndex:
        assert n_items is not None and n_items > 0
        emb = self.item_embeddings(start_id + np.arange(n_items))
        qt = quantize_table(jnp.asarray(emb), bits=self.bits)
        return ItemIndex(qt=qt, start_id=int(start_id), n_items=int(n_items))
