"""Per-request item filtering for corpus retrieval.

Production retrieval never serves the raw corpus top-k: candidates the user
has already seen must be excluded at scoring time (TransAct V2's seen-item
filtering on the hot path), and a request may be constrained to a surface
(e.g. only video items on the video feed).  Both constraints reduce to the
same primitive — a per-query set of *excluded corpus rows* — which this
module represents as a packed little-endian bitmask:

    word w of query q, bit j  <->  corpus row 32*w + j;  bit 1 = EXCLUDED

so an all-zeros mask means "no filtering" (the padding default), and a
(Q, ceil(R/32)) int32 array covers a corpus window of R rows in R/8 bytes
per query.  Every scorer path applies the mask by pinning excluded scores
to ``-inf`` BEFORE top-k selection, in both the block-max phase and the
rescore phase of the fused path, so the exactness proof in
``retrieval.scorer`` carries over unchanged (masked rows behave exactly
like the padded rows ``n_valid`` already excludes).

Tie-break contract: an excluded row is indistinguishable from a padded row;
when fewer than k rows survive, every path fills the remaining slots with
``-inf`` scores and the LOWEST excluded/padded row indices, matching the
``retrieval_topk_ref`` oracle bit-for-bit (lower index wins on ties).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ItemFilter:
    """One request's retrieval constraints.

    Args:
      exclude_ids: item IDS (not corpus rows) to drop — typically the
        user's already-seen items.  Ids outside the index id range are
        ignored.
      allow_surfaces: when set, keep ONLY items whose surface id (from
        ``ItemIndex.surfaces``) is in this collection; requires the index
        to carry per-item surface metadata.
    """
    exclude_ids: Optional[Sequence[int]] = None
    allow_surfaces: Optional[Tuple[int, ...]] = None

    def is_empty(self) -> bool:
        return ((self.exclude_ids is None or len(self.exclude_ids) == 0)
                and self.allow_surfaces is None)

    def fingerprint(self) -> bytes:
        """Order-independent identity bytes — requests with the same user
        AND the same fingerprint may share one retrieval execution."""
        if self.is_empty():
            return b""
        parts = []
        if self.exclude_ids is not None and len(self.exclude_ids):
            parts.append(np.unique(np.asarray(self.exclude_ids,
                                              np.int64)).tobytes())
        parts.append(b"|")
        if self.allow_surfaces is not None:
            parts.append(np.unique(np.asarray(self.allow_surfaces,
                                              np.int64)).tobytes())
        return b"".join(parts)


def mask_bit(words, rows):
    """Device-side mask probe shared by the jnp scorer paths: words is a
    (Q, W) int32 packed mask, rows a (Q, N) int32 array of LOCAL row
    indices -> (Q, N) int32, 1 where the row is excluded.  Rows past the
    mask width clamp to the last word — callers must already be dropping
    them via their ``n_valid`` padding mask.  (The Pallas kernel and the
    ``retrieval_topk_ref`` oracle intentionally re-implement this layout
    in their own idiom; the lattice parity tests pin all of them to the
    same contract.)"""
    import jax.numpy as jnp
    words = jnp.asarray(words, jnp.int32)
    mw = jnp.take_along_axis(words, rows >> 5, axis=1, mode="clip")
    return (mw >> (rows & 31)) & 1


def pack_bits(excluded: np.ndarray) -> np.ndarray:
    """(n,) bool -> (ceil(n/32),) int32, little-endian bit order: row r of
    the window maps to word r >> 5, bit r & 31 (bit set = excluded)."""
    excluded = np.asarray(excluded, bool)
    pad = -len(excluded) % 32
    if pad:
        excluded = np.concatenate([excluded, np.zeros(pad, bool)])
    return np.packbits(excluded, bitorder="little").view(np.int32)


def unpack_bits(words: np.ndarray, n: int) -> np.ndarray:
    """Inverse of :func:`pack_bits`: (..., W) int32 -> (..., n) bool."""
    w = np.asarray(words).astype(np.int32).view(np.uint8)
    return np.unpackbits(w, axis=-1, bitorder="little")[..., :n].astype(bool)


def excluded_rows(f: Optional[ItemFilter], index, row_start: int,
                  n_rows: int) -> np.ndarray:
    """Resolve one filter against the corpus window
    [row_start, row_start + n_rows) -> (n_rows,) bool, True = excluded.
    Rows past ``index.n_items`` stay False — they are already dropped by
    the scorers' ``n_valid`` padding mask."""
    excl = np.zeros(n_rows, bool)
    if f is None or f.is_empty():
        return excl
    if f.allow_surfaces is not None:
        if index.surfaces is None:
            raise ValueError("filter has allow_surfaces but the ItemIndex "
                             "carries no per-item surfaces metadata")
        sl = np.asarray(index.surfaces)[row_start:row_start + n_rows]
        excl[:len(sl)] = ~np.isin(sl, np.asarray(f.allow_surfaces))
    if f.exclude_ids is not None and len(f.exclude_ids):
        # id -> physical row through the index (on an IVF-permuted index
        # this consults inv_perm, so exclude_ids stay in id space)
        rows = index.id_rows(np.asarray(f.exclude_ids, np.int64)) - row_start
        rows = rows[(rows >= 0) & (rows < n_rows)]
        excl[rows] = True
    return excl


def filter_masks(filters, index, *, row_start: int = 0,
                 n_rows: Optional[int] = None) -> Optional[np.ndarray]:
    """Convert per-query filters into the packed row bitmask of a corpus
    window.

    Args:
      filters: sequence of ``Optional[ItemFilter]``, one per query row.
      index: the ``ItemIndex`` (supplies ``start_id`` / ``surfaces``).
      row_start / n_rows: the corpus row window, in the index's local row
        space (``n_rows`` defaults to the whole corpus).  Sharded and
        chunked executors pass their own window so the returned bits are
        already in shard/chunk-local coordinates.

    Returns:
      (len(filters), ceil(n_rows/32)) int32, bit 1 = excluded — or ``None``
      when every filter is empty (callers keep their unmasked fast path).
    """
    if filters is None or all(f is None or f.is_empty() for f in filters):
        return None
    if n_rows is None:
        n_rows = index.n_items - row_start
    return np.stack([pack_bits(excluded_rows(f, index, row_start, n_rows))
                     for f in filters])


def as_filter_list(filters, n_queries: int):
    """Normalize the user-facing ``filters`` argument: a single ItemFilter
    broadcasts to every query; a sequence must match the query count."""
    if filters is None or isinstance(filters, ItemFilter):
        return [filters] * n_queries
    filters = list(filters)
    if len(filters) != n_queries:
        raise ValueError(f"{len(filters)} filters for {n_queries} queries")
    return filters
