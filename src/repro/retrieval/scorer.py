"""CorpusScorer: exact top-k over a packed int4/int8 item corpus.

Three interchangeable execution paths, all returning (scores (Q, k),
rows (Q, k)) with ties broken by lower row index:

  * ``pallas`` — the fused TPU kernel (``kernels.retrieval_topk``):
    in-register dequant + score + running top-k carried across corpus
    blocks.  Interpret mode on CPU.
  * ``fused``  — the pure-jnp analogue of the kernel, shaped for CPU/XLA:
    a ``lax.scan`` over corpus chunks streams dequant + score entirely in
    cache (no (Q, R) score matrix), emitting only per-block score maxima;
    the top-k *blocks* are then rescored exactly.  This is the fast path
    the benchmark runs and what each shard of the ShardedRetriever runs.
  * ``ref``    — the brute-force oracle (``kernels.ref.retrieval_topk_ref``).

Why block-max selection is exact (including index ties): corpus blocks
partition the row range in index order.  If row x in block E is excluded,
stable top-k kept k blocks, each with max > max_E, or max == max_E and a
lower block index.  Each kept block therefore contributes at least one
item that beats x — strictly, or by tying with a lower row index (block
index order == row index order).  So at least k items rank ahead of x and
x cannot be in the true top-k.

The argument compares phase-1 maxima with phase-2 rescored values, so both
phases evaluate the SAME fp operands (dequantize row, dot with query) —
any divergence is limited to XLA reduction-order ulps, which the lattice
parity tests pin to zero by construction.

Filtering: every path accepts a packed per-query row bitmask (see
``retrieval.filters``) and pins excluded scores to -inf before selection —
in BOTH phases of the fused path, so the proof above applies with "masked"
read as "padded".  All-paths parity under masks (including -inf tie fills
when fewer than k rows survive) is pinned by tests/test_retrieval_filters.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import retrieval_topk_ref
from repro.kernels.retrieval_topk import retrieval_topk
from repro.retrieval.filters import as_filter_list, filter_masks, mask_bit
from repro.retrieval.index import ItemIndex

MODES = ("fused", "pallas", "ref")


def unpack_codes(packed, bits: int):
    """(..., W) int32 packed words -> (..., W * 32/bits) fp32 codes."""
    per_word = 32 // bits
    shifts = jnp.arange(per_word, dtype=jnp.int32) * bits
    nib = (packed[..., None] >> shifts) & ((1 << bits) - 1)
    return nib.astype(jnp.float32).reshape(
        *packed.shape[:-1], packed.shape[-1] * per_word)


def fused_topk(queries, packed, scale, bias, *, k: int, bits: int = 4,
               chunk_rows: int = 32768, block_rows: int = 32,
               n_valid=None, row_offset=0, mask=None):
    """Two-phase exact top-k, jnp only (jit-friendly; shard_map-friendly).

    queries: (Q, D) fp32; packed: (R, W) int32 with R % chunk_rows == 0
    and chunk_rows % block_rows == 0; scale/bias: (R, 1) fp16.
    ``n_valid`` (traced ok) masks trailing padded rows; ``row_offset``
    (traced ok) shifts the returned row indices (sharding); ``mask``
    (traced ok) is an optional (Q, >= ceil(n_valid/32)) int32 packed row
    bitmask in LOCAL (pre-offset) row space — bit 1 = row excluded, scores
    pinned to -inf in BOTH phases, so the block-max exactness proof above
    applies verbatim (an excluded row is exactly a padded row).
    """
    Q, D = queries.shape
    R, W = packed.shape
    assert R % chunk_rows == 0 and chunk_rows % block_rows == 0
    nch, nb = R // chunk_rows, chunk_rows // block_rows
    nb_total = nch * nb
    n_sel = min(k, nb_total)
    if n_valid is None:
        n_valid = R
    n_valid = jnp.asarray(n_valid, jnp.int32)
    if mask is not None:
        mask = jnp.asarray(mask, jnp.int32)
    q32 = queries.astype(jnp.float32)
    qT = q32.T

    # phase 1: stream chunks, emit per-block score maxima only
    def body(chunk_idx, inp):
        pk, sc, bs = inp
        deq = (unpack_codes(pk, bits) * sc.astype(jnp.float32)
               + bs.astype(jnp.float32))                      # (CH, D)
        s = jnp.dot(deq, qT, preferred_element_type=jnp.float32)  # (CH, Q)
        ridx = chunk_idx * chunk_rows + jnp.arange(chunk_rows, dtype=jnp.int32)
        s = jnp.where((ridx < n_valid)[:, None], s, -jnp.inf)
        if mask is not None:
            bit = mask_bit(mask, jnp.broadcast_to(ridx[None, :],
                                                  (Q, chunk_rows)))  # (Q, CH)
            s = jnp.where(bit.T == 1, -jnp.inf, s)
        return chunk_idx + 1, jnp.max(s.reshape(nb, block_rows, Q), axis=1)

    _, bms = jax.lax.scan(
        body, jnp.int32(0),
        (packed.reshape(nch, chunk_rows, W),
         scale.reshape(nch, chunk_rows, 1),
         bias.reshape(nch, chunk_rows, 1)))
    bm = bms.reshape(nb_total, Q).T                           # (Q, nb_total)

    # phase 2: pick the top blocks (stable => lower block index on ties),
    # rescore just their rows, final stable top-k over index-ordered rows
    _, blk = jax.lax.top_k(bm, n_sel)
    blk = jnp.sort(blk, axis=1)
    rows = (blk[:, :, None] * block_rows
            + jnp.arange(block_rows, dtype=jnp.int32)[None, None, :]
            ).reshape(Q, n_sel * block_rows)
    flat = rows.reshape(-1)
    pk_r = jnp.take(packed, flat, axis=0).reshape(Q, -1, W)
    sc_r = jnp.take(scale, flat, axis=0).reshape(Q, -1, 1).astype(jnp.float32)
    bs_r = jnp.take(bias, flat, axis=0).reshape(Q, -1, 1).astype(jnp.float32)
    # same dequant-then-dot formula as phase 1 — a factored rescore
    # (codes.q * scale + sum(q) * bias) rounds differently and could flip
    # a block-boundary decision on non-lattice data
    deq_r = unpack_codes(pk_r, bits) * sc_r + bs_r
    s = jnp.einsum('qnd,qd->qn', deq_r, q32)
    s = jnp.where(rows < n_valid, s, -jnp.inf)
    if mask is not None:
        s = jnp.where(mask_bit(mask, rows) == 1, -jnp.inf, s)
    top_s, top_p = jax.lax.top_k(s, k)
    top_rows = jnp.take_along_axis(rows, top_p, axis=1)
    return top_s, top_rows + jnp.asarray(row_offset, jnp.int32)


def chunk_topk(queries, packed, scale, bias, base_row, n_valid, *, k: int,
               bits: int = 4, mask=None):
    """Single-chunk executor body for the serving engine: dequantize one
    corpus chunk, score, return its top-k with GLOBAL row indices.  Chunk
    shape is static (one jit per query bucket); ``base_row`` / ``n_valid``
    are traced scalars so every chunk of the corpus — including chunks
    appended later by an index refresh — reuses the executor with zero new
    compiles.  ``mask`` is an optional (Q, CH/32) int32 packed bitmask in
    CHUNK-LOCAL row space (bit 1 = excluded -> score pinned to -inf); its
    shape is chunk-static too, so the filtered and unfiltered hot paths
    share one executor (an empty filter is the all-zeros mask)."""
    q32 = queries.astype(jnp.float32)
    deq = (unpack_codes(packed, bits) * scale.astype(jnp.float32)
           + bias.astype(jnp.float32))
    s = jnp.dot(q32, deq.T, preferred_element_type=jnp.float32)   # (Q, CH)
    local = jnp.arange(packed.shape[0], dtype=jnp.int32)
    s = jnp.where((local < n_valid)[None, :], s, -jnp.inf)
    if mask is not None:
        rows2d = jnp.broadcast_to(local[None, :], s.shape)
        s = jnp.where(mask_bit(mask, rows2d) == 1, -jnp.inf, s)
    top_s, top_i = jax.lax.top_k(s, k)
    return top_s, top_i + jnp.asarray(base_row, jnp.int32)


def merge_topk(scores, rows, k: int):
    """THE host-side merge of partial top-ks — every host merge in the
    subsystem (engine chunks, sharded partials, the IVF route's
    probe+tail combine) goes through this one helper; its device-side
    counterpart is ``kernels.retrieval_topk.bitonic_topk_merge`` (the
    kernel carry merge and the IVF slice scan).  Two implementations of
    the (score desc, lower index) order total — one per side of the
    host/device boundary.

    scores/rows: (..., Q, k_part) numpy, candidate groups ordered by
    ascending row range (chunks/shards in index order, each group sorted by
    score with ties already index-ordered) — a stable sort on the
    concatenation then preserves the global lower-index-wins tie-break."""
    s = np.concatenate([np.asarray(x) for x in scores], axis=-1)
    r = np.concatenate([np.asarray(x) for x in rows], axis=-1)
    order = np.argsort(-s, axis=-1, kind="stable")[..., :k]
    return (np.take_along_axis(s, order, axis=-1),
            np.take_along_axis(r, order, axis=-1))


class CorpusScorer:
    """Exact corpus top-k against an :class:`ItemIndex`.

    Invariants shared by every mode:
      * results are sorted by score descending, equal scores broken by
        LOWER row index (all paths match ``retrieval_topk_ref`` exactly);
      * per-query :class:`~repro.retrieval.filters.ItemFilter` constraints
        (already-seen ids, surface targeting) pin excluded rows to -inf
        before selection — when fewer than k rows survive, the tail slots
        are (-inf, lowest excluded/padded row index).
    """

    def __init__(self, index: ItemIndex, *, mode: str = "fused",
                 chunk_rows: int = 32768, block_rows: int = 32,
                 kernel_block_rows: int = 512, kernel_merge: str = "bitonic",
                 interpret: Optional[bool] = None):
        assert mode in MODES, f"mode {mode!r} not in {MODES}"
        self.index = index
        self.mode = mode
        self.block_rows = block_rows
        self.kernel_block_rows = kernel_block_rows
        self.kernel_merge = kernel_merge
        # run the Pallas kernel compiled on TPU, interpreted elsewhere
        self.interpret = (jax.default_backend() != "tpu"
                          if interpret is None else interpret)
        qt = index.qt
        self.bits, self.dim = qt.bits, qt.dim
        R = qt.packed.shape[0]
        self.chunk_rows = min(chunk_rows, _round_up(R, block_rows))
        if mode == "fused":       # ref/pallas read the unpadded index as-is
            pad = -R % self.chunk_rows
            self.packed = jnp.pad(jnp.asarray(qt.packed), ((0, pad), (0, 0)))
            self.scale = jnp.pad(jnp.asarray(qt.scale, jnp.float16),
                                 ((0, pad), (0, 0)))
            self.bias = jnp.pad(jnp.asarray(qt.bias, jnp.float16),
                                ((0, pad), (0, 0)))
        self._jitted = {}

    def topk(self, queries, k: int, *, filters=None, mask=None):
        """queries: (Q, dim) -> (scores (Q, k) fp32, rows (Q, k) int32).

        ``filters`` is a single :class:`ItemFilter` (broadcast to every
        query) or a sequence of Q of them; ``mask`` is the pre-packed
        (Q, ceil(n_items/32)) int32 row bitmask for callers that build
        their own (mutually exclusive with ``filters``).  Passing a mask
        re-traces the jitted fused path once per (k, Q) — warm both
        variants if steady-state traffic mixes them."""
        assert 0 < k <= self.index.n_items
        queries = jnp.asarray(queries, jnp.float32)
        assert queries.ndim == 2 and queries.shape[1] == self.dim
        if filters is not None:
            assert mask is None, "pass filters or mask, not both"
            mask = filter_masks(as_filter_list(filters, queries.shape[0]),
                                self.index)
        if mask is not None:
            mask = jnp.asarray(mask, jnp.int32)
            assert mask.shape[0] == queries.shape[0], \
                (mask.shape, queries.shape)
        if self.mode == "ref":
            return retrieval_topk_ref(
                self.index.qt.packed, self.index.qt.scale, self.index.qt.bias,
                queries, k=k, bits=self.bits, mask=mask)
        if self.mode == "pallas":
            return retrieval_topk(
                self.index.qt.packed, self.index.qt.scale, self.index.qt.bias,
                queries, k=k, bits=self.bits,
                block_rows=self.kernel_block_rows, interpret=self.interpret,
                mask=mask, merge=self.kernel_merge)
        fn = self._jitted.get(k)
        if fn is None:
            fn = jax.jit(functools.partial(
                fused_topk, k=k, bits=self.bits, chunk_rows=self.chunk_rows,
                block_rows=self.block_rows, n_valid=self.index.n_items))
            self._jitted[k] = fn
        if mask is None:
            return fn(queries, self.packed, self.scale, self.bias)
        return fn(queries, self.packed, self.scale, self.bias, mask=mask)

    def retrieve(self, queries, k: int, *, filters=None, mask=None):
        """Like :meth:`topk` but maps rows to item ids (numpy)."""
        scores, rows = self.topk(queries, k, filters=filters, mask=mask)
        return np.asarray(scores), self.index.item_ids(rows)


def _round_up(n: int, m: int) -> int:
    return n + (-n % m)
