import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: prove every (architecture x input shape x mesh) combo
lowers, compiles, and fits — without hardware.

For each combo this driver builds abstract inputs (ShapeDtypeStruct only),
jits the step with explicit in/out shardings over the production mesh
(16x16 single pod, 2x16x16 multi-pod), compiles, and records:

  * memory_analysis()  — per-device bytes (proves it fits),
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes   — parsed from the post-SPMD optimized HLO
                         (all-gather / all-reduce / reduce-scatter /
                          all-to-all / collective-permute),

into experiments/dryrun/<arch>__<shape>__<mesh>.json (read by
benchmarks/roofline.py and EXPERIMENTS.md).

NOTE: the XLA_FLAGS line above MUST run before any other jax import — jax
locks the device count at first init.  Do not import this module from code
that already initialized jax with real devices.
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import ASSIGNED_SHAPES, SHAPES, applicable
from repro.launch.steps import StepBundle, make_bundle, shard_tree
from repro.models.config import get_config

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(text: str) -> int:
    """Bytes of the first shape literal in an HLO result type, incl tuples."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum output bytes per collective kind from optimized HLO.

    Methodology (EXPERIMENTS.md §Roofline): per-chip traffic is approximated
    by the op's result bytes, x2 for all-reduce (reduce-scatter+all-gather
    phases).  Ring-factor (n-1)/n ~ 1 is folded in.
    """
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("%") or ls.startswith("ROOT"):
            m = re.search(r"=\s*(.+?)\s+(\S+)\(", ls)
            if not m:
                continue
            result_ty, opname = m.group(1), m.group(2)
            for c in COLLECTIVES:
                if opname == c or opname.startswith(c + "-start") or \
                        opname.startswith(c + "."):
                    b = _shape_bytes(result_ty)
                    if c == "all-reduce":
                        b *= 2
                    out[c]["count"] += 1
                    out[c]["bytes"] += b
                    break
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def run_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
              outdir: str = "experiments/dryrun", verbose: bool = True,
              save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = applicable(cfg, shape)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    if not ok:
        rec.update(status="skipped", reason=reason)
        return _finish(rec, outdir, save, verbose)

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        bundle: StepBundle = make_bundle(cfg, shape, multi_pod=multi_pod)
        in_sh = tuple(shard_tree(mesh, ps) for ps in bundle.in_pspecs)
        out_sh = shard_tree(mesh, bundle.out_pspecs) \
            if bundle.out_pspecs is not None else None
        from repro.distributed.sharding import activation_constraints
        with mesh, activation_constraints(mesh, bundle.policy):
            jitted = jax.jit(bundle.step, in_shardings=in_sh,
                             out_shardings=out_sh,
                             donate_argnums=bundle.donate)
            lowered = jitted.lower(*bundle.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        from repro.launch.hlo_analysis import analyze
        ana = analyze(hlo)          # trip-count-aware (see hlo_analysis.py)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory={k: int(getattr(mem, k, 0)) for k in
                    ("temp_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes")},
            cost={k: float(v) for k, v in (cost or {}).items()
                  if isinstance(v, (int, float))},
            hlo_analysis=ana.to_dict(),
            collectives=ana.to_dict()["collectives"],
            n_devices=mesh.devices.size,
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return _finish(rec, outdir, save, verbose)


def _finish(rec, outdir, save, verbose):
    if save:
        os.makedirs(outdir, exist_ok=True)
        fn = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
        with open(os.path.join(outdir, fn), "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        if rec["status"] == "ok":
            mem_gb = rec["memory"]["temp_size_in_bytes"] / 2**30
            arg_gb = rec["memory"]["argument_size_in_bytes"] / 2**30
            fl = rec.get("hlo_analysis", {}).get("flops",
                                                 rec["cost"].get("flops", 0))
            cb = rec["collectives"]["total_bytes"] / 2**30
            print(f"[OK]   {rec['arch']:22s} {rec['shape']:13s} "
                  f"{rec['mesh']:16s} temp={mem_gb:8.2f}GiB "
                  f"args={arg_gb:8.2f}GiB flops={fl:.3e} coll={cb:8.2f}GiB "
                  f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")
        elif rec["status"] == "skipped":
            print(f"[SKIP] {rec['arch']:22s} {rec['shape']:13s} "
                  f"{rec['mesh']:16s} {rec['reason']}")
        else:
            print(f"[ERR]  {rec['arch']:22s} {rec['shape']:13s} "
                  f"{rec['mesh']:16s} {rec['error']}")
    return rec


def main():
    from repro.configs import ASSIGNED
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--outdir", default="experiments/dryrun")
    ap.add_argument("--pinfm", action="store_true",
                    help="also run pinfm-20b's own shapes")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = [args.shape] if args.shape else list(ASSIGNED_SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    results = []
    for mp in meshes:
        for arch in archs:
            arch_shapes = shapes
            if arch == "pinfm-20b":
                arch_shapes = ["pinfm_pretrain", "rank_serve"]
            for sh in arch_shapes:
                results.append(run_combo(arch, sh, multi_pod=mp,
                                         outdir=args.outdir))
        if args.pinfm and not args.arch:
            for sh in ("pinfm_pretrain", "rank_serve"):
                results.append(run_combo("pinfm-20b", sh, multi_pod=mp,
                                         outdir=args.outdir))
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"of {len(results)} combos ==")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
