"""Production mesh definitions (TPU v5e).

Single pod: (data=16, model=16) = 256 chips.
Multi-pod:  (pod=2, data=16, model=16) = 512 chips.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


# v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12         # FLOP/s
HBM_BW = 819e9                   # bytes/s
ICI_BW = 50e9                    # bytes/s per link
