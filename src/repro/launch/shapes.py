"""The four assigned input shapes + PinFM's own serving/pretrain shapes."""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    kind: str              # train | prefill | decode | rank_serve | pretrain
    seq: int
    batch: int


SHAPES = {
    "train_4k":    InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k":  InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k":   InputShape("long_500k", "decode", 524_288, 1),
    # PinFM's own workloads (extra, not part of the 10x4 matrix):
    "pinfm_pretrain": InputShape("pinfm_pretrain", "pretrain", 256, 4096),
    "rank_serve":  InputShape("rank_serve", "rank_serve", 256, 2048),
}

ASSIGNED_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def applicable(cfg, shape: InputShape) -> tuple[bool, str]:
    """Shape/arch applicability (skips are recorded in DESIGN.md §6)."""
    if cfg.family == "audio" and shape.name == "long_500k":
        return False, ("enc-dec audio model: no 500k-token decode regime "
                       "(30s windows -> <=1500 frames); skipped per DESIGN.md §6")
    if cfg.name == "pinfm-20b" and shape.name in ASSIGNED_SHAPES:
        return False, "pinfm-20b uses its own shapes (pinfm_pretrain, rank_serve)"
    if cfg.name != "pinfm-20b" and shape.kind in ("rank_serve", "pretrain"):
        return False, "PinFM-specific shape"
    return True, ""


def resolve_config(cfg, shape: InputShape):
    """Shape-specific config overrides:
    * long_500k on full-attention archs runs the sliding-window variant
      (DESIGN.md §6 carve-out) — window = cfg.long_context_window;
    * decode steps never remat."""
    out = cfg
    if (shape.name == "long_500k" and out.window is None
            and out.family in ("dense", "vlm", "moe")):
        # full-attention archs (incl. full-attn MoE) run long_500k only in
        # the sliding-window variant (DESIGN.md §6) — otherwise the 524k KV
        # cache alone busts HBM (measured 20.1 GiB for qwen2-moe)
        out = out.replace(window=out.long_context_window)
    if shape.kind in ("decode", "rank_serve"):
        out = out.replace(remat="none")
    return out
