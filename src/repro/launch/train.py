"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

With --smoke (default on CPU) the arch's reduced family variant trains for a
few steps on synthetic tokens — the runnable path.  Without --smoke the full
config is built and the step is lowered against the production mesh (use
repro.launch.dryrun for the full matrix).
"""
import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()

    from repro.configs import smoke_config
    from repro.models.config import get_config
    from repro.launch.steps import build_model
    from repro.training.optim import AdamWConfig, adamw_init
    from repro.training.train import make_train_step, train_loop

    cfg = smoke_config(get_config(args.arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)
    step = jax.jit(make_train_step(model.loss, opt_cfg))
    opt = adamw_init(params)
    rng = np.random.RandomState(0)
    B, S = args.batch, args.seq

    def batches():
        for _ in range(args.steps):
            toks = rng.randint(0, cfg.vocab, (B, S))
            b = {"tokens": toks, "labels": np.roll(toks, -1, 1)}
            if cfg.family == "vlm":
                b["embeds"] = rng.randn(B, 4, cfg.frontend_dim).astype(np.float32)
            if cfg.family == "audio":
                b = {"frames": rng.randn(B, S, cfg.d_model).astype(np.float32),
                     "tokens": toks[:, :16], "labels": toks[:, :16]}
            yield b

    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={args.arch} (reduced) params={n / 1e6:.2f}M")
    t0 = time.time()
    params, opt, hist = train_loop(step, params, opt, batches(), log_every=5)
    print(f"{args.steps} steps in {time.time() - t0:.1f}s; "
          f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
