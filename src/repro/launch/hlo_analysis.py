"""Trip-count-aware analysis of post-SPMD optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE,
but this framework deliberately puts layers, microbatches, attention blocks
and SSD chunks inside scans — so flops/bytes/collectives must be scaled by
loop trip counts.  This module parses the HLO text into computations, builds
the call graph (while/call/fusion/conditional), extracts each while loop's
trip count from the comparison constant in its condition computation, and
propagates multipliers to every op:

  * flops            — 2 * prod(result_dims) * contraction for dot ops
                       (operand shapes resolved through a per-computation
                       symbol table)
  * hbm_bytes        — Σ result bytes of top-level materializing ops
                       (+ dot operand reads): traffic at fusion boundaries
  * collective bytes — per collective kind, result bytes (x2 for all-reduce)

All numbers are PER DEVICE: the text is the partitioned single-device module.
Validated against hand-computable scans in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]\w*?)\[([\d,]*)\](?:\{[^}]*\})?")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?.*?\)?)\s+([\w\-]+)\((.*)$")
_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, shape in _shapes_in(text):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    result_text: str
    rest: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]
    symbols: Dict[str, str]          # op name -> result type text


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation],
                                          Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        s = line.rstrip()
        if cur is None:
            m = _HDR_RE.match(s.strip())
            if m and s.endswith("{") and "->" in s:
                cur = Computation(m.group(2), bool(m.group(1)), [], {})
                comps[cur.name] = cur
                if cur.is_entry:
                    entry = cur.name
            continue
        if s.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(s)
        if m:
            name, result_ty, opkind, rest = m.groups()
            op = Op(name, opkind, result_ty, rest, s)
            cur.ops.append(op)
            cur.symbols[name] = result_ty
    return comps, entry


_REF_RES = [re.compile(p) for p in (
    r"to_apply=%?([\w\.\-]+)",
    r"calls=%?([\w\.\-]+)",
    r"true_computation=%?([\w\.\-]+)",
    r"false_computation=%?([\w\.\-]+)",
)]
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONST_LINE_RE = re.compile(r"s32\[\]\{?\}?\s+constant\((\d+)\)")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def while_trip_count(cond: Computation) -> int:
    consts = [int(v) for op in cond.ops
              for v in _CONST_LINE_RE.findall(op.line)]
    return max(consts) if consts else 1


def _dot_flops(op: Op, symbols: Dict[str, str]) -> Tuple[float, float]:
    """-> (flops, operand_bytes)."""
    res = _shapes_in(op.result_text)
    if not res:
        return 0.0, 0.0
    n_res = 1
    for d in res[0][1]:
        n_res *= d
    args = op.rest.split(")", 1)[0]
    names = _OPERANDS_RE.findall(args)
    operand_bytes = sum(_bytes_of(symbols.get(n, "")) for n in names)
    m = _LHS_C_RE.search(op.rest)
    contr = 1
    if m and names:
        lhs_shapes = _shapes_in(symbols.get(names[0], ""))
        if lhs_shapes:
            lhs = lhs_shapes[0][1]
            for d in m.group(1).split(","):
                if d and int(d) < len(lhs):
                    contr *= lhs[int(d)]
    return 2.0 * n_res * contr, float(operand_bytes)


# HBM-traffic op set: data movers + matmul results only.  Elementwise /
# softmax / norm intermediates are EXCLUDED — on the TPU target those fuse
# into neighbors (and the perf-critical ones live in our Pallas kernels'
# VMEM).  The memory term is therefore a fusion-optimistic lower bound;
# the CPU-lowered HLO's unfused elementwise ops would otherwise inflate it
# ~100x (§Perf iteration 5 measured this).
_MATERIALIZING = {"dot", "convolution", "custom-call",
                  "dynamic-slice", "dynamic-update-slice",
                  "scatter", "gather", "sort", "rng"}


@dataclasses.dataclass
class Analysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: {k: {"count": 0, "bytes": 0.0}
                                 for k in COLLECTIVES})

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.collectives.values())

    def to_dict(self):
        d = {k: dict(v) for k, v in self.collectives.items()}
        d["total_bytes"] = self.collective_bytes
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collectives": d}


def analyze(hlo: str) -> Analysis:
    comps, entry = parse_computations(hlo)
    if entry is None:
        entry = max(comps, key=lambda c: len(comps[c].ops))

    out = Analysis()
    stack = set()

    def visit(name: str, mult: float):
        if name not in comps or name in stack:
            return
        stack.add(name)
        comp = comps[name]
        for op in comp.ops:
            if op.kind == "while":
                mb, mc = _BODY_RE.search(op.rest), _COND_RE.search(op.rest)
                trips = 1
                if mc and mc.group(1) in comps:
                    trips = while_trip_count(comps[mc.group(1)])
                if mb:
                    visit(mb.group(1), mult * trips)
                continue
            if op.kind == "conditional":
                mbr = _BRANCHES_RE.search(op.rest)
                if mbr:
                    for b in mbr.group(1).split(","):
                        visit(b.strip().lstrip("%"), mult)
            for rx in _REF_RES:
                for r in rx.findall(op.rest):
                    visit(r, mult)
            if op.kind == "dot":
                fl, ob = _dot_flops(op, comp.symbols)
                out.flops += mult * fl
                out.hbm_bytes += mult * ob
            hit_coll = False
            for c in COLLECTIVES:
                if op.kind == c or op.kind.startswith(c + "-start"):
                    b = _bytes_of(op.result_text)
                    if c == "all-reduce":
                        b *= 2
                    out.collectives[c]["count"] += int(round(mult))
                    out.collectives[c]["bytes"] += mult * b
                    hit_coll = True
            if not hit_coll and op.kind in _MATERIALIZING:
                out.hbm_bytes += mult * _bytes_of(op.result_text)
        stack.discard(name)

    visit(entry, 1.0)
    return out
