"""Step builders + abstract input specs for every (arch x shape) combo.

Everything here is allocation-free: inputs are ShapeDtypeStructs, parameters
are abstract trees from the module specs, and the dry-run lowers
``jax.jit(step, in_shardings, out_shardings).lower(*specs).compile()``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.distributed.sharding import (attention_tp_axis, batch_axes, clean,
                                        make_policy, param_pspecs)
from repro.launch.shapes import InputShape, resolve_config
from repro.models.config import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.transformer import TransformerLM
from repro.nn.module import abstract
from repro.training.optim import AdamWConfig, adamw_update

WHISPER_DEC_FRAC = 8      # decoder tokens = seq // 8 for train shapes
WHISPER_ENC_LEN = 1536    # encoder frames cached at decode (30 s window)


def build_model(cfg: ModelConfig):
    if cfg.family == "audio":
        return EncDecLM(cfg)
    return TransformerLM(cfg)


def sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def abstract_opt_state(abstract_params):
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return {"m": jax.tree.map(f32, abstract_params),
            "v": jax.tree.map(f32, abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


# ---------------------------------------------------------------------------
# sharding spec trees
# ---------------------------------------------------------------------------

def opt_pspecs(param_ps):
    return {"m": param_ps, "v": param_ps, "step": P()}


def cache_pspecs(abstract_caches, policy, cfg: ModelConfig, shardable_batch):
    """PartitionSpec tree matching the cache pytree structure."""
    dp = batch_axes(policy) if shardable_batch else None
    kv_ax = attention_tp_axis(cfg.n_kv, cfg.n_heads // max(cfg.n_kv, 1),
                              cfg.resolved_head_dim, 16)
    heads_ok = policy.get("heads") == "model"

    def leaf_spec(path, leaf):
        name = None
        for k in reversed(path):
            s = str(getattr(k, "name", getattr(k, "key", "")))
            if s:
                name = s
                break
        nd = len(leaf.shape)
        if name in ("k", "v", "xk", "xv"):          # (reps, B, size, K, D)
            return P(None, dp, None,
                     "model" if kv_ax == "kv_heads" else None,
                     "model" if kv_ax == "head_dim" else None)
        if name == "pos":
            return P(None, dp)
        if name == "h" and nd == 5:                  # SSD (reps,B,H,N,P)
            return P(None, dp, "model" if heads_ok else None, None, None)
        if name == "h":                              # RG-LRU (reps,B,W)
            return P(None, dp, "model")
        if name == "conv":                           # (reps,B,k,C)
            return P(None, dp, None,
                     "model" if leaf.shape[-1] % 16 == 0 else None)
        return P(*([None] * nd))

    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_caches)
    return jax.tree.unflatten(treedef, [leaf_spec(p, l) for p, l in flat])


def shard_tree(mesh, pspec_tree):
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps), pspec_tree,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# bundles: (step_fn, abstract args, shardings) per shape kind
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StepBundle:
    name: str
    step: Callable
    args: tuple                 # abstract arg trees
    in_pspecs: tuple
    out_pspecs: Any
    donate: tuple = ()
    policy: dict = None         # the sharding policy actually used


def _scalar_metrics(d):
    return {k: v for k, v in d.items() if hasattr(v, "ndim") and v.ndim == 0}


def make_accum_train_step(loss_fn, opt_cfg: AdamWConfig, microbatches: int):
    """Train step with gradient accumulation over `microbatches` slices
    (lax.scan) — activation memory scales down by the microbatch factor at
    the cost of one fp32 grad accumulator (§Perf iteration 3)."""

    def step(params, opt_state, b):
        if microbatches <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, b)
        else:
            m = microbatches
            bm = jax.tree.map(
                lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), b)
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, mb):
                (l, mets), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32) / m, acc, g)
                return acc, l

            grads, losses = jax.lax.scan(body, acc0, bm)
            loss, metrics = jnp.mean(losses), {}
        params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                             opt_state)
        return params, opt_state, {"loss": loss, **_scalar_metrics(metrics),
                                   **_scalar_metrics(om)}

    return step


def make_bundle(cfg: ModelConfig, shape: InputShape, *,
                multi_pod: bool = False,
                opt_cfg: Optional[AdamWConfig] = None) -> StepBundle:
    cfg = resolve_config(cfg, shape)
    policy = make_policy(cfg.sharding, multi_pod=multi_pod, model_cfg=cfg)
    dp = batch_axes(policy)
    if cfg.name == "pinfm-20b":
        return _pinfm_bundle(cfg, shape, policy, opt_cfg)
    model = build_model(cfg)
    aparams = abstract(model.spec())
    pps = param_pspecs(model.spec(), policy)
    opt_cfg = opt_cfg or AdamWConfig()
    B, S = shape.batch, shape.seq

    if shape.kind == "train":
        batch, bps = _train_batch_specs(cfg, B, S, dp)
        aopt = abstract_opt_state(aparams)
        ops_ = opt_pspecs(pps)
        step = make_accum_train_step(model.loss, opt_cfg, cfg.microbatches)
        out_ps = (pps, ops_, None)
        return StepBundle(f"{cfg.name}/{shape.name}/train", step,
                          (aparams, aopt, batch), (pps, ops_, bps), out_ps,
                          donate=(0, 1), policy=policy)

    if shape.kind == "prefill":
        batch, bps = _train_batch_specs(cfg, B, S, dp, labels=False)

        def step(params, b):
            if cfg.family == "audio":
                enc = model.encode(params, b["frames"])
                logits = model.decode_fwd(params, b["tokens"], enc)
            else:
                logits, _ = model.forward(params, b["tokens"],
                                          embeds=b.get("embeds"))
            return logits[:, -1]

        return StepBundle(f"{cfg.name}/{shape.name}/prefill", step,
                          (aparams, batch), (pps, bps),
                          P(dp, None), policy=policy)

    if shape.kind == "decode":
        shardable = B % (32 if multi_pod else 16) == 0
        dpb = dp if shardable else None
        tokens = sds((B, 1))
        positions = sds((B, 1))
        cdtype = cfg.cdtype()
        if cfg.family == "audio":
            acaches = model.abstract_caches(B, min(S, 8192), WHISPER_ENC_LEN,
                                            cdtype)
        else:
            acaches = model.abstract_caches(B, S, cdtype)
        cps = cache_pspecs(acaches, policy, cfg, shardable)

        def step(params, tok, caches, pos):
            return model.decode_step(params, tok, caches, pos)

        return StepBundle(
            f"{cfg.name}/{shape.name}/decode", step,
            (aparams, tokens, acaches, positions),
            (pps, P(dpb, None), cps, P(dpb, None)),
            (P(dpb, None, None), cps), donate=(2,), policy=policy)

    raise ValueError(shape.kind)


def _train_batch_specs(cfg: ModelConfig, B, S, dp, labels=True):
    if cfg.family == "audio":
        sd = max(S // WHISPER_DEC_FRAC, 8)
        batch = {"frames": sds((B, S, cfg.d_model), cfg.cdtype()),
                 "tokens": sds((B, sd))}
        bps = {"frames": P(dp, None, None), "tokens": P(dp, None)}
        if labels:
            batch["labels"] = sds((B, sd))
            bps["labels"] = P(dp, None)
        return batch, bps
    if cfg.family == "vlm":
        st = S - cfg.n_patches
        batch = {"tokens": sds((B, st)),
                 "embeds": sds((B, cfg.n_patches, cfg.frontend_dim),
                               cfg.cdtype())}
        bps = {"tokens": P(dp, None), "embeds": P(dp, None, None)}
        if labels:
            batch["labels"] = sds((B, st))
            bps["labels"] = P(dp, None)
        return batch, bps
    batch = {"tokens": sds((B, S))}
    bps = {"tokens": P(dp, None)}
    if labels:
        batch["labels"] = sds((B, S))
        bps["labels"] = P(dp, None)
    return batch, bps


# ---------------------------------------------------------------------------
# PinFM's own shapes
# ---------------------------------------------------------------------------

def production_pinfm_config() -> PinFMConfig:
    from repro.core.losses import LossConfig
    return PinFMConfig(rows=80_000_000, n_tables=8, sub_dim=32, seq_len=256,
                       loss=LossConfig(window=16, downstream_len=128))


def _pinfm_bundle(cfg, shape, policy, opt_cfg):
    pcfg = production_pinfm_config()
    if shape.kind == "pretrain":
        # sub-1B backbone: pure data parallelism over the full mesh beats
        # tensor parallelism ~10x on collectives (§Perf iteration 7)
        policy = make_policy("dp", multi_pod="pod" in str(policy["_batch"]))
    dp = batch_axes(policy)
    if shape.kind == "pretrain":
        model = PinFMPretrain(pcfg, cfg)
        aparams = abstract(model.spec())
        pps = param_pspecs(model.spec(), policy)
        opt_cfg = opt_cfg or AdamWConfig()
        B, L = shape.batch, shape.seq
        batch = {"ids": sds((B, L)), "actions": sds((B, L)),
                 "surfaces": sds((B, L)), "valid": sds((B, L), jnp.bool_),
                 "user_id": sds((B,))}
        bps = {"ids": P(dp, None), "actions": P(dp, None),
               "surfaces": P(dp, None), "valid": P(dp, None),
               "user_id": P(dp)}
        aopt = abstract_opt_state(aparams)
        ops_ = opt_pspecs(pps)

        def step(params, opt_state, b):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, b)
            params, opt_state, om = adamw_update(opt_cfg, params, grads,
                                                 opt_state)
            return params, opt_state, {"loss": loss,
                                        **_scalar_metrics(metrics),
                                        **_scalar_metrics(om)}

        return StepBundle(f"pinfm-20b/{shape.name}", step,
                          (aparams, aopt, batch), (pps, ops_, bps),
                          (pps, ops_, None), donate=(0, 1), policy=policy)

    if shape.kind == "rank_serve":
        from repro.core.dcat import DCATOptions
        fcfg = FinetuneConfig(
            variant="graphsage-lt", seq_len=shape.seq,
            dcat=DCATOptions(rotate_replace=False, skip_last_self_attn=True))
        model = PinFMRankingModel(pcfg, fcfg)
        aparams = abstract(model.spec())
        pps = param_pspecs(model.spec(), policy)
        B_c = shape.batch
        min_u = 32 if isinstance(dp, tuple) else 16
        B_u = max(B_c // 128, min_u)         # ~1:128 dedup at serving
        L = shape.seq
        batch = {
            "seq_ids": sds((B_u, L)), "seq_actions": sds((B_u, L)),
            "seq_surfaces": sds((B_u, L)),
            "inverse_idx": sds((B_c,)),
            "cand_ids": sds((B_c,)),
            "cand_feats": sds((B_c, fcfg.cand_feat_dim), jnp.float32),
            "user_feats": sds((B_u, fcfg.user_feat_dim), jnp.float32),
            "graphsage": sds((B_c, fcfg.graphsage_dim), jnp.float32),
            "cand_age_days": sds((B_c,), jnp.float32),
        }
        bps = {"seq_ids": P(dp, None), "seq_actions": P(dp, None),
               "seq_surfaces": P(dp, None),
               "inverse_idx": P(dp), "cand_ids": P(dp),
               "cand_feats": P(dp, None), "user_feats": P(dp, None),
               "graphsage": P(dp, None), "cand_age_days": P(dp)}

        def step(params, b):
            logits, _, _ = model.forward(params, b, train=False)
            return jax.nn.sigmoid(logits.astype(jnp.float32))

        return StepBundle(f"pinfm-20b/{shape.name}", step,
                          (aparams, batch), (pps, bps), P(dp, None),
                          policy=policy)

    raise ValueError(shape.kind)
