"""ContextCache: per-user LRU of candidate-independent PinFM outputs.

The paper's §3.2 observation — late fusion makes the PinFM output cacheable
because the candidate never enters the sequence — generalizes to EARLY
fusion: DCAT's context component (§4.1) is equally candidate-independent.
So the cache stores, per user sequence:

  * lite variants:         the pooled user embedding (id_dim,)
  * early-fusion variants: the per-layer context KV / state pytree emitted
                           by ``DCAT.context`` (``ctx_slice`` of the batch),

and repeat-user traffic skips the context transformer entirely, going
straight to ``DCAT.crossing``.  Values are host-side numpy pytrees; the
cache also tracks its approximate byte footprint for observability.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Optional

import numpy as np

from repro.core.dcat import ctx_nbytes


class ContextCache:
    """LRU keyed by the user-sequence identity bytes (see
    ``plan.request_key``)."""

    def __init__(self, capacity: int = 4096):
        self.capacity = capacity
        self._d: OrderedDict = OrderedDict()
        self._bytes: dict = {}
        self.hits = 0
        self.misses = 0
        self.nbytes = 0

    @staticmethod
    def key(seq_ids, seq_actions, seq_surfaces=None) -> bytes:
        k = (np.asarray(seq_ids).tobytes()
             + np.asarray(seq_actions).tobytes())
        if seq_surfaces is not None:
            k += np.asarray(seq_surfaces).tobytes()
        return k

    def __len__(self):
        return len(self._d)

    def get(self, key) -> Optional[Any]:
        """-> cached value or None; counts a hit/miss and refreshes the
        entry's LRU position on hit."""
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def peek(self, key) -> Optional[Any]:
        """Lookup without touching hit/miss counters or LRU order."""
        return self._d.get(key)

    def put(self, key, value):
        """Insert/refresh ``key``; evicts least-recently-used entries past
        ``capacity`` and keeps the byte-footprint gauge in sync."""
        if key in self._d:
            self.nbytes -= self._bytes.pop(key, 0)
        self._d[key] = value
        self._d.move_to_end(key)
        nb = ctx_nbytes(value)
        self._bytes[key] = nb
        self.nbytes += nb
        while len(self._d) > self.capacity:
            old, _ = self._d.popitem(last=False)
            self.nbytes -= self._bytes.pop(old, 0)

    def stats(self) -> dict:
        return {"entries": len(self._d), "hits": self.hits,
                "misses": self.misses, "nbytes": self.nbytes}
