"""ContextCache: per-user LRU of candidate-independent PinFM outputs.

The paper's §3.2 observation — late fusion makes the PinFM output cacheable
because the candidate never enters the sequence — generalizes to EARLY
fusion: DCAT's context component (§4.1) is equally candidate-independent.
So the cache stores, per user sequence:

  * lite variants:         the pooled user embedding (id_dim,)
  * early-fusion variants: the per-layer context KV / state pytree emitted
                           by ``DCAT.context`` (``ctx_slice`` of the batch),
                           tagged with its layout ("full", or the
                           pre-rotated ``rotate_replace`` layout)

and repeat-user traffic skips the context transformer entirely, going
straight to ``DCAT.crossing``.  Values are host-side numpy pytrees; the
cache also tracks its approximate byte footprint for observability.

On top of the per-user store sits the **device-side pack memo**: an LRU of
PACKED DEVICE batches keyed by the ordered tuple of unique-user keys (plus
the bucket shape).  An exact-repeat batch — the dominant steady-state case
under micro-batched repeat-user traffic — then skips ``ctx_slice`` /
``ctx_pack`` and the host->device transfer entirely and feeds the crossing
executor the very same device buffers as the pass that created them
(bit-identical scores for free).  Consistency invariant: ANY ``put`` or
eviction of a user key drops every memo entry whose packed batch contains
that user, so a memoized batch can never serve stale per-user context.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, Optional, Sequence, Set

import numpy as np

from repro.core.dcat import ctx_nbytes


class ContextCache:
    """LRU keyed by the user-sequence identity bytes (see
    ``plan.request_key``), plus the device-side pack memo.

    Args:
      capacity: max per-user entries.
      memo_capacity: max memoized packed device batches (0 disables the
        memo — the PR-3 behaviour).
      on_evict: optional ``fn(key, value)`` called whenever an entry's
        value leaves the cache — capacity eviction, explicit
        :meth:`evict_lru`, or replacement by a ``put`` of the same key.
        The KV-slab engine uses it to return the entry's device slot to
        the slab free list (value-identity bookkeeping lives with the
        owner of the values, not the cache)."""

    def __init__(self, capacity: int = 4096, memo_capacity: int = 32,
                 on_evict=None):
        self.capacity = capacity
        self.on_evict = on_evict
        self._d: OrderedDict = OrderedDict()
        self._bytes: dict = {}
        self.hits = 0
        self.misses = 0
        self.nbytes = 0
        # -- pack memo: memo_key -> packed device pytree ------------------
        self.memo_capacity = memo_capacity
        self._memo: OrderedDict = OrderedDict()
        self._memo_users: Dict[Any, Set] = {}   # user key -> {memo keys}
        self._memo_keys: dict = {}              # memo key -> its user keys
        self._memo_bytes: dict = {}
        self.memo_hits = 0
        self.memo_misses = 0
        self.memo_invalidations = 0
        self.memo_nbytes = 0

    @staticmethod
    def key(seq_ids, seq_actions, seq_surfaces=None) -> bytes:
        k = (np.asarray(seq_ids).tobytes()
             + np.asarray(seq_actions).tobytes())
        if seq_surfaces is not None:
            k += np.asarray(seq_surfaces).tobytes()
        return k

    def __len__(self):
        return len(self._d)

    def get(self, key) -> Optional[Any]:
        """-> cached value or None; counts a hit/miss and refreshes the
        entry's LRU position on hit."""
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def peek(self, key) -> Optional[Any]:
        """Lookup without touching hit/miss counters or LRU order."""
        return self._d.get(key)

    def put(self, key, value):
        """Insert/refresh ``key``; evicts least-recently-used entries past
        ``capacity`` and keeps the byte-footprint gauge in sync.  Any memo
        entry containing ``key`` (or an evicted key) is dropped — a packed
        batch must never outlive one of its per-user constituents."""
        self._invalidate_user_memos(key)
        if key in self._d:
            self.nbytes -= self._bytes.pop(key, 0)
            if self.on_evict is not None:
                self.on_evict(key, self._d[key])
        self._d[key] = value
        self._d.move_to_end(key)
        nb = ctx_nbytes(value)
        self._bytes[key] = nb
        self.nbytes += nb
        while len(self._d) > self.capacity:
            self._evict_oldest()

    def _evict_oldest(self):
        old, val = self._d.popitem(last=False)
        self.nbytes -= self._bytes.pop(old, 0)
        self._invalidate_user_memos(old)
        if self.on_evict is not None:
            self.on_evict(old, val)

    def evict_lru(self, n: int = 1) -> int:
        """Explicitly evict up to ``n`` least-recently-used entries (memo
        invalidation and ``on_evict`` fire exactly as for capacity
        eviction).  -> number actually evicted.  The slab engine calls
        this to recycle device slots when the free list runs dry."""
        done = 0
        while done < n and self._d:
            self._evict_oldest()
            done += 1
        return done

    # -- device-side pack memo ---------------------------------------------
    def memo_get(self, memo_key) -> Optional[Any]:
        """-> memoized packed device batch or None; LRU-refreshes on hit."""
        if self.memo_capacity <= 0:
            return None
        if memo_key in self._memo:
            self._memo.move_to_end(memo_key)
            self.memo_hits += 1
            return self._memo[memo_key]
        self.memo_misses += 1
        return None

    def memo_put(self, memo_key, user_keys: Sequence, value):
        """Memoize a packed device batch under ``memo_key`` and register it
        against every constituent ``user_keys`` entry for invalidation."""
        if self.memo_capacity <= 0:
            return
        if memo_key in self._memo:
            self._drop_memo(memo_key)
        self._memo[memo_key] = value
        nb = ctx_nbytes(value)
        self._memo_bytes[memo_key] = nb
        self.memo_nbytes += nb
        self._memo_keys[memo_key] = tuple(user_keys)
        for uk in user_keys:
            self._memo_users.setdefault(uk, set()).add(memo_key)
        while len(self._memo) > self.memo_capacity:
            old = next(iter(self._memo))
            self._drop_memo(old)

    def _drop_memo(self, memo_key):
        self._memo.pop(memo_key, None)
        self.memo_nbytes -= self._memo_bytes.pop(memo_key, 0)
        for uk in self._memo_keys.pop(memo_key, ()):
            s = self._memo_users.get(uk)
            if s is not None:
                s.discard(memo_key)
                if not s:
                    del self._memo_users[uk]

    def _invalidate_user_memos(self, user_key):
        """Drop every memoized packed batch containing ``user_key``."""
        for mk in list(self._memo_users.get(user_key, ())):
            self._drop_memo(mk)
            self.memo_invalidations += 1

    def stats(self) -> dict:
        return {"entries": len(self._d), "hits": self.hits,
                "misses": self.misses, "nbytes": self.nbytes,
                "memo_entries": len(self._memo),
                "memo_hits": self.memo_hits,
                "memo_misses": self.memo_misses,
                "memo_invalidations": self.memo_invalidations,
                "memo_nbytes": self.memo_nbytes}
