"""KVSlab: the device-resident quantized backing store of the ContextCache.

The host-pack serving path keeps one numpy ctx pytree PER USER and
reassembles device batches with ``ctx_slice``/``ctx_pack`` + an H2D copy
per chunk.  The slab replaces that with one preallocated device ARENA per
DCAT context leaf:

  codes  (slots+1, reps, L', K, Wq) int8   Wq = D (int8) | D//2 (int4)
  scale  (slots+1, reps, L', K, 1)  fp16   per-(slot, head) symmetric
                                            min-max (quant/kv_cache.py)

(or a single unquantized arena in the ``fp16`` escape-hatch mode — stored
at the model's NATIVE ctx dtype so the escape hatch stays bit-identical
to the host-pack path, as the house rule demands; on this repo's fp32
models that is fp32).  One user's context is one SLOT of every arena:

  * put   = quantize + ``.at[slots].set`` scatter (a jitted executor with
    the arena DONATED, so XLA updates in place — no arena-sized copy);
  * evict = host bookkeeping only (push the slot id back on the free
    list; the stale device bytes are simply unreachable);
  * batch assembly = a jitted slot-id gather with the dequant fused in
    (``kernels/slab_gather.py``) — the hit path never runs ``ctx_slice``
    / ``ctx_pack`` and ships zero context bytes host<->device.

The LAST slot is a scratch row: padded put rows and padded gather rows
both target it, so every bucket shape runs one fixed-shape executor.
LRU ordering, slot ownership, and the free list all live on the host
(``ServingEngine`` + ``ContextCache``); the slab only owns device memory
and the executor factories (registered as "slab_put"/"slab_gather" so the
zero-recompile contract covers them).
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dcat import ctx_rotate
from repro.kernels.slab_gather import slab_gather
from repro.quant.kv_cache import quantize_kv

SLAB_DTYPES = ("int8", "int4", "fp16")


class KVSlab:
    """Fixed-capacity per-leaf device arenas + the host free list.

    Args:
      model / params: the engine's ranking model (template source).
      seq_len: raw context length L the arenas are sized for.
      slots: resident-user capacity (arena row count is ``slots + 1``;
        the extra row is the shared scratch slot).
      dtype: "int8" | "int4" (quantized, per-(slot, head) fp16 scales) |
        "fp16" (escape hatch: unquantized at the native ctx dtype —
        bit-identical to the host-pack path).
      rotated / n_new: store the pre-rotated fixed-L ``rotate_replace``
        layout (see ``ctx_rotate``) — matches what the engine caches.
      gather_impl: "jnp" | "pallas" backend for the fused gather.
    """

    def __init__(self, model, params, *, seq_len: int, slots: int,
                 dtype: str = "int8", rotated: bool = False,
                 n_new: int = 1, gather_impl: str = "jnp"):
        assert dtype in SLAB_DTYPES, dtype
        assert slots >= 1, slots
        self.seq_len = int(seq_len)
        self.capacity = int(slots)
        self.scratch = int(slots)          # arena row `slots` = scratch
        self.dtype = dtype
        self.bits: Optional[int] = {"int8": 8, "int4": 4,
                                    "fp16": None}[dtype]
        self.rotated = bool(rotated)
        self.n_new = int(n_new)
        self.gather_impl = gather_impl
        # per-user leaf template via eval_shape: trace the context encoder
        # (+ the rotation the cache layout applies) without running it
        def one_user(ids):
            ctxs = model.encode_context(params, ids, ids, ids,
                                        serving=True)[1]
            if self.rotated:
                ctxs = ctx_rotate(ctxs, self.n_new, self.seq_len)
            return ctxs
        dummy = jax.ShapeDtypeStruct((1, self.seq_len), jnp.int32)
        shapes = jax.eval_shape(one_user, dummy)
        leaves, self.treedef = jax.tree.flatten(shapes)
        # batched leaf (reps, 1, L', K, D) -> per-user (reps, L', K, D)
        self.leaf_shapes = [(l.shape[0],) + l.shape[2:] for l in leaves]
        self.leaf_dtypes = [l.dtype for l in leaves]
        for s in self.leaf_shapes:
            if self.bits == 4:
                assert s[-1] % 2 == 0, \
                    f"int4 slab needs an even head_dim, got leaf {s}"
        self.arenas = tuple(self._alloc_arena(s, dt)
                            for s, dt in zip(self.leaf_shapes,
                                             self.leaf_dtypes))
        self.free: List[int] = list(range(self.capacity))
        # telemetry (mutated only under the engine lock)
        self.puts = 0
        self.evictions = 0
        self.gathers = 0

    def _alloc_arena(self, shape, dtype):
        rows = (self.capacity + 1,) + shape
        if self.bits is None:
            return (jnp.zeros(rows, dtype),)
        wq = shape[-1] if self.bits == 8 else shape[-1] // 2
        return (jnp.zeros(rows[:-1] + (wq,), jnp.int8),
                jnp.zeros(rows[:-1] + (1,), jnp.float16))

    # -- host-side slot accounting -----------------------------------------
    @property
    def occupancy(self) -> int:
        return self.capacity - len(self.free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` slot ids off the free list, or None if it is short
        (the engine then evicts LRU users to replenish it)."""
        if len(self.free) < n:
            return None
        out, self.free = self.free[:n], self.free[n:]
        return out

    def release(self, slot: int) -> None:
        """Return an evicted user's slot — host bookkeeping only; the
        stale arena row is overwritten by the slot's next occupant."""
        self.free.append(slot)
        self.evictions += 1

    # -- byte accounting ----------------------------------------------------
    @property
    def bytes_per_user(self) -> int:
        total = 0
        for shape, dt in zip(self.leaf_shapes, self.leaf_dtypes):
            n = int(np.prod(shape))
            if self.bits is None:
                total += n * jnp.dtype(dt).itemsize
            else:
                total += n // (2 if self.bits == 4 else 1)       # codes
                total += (n // shape[-1]) * 2                    # fp16 scale
        return total

    @property
    def nbytes(self) -> int:
        return sum(int(a.size) * a.dtype.itemsize
                   for leaf in self.arenas for a in leaf)

    # -- executor factories (registered on the engine's registry) -----------
    def put_factory(self, key):
        """"slab_put" executor for bucket ``key = (b_m, L)``: quantize a
        fresh ctx batch and scatter it into the (DONATED) arenas at
        ``slots`` — padded rows aim at the scratch slot.
        ``fn(arenas, ctxs, slots) -> arenas``."""
        rotated, n_new, L = self.rotated, self.n_new, self.seq_len
        bits = self.bits

        def fn(arenas, ctxs, slots):
            if rotated:
                ctxs = ctx_rotate(ctxs, n_new, L)
            new = []
            for arena, leaf in zip(arenas, jax.tree.leaves(ctxs)):
                x = jnp.moveaxis(leaf, 1, 0)     # (b_m, reps, L', K, D)
                if bits is None:
                    new.append((arena[0].at[slots].set(
                        x.astype(arena[0].dtype)),))
                else:
                    codes, scale = quantize_kv(x, bits=bits)
                    new.append((arena[0].at[slots].set(codes),
                                arena[1].at[slots].set(scale)))
            return tuple(new)
        return fn

    def gather_factory(self, key):
        """"slab_gather" executor for bucket ``key = (b_u, L)``: assemble
        a packed ctx pytree from slot ids, dequant fused (padded rows read
        the scratch slot; their contents never reach a real candidate).
        ``fn(arenas, slots) -> ctxs``."""
        bits, impl = self.bits, self.gather_impl
        shapes, dtypes, treedef = (self.leaf_shapes, self.leaf_dtypes,
                                   self.treedef)

        def fn(arenas, slots):
            outs = []
            for arena, shape, dt in zip(arenas, shapes, dtypes):
                if bits is None:
                    x = jnp.take(arena[0], slots, axis=0)
                else:
                    rows = int(np.prod(shape[:-1]))
                    codes = arena[0].reshape(self.capacity + 1, rows, -1)
                    scale = arena[1].reshape(self.capacity + 1, rows, 1)
                    x = slab_gather(codes, scale, slots, bits=bits,
                                    out_dtype=dt, impl=impl)
                    x = x.reshape((slots.shape[0],) + shape)
                outs.append(jnp.moveaxis(x, 0, 1))   # (reps, b_u, ...)
            return jax.tree.unflatten(treedef, outs)
        return fn

    def stats(self) -> dict:
        return {"capacity": self.capacity, "occupancy": self.occupancy,
                "dtype": self.dtype, "seq_len": self.seq_len,
                "puts": self.puts, "evictions": self.evictions,
                "gathers": self.gathers, "bytes_resident": self.nbytes,
                "bytes_per_user": self.bytes_per_user}
