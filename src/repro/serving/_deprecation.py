"""Once-per-process DeprecationWarning helper shared by the compat shims
(``microbatch.MicroBatcher``, ``router.InferenceRouter``).  Tests reset a
key via ``_warned.discard(key)`` to re-assert the warning."""
from __future__ import annotations

import warnings

_warned: set = set()


def warn_once(key: str, message: str) -> None:
    """Emit ``message`` as a DeprecationWarning the first time ``key`` is
    seen in this process; no-op afterwards."""
    if key not in _warned:
        _warned.add(key)
        warnings.warn(message, DeprecationWarning, stacklevel=3)
