"""Batched autoregressive generation for the LM architectures (the serving
loop behind the decode_32k / long_500k shapes): prefill once, then jitted
single-token steps against the ring-buffer caches, with greedy / temperature
/ top-k sampling."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import TransformerLM


@dataclasses.dataclass
class GenerateConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0        # 0 => greedy
    top_k: Optional[int] = None
    cache_size: Optional[int] = None   # default: prompt + new tokens


def sample_token(logits, rng, cfg: GenerateConfig):
    """logits: (B, V) -> (B,) int32."""
    if cfg.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lg = logits.astype(jnp.float32) / cfg.temperature
    if cfg.top_k:
        kth = jax.lax.top_k(lg, cfg.top_k)[0][:, -1:]
        lg = jnp.where(lg < kth, -1e30, lg)
    return jax.random.categorical(rng, lg).astype(jnp.int32)


class Generator:
    def __init__(self, model: TransformerLM, params,
                 cfg: Optional[GenerateConfig] = None):
        self.model, self.params = model, params
        self.cfg = cfg or GenerateConfig()
        self._step = jax.jit(self._decode_one)

    def _decode_one(self, params, tok, caches, pos, rng):
        logits, caches = self.model.decode_step(params, tok, caches, pos)
        nxt = sample_token(logits[:, -1], rng, self.cfg)
        return nxt, caches

    def generate(self, prompts, *, rng=None):
        """prompts: (B, S) int32 -> (B, max_new_tokens) int32.

        Prefill runs through the decode path token-by-token for correctness
        parity with serving (prompt lengths are uniform here; a production
        server would batch a true prefill kernel — see launch/steps.py
        prefill bundles)."""
        cfg = self.cfg
        prompts = jnp.asarray(prompts, jnp.int32)
        B, S = prompts.shape
        size = cfg.cache_size or (S + cfg.max_new_tokens)
        caches = self.model.init_caches(B, size)
        rng = rng if rng is not None else jax.random.PRNGKey(0)

        tok = prompts[:, :1]
        nxt = tok[:, 0]
        for t in range(S):
            pos = jnp.full((B, 1), t, jnp.int32)
            nxt, caches = self._step(self.params, prompts[:, t:t + 1],
                                     caches, pos, jax.random.fold_in(rng, t))
        out = [nxt]
        for i in range(cfg.max_new_tokens - 1):
            t = S + i
            pos = jnp.full((B, 1), t, jnp.int32)
            nxt, caches = self._step(self.params, out[-1][:, None], caches,
                                     pos, jax.random.fold_in(rng, t))
            out.append(nxt)
        return jnp.stack(out, axis=1)
