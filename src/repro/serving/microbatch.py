"""Micro-batching front-end: coalesce RankRequests across callers before
planning, so concurrent low-fanout callers share one device batch (and one
Ψ pass — duplicate users ACROSS callers dedup too, which is where the
paper's 1:1000 serving ratio comes from).

Two operating modes:

  * synchronous (default, ``max_wait_ms=None``) — no threads: the queue
    flushes when ``max_requests`` or ``max_candidates`` worth of work has
    accumulated, on demand (``flush()`` / ``ticket.result()``), or when a
    server loop calls ``poll()`` past ``max_wait_s``.  Deterministic for
    tests.
  * background flusher (``max_wait_ms=<float>``) — a daemon thread bounds
    the age of the oldest pending request, so the engine's depth-2
    pipeline is fed continuously WITHOUT any caller blocking in
    ``result()``: callers submit and pick results up later; the flusher
    drains the queue behind them.  ``close()`` (or the context manager)
    stops the thread.

Flush/result race contract: a ticket whose request was already picked up
by an in-flight flush (another caller's, or the background flusher's) must
NOT trigger a redundant flush from ``result()`` — the membership check and
the queue swap happen atomically under the queue lock, so ``result()``
either drains the batch its request is actually in, or just waits for the
in-flight one to land.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional

import numpy as np

from repro.serving.plan import RankRequest


class Ticket:
    """Handle for one submitted request; ``result()`` flushes only if the
    request is still queued — if an in-flight flush already picked it up,
    it waits for that batch instead of triggering a redundant one."""

    def __init__(self, batcher: "MicroBatcher"):
        self._batcher = batcher
        self._done = threading.Event()
        self._value: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self) -> np.ndarray:
        if not self._done.is_set():
            # targeted flush: atomically checks whether THIS request is
            # still pending; a no-op when another flush has it in flight
            self._batcher._flush(only_if_pending=self)
            self._done.wait()
        if self._error is not None:
            raise self._error
        return self._value

    def _set(self, value):
        self._value = value
        self._done.set()

    def _set_error(self, exc: BaseException):
        self._error = exc
        self._done.set()


class MicroBatcher:
    """Queue-and-coalesce front-end over a ``ServingEngine``.

    Args:
      engine: the engine whose ``score`` handles flushed batches.
      max_requests / max_candidates: flush thresholds (candidates default
        to the engine's bucket maximum).
      max_wait_s: age bound enforced by ``poll()``.
      max_wait_ms: when set, starts the BACKGROUND FLUSHER: a daemon
        thread that flushes whenever the oldest pending request has waited
        this long, feeding the engine pipeline without a caller blocking
        in ``result()``.  Overrides ``max_wait_s``.

    Invariant: every submitted request's ticket resolves exactly once —
    with the result, or with the engine's exception if a flush fails.

    Concurrency contract: the engine itself (ContextCache, stats lists,
    mask cache) is NOT thread-safe; the batcher serializes all flush-driven
    ``engine.score`` calls through ``engine_lock``.  With a background
    flusher running, any DIRECT engine use from another thread
    (``engine.retrieve``, ad-hoc ``engine.score``) must hold that same
    lock::

        with mb.engine_lock:
            engine.retrieve(reqs)
    """

    def __init__(self, engine, *, max_requests: int = 32,
                 max_candidates: Optional[int] = None,
                 max_wait_s: float = 0.01,
                 max_wait_ms: Optional[float] = None):
        self.engine = engine
        self.max_requests = max_requests
        self.max_candidates = (max_candidates if max_candidates is not None
                               else engine.max_candidates)
        self.max_wait_s = (max_wait_ms / 1e3 if max_wait_ms is not None
                           else max_wait_s)
        self._lock = threading.Lock()
        # the engine (ContextCache LRU, stats lists) is not thread-safe:
        # serialize engine.score across flushing callers + the flusher;
        # public so direct engine users can join the serialization
        self.engine_lock = threading.Lock()
        self._pending: List[RankRequest] = []
        self._tickets: List[Ticket] = []
        self._oldest: Optional[float] = None
        self.flushes = 0
        self.coalesced = 0
        self._stop = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        if max_wait_ms is not None:
            tick = min(max(self.max_wait_s / 4, 5e-4), 0.05)
            self._flusher = threading.Thread(
                target=self._flusher_loop, args=(tick,),
                name="microbatch-flusher", daemon=True)
            self._flusher.start()

    # -- background flusher -------------------------------------------------
    def _flusher_loop(self, tick: float):
        while not self._stop.wait(tick):
            try:
                self.poll()
            except BaseException:
                # the failing batch's tickets already carry the exception
                # (flush resolves them before re-raising); the flusher
                # itself must survive to serve subsequent batches
                pass

    def close(self):
        """Stop the background flusher (if any) after draining the queue.
        Idempotent; the batcher remains usable in synchronous mode."""
        self._stop.set()
        if self._flusher is not None:
            self._flusher.join()
            self._flusher = None
        try:
            self.flush()
        except BaseException:
            pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- submit / flush -----------------------------------------------------
    def submit(self, request: RankRequest) -> Ticket:
        """Enqueue one request -> ticket.  Flushes inline when a size
        threshold trips; otherwise the batch waits for the background
        flusher, ``poll()``, ``flush()``, or a ``ticket.result()``."""
        with self._lock:
            t = Ticket(self)
            self._pending.append(request)
            self._tickets.append(t)
            if self._oldest is None:
                self._oldest = time.time()
            full = (len(self._pending) >= self.max_requests
                    or sum(len(r.cand_ids) for r in self._pending)
                    >= self.max_candidates)
        if full:
            self.flush()
        return t

    def poll(self):
        """Flush if the oldest pending request has waited past max_wait_s."""
        with self._lock:
            expired = (self._oldest is not None
                       and time.time() - self._oldest >= self.max_wait_s)
        if expired:
            self.flush()

    def flush(self):
        """Drain the queue through one ``engine.score`` call (one Ψ pass
        over every pending caller's requests) and resolve the tickets."""
        self._flush()

    def _flush(self, only_if_pending: Optional[Ticket] = None):
        with self._lock:
            if (only_if_pending is not None
                    and only_if_pending not in self._tickets):
                return      # picked up by an in-flight flush: just wait
            pending, tickets = self._pending, self._tickets
            self._pending, self._tickets, self._oldest = [], [], None
            if pending:
                self.flushes += 1
                self.coalesced += len(pending)
        if not pending:
            return
        try:
            with self.engine_lock:
                results = self.engine.score(pending)
        except BaseException as exc:
            # never orphan a ticket: a caller blocked in result() must see
            # the failure, not hang
            for t in tickets:
                t._set_error(exc)
            raise
        for t, r in zip(tickets, results):
            t._set(r)
