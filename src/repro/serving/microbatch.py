"""DEPRECATED micro-batching front-end — superseded by the engine's own
``submit()`` front door.

The queue/coalesce/background-flush machinery that used to live here is
now :class:`repro.serving.scheduler.RequestScheduler`, owned by the
``ServingEngine`` itself (``engine.submit`` / ``engine.submit_many``),
where it batches EVERY workload — rank, retrieve, fused two-stage,
generate — through one flush with a shared user-encode pass.

``MicroBatcher`` remains as a thin compatibility shim: it is a
``RequestScheduler`` whose flush function forwards to the engine's
mixed-workload flush (``_flush_requests`` — the same code path
``submit_many`` uses), so existing callers keep working and keep getting
identical results; it emits a :class:`DeprecationWarning` once per
process.  ``Ticket`` is the old name for :class:`Future`.  New code
should call ``engine.submit(request)`` directly.
"""
from __future__ import annotations

from repro.serving._deprecation import warn_once
from repro.serving.scheduler import Future, RequestScheduler

# the old name: a MicroBatcher ticket IS a scheduler future
Ticket = Future


class MicroBatcher(RequestScheduler):
    """Deprecated queue-and-coalesce front-end over a ``ServingEngine``.

    Forwards every flush to the engine's mixed-workload flush (the same
    path as ``engine.submit_many``), so results are identical to the new
    API; falls back to ``engine.score`` for engine stand-ins that only
    implement ``score`` (as the concurrency tests' fakes do).

    Args match the historical surface: ``max_requests`` /
    ``max_candidates`` flush thresholds (candidates default to the
    engine's bucket maximum), ``max_wait_s`` age bound enforced by
    ``poll()``, and ``max_wait_ms`` enabling the background flusher.
    """

    def __init__(self, engine, *, max_requests: int = 32,
                 max_candidates=None, max_wait_s: float = 0.01,
                 max_wait_ms=None):
        warn_once(
            "microbatch",
            "MicroBatcher is deprecated: the ServingEngine batches "
            "requests itself now — use engine.submit(request) / "
            "engine.submit_many(requests) (one front door for rank, "
            "retrieve, two-stage and generate traffic)")
        self.engine = engine
        flush_fn = getattr(engine, "_flush_requests", None) or engine.score
        super().__init__(
            flush_fn, max_requests=max_requests,
            max_candidates=(max_candidates if max_candidates is not None
                            else engine.max_candidates),
            max_wait_s=max_wait_s, max_wait_ms=max_wait_ms)
