"""Micro-batching front-end: coalesce RankRequests across callers before
planning, so concurrent low-fanout callers share one device batch (and one
Ψ pass — duplicate users ACROSS callers dedup too, which is where the
paper's 1:1000 serving ratio comes from).

Synchronous-friendly design: ``submit`` enqueues and returns a ticket;
the queue flushes when ``max_requests`` or ``max_candidates`` worth of work
has accumulated, when ``max_wait_s`` has elapsed since the oldest pending
request, or on demand (``flush()`` / ``ticket.result()``).  No background
thread — deterministic for tests; a server loop calls ``poll()``.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional

import numpy as np

from repro.serving.plan import RankRequest


class Ticket:
    """Handle for one submitted request; ``result()`` forces a flush if the
    batch has not gone out yet."""

    def __init__(self, batcher: "MicroBatcher"):
        self._batcher = batcher
        self._done = threading.Event()
        self._value: Optional[np.ndarray] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self) -> np.ndarray:
        if not self._done.is_set():
            self._batcher.flush()
            # another caller's flush may have picked this request up and
            # still be inside engine.score — wait for it to land
            self._done.wait()
        if self._error is not None:
            raise self._error
        return self._value

    def _set(self, value):
        self._value = value
        self._done.set()

    def _set_error(self, exc: BaseException):
        self._error = exc
        self._done.set()


class MicroBatcher:
    """Queue-and-coalesce front-end over a ``ServingEngine``.

    Args:
      engine: the engine whose ``score`` handles flushed batches.
      max_requests / max_candidates: flush thresholds (candidates default
        to the engine's bucket maximum).
      max_wait_s: age bound enforced by ``poll()``.

    Invariant: every submitted request's ticket resolves exactly once —
    with the result, or with the engine's exception if a flush fails."""

    def __init__(self, engine, *, max_requests: int = 32,
                 max_candidates: Optional[int] = None,
                 max_wait_s: float = 0.01):
        self.engine = engine
        self.max_requests = max_requests
        self.max_candidates = (max_candidates if max_candidates is not None
                               else engine.max_candidates)
        self.max_wait_s = max_wait_s
        self._lock = threading.Lock()
        # the engine (ContextCache LRU, ExecutorRegistry dicts, stats list)
        # is not thread-safe: serialize engine.score across flushing callers
        self._engine_lock = threading.Lock()
        self._pending: List[RankRequest] = []
        self._tickets: List[Ticket] = []
        self._oldest: Optional[float] = None
        self.flushes = 0
        self.coalesced = 0

    def submit(self, request: RankRequest) -> Ticket:
        """Enqueue one request -> ticket.  Flushes inline when a size
        threshold trips; otherwise the batch waits for ``poll()``,
        ``flush()``, or a ``ticket.result()``."""
        with self._lock:
            t = Ticket(self)
            self._pending.append(request)
            self._tickets.append(t)
            if self._oldest is None:
                self._oldest = time.time()
            full = (len(self._pending) >= self.max_requests
                    or sum(len(r.cand_ids) for r in self._pending)
                    >= self.max_candidates)
        if full:
            self.flush()
        return t

    def poll(self):
        """Flush if the oldest pending request has waited past max_wait_s."""
        with self._lock:
            expired = (self._oldest is not None
                       and time.time() - self._oldest >= self.max_wait_s)
        if expired:
            self.flush()

    def flush(self):
        """Drain the queue through one ``engine.score`` call (one Ψ pass
        over every pending caller's requests) and resolve the tickets."""
        with self._lock:
            pending, tickets = self._pending, self._tickets
            self._pending, self._tickets, self._oldest = [], [], None
            if pending:
                self.flushes += 1
                self.coalesced += len(pending)
        if not pending:
            return
        try:
            with self._engine_lock:
                results = self.engine.score(pending)
        except BaseException as exc:
            # never orphan a ticket: a caller blocked in result() must see
            # the failure, not hang
            for t in tickets:
                t._set_error(exc)
            raise
        for t, r in zip(tickets, results):
            t._set(r)
