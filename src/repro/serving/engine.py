"""Serving engine (paper §4.3, Figure 2) — the layered successor of the
seed's monolithic ``InferenceRouter``:

  submit(req) ─► RequestScheduler ─► mixed-workload flush: lanes
  (typed requests)  (coalesce/dedup)    rank │ retrieve │ two-stage │ gen
                                   │  one shared user-encode pass
                                   ▼
               BatchPlan (Ψ + shape bucket, host)
                                   │
                                   ▼
               ExecutorRegistry — one jitted fn per (kind, bucket)
                 "rank"     full forward            (cache disabled)
                 "context"  DCAT context -> ctx KV  (early fusion)
                 "cross"    DCAT crossing + ranker  (early fusion)
                 "encode"   pooled user embedding   (lite)
                 "score_emb" ranker from pooled emb (lite)
                 "retrieve"  corpus-chunk top-k     (attach_index; chunk
                             data + filter bitmask as traced operands)
                                   │
               ContextCache ───────┘  per-user ctx KV / pooled emb

THE FRONT DOOR is ``submit(request) -> Future`` / ``submit_many``: every
workload — ranking (``RankRequest``), candidate generation
(``RetrieveRequest``), the paper's fused two-stage retrieve-then-rank
(``RetrieveThenRankRequest``), LM generation (``GenerateRequest``) — goes
through one scheduler and one flush.  A flush partitions the pending mix
into per-workload lanes that share a single ``_lookup_users`` /
``_encode_rows`` pass, so a user appearing in a rank AND a retrieve
request in the same flush is encoded exactly once.  ``score()`` and
``retrieve()`` remain as thin batch shims over ``submit_many`` (same
results, same chunking).

Because the bucket ladder is finite, ``warmup()`` precompiles every
executor the engine can ever dispatch; steady-state traffic — including a
mixed-shape request stream — then runs with zero fresh XLA compiles
(``registry.compiles_after_warmup == 0``).

The cached early-fusion path has two backing stores.  The HOST-PACK path
round-trips contexts through per-user host slices (``ctx_slice_batch`` /
``ctx_pack``), so a cache-hit pass feeds the crossing executor the exact
same bytes as the pass that populated the cache: hit and miss scoring
agree bit-for-bit on the same bucket.  With ``slab_slots > 0`` the
DEVICE-RESIDENT KV SLAB replaces it (``serving/kv_slab.py``): contexts
live quantized (int8 / opt-in int4, per-(slot, head) fp16 scales from
``quant/kv_cache.py``) in preallocated per-leaf device arenas, puts are
donated ``.at[slots].set`` scatters, and batch assembly is a jitted
slot-id gather with the dequant fused in (``kernels/slab_gather.py``) —
the hit path never touches ``ctx_slice``/``ctx_pack`` or H2D, and evicts
are pure host bookkeeping (free-list push).  The ``slab_dtype="fp16"``
escape hatch stores the native ctx dtype and is bit-identical to the
host-pack path; the ContextCache still owns LRU order and keys, with
cache eviction returning slots through its ``on_evict`` hook.

``score`` runs as a DEPTH-2 HOST/DEVICE PIPELINE: every chunk is split
into prepare (host: plan + cache + pack + H2D dispatch) -> launch (async
executor dispatch) -> finalize (device->host sync).  JAX dispatches
executors asynchronously, so the host prepares chunk k+1 while the device
executes chunk k; ``PipelineStats`` records per-stage ms and the overlap
fraction, and ``pipeline_depth=1`` falls back to the fully synchronous
prepare->launch->finalize order — bit-identical scores either way, since
both orders feed identical operands to identical executors.  Three
host-cost eliminations ride the same path: the ContextCache's device-side
PACK MEMO short-circuits ``ctx_slice``/``ctx_pack``/H2D for exact-repeat
batches, ``rotate_replace`` engines cache contexts in the pre-rotated
fixed-L layout (``ctx_rotate``) so crossing skips the per-call rotation,
and packed per-chunk retrieval filter masks are memoized per
``ItemFilter`` fingerprint.  The pack memo keys on the UNORDERED unique-
user set: a permuted repeat batch is still a hit, served by relabelling
``inverse_idx``/``user_feats`` into the memoized row order on host
(bit-identical — the crossing consumes per-user rows only through
``inverse_idx`` gathers).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict, deque
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dcat import ctx_pack, ctx_rotate, ctx_slice_batch
from repro.core.finetune import PinFMRankingModel
from repro.obs import Observability
from repro.serving.context_cache import ContextCache
from repro.serving.executors import ExecutorRegistry
from repro.serving.kv_slab import KVSlab, SLAB_DTYPES
from repro.serving.plan import (BatchPlan, BucketLadder, GenerateRequest,
                                LanePolicy, PipelineStats, RankRequest,
                                RetrieveRequest, RetrieveThenRankRequest,
                                TwoStageResult, _pad_rows, build_plan,
                                request_key, split_requests)
from repro.serving.scheduler import Future, RequestScheduler

LITE_VARIANTS = ("lite-mean", "lite-last")
_CROSS_KEYS = ("inverse_idx", "cand_ids", "cand_feats", "user_feats")
_MASK_CACHE_CAP = 1024     # (filter fingerprint, chunk base) mask rows


def _is_ready(out) -> bool:
    """True when a dispatched executor output has already materialized
    (device idle); leaves without is_ready (plain numpy) count as ready."""
    try:
        return all(getattr(l, "is_ready", lambda: True)()
                   for l in jax.tree.leaves(out))
    except Exception:       # pragma: no cover - defensive, gauge-only
        return True


class _Inflight:
    """One chunk's pipeline state between prepare and finalize."""
    __slots__ = ("plan", "idxs", "kind", "key", "args", "out",
                 "t0", "prepare_s", "launch_s", "obs_args")

    def __init__(self, plan, kind, key, args, t0):
        self.plan, self.kind, self.key, self.args = plan, kind, key, args
        self.t0 = t0
        self.idxs = None
        self.out = None
        self.prepare_s = 0.0
        self.launch_s = 0.0
        self.obs_args = None        # cache/memo outcome (tracing only)


class ServingEngine:
    """Dedup-aware, shape-bucketed, cache-accelerated ranking + retrieval
    engine with ONE async front door: ``submit(request) -> Future``.

    Args:
      model / params: a ``PinFMRankingModel`` (any variant) and its params.
      max_unique / max_candidates: bucket-ladder maxima — one request chunk
        never exceeds these; larger request lists are split transparently.
      cache: optional ``ContextCache``; enables the split (cached) scoring
        paths and the cross-workload embedding sharing (a user hit in any
        lane is a hit in every lane).
      key_fn: optional ``request -> bytes`` cache key override (default:
        full sequence identity, ``plan.request_key``).
      max_pending / max_wait_ms: scheduler knobs — ``submit`` auto-flushes
        a lane at ``max_pending`` queued requests; ``max_wait_ms`` starts
        the background flusher bounding each lane's oldest request's
        age.
      lane_policies / isolate_lanes: per-lane SLO policies
        (``{lane: LanePolicy}`` — independent flush thresholds, age
        bounds, ``shed_ms`` latency budgets with the typed ``ShedError``
        path, ``max_queue`` admission control, and the ``auto_tune``
        wait tuner; see :class:`~repro.serving.plan.LanePolicy`).  With
        ``isolate_lanes=True`` (default) size/age/result-triggered
        flushes drain only their own lane, so a slow large-k corpus pass
        never delays a rank flush; ``isolate_lanes=False`` restores the
        pre-SLO shared-flush behaviour (every trigger drains every lane
        in one combined flush) — the bit-parity baseline: unshed results
        are identical either way, since both paths run the same lane
        runners on the same requests.
      slab_slots: > 0 enables the device-resident KV slab backing store
        for the early-fusion ContextCache (``serving/kv_slab.py``):
        ``slab_slots`` resident users per device, quantized per
        ``slab_dtype`` ("int8", "int4", or the bit-identical "fp16"
        escape hatch storing the native ctx dtype).  Requires a cache and
        an early-fusion variant; must be >= max_unique so a flush can
        always seat its own unique users.  ``slab_gather_impl`` picks the
        fused gather backend ("jnp" | "pallas", see
        ``kernels/slab_gather.py``).
      obs / obs_enabled / obs_annotate: the observability handle
        (``repro.obs.Observability``).  By default the engine builds its
        own enabled handle; ``obs_enabled=False`` swaps in the shared
        null metrics/tracer singletons (near-zero hot-loop cost, proven
        by bench_serving_engine.py section 5); pass ``obs=`` to share one
        handle across engines.  ``obs_annotate=True`` additionally wraps
        executor dispatch in ``jax.profiler.TraceAnnotation`` so device
        profiles carry the same lane/stage names as the host trace.
        Export via ``engine.obs`` (``chrome_trace()`` /
        ``prometheus_text()`` / ``snapshot()``); ad-hoc engine counters
        are mirrored into the registry at export time by a collector, so
        the ``stats()`` dict contract is unchanged.

    Invariants:
      * ZERO-RECOMPILE CONTRACT — after :meth:`warmup` (plus
        :meth:`attach_index` for retrieval), steady-state traffic of ANY
        request mix compiles nothing: every executor shape is drawn from
        the finite bucket ladder and precompiled;
        ``registry.compiles_after_warmup`` stays 0 and is asserted in
        tests.  Anything dynamic per call (corpus chunk contents, filter
        bitmasks, chunk base/valid scalars) rides as traced operands.
      * Cache-hit scoring is bit-identical to cache-miss scoring on the
        same bucket (contexts round-trip through host slices both ways).
      * Retrieval results are ordered by score descending; equal scores
        break toward the LOWER item id (= lower corpus row), matching
        ``kernels.ref.retrieval_topk_ref`` exactly.
    """

    def __init__(self, model: PinFMRankingModel, params, *,
                 max_unique: int = 8, max_candidates: int = 64,
                 min_unique: int = 1, min_candidates: int = 8,
                 cache: Optional[ContextCache] = None, key_fn=None,
                 pipeline_depth: int = 2,
                 max_pending: int = 32, max_wait_ms: Optional[float] = None,
                 lane_policies: Optional[Dict[str, LanePolicy]] = None,
                 isolate_lanes: bool = True,
                 slab_slots: int = 0, slab_dtype: str = "int8",
                 slab_gather_impl: str = "jnp",
                 obs: Optional[Observability] = None,
                 obs_enabled: bool = True, obs_annotate: bool = False):
        self.model, self.params = model, params
        self.variant = model.cfg.variant
        self.lite = self.variant in LITE_VARIANTS
        self.use_graphsage = self.variant in ("graphsage", "graphsage-lt")
        self.max_unique, self.max_candidates = max_unique, max_candidates
        self.ladder_u = BucketLadder(max_unique, min(min_unique, max_unique))
        self.ladder_c = BucketLadder(max_candidates,
                                     min(min_candidates, max_candidates))
        self.cache = cache
        self._key_fn = key_fn
        # 1 = fully synchronous (bit-identical escape hatch), 2 = classic
        # host/device overlap, >2 = deeper lookahead: the rank lane keeps
        # up to depth-1 chunks in flight, finalizing the OLDEST as soon as
        # the window fills (back-pressure — the host never runs more than
        # depth-1 prepares ahead of the device).  The two-stage lane's
        # fused schedule stays depth-2 at any depth >= 2 (its group
        # pipeline interleaves two stages; deeper lookahead applies to the
        # rank lane's chunk stream).  The cap keeps the in-flight operand
        # footprint bounded; silently clamping out-of-range depths would
        # make lookahead experiments lie, so it raises instead.
        if not 1 <= int(pipeline_depth) <= 8:
            raise ValueError(f"pipeline_depth={pipeline_depth!r}: expected "
                             "1 (synchronous) .. 8 (depth-1 chunks of "
                             "lookahead with back-pressure)")
        self.pipeline_depth = int(pipeline_depth)
        self.pipeline_stats: List[PipelineStats] = []
        # rotate_replace engines cache the PRE-ROTATED fixed-L KV layout
        # (ctx_rotate) so crossing concats instead of rotating per call;
        # gated on attention-only bodies — ctx_rotate identifies KV leaves
        # by their length axis, which rec/ssm state tensors must not alias
        self._n_new = model.n_cand_tokens
        self._ctx_rot = (
            not self.lite
            and getattr(model.dcat.opts, "rotate_replace", False)
            and all(k in ("attn", "moe")
                    for k in model.pinfm.bb.block_kinds()))
        self._ctx_tag = "rot" if self._ctx_rot else "full"
        # -- device-resident KV slab (built lazily at the first known L) --
        if slab_dtype not in SLAB_DTYPES:
            raise ValueError(f"slab_dtype={slab_dtype!r}: expected one of "
                             f"{SLAB_DTYPES}")
        if slab_slots:
            if self.lite:
                raise ValueError("slab_slots needs an early-fusion variant "
                                 f"(ctx KV to store); got {self.variant!r}")
            if cache is None:
                raise ValueError("slab_slots needs a ContextCache (it owns "
                                 "LRU order and slot->user keys)")
            if slab_slots < max_unique:
                raise ValueError(
                    f"slab_slots={slab_slots} < max_unique={max_unique}: a "
                    "single flush could need more slots than exist")
            cache.on_evict = self._on_cache_evict
        self._slab_slots = int(slab_slots)
        self._slab_dtype = slab_dtype
        self._slab_gather_impl = slab_gather_impl
        self._slab: Optional[KVSlab] = None
        self.slab_fallbacks = 0      # flushes at an L the slab isn't sized for
        self.memo_perm_hits = 0      # pack-memo hits served via row remap
        self.registry = ExecutorRegistry()
        self.call_stats: List[dict] = []  # one entry per executed chunk
        # one RLock serializes every flush (scheduler-driven or via the
        # score()/retrieve() shims), so engine state (cache, counters,
        # call_stats) needs no finer locking; stats() snapshots under it
        self._engine_lock = threading.RLock()
        # -- observability: metric handles are pre-created here (hot paths
        # never re-look them up); with obs off every handle is the shared
        # null object and record sites cost one constant no-op call
        self.obs = obs if obs is not None else Observability(
            enabled=obs_enabled, annotate=obs_annotate)
        self._obs_on = self.obs.enabled
        self._tracer = self.obs.tracer
        m = self.obs.metrics
        lane_names = ("rank", "retrieve", "two_stage", "generate")
        self._h_lane_ms = {
            n: m.histogram("serving_flush_latency_ms",
                           "per-lane wall time of one flush, ms", lane=n)
            for n in lane_names}
        self._h_lane_reqs = {
            n: m.histogram("serving_lane_batch_requests",
                           "requests served by one lane in one flush",
                           lo=1.0, hi=1e4, per_decade=10, lane=n)
            for n in lane_names}
        self._h_retr_ms = m.histogram(
            "serving_retrieval_group_ms",
            "corpus dispatch+merge wall time per retrieval group, ms")
        self._lane_tid = {n: self._tracer.tid("lane:" + n)
                          for n in lane_names}
        self._stage_tid = {"rank": self._tracer.tid("pipeline:rank"),
                           "two_stage":
                               self._tracer.tid("pipeline:two_stage")}
        self._retr_tid = self._tracer.tid("retrieval")
        self._slab_tid = self._tracer.tid("slab")
        self._prep_obs = None       # cache/memo outcome of the last prepare
        if self._obs_on:
            m.register_collector(self._collect_obs)
        # created eagerly: a lazy check-then-set would race on the first
        # concurrent submit() and orphan one of two queues
        self._scheduler = RequestScheduler(
            self._flush_requests, lock=self._engine_lock,
            max_requests=max_pending,
            max_candidates=max_candidates * max_pending,
            max_wait_ms=max_wait_ms, obs=self.obs,
            lane_policies=lane_policies, isolate_lanes=isolate_lanes)
        self._lane_counts = {"rank": 0, "retrieve": 0, "two_stage": 0,
                             "generate": 0}
        self.shared_encode_users = 0      # users encoded by the shared pass
        self._features_fn = None          # attach_features provider
        self._generator = None            # attach_generator provider
        self.index = None                 # retrieval corpus (attach_index)
        self._chunks = None               # fixed-shape device corpus chunks
        self._chunk_size = 0              # rows per chunk (static, mult. 32)
        self._attach_key = None           # (k, bits, dim, chunk_rows, ivf)
        self._zero_masks: Dict[int, jnp.ndarray] = {}   # b_q -> zeros mask
        self._ivf = None                  # IVF runtime state (attach_index)
        self._ivf_zero_masks: Dict[tuple, jnp.ndarray] = {}
        self.ivf_clusters_probed = 0      # cumulative across attaches
        self.ivf_rows_scanned = 0
        self.ivf_widened = 0
        self.ivf_last_fill = 1.0          # recall proxy of the last probe
        # packed per-chunk filter-mask rows, (fingerprint, chunk base) keyed
        self._mask_cache: OrderedDict = OrderedDict()
        self.mask_hits = 0
        self.mask_misses = 0
        self.retrieve_k = 0
        self._warmed_up = False
        self._warm_L = None
        self._register_executors()

    # ------------------------------------------------------------------
    def _register_executors(self):
        model = self.model

        def rank_factory(key):
            def fn(p, batch):
                logits, _, _ = model.forward(p, batch, train=False,
                                             serving=True)
                return jax.nn.sigmoid(logits.astype(jnp.float32))
            return fn

        self.registry.register("rank", rank_factory)

        if self.lite:
            self.registry.register(
                "encode", lambda key: model.encode_user)
            self.registry.register(
                "score_emb", lambda key: lambda p, emb, batch: jax.nn.sigmoid(
                    model.score_with_user_emb(p, emb, batch)
                    .astype(jnp.float32)))
        else:
            self.registry.register(
                "context",
                lambda key: lambda p, ids, actions, surfaces:
                    model.encode_context(p, ids, actions, surfaces,
                                         serving=True)[1])

            rotated = self._ctx_rot

            def cross_factory(key):
                ctx_len = key[2]             # (b_u, b_c, L)

                def fn(p, batch, ctxs):
                    return jax.nn.sigmoid(
                        model.score_with_ctxs(p, batch, ctxs,
                                              ctx_len=ctx_len,
                                              rotated=rotated)
                        .astype(jnp.float32))
                return fn

            self.registry.register("cross", cross_factory)

    # ------------------------------------------------------------------
    @staticmethod
    def _device(batch):
        return jax.tree.map(jnp.asarray, batch)

    def _cross_batch(self, batch: Dict[str, np.ndarray]):
        keys = _CROSS_KEYS + (("graphsage",) if self.use_graphsage else ())
        return {k: batch[k] for k in keys}

    # -- the async front door ----------------------------------------------
    @property
    def scheduler(self) -> RequestScheduler:
        """The engine-owned request scheduler."""
        return self._scheduler

    def _validate_request(self, r) -> None:
        """Fail-fast at submit() time: a request that can be KNOWN to be
        misconfigured must not enter the queue, where its failure would
        poison the whole coalesced flush (every future in a flush shares
        one fate — so attach providers before submitting).  Runtime
        errors a lane discovers later still fail the flush as a unit.

        Reads attach state WITHOUT the engine lock — submit must never
        block behind a running flush; the flush-time gates re-check these
        preconditions under the lock."""
        if isinstance(r, (RetrieveRequest, RetrieveThenRankRequest)):
            if self._chunks is None:
                raise ValueError("no retrieval corpus: call attach_index() "
                                 "first")
            if r.k > self.retrieve_k:
                raise ValueError(
                    f"k={r.k} but the attached index serves "
                    f"k<={self.retrieve_k}; re-attach with a larger k")
            route = getattr(r, "route", "exact")
            if route not in ("exact", "ivf"):
                raise ValueError(f"unknown retrieval route {route!r} "
                                 "(expected 'exact' or 'ivf')")
            if route == "ivf" and self._ivf is None:
                raise ValueError(
                    "route='ivf' but the attached index has no IVF "
                    "structure: build it with retrieval.ivf.build_ivf() "
                    "and re-attach")
            nprobe = getattr(r, "nprobe", None)
            if nprobe is not None:
                if route != "ivf":
                    raise ValueError("nprobe only applies to route='ivf'")
                if nprobe < 1:
                    raise ValueError(f"nprobe={nprobe} must be >= 1")
            if isinstance(r, RetrieveThenRankRequest):
                if r.k < 1:
                    raise ValueError("two-stage requests need k >= 1 "
                                     "(there is nothing to rank)")
                if r.cand_feats_fn is None and self._features_fn is None:
                    raise ValueError(
                        "two-stage ranking needs candidate features: set "
                        "request.cand_feats_fn or call "
                        "engine.attach_features() before submitting")
        elif isinstance(r, GenerateRequest):
            if self._generator is None:
                raise ValueError("no generator: call attach_generator() "
                                 "before submitting GenerateRequests")
        elif not isinstance(r, RankRequest):
            raise TypeError(
                f"{type(r).__name__} is not a serving request type "
                "(RankRequest, RetrieveRequest, RetrieveThenRankRequest, "
                "GenerateRequest)")

    def submit(self, request) -> Future:
        """Enqueue ONE typed request — ``RankRequest``,
        ``RetrieveRequest``, ``RetrieveThenRankRequest`` or
        ``GenerateRequest`` — and return its :class:`Future`.  Requests
        coalesce across callers and workloads until a flush (size
        threshold, ``flush()``, ``poll()``, the background flusher, or a
        ``future.result()``); one flush serves the whole mix with a single
        shared user-encode pass."""
        self._validate_request(request)
        return self.scheduler.submit(request)

    def submit_many(self, requests: Sequence) -> List[Future]:
        """Enqueue a request list atomically -> one future per request
        (the list is never size-split across flushes by its own length)."""
        requests = list(requests)
        for r in requests:
            self._validate_request(r)
        return self.scheduler.submit_many(requests)

    def flush(self, lane: Optional[str] = None):
        """Drain every pending submitted request through one
        mixed-workload flush; ``lane`` restricts the drain to one
        scheduler lane (``"rank"`` / ``"retrieve"`` / ``"two_stage"`` /
        ``"generate"``)."""
        self.scheduler.flush(lane=lane)

    def poll(self):
        """Flush every lane whose oldest pending request has waited past
        that lane's age bound (and shed anything past its lane's latency
        budget)."""
        self.scheduler.poll()

    def close(self):
        """Stop the background flusher (if running) after a final drain."""
        self._scheduler.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- mixed-workload flush ------------------------------------------------
    def _flush_requests(self, requests: Sequence) -> List:
        """One flush: partition the pending mix into per-workload lanes,
        run the shared user-encode pass, execute each lane, and scatter
        results back into request order.  This is the scheduler's flush_fn
        and the single place every public entry point funnels through."""
        with self._engine_lock:
            lanes: Dict[str, List[int]] = {
                "retrieve": [], "two_stage": [], "generate": [], "rank": []}
            for i, r in enumerate(requests):
                if isinstance(r, RetrieveRequest):
                    lanes["retrieve"].append(i)
                elif isinstance(r, RetrieveThenRankRequest):
                    lanes["two_stage"].append(i)
                elif isinstance(r, GenerateRequest):
                    lanes["generate"].append(i)
                elif isinstance(r, RankRequest):
                    lanes["rank"].append(i)
                else:
                    raise TypeError(
                        f"request {i}: {type(r).__name__} is not a serving "
                        "request type (RankRequest, RetrieveRequest, "
                        "RetrieveThenRankRequest, GenerateRequest)")
            for name, idxs in lanes.items():
                self._lane_counts[name] += len(idxs)
            # fail a misconfigured request BEFORE any lane runs (by the
            # time a late lane noticed, executors for the whole coalesced
            # flush would already be in flight); submit() validates too,
            # but a custom RequestScheduler over the flush enters here
            # directly
            for i in lanes["two_stage"]:
                if requests[i].k < 1:
                    raise ValueError(f"request {i}: two-stage requests "
                                     "need k >= 1 (there is nothing to "
                                     "rank)")
                if (requests[i].cand_feats_fn is None
                        and self._features_fn is None):
                    raise ValueError(
                        f"request {i}: two-stage ranking needs "
                        "candidate features: set request.cand_feats_fn "
                        "or call engine.attach_features()")
            if lanes["generate"] and self._generator is None:
                raise ValueError(
                    "no generator: call attach_generator() first")
            # encode each unique user ONCE for the whole flush when more
            # than one encode-consuming lane is populated
            encode_lanes = [n for n in ("rank", "retrieve", "two_stage")
                            if lanes[n]]
            if len(encode_lanes) > 1:
                self._prime_shared_users(
                    [requests[i] for n in encode_lanes for i in lanes[n]])
            results: List = [None] * len(requests)
            runners = {"rank": self._score_batch,
                       "retrieve": self._retrieve_batch,
                       "two_stage": self._two_stage_batch,
                       "generate": self._generate_batch}
            for name, idxs in lanes.items():
                if not idxs:
                    continue
                if self._obs_on:
                    t_lane = time.perf_counter()
                out = runners[name]([requests[i] for i in idxs])
                if self._obs_on:
                    dt = time.perf_counter() - t_lane
                    self._h_lane_ms[name].record(dt * 1e3)
                    self._h_lane_reqs[name].record(len(idxs))
                    self._tracer.event(
                        "lane:" + name, "lane", t_lane, dt,
                        tid=self._lane_tid[name],
                        args={"requests": len(idxs)})
                for i, r in zip(idxs, out):
                    results[i] = r
            return results

    def _prime_shared_users(self, reqs: Sequence) -> None:
        """The shared encode pass: resolve every unique user sequence the
        flush touches into the ContextCache BEFORE the lanes run, in
        bucketed batches, so each lane's own ``_lookup_users`` is a pure
        hit and a user spanning lanes is encoded exactly once.  Lite
        engines only (retrieval/two-stage require the pooled-embedding
        variants; early-fusion engines have nothing to share with
        retrieval), and only with a cache to share through."""
        if not self.lite or self.cache is None:
            return
        key_fn = self._key_fn or request_key
        missing: Dict[bytes, object] = {}      # key -> first request
        for r in reqs:
            key = key_fn(r)
            if key not in missing and self.cache.peek(key) is None:
                missing[key] = r
        keys, rows = list(missing), list(missing.values())
        for off in range(0, len(keys), self.max_unique):
            # the regular cache-miss/encode/populate protocol; the
            # returned embeddings are discarded — the lanes re-read them
            # from the cache as pure hits
            self._user_embeddings(rows[off:off + self.max_unique],
                                  keys[off:off + self.max_unique])
        self.shared_encode_users += len(keys)

    # ------------------------------------------------------------------
    def score(self, requests: Sequence[RankRequest]) -> List[np.ndarray]:
        """-> per-request (N_b, n_tasks) probabilities.  A thin batch shim
        over the ``submit_many`` front door: the whole list lands in one
        flush (plus whatever else other callers queued), and the futures
        are gathered in order — results are identical to the pre-submit()
        engine because the rank lane runs the same ``_score_batch``."""
        futures = self.submit_many(requests)
        self.flush()
        return [f.result() for f in futures]

    def _score_batch(self, requests: Sequence[RankRequest]) \
            -> List[np.ndarray]:
        """The rank lane: oversized request lists are transparently split
        into bucket-sized chunks; a single request with more than
        max_candidates candidates is split by candidate slice and
        reassembled.

        Chunks flow through the lookahead pipeline: up to
        ``pipeline_depth - 1`` chunks stay in flight on the device while
        the host prepares the next one (plan, cache, pack, H2D); once the
        window is full the OLDEST chunk is finalized before another
        prepare starts — that drain is the back-pressure bounding the
        in-flight operand footprint, so ``pipeline_depth=8`` on a
        thousand-chunk stream holds at most 7 chunks of device operands
        at once.  Results land in request order regardless.
        ``pipeline_depth=1`` processes each chunk fully before the next —
        the escape hatch is bit-identical (at EVERY depth) because all
        orders run the same executors on the same operands and mutate the
        cache at the same points (prepare), never at finalize."""
        pieces, owner = [], []               # flattened sub-requests
        for i, r in enumerate(requests):
            for part in self._split_candidates(r):
                pieces.append(part)
                owner.append(i)
        scored: List[Optional[np.ndarray]] = [None] * len(pieces)
        ps = PipelineStats(depth=self.pipeline_depth)
        t_all = time.perf_counter()
        if self.cache is not None:
            memo0 = (self.cache.memo_hits, self.cache.memo_misses)
        inflight: deque = deque()            # oldest-first launched chunks
        for idxs in split_requests(pieces, self.max_unique,
                                   self.max_candidates):
            # overlap gauge: only count this prepare as hidden work if
            # some launched chunk is genuinely still executing when it
            # starts (all-ready outputs mean the device beat the host and
            # nothing is being hidden)
            in_flight = any(not _is_ready(p.out) for p in inflight)
            infl = self._prepare_chunk([pieces[i] for i in idxs])
            infl.idxs = idxs
            ps.chunks += 1
            ps.prepare_ms += infl.prepare_s * 1e3
            if in_flight:
                ps.overlapped_ms += infl.prepare_s * 1e3
            self._launch(infl)
            ps.launch_ms += infl.launch_s * 1e3
            inflight.append(infl)
            # back-pressure: drain the oldest chunk(s) until at most
            # depth-1 remain in flight (depth=1 drains immediately —
            # fully synchronous; depth=2 reproduces the classic one-deep
            # overlap exactly)
            while len(inflight) >= self.pipeline_depth:
                ps.wait_ms += self._finalize(inflight.popleft(), scored)
        while inflight:
            ps.wait_ms += self._finalize(inflight.popleft(), scored)
        ps.total_ms = (time.perf_counter() - t_all) * 1e3
        if self.cache is not None:
            ps.memo_hits = self.cache.memo_hits - memo0[0]
            ps.memo_misses = self.cache.memo_misses - memo0[1]
        self.pipeline_stats.append(ps)
        if self._obs_on:
            ps.record_to(self.obs.metrics)
        out: List[List[np.ndarray]] = [[] for _ in requests]
        for i, p in zip(owner, scored):
            out[i].append(p)
        return [p[0] if len(p) == 1 else np.concatenate(p) for p in out]

    def _split_candidates(self, r: RankRequest) -> List[RankRequest]:
        n = len(r.cand_ids)
        if n <= self.max_candidates:
            return [r]
        return [dataclasses.replace(
            r, cand_ids=r.cand_ids[o:o + self.max_candidates],
            cand_feats=r.cand_feats[o:o + self.max_candidates],
            graphsage=(None if r.graphsage is None
                       else r.graphsage[o:o + self.max_candidates]))
            for o in range(0, n, self.max_candidates)]

    # -- pipeline stages ----------------------------------------------------
    def _prepare_chunk(self, chunk: Sequence[RankRequest]) -> _Inflight:
        """HOST stage: plan the chunk, resolve caches, pack/memo contexts,
        and dispatch the H2D transfers.  Returns the inflight record whose
        (kind, key, args) the launch stage feeds to the executor registry.
        The only device sync here is the cache-MISS path (fresh contexts /
        embeddings must land host-side to populate the ContextCache)."""
        t0 = time.perf_counter()
        plan = build_plan(chunk, self.ladder_u, self.ladder_c,
                          **({"key_fn": self._key_fn} if self._key_fn else {}))
        if not self.use_graphsage:
            plan.batch.pop("graphsage", None)
        elif "graphsage" not in plan.batch:
            raise ValueError(f"variant {self.variant!r} requires graphsage "
                             "features on every request")

        if self.cache is None:
            kind, key = "rank", (plan.b_u, plan.b_c, plan.seq_len)
            args = (self.params, self._device(plan.batch))
        elif self.lite:
            kind, key, args = self._prepare_lite(plan)
        else:
            kind, key, args = self._prepare_early(plan)
        infl = _Inflight(plan, kind, key, args, t0)
        infl.prepare_s = time.perf_counter() - t0
        if self._obs_on:
            infl.obs_args, self._prep_obs = self._prep_obs, None
        return infl

    def _launch(self, infl: _Inflight) -> None:
        """Dispatch the executor — returns as soon as XLA has enqueued the
        computation (JAX async dispatch); ``infl.out`` is a device future."""
        t0 = time.perf_counter()
        with self._tracer.annotation(infl.kind):
            infl.out = self.registry(infl.kind, infl.key, *infl.args)
        infl.args = None                 # drop operand refs early
        infl.launch_s = time.perf_counter() - t0

    def _finalize(self, infl: _Inflight, scored: List) -> float:
        """Device->host sync: block on the chunk's output, record stats,
        scatter per-request slices into ``scored``.  -> ms spent blocked."""
        plan = infl.plan
        t0 = time.perf_counter()
        probs = np.asarray(infl.out)
        wait_s = time.perf_counter() - t0
        probs = probs[:plan.n_candidates]
        entry = {"candidates": plan.n_candidates,
                 "unique_users": plan.n_unique,
                 "dedup_ratio": plan.dedup_ratio,
                 "b_u": plan.b_u, "b_c": plan.b_c,
                 # host span of this chunk's stages (prepare+launch+wait);
                 # under the pipeline this is NOT wall time — chunks overlap
                 "latency_s": infl.prepare_s + infl.launch_s + wait_s,
                 **{f"exec_{k}": v for k, v in
                    self.registry.telemetry().items()}}
        if self.cache is not None:
            entry["cache_hits"] = self.cache.hits
            entry["cache_misses"] = self.cache.misses
            entry["memo_hits"] = self.cache.memo_hits
            entry["memo_misses"] = self.cache.memo_misses
        self.call_stats.append(entry)
        if self._obs_on:
            self._trace_chunk("rank", infl.t0, infl.prepare_s,
                              infl.launch_s, t0, wait_s,
                              {"kind": infl.kind, "b_u": plan.b_u,
                               "b_c": plan.b_c,
                               "candidates": plan.n_candidates,
                               **(infl.obs_args or {})})

        off = 0
        for i, c in zip(infl.idxs, plan.counts):
            scored[i] = probs[off:off + c]
            off += c
        return wait_s * 1e3

    def _trace_chunk(self, lane, t0, prepare_s, launch_s, t_wait0, wait_s,
                     args):
        """Emit one chunk's stage spans from ALREADY-measured timings (no
        extra clock reads): prepare and launch sit at dispatch time, wait
        at finalize time — under the depth-2 pipeline the wait span starts
        later than launch ends, and the visible gap on the track is device
        time the host spent preparing the NEXT chunk."""
        tid = self._stage_tid[lane]
        self._tracer.event("prepare", "stage", t0, prepare_s, tid=tid,
                           args=args)
        self._tracer.event("launch", "stage", t0 + prepare_s, launch_s,
                           tid=tid)
        self._tracer.event("wait", "stage", t_wait0, wait_s, tid=tid)

    # -- per-user context/embedding cache protocol (rank + retrieve) --------
    def _lookup_users(self, user_keys: Sequence[bytes]):
        """Cache lookup per unique user key -> (hit values, miss rows)."""
        values: Dict[int, object] = {}
        miss_rows: List[int] = []
        for u, key in enumerate(user_keys):
            v = self.cache.get(key) if self.cache is not None else None
            if v is None:
                miss_rows.append(u)
            else:
                values[u] = v
        return values, miss_rows

    def _encode_rows(self, kind: str, seq_ids, seq_actions, seq_surfaces):
        """Run the context/encode executor over (n, L) user-sequence rows,
        padded to their own bucket -> device output batched over rows."""
        b_m = self.ladder_u.fit(len(seq_ids))
        dev = lambda x: jnp.asarray(_pad_rows(np.asarray(x, np.int32), b_m))
        return self.registry(
            kind, (b_m, seq_ids.shape[1]), self.params,
            dev(seq_ids), dev(seq_actions), dev(seq_surfaces))

    def _encode_missing(self, plan: BatchPlan, miss_rows: List[int], kind: str):
        return self._encode_rows(kind, plan.batch["seq_ids"][miss_rows],
                                 plan.batch["seq_actions"][miss_rows],
                                 plan.batch["seq_surfaces"][miss_rows])

    def _prepare_early(self, plan: BatchPlan):
        """Early-fusion prepare: per-user ctx KV from the ContextCache —
        slot ids into the device slab when one is enabled, host pytrees
        otherwise (tagged with the layout: "full", or "rot" = pre-rotated
        fixed-L ``rotate_replace`` layout) — assembled into the bucket
        batch by the fused slab gather / host ``ctx_pack``.  The pack memo
        short-circuits assembly for any repeat of the same UNORDERED
        unique-user set: an exact-order repeat reuses the memoized device
        batch as-is; a permuted repeat reuses it through a host-side
        ``inverse_idx``/``user_feats`` remap into the memoized row order
        (bit-identical, and still zero context bytes moved)."""
        slab = self._ensure_slab(plan.seq_len)
        if slab is None and self._slab_slots:
            self.slab_fallbacks += 1        # wrong-L traffic -> host path
        want = 3 if slab is not None else 2
        values, miss_rows = self._lookup_users(plan.user_keys)
        # layout discipline: entries written by an engine with a different
        # ctx layout/backing store re-encode rather than mis-score
        for u in list(values):
            v = values[u]
            ok = (isinstance(v, tuple) and len(v) == want
                  and (v[:2] == ("slab", self._ctx_tag) if want == 3
                       else v[0] == self._ctx_tag))
            if not ok:
                del values[u]
                miss_rows.append(u)
        miss_rows.sort()
        memo_key = (self._ctx_tag, plan.b_u, plan.seq_len, plan.user_set)
        batch = self._cross_batch(plan.batch)
        hit = self.cache.memo_get(memo_key)
        if hit is not None:
            memo_state = "hit"
            stored_order, packed_dev = hit
            if stored_order != tuple(plan.user_keys):
                batch = self._remap_unique_rows(batch, stored_order, plan)
                self.memo_perm_hits += 1
                memo_state = "perm_hit"
        else:
            memo_state = "miss"
            packed_dev = (self._pack_slab(plan, values, miss_rows, slab)
                          if slab is not None
                          else self._pack_host(plan, values, miss_rows))
            self.cache.memo_put(memo_key, plan.user_keys,
                                (tuple(plan.user_keys), packed_dev))
        if self._obs_on:
            self._prep_obs = {"memo": memo_state,
                              "ctx_misses": len(miss_rows),
                              "ctx_hits": plan.n_unique - len(miss_rows),
                              "slab": slab is not None}
        return ("cross", (plan.b_u, plan.b_c, plan.seq_len),
                (self.params, self._device(batch), packed_dev))

    def _pack_host(self, plan: BatchPlan, values, miss_rows):
        """Host-pack assembly: encode misses (ONE vectorized device->host
        slice per flush — ``ctx_slice_batch`` — instead of a blocking
        per-user loop), populate the cache, ``ctx_pack`` + H2D."""
        if miss_rows:
            ctxs = self._encode_missing(plan, miss_rows, "context")
            if self._ctx_rot:
                ctxs = ctx_rotate(ctxs, self._n_new, plan.seq_len)
            sls = ctx_slice_batch(ctxs, len(miss_rows))  # one device sync
            for j, u in enumerate(miss_rows):
                self.cache.put(plan.user_keys[u], (self._ctx_tag, sls[j]))
                values[u] = (self._ctx_tag, sls[j])
        packed = ctx_pack([values[u][1] for u in range(plan.n_unique)],
                          plan.b_u)
        return self._device(packed)

    def _pack_slab(self, plan: BatchPlan, values, miss_rows, slab: KVSlab):
        """Slab assembly: encode misses straight into freshly allocated
        arena slots (quantize + donated scatter, NO device sync, no host
        ctx bytes), then gather the whole bucket by slot id with dequant
        fused — the packed device batch without ctx_slice/ctx_pack/H2D."""
        if miss_rows:
            with self._tracer.span("slab:put", "slab", tid=self._slab_tid,
                                   args={"miss_users": len(miss_rows)}):
                ctxs = self._encode_missing(plan, miss_rows, "context")
                slots = self._alloc_slots(slab, len(miss_rows))
                b_m = self.ladder_u.fit(len(miss_rows))
                vec = np.full(b_m, slab.scratch, np.int32)
                vec[:len(miss_rows)] = slots
                slab.arenas = self.registry(
                    "slab_put", (b_m, plan.seq_len),
                    slab.arenas, ctxs, jnp.asarray(vec))
                slab.puts += len(miss_rows)
            for j, u in enumerate(miss_rows):
                v = ("slab", self._ctx_tag, slots[j])
                self.cache.put(plan.user_keys[u], v)
                values[u] = v
        vec = np.full(plan.b_u, slab.scratch, np.int32)
        for u in range(plan.n_unique):
            vec[u] = values[u][2]
        with self._tracer.span("slab:gather", "slab", tid=self._slab_tid,
                               args={"b_u": plan.b_u}):
            out = self.registry("slab_gather", (plan.b_u, plan.seq_len),
                                slab.arenas, jnp.asarray(vec))
        slab.gathers += 1
        return out

    def _alloc_slots(self, slab: KVSlab, n: int):
        """Take ``n`` free slots, evicting LRU cache entries to recycle
        theirs when the free list runs dry.  Safe with respect to the
        in-flight plan: its hit users were LRU-refreshed by
        ``_lookup_users`` moments ago, so (with capacity >= max_unique)
        eviction can only reach users outside the current flush."""
        slots = slab.alloc(n)
        while slots is None:
            if self.cache.evict_lru(1) == 0:   # pragma: no cover - guarded
                raise RuntimeError(
                    f"KV slab exhausted: need {n} slots, "
                    f"{len(slab.free)} free and nothing left to evict")
            slots = slab.alloc(n)
        return slots

    def _on_cache_evict(self, key, value):
        """ContextCache ``on_evict`` hook: when an evicted/replaced entry
        owned a slab slot, push the slot back on the free list (the stale
        device row is simply unreachable until reused)."""
        if (self._slab is not None and isinstance(value, tuple)
                and len(value) == 3 and value[0] == "slab"):
            self._slab.release(value[2])

    def _ensure_slab(self, L: int) -> Optional[KVSlab]:
        """The slab for context length ``L`` — built (and its executors
        registered) on first sight of a concrete L; None when the slab is
        disabled or sized for a different L (those flushes fall back to
        the host-pack path rather than reallocating arenas)."""
        if not self._slab_slots:
            return None
        if self._slab is None:
            self._slab = KVSlab(
                self.model, self.params, seq_len=L,
                slots=self._slab_slots, dtype=self._slab_dtype,
                rotated=self._ctx_rot, n_new=self._n_new,
                gather_impl=self._slab_gather_impl)
            # the arena argument is DONATED: put updates slots in place
            # instead of copying the whole arena every miss batch
            self.registry.register("slab_put", self._slab.put_factory,
                                   jit_kwargs={"donate_argnums": 0})
            self.registry.register("slab_gather", self._slab.gather_factory)
        return self._slab if self._slab.seq_len == L else None

    @staticmethod
    def _remap_unique_rows(batch, stored_order, plan: BatchPlan):
        """Serve a PERMUTED pack-memo hit: relabel ``inverse_idx`` into
        the memoized batch's row order and permute ``user_feats`` rows to
        match.  Bit-identical to repacking — the crossing consumes
        per-user rows (ctxs and user_feats alike) only through
        ``inverse_idx`` gathers, so scores depend on which row each
        candidate reads, never on row order itself."""
        pos = {k: i for i, k in enumerate(stored_order)}
        m = np.array([pos[k] for k in plan.user_keys], np.int32)
        batch = dict(batch)
        batch["inverse_idx"] = m[batch["inverse_idx"]]
        uf = batch["user_feats"]
        uf2 = np.zeros_like(uf)
        uf2[m] = uf[:len(m)]
        batch["user_feats"] = uf2
        return batch

    # -- lite path: pooled-embedding cache (dedup-aware) --------------------
    def _prepare_lite(self, plan: BatchPlan):
        values, miss_rows = self._lookup_users(plan.user_keys)
        if self._obs_on:
            self._prep_obs = {"ctx_misses": len(miss_rows),
                              "ctx_hits": plan.n_unique - len(miss_rows)}
        if miss_rows:
            fresh = np.asarray(self._encode_missing(plan, miss_rows, "encode"))
            for j, u in enumerate(miss_rows):
                self.cache.put(plan.user_keys[u], fresh[j])
                values[u] = fresh[j]
        emb_u = np.zeros((plan.b_u, values[0].shape[-1]), np.float32)
        for u in range(plan.n_unique):
            emb_u[u] = values[u]
        user_emb = emb_u[plan.batch["inverse_idx"]]          # Ψ⁻¹ on host
        return ("score_emb", (plan.b_u, plan.b_c),
                (self.params, jnp.asarray(user_emb),
                 self._device(self._cross_batch(plan.batch))))

    # -- retrieval path: corpus top-k from the cached pooled embedding ------
    def attach_index(self, index, *, k: int = 100,
                     chunk_rows: int = 65536, ivf_nprobe: int = 8,
                     ivf_widen: int = 2, ivf_slice_rows: int = 4096,
                     ivf_recall_floor: Optional[float] = None) -> None:
        """Attach an ``ItemIndex`` as the retrieval corpus.

        The corpus is cut into FIXED-SHAPE device chunks so a single jitted
        executor per query bucket covers any corpus size — chunk data and
        base/valid-count scalars ride along as traced operands, never as
        fresh shapes.  That makes an index REFRESH free: re-attaching an
        index with the same (k, bits, dim, chunk_rows) — e.g. one grown by
        ``IndexBuilder.append`` — keeps every warmed executor, so new items
        become retrievable with ZERO new XLA compiles (the appended rows
        simply fill the tail chunk's padding and/or arrive as extra chunk
        operands).  An INCOMPATIBLE re-attach (different k/bits/dim/chunk
        size) invalidates the retrieval executors and, on an already-warmed
        engine, re-warms them before returning.

        An IVF-built index (``retrieval.ivf.build_ivf``) additionally
        enables ``route="ivf"`` on retrieval requests: ``ivf_nprobe`` is
        the base probe width, widened up a doubling ladder of ``ivf_widen``
        extra levels — each level a precompiled executor shape — when
        ``ivf_recall_floor`` demands it (fill fraction = finite slots / k,
        the recall proxy).  Clusters are visited as fixed ``ivf_slice_rows``
        slices of the cluster-contiguous layout.  The append story carries
        over: re-attaching an appended IVF index keeps every warmed
        executor (clusters — and hence every slice-table shape — are
        untouched by ``append``; the appended rows live in an unclustered
        tail scanned EXACTLY through the regular chunk executors and merged
        with the IVF partial)."""
        if not self.lite:
            raise ValueError("retrieval needs a lite variant (pooled user "
                             f"embedding); got {self.variant!r}")
        assert 0 < k <= index.n_items
        assert index.dim == self.model.pcfg.id_dim, \
            (index.dim, self.model.pcfg.id_dim)
        assert chunk_rows % 32 == 0, \
            f"chunk_rows={chunk_rows} must be a multiple of 32 (one packed " \
            "filter-mask word covers 32 rows)"
        assert ivf_slice_rows % 32 == 0, \
            f"ivf_slice_rows={ivf_slice_rows} must be a multiple of 32"
        # a live refresh must not swap corpus state under a flush in
        # progress on the background flusher (or any other) thread
        with self._engine_lock:
            self._attach_index_locked(index, k, chunk_rows, ivf_nprobe,
                                      ivf_widen, ivf_slice_rows,
                                      ivf_recall_floor)

    def _attach_index_locked(self, index, k: int, chunk_rows: int,
                             ivf_nprobe: int, ivf_widen: int,
                             ivf_slice_rows: int,
                             ivf_recall_floor: Optional[float]) -> None:
        R = index.qt.packed.shape[0]
        ivf_sig = None
        if index.ivf is not None:
            from repro.retrieval.ivf import SliceTable
            from repro.retrieval.scorer import _round_up
            ivf = index.ivf
            sr = int(min(ivf_slice_rows,
                         max(32, _round_up(max(ivf.max_cluster_rows(), 1),
                                           32))))
            tab = SliceTable(ivf, sr)
            C = ivf.n_clusters
            base_p = int(min(max(1, ivf_nprobe), C))
            levels = sorted({min(base_p * 2 ** j, C)
                             for j in range(max(0, ivf_widen) + 1)})
            s_of = {p: tab.slots(p) for p in levels}
            # the executor-shape signature: appends never change it
            # (clusters are untouched), so append + re-attach is compatible
            ivf_sig = (sr, tuple(levels), tuple(s_of[p] for p in levels))
        attach_key = (k, index.bits, index.dim, chunk_rows, ivf_sig)
        compatible = (self._attach_key == attach_key
                      and self.retrieve_k <= self._chunk_size)
        ch = (self._chunk_size if compatible
              else min(chunk_rows, R + (-R % 32)))
        assert k <= ch, f"k={k} exceeds chunk_rows={ch}"
        self.index, self.retrieve_k = index, k
        self._attach_key, self._chunk_size = attach_key, ch

        # one (ch, .) device slice per chunk + its base/valid traced scalars
        # (base also kept as a host int for chunk-local mask building);
        # only the tail chunk pays a pad copy — no transient whole-corpus
        # padded duplicate on attach/refresh
        def chunk(arr, base, dtype=None):
            sl = jnp.asarray(arr[base:min(base + ch, R)])
            if dtype is not None:
                sl = sl.astype(dtype)
            if sl.shape[0] < ch:
                sl = jnp.pad(sl, ((0, ch - sl.shape[0]), (0, 0)))
            return sl

        self._chunks = [
            (chunk(index.qt.packed, base),
             chunk(index.qt.scale, base, jnp.float16),
             chunk(index.qt.bias, base, jnp.float16),
             jnp.asarray(base, jnp.int32),
             jnp.asarray(min(index.n_items - base, ch), jnp.int32), base)
            for base in range(0, R, ch)]
        self._zero_masks = {}
        self._ivf_zero_masks = {}
        # cached packed mask rows are chunk-window- and corpus-relative:
        # any (re-)attach invalidates them (start_id / surfaces / chunking
        # may all have changed); hit/miss counters stay cumulative
        self._mask_cache.clear()
        # IVF runtime state rebuilds on EVERY attach (the index — and its
        # appended tail — is new even when the executor shapes are not)
        self._ivf = None
        if index.ivf is not None:
            from repro.retrieval.filters import pack_bits
            from repro.retrieval.ivf import pad_for_slices
            pk_p, sc_p, bs_p = pad_for_slices(index.qt, sr)
            nc = ivf.n_clustered
            tail_chunks = []
            if ivf.appended_unclustered:
                for chk in self._chunks:
                    if chk[5] + ch <= nc:
                        continue
                    standing = None
                    if chk[5] < nc:   # straddling chunk: hide rows the
                        excl = np.zeros(ch, bool)     # probe already saw
                        excl[:nc - chk[5]] = True
                        standing = pack_bits(excl)
                    tail_chunks.append((chk, standing))
            self._ivf = {"data": ivf, "tab": tab, "sr": sr,
                         "levels": levels, "S_of": s_of,
                         "pk": pk_p, "sc": sc_p, "bs": bs_p,
                         "floor": ivf_recall_floor,
                         "tail_chunks": tail_chunks}
        if compatible:          # warmed executors stay valid: same shapes,
            return              # same closed-over (k, bits, ch)
        bits = index.bits

        def retrieve_factory(key):
            from repro.retrieval.scorer import chunk_topk

            def fn(queries, packed, scale, bias, base, n_valid, mask):
                return chunk_topk(queries, packed, scale, bias,
                                  base, n_valid, k=k, bits=bits, mask=mask)
            return fn

        # an incompatible re-attach (new k/bits/chunk shape) must not serve
        # executors that closed over the previous index's parameters
        self.registry.invalidate("retrieve")
        self.registry.register("retrieve", retrieve_factory)
        self.registry.invalidate("ivf")
        if self._ivf is not None:
            sr_c = self._ivf["sr"]

            def ivf_factory(key):
                from repro.retrieval.ivf import ivf_topk

                def fn(queries, packed, scale, bias, off, val, mask):
                    return ivf_topk(queries, packed, scale, bias, off, val,
                                    mask, k=k, bits=bits, slice_rows=sr_c)
                return fn

            self.registry.register("ivf", ivf_factory)
        if self._warmed_up:   # keep the zero-recompile steady-state promise
            self._warm_retrieval()

    def _zero_mask(self, b_q: int):
        """All-zeros (= nothing excluded) chunk mask for bucket ``b_q`` —
        the shared operand that lets filtered and unfiltered requests run
        the same executor."""
        m = self._zero_masks.get(b_q)
        if m is None:
            m = self._zero_masks[b_q] = jnp.zeros(
                (b_q, self._chunk_size // 32), jnp.int32)
        return m

    def _ivf_zero_mask(self, b_q: int, S: int):
        """All-zeros slice-pushdown mask — the IVF analogue of
        :meth:`_zero_mask` (filtered and unfiltered probes share one
        executor)."""
        m = self._ivf_zero_masks.get((b_q, S))
        if m is None:
            m = self._ivf_zero_masks[(b_q, S)] = jnp.zeros(
                (b_q, S, self._ivf["sr"] // 32), jnp.int32)
        return m

    def _ivf_level(self, nprobe: Optional[int]) -> int:
        """Serve a requested nprobe at the nearest configured level >= it
        (levels are the precompiled executor shapes); ``None`` = the attach
        base level."""
        levels = self._ivf["levels"]
        if nprobe is None:
            return levels[0]
        for p in levels:
            if p >= nprobe:
                return p
        return levels[-1]

    def _warm_ivf(self, b_u: int) -> None:
        """Warm the IVF probe executors of one query bucket — every nprobe
        level's slot shape, with inert (valid=0) slot operands."""
        iv = self._ivf
        d = self.model.pcfg.id_dim
        for S in sorted(set(iv["S_of"].values())):
            self.registry.warm("ivf", (b_u, S),
                               jnp.zeros((b_u, d), jnp.float32),
                               iv["pk"], iv["sc"], iv["bs"],
                               jnp.zeros((b_u, S), jnp.int32),
                               jnp.zeros((b_u, S), jnp.int32),
                               self._ivf_zero_mask(b_u, S))

    def _warm_retrieval(self):
        """Warm (or re-warm) just the retrieval ladder — called when an
        index is attached to an ALREADY-warmed engine, so the steady-state
        zero-recompile contract survives warmup-then-attach orderings and
        index refreshes without a full warmup() pass."""
        L = int(self._warm_L if self._warm_L is not None
                else self.model.cfg.seq_len)
        d = self.model.pcfg.id_dim
        zi = lambda *s: jnp.zeros(s, jnp.int32)
        for b_u in self.ladder_u.sizes():
            if self.cache is None:     # not covered by the warmup() pass
                self.registry.warm("encode", (b_u, L), self.params,
                                   zi(b_u, L), zi(b_u, L), zi(b_u, L))
                for b_c in self.ladder_c.sizes():
                    self._warm_score_emb(b_u, b_c, L)
            self.registry.warm("retrieve", (b_u,),
                               jnp.zeros((b_u, d), jnp.float32),
                               *self._chunks[0][:5], self._zero_mask(b_u))
            if self._ivf is not None:
                self._warm_ivf(b_u)

    def retrieve(self, requests: Sequence[RetrieveRequest]):
        """-> per-request (item_ids (k,), scores (k,)) numpy pairs.  A thin
        batch shim over ``submit_many`` — the retrieve lane of one flush
        (``_retrieve_batch``) does the work."""
        futures = self.submit_many(requests)
        self.flush()
        return [f.result() for f in futures]

    def _group_retrieval(self, requests):
        """Shared retrieval planning: validate per-request k, build
        ``ItemFilter``s, and dedupe requests into unique (user key, filter
        fingerprint, route) rows.  -> (filts, keys, owners, rconfs) where
        ``owners[u]`` lists the request indices sharing unique row u and
        ``rconfs[u]`` is its route conf — ``("exact", None)`` or
        ``("ivf", effective_nprobe_level)`` (two requests whose nprobes
        map to the same level share one execution)."""
        if self._chunks is None:
            raise ValueError("no retrieval corpus: call attach_index() first")
        from repro.retrieval.filters import ItemFilter
        filts, confs = [], []
        for i, r in enumerate(requests):
            if r.k > self.retrieve_k:
                raise ValueError(
                    f"request {i} wants k={r.k} but the attached index "
                    f"serves k<={self.retrieve_k}; re-attach with a larger k")
            f = ItemFilter(
                exclude_ids=r.exclude_ids,
                allow_surfaces=(None if r.allow_surfaces is None
                                else tuple(r.allow_surfaces)))
            filts.append(None if f.is_empty() else f)
            route = getattr(r, "route", "exact")
            if route == "ivf":
                if self._ivf is None:   # flush-time re-check under the lock
                    raise ValueError(
                        "route='ivf' but the attached index has no IVF "
                        "structure (build_ivf + attach_index)")
                confs.append(("ivf",
                              self._ivf_level(getattr(r, "nprobe", None))))
            else:
                confs.append(("exact", None))
        key_fn = self._key_fn or request_key   # same namespace as ranking
        keys = [key_fn(r) for r in requests]
        uniq: Dict[tuple, int] = {}
        owners: List[List[int]] = []   # unique row -> request indices
        rconfs: List[tuple] = []       # unique row -> route conf
        for i, key in enumerate(keys):
            fkey = filts[i].fingerprint() if filts[i] is not None else b""
            u = uniq.setdefault((key, fkey, confs[i]), len(owners))
            if u == len(owners):
                owners.append([])
                rconfs.append(confs[i])
            owners[u].append(i)
        return filts, keys, owners, rconfs

    def _route_groups(self, owners, rconfs):
        """Partition unique retrieval rows into ROUTE-UNIFORM dispatch
        groups of <= max_unique (one group = one executor family + probe
        width; mixing routes in a group would need two dispatches anyway).
        First-seen route order, row order preserved within a route.
        -> [(rconf, [unique row, ...]), ...]."""
        by: Dict[tuple, List[int]] = {}
        route_order = []
        for u, rc in enumerate(rconfs):
            if rc not in by:
                by[rc] = []
                route_order.append(rc)
            by[rc].append(u)
        out = []
        for rc in route_order:
            rows = by[rc]
            for g0 in range(0, len(rows), self.max_unique):
                out.append((rc, rows[g0:g0 + self.max_unique]))
        return out

    def _retrieve_batch(self, requests: Sequence[RetrieveRequest]):
        """The retrieve lane.

        The pooled user embedding comes from the ContextCache when present
        (shared with the lite ranking path); misses run the bucketed
        ``encode`` executor.  Unique (user, filter) pairs beyond max_unique
        are processed in bucket-sized groups.  Per-request ``exclude_ids``
        / ``allow_surfaces`` become packed chunk bitmasks applied inside
        the corpus executors — the same warmed executor serves filtered
        and unfiltered traffic (an empty filter is the all-zeros mask), so
        filters never cost a compile.  Requests from the same user with
        DIFFERENT filters are distinct retrieval groups but still share
        one cached user embedding; when fewer than k items survive a
        filter, the tail scores are -inf.  ``route="ivf"`` rows go through
        the IVF probe executors instead (groups are route-uniform); their
        unfilled tails are (-inf, id -1)."""
        filts, keys, owners, rconfs = self._group_retrieval(requests)
        out: List[Optional[tuple]] = [None] * len(requests)
        for rconf, group in self._route_groups(owners, rconfs):
            emb, tel_extra = self._user_embeddings(
                [requests[owners[u][0]] for u in group],
                [keys[owners[u][0]] for u in group])
            scores, rows = self._corpus_topk(
                emb, len(group), tel_extra,
                [filts[owners[u][0]] for u in group], route=rconf)
            for j, u in enumerate(group):
                ids = self.index.item_ids(rows[j])
                for i in owners[u]:
                    kk = requests[i].k
                    out[i] = (ids[:kk], scores[j, :kk])
        return out

    def _user_embeddings(self, reqs, keys):
        """Pooled embeddings for <= max_unique deduplicated users — the
        same cache + bucketed-encode protocol as the lite scoring path
        (``_lookup_users``/``_encode_rows``), fed from raw requests instead
        of a BatchPlan.  Cache misses are deduplicated by user key before
        encoding, so the same user appearing in several rows (e.g. one per
        filter variant) is encoded exactly once.
        -> ((n, id_dim) np, telemetry)."""
        values, miss_rows = self._lookup_users(keys)
        if miss_rows:
            slot: Dict[bytes, int] = {}       # key -> row in the encode batch
            enc_rows: List[int] = []          # first missing row per key
            for u in miss_rows:
                if keys[u] not in slot:
                    slot[keys[u]] = len(enc_rows)
                    enc_rows.append(u)

            def gather(name):
                return np.stack([np.asarray(getattr(reqs[u], name), np.int32)
                                 for u in enc_rows])

            fresh = np.asarray(self._encode_rows(
                "encode", gather("seq_ids"), gather("seq_actions"),
                gather("seq_surfaces")))
            for u in miss_rows:
                values[u] = fresh[slot[keys[u]]]
            if self.cache is not None:
                for key, j in slot.items():
                    self.cache.put(key, fresh[j])
            miss_rows = enc_rows
        emb = np.stack([values[u] for u in range(len(reqs))])
        return emb, {"encode_misses": len(miss_rows)}

    def encode_users(self, requests: Sequence) -> np.ndarray:
        """Pooled user embeddings for a request list, synchronously —
        the cluster tier's encode hook.  Runs the same cache + bucketed
        ``encode``-executor protocol as the retrieval/scoring paths
        (misses land in the ContextCache, so later rank/retrieve traffic
        for the same users hits), in chunks of ``max_unique`` under the
        engine lock.  Lite variants only (early-fusion variants have no
        standalone pooled embedding).  -> (len(requests), id_dim) fp32."""
        if not self.lite:
            raise ValueError("encode_users needs a lite variant (pooled "
                             f"user embedding); got {self.variant!r}")
        reqs = list(requests)
        key_fn = self._key_fn or request_key
        keys = [key_fn(r) for r in reqs]
        if not reqs:
            return np.zeros((0, self.model.pcfg.id_dim), np.float32)
        out = []
        with self._engine_lock:
            for i in range(0, len(reqs), self.max_unique):
                emb, _ = self._user_embeddings(reqs[i:i + self.max_unique],
                                               keys[i:i + self.max_unique])
                out.append(emb)
        return np.concatenate(out).astype(np.float32, copy=False)

    def _chunk_mask_rows(self, filters, fps, base_host: int):
        """Per-chunk packed mask rows with fingerprint memoization: the
        (W,) row a filter packs for a chunk window depends only on the
        filter's fingerprint and the window, and seen-lists repeat across a
        session's requests — so rows are served from an LRU keyed by
        (fingerprint, chunk base) and only packed on first sight.  ``fps``
        carries the per-filter fingerprints precomputed ONCE per call (a
        fingerprint re-sorts the whole seen-list — per-chunk recomputation
        would dwarf the packing the cache saves).
        -> (n, W) int32 stack, or None when nothing in this chunk is
        excluded."""
        from repro.retrieval.filters import excluded_rows, pack_bits
        W = self._chunk_size // 32
        zero_row = None
        rows, any_set = [], False
        for f, fp in zip(filters, fps):
            if fp is None:
                if zero_row is None:
                    zero_row = np.zeros(W, np.int32)
                rows.append(zero_row)
                continue
            ck = (fp, base_host)
            row = self._mask_cache.get(ck)
            # counters mutate under the engine RLock every flush holds —
            # the same lock the stats() snapshot takes, so no finer guard
            if row is None:
                self.mask_misses += 1
                row = pack_bits(excluded_rows(f, self.index, base_host,
                                              self._chunk_size))
                self._mask_cache[ck] = row
                while len(self._mask_cache) > _MASK_CACHE_CAP:
                    self._mask_cache.popitem(last=False)
            else:
                self._mask_cache.move_to_end(ck)
                self.mask_hits += 1
            if row.any():
                any_set = True
            rows.append(row)
        return np.stack(rows) if any_set else None

    def _dispatch_retrieval(self, emb, n_users, filters=None, route=None):
        """Dispatch the bucketed chunk executors over the whole corpus —
        async: returns the per-chunk (scores, rows) device futures without
        waiting for any of them.  ``filters`` (one Optional[ItemFilter]
        per user row) is resolved per chunk into a packed (b_q, chunk/32)
        bitmask — rows are memoized per filter fingerprint
        (``_chunk_mask_rows``), and chunks no filter touches reuse the
        cached all-zeros mask, so the common case ships no bytes.
        ``route=("ivf", nprobe_level)`` takes the IVF probe path instead.
        -> (parts, b_q, rinfo) — rinfo is None on the exact route; on IVF
        it carries what :meth:`_merge_retrieval` needs to widen."""
        if route is not None and route[0] == "ivf":
            return self._dispatch_ivf(emb, n_users, filters, route[1])
        b_q = self.ladder_u.fit(n_users)
        q = jnp.asarray(_pad_rows(emb.astype(np.float32), b_q))
        filtered = filters is not None and any(f is not None for f in filters)
        fps = ([None if f is None or f.is_empty() else f.fingerprint()
                for f in filters] if filtered else None)
        parts = []
        for pk, sc, bs, base, n_valid, base_host in self._chunks:
            mask = self._zero_mask(b_q)
            if filtered:
                m = self._chunk_mask_rows(filters, fps, base_host)
                if m is not None:
                    mask = jnp.asarray(_pad_rows(m, b_q))
            parts.append(self.registry("retrieve", (b_q,), q, pk, sc, bs,
                                       base, n_valid, mask))
        return parts, b_q, None

    def _dispatch_ivf(self, emb, n_users, filters, nprobe):
        """The IVF probe dispatch: host routing to the nprobe-level nearest
        clusters, slice gather + filter pushdown, ONE warmed (b_q, S)
        executor call over the probed slices — plus, when the index carries
        appended-but-unclustered rows, the regular chunk executors over the
        tail (standing masks hide the rows the probe already covered), so
        freshness costs neither recall nor a recompile.  Async like the
        exact dispatch.  -> (parts, b_q, rinfo)."""
        from repro.retrieval.ivf import ivf_route, slice_masks
        iv = self._ivf
        level = self._ivf_level(nprobe)
        S = iv["S_of"][level]
        b_q = self.ladder_u.fit(n_users)
        q = emb.astype(np.float32)
        clusters = ivf_route(iv["data"].centroids, q, level)
        off, val = iv["tab"].gather(clusters, S)
        filtered = filters is not None and any(f is not None for f in filters)
        mask = None
        if filtered:
            mask = slice_masks(filters, self.index, off, val, iv["sr"],
                               cache=self._mask_cache)
            while len(self._mask_cache) > _MASK_CACHE_CAP:
                self._mask_cache.popitem(last=False)
        self.ivf_clusters_probed += int(clusters.size)
        self.ivf_rows_scanned += int(val.sum())
        qd = jnp.asarray(_pad_rows(q, b_q))
        md = (self._ivf_zero_mask(b_q, S) if mask is None
              else jnp.asarray(_pad_rows(mask, b_q)))
        parts = [self.registry("ivf", (b_q, S), qd, iv["pk"], iv["sc"],
                               iv["bs"], jnp.asarray(_pad_rows(off, b_q)),
                               jnp.asarray(_pad_rows(val, b_q)), md)]
        fps = ([None if f is None or f.is_empty() else f.fingerprint()
                for f in filters] if filtered else None)
        nc = iv["data"].n_clustered
        for chk, standing in iv["tail_chunks"]:
            pk, sc, bs, base, n_valid, base_host = chk
            self.ivf_rows_scanned += n_users * max(
                0, min(base_host + self._chunk_size, self.index.n_items)
                - max(base_host, nc))
            rows_m = None
            if filtered:
                fm = self._chunk_mask_rows(filters, fps, base_host)
                if fm is not None:
                    rows_m = fm if standing is None else fm | standing
            if rows_m is None and standing is not None:
                rows_m = np.broadcast_to(standing, (n_users, len(standing)))
            cmask = (self._zero_mask(b_q) if rows_m is None
                     else jnp.asarray(_pad_rows(np.ascontiguousarray(rows_m),
                                                b_q)))
            parts.append(self.registry("retrieve", (b_q,), qd, pk, sc, bs,
                                       base, n_valid, cmask))
        rinfo = {"level": level, "emb": emb, "filters": filters}
        return parts, b_q, rinfo

    def _merge_retrieval(self, parts, n_users, rinfo=None):
        """Retrieval finalize: sync on the partials and merge them on host
        (stable, lower row index wins).  On the IVF route (``rinfo``),
        this is also where the recall floor acts: if the fill fraction
        (finite slots / k — the recall proxy) lands below the attach-time
        floor, the probe re-dispatches at the next nprobe level up the
        ladder (each a pre-warmed shape) and re-merges — widening costs
        pipeline overlap, never a compile.  IVF tails normalize to
        (-inf, -1): an unvisited row has no honest index.
        -> (scores (n_users, k), rows (n_users, k))."""
        from repro.retrieval.scorer import merge_topk
        scores, rows = merge_topk([p[0] for p in parts],
                                  [p[1] for p in parts], self.retrieve_k)
        scores, rows = scores[:n_users], rows[:n_users]
        if rinfo is not None:
            iv = self._ivf
            floor = iv["floor"]
            while True:
                fill = (float(np.min(np.mean(scores > -np.inf, axis=1)))
                        if n_users else 1.0)
                self.ivf_last_fill = fill
                li = iv["levels"].index(rinfo["level"])
                if (floor is None or fill >= floor
                        or li + 1 >= len(iv["levels"])):
                    break
                self.ivf_widened += 1
                parts, _, rinfo = self._dispatch_ivf(
                    rinfo["emb"], n_users, rinfo["filters"],
                    iv["levels"][li + 1])
                scores, rows = merge_topk([p[0] for p in parts],
                                          [p[1] for p in parts],
                                          self.retrieve_k)
                scores, rows = scores[:n_users], rows[:n_users]
            rows = np.where(scores == -np.inf, -1, rows)
        return scores, rows

    def _retrieval_stats_entry(self, n_users, b_q, t0, tel_extra, filters):
        entry = {"retrieve_users": n_users, "b_q": b_q,
                 "corpus_items": self.index.n_items,
                 "corpus_chunks": len(self._chunks),
                 "filtered_users": (sum(f is not None for f in filters)
                                    if filters else 0),
                 "mask_hits": self.mask_hits,
                 "mask_misses": self.mask_misses,
                 "latency_s": time.perf_counter() - t0, **tel_extra,
                 **{f"exec_{k}": v for k, v in
                    self.registry.telemetry().items()}}
        if self.cache is not None:
            entry["cache_hits"] = self.cache.hits
            entry["cache_misses"] = self.cache.misses
        self.call_stats.append(entry)
        if self._obs_on:
            self._h_retr_ms.record(entry["latency_s"] * 1e3)
            self._tracer.event(
                "retrieval:group", "retrieval", t0, entry["latency_s"],
                tid=self._retr_tid,
                args={"users": n_users, "b_q": b_q,
                      "chunks": entry["corpus_chunks"],
                      "filtered_users": entry["filtered_users"],
                      **tel_extra})

    def _corpus_topk(self, emb, n_users, tel_extra, filters=None,
                     route=None):
        """Synchronous dispatch + merge over the corpus (the retrieve
        lane's path; the fused two-stage lane drives the two stages
        separately to overlap the merge with ranking).
        -> (scores (n_users, k), rows (n_users, k))."""
        t0 = time.perf_counter()
        parts, b_q, rinfo = self._dispatch_retrieval(emb, n_users, filters,
                                                     route)
        scores, rows = self._merge_retrieval(parts, n_users, rinfo)
        tel_extra = dict(tel_extra,
                         route=(route[0] if route is not None else "exact"))
        if rinfo is not None:
            tel_extra["nprobe"] = rinfo["level"]
        self._retrieval_stats_entry(n_users, b_q, t0, tel_extra, filters)
        return scores, rows

    # -- fused two-stage lane: retrieve -> rank in one pipeline schedule ----
    def attach_features(self, fn) -> None:
        """Register the engine-level candidate-feature provider for the
        fused two-stage path: ``fn(item_ids) -> (n, cand_feat_dim)``
        float32 ranking features of retrieved items.  A request-level
        ``cand_feats_fn`` overrides it."""
        with self._engine_lock:     # not under a flush on another thread
            self._features_fn = fn

    def attach_generator(self, generator) -> None:
        """Register the LM generator behind ``GenerateRequest`` routing —
        any object with ``generate(prompts, rng=...)`` (see
        ``serving.generate.Generator``)."""
        with self._engine_lock:     # not under a flush on another thread
            self._generator = generator

    def _generate_batch(self, requests: Sequence[GenerateRequest]):
        """The generate lane: forward each request to the attached
        generator (LM generation has its own internal batching; requests
        are independent decode loops)."""
        if self._generator is None:
            raise ValueError("no generator: call attach_generator() first")
        out = []
        for r in requests:
            kw = {"rng": r.rng} if r.rng is not None else {}
            out.append(np.asarray(self._generator.generate(r.prompts, **kw)))
        return out

    def _two_stage_batch(self, requests: Sequence[RetrieveThenRankRequest]) \
            -> List[TwoStageResult]:
        """The fused retrieve->rank lane: retrieval top-k feeds the rank
        path INSIDE one pipeline schedule.

        Requests dedupe into unique (user, filter) rows and process in
        groups of <= max_unique, exactly like the retrieve lane; the
        pooled user embedding comes from the ContextCache (one encode per
        user across BOTH stages).  The rank stage is then built DIRECTLY
        from what the retrieval stage already knows — the group is
        pre-deduplicated and the pooled embeddings are in hand — so the
        ``score_emb`` operands are assembled without a second Ψ pass: no
        ``build_plan`` identity hashing, no ``np.unique``, no second round
        of cache lookups.  (This is the fused path's main saving over the
        sequential ``retrieve()`` + ``score()`` shims, whose rank stage
        must re-deduplicate from scratch; the scores are identical because
        ``score_emb`` is row-wise in the candidates.)

        Under ``pipeline_depth=2`` the groups software-pipeline: group g's
        corpus-chunk executors are dispatched (async) BEFORE group g-1's
        retrieval finalize + rank build/launch run on the host, so the
        device scores group g's corpus while the host merges and ranks
        group g-1 — and the last launched rank chunk is always finalized
        one step behind, like the rank lane's own depth-2 pipeline.
        ``pipeline_depth=1`` runs each group to completion first; both
        orders feed identical operands to identical executors, so results
        are bit-identical either way, and match the sequential
        retrieve-then-rank path run on a cache-enabled engine (whose rank
        stage serves the same cached embeddings to the same executor).

        Per-flush ``PipelineStats(lane="two_stage")`` lands in
        ``pipeline_stats`` with the retrieval stage broken out
        (``retrieve_ms``)."""
        filts, keys, owners, rconfs = self._group_retrieval(requests)
        groups = self._route_groups(owners, rconfs)
        ps = PipelineStats(depth=self.pipeline_depth, lane="two_stage")
        t_all = time.perf_counter()
        probs_parts: List[List[np.ndarray]] = [[] for _ in requests]
        meta: Dict[int, tuple] = {}         # request -> (ids, retr scores)
        infl: Optional[dict] = None         # rank chunk awaiting finalize

        def finalize(fl) -> float:
            t0 = time.perf_counter()
            probs = np.asarray(fl["out"])
            wait_s = time.perf_counter() - t0
            off = 0
            for i, c in fl["scatter"]:
                probs_parts[i].append(probs[off:off + c])
                off += c
            self.call_stats.append(
                {"candidates": fl["n_c"], "unique_users": fl["n_u"],
                 "b_u": fl["b_u"], "b_c": fl["b_c"], "lane": "two_stage",
                 # same span as the rank lane's entries: prepare+launch+wait
                 "latency_s": fl["prepare_s"] + fl["launch_s"] + wait_s,
                 **{f"exec_{k}": v for k, v in
                    self.registry.telemetry().items()}})
            if self._obs_on:
                self._trace_chunk(
                    "two_stage", fl["t0"], fl["prepare_s"], fl["launch_s"],
                    t0, wait_s,
                    {"kind": "score_emb", "b_u": fl["b_u"],
                     "b_c": fl["b_c"], "candidates": fl["n_c"]})
            return wait_s * 1e3

        def launch_rank(chunk):
            """One rank chunk straight from retrieval-stage state: chunk
            entries are (req idx, cand ids, cand feats, pooled emb row,
            user_feats, identity key); unique users dedupe by FULL sequence
            identity within the chunk (first occurrence wins) — the same
            rule as build_plan's Ψ, deliberately NOT the engine's custom
            cache ``key_fn``: a coarser key_fn may share cached embeddings
            across sequences, but must not collapse their user_feats
            rows."""
            nonlocal infl
            in_flight = infl is not None and not _is_ready(infl["out"])
            t0 = time.perf_counter()
            rows: Dict[bytes, int] = {}
            emb_rows, uf_rows = [], []
            inv, cand_ids, cand_feats, scatter = [], [], [], []
            for i, ids, feats, emb_vec, uf, ukey in chunk:
                u = rows.get(ukey)
                if u is None:
                    u = rows[ukey] = len(rows)
                    emb_rows.append(emb_vec)
                    uf_rows.append(np.asarray(uf, np.float32))
                inv.append(np.full(len(ids), u, np.int32))
                cand_ids.append(np.asarray(ids, np.int32))
                cand_feats.append(feats)
                scatter.append((i, len(ids)))
            n_u, n_c = len(rows), sum(c for _, c in scatter)
            b_u, b_c = self.ladder_u.fit(n_u), self.ladder_c.fit(n_c)
            inv = _pad_rows(np.concatenate(inv), b_c)
            batch = {
                "inverse_idx": inv,
                "cand_ids": _pad_rows(np.concatenate(cand_ids), b_c),
                "cand_feats": _pad_rows(
                    np.concatenate(cand_feats).astype(np.float32), b_c),
                "user_feats": _pad_rows(np.stack(uf_rows), b_u),
            }
            user_emb = _pad_rows(np.stack(emb_rows).astype(np.float32),
                                 b_u)[inv]
            prepare_s = time.perf_counter() - t0
            ps.chunks += 1
            ps.prepare_ms += prepare_s * 1e3
            if in_flight:
                ps.overlapped_ms += prepare_s * 1e3
            t1 = time.perf_counter()
            with self._tracer.annotation("score_emb"):
                out = self.registry("score_emb", (b_u, b_c), self.params,
                                    jnp.asarray(user_emb),
                                    self._device(batch))
            launch_s = time.perf_counter() - t1
            ps.launch_ms += launch_s * 1e3
            fresh = {"out": out, "scatter": scatter, "n_c": n_c, "n_u": n_u,
                     "b_u": b_u, "b_c": b_c, "prepare_s": prepare_s,
                     "launch_s": launch_s, "t0": t0}
            if self.pipeline_depth >= 2:
                prev, infl = infl, fresh
                if prev is not None:
                    ps.wait_ms += finalize(prev)
            else:
                ps.wait_ms += finalize(fresh)

        def absorb(state):
            """Retrieval finalize for one group + build/launch its rank
            chunks (host work that overlaps the NEXT group's retrieval
            executors and the previous rank chunk's device time)."""
            group, parts, b_q, t0g, tel, emb, rinfo = state
            rank_busy = infl is not None and not _is_ready(infl["out"])
            t_m = time.perf_counter()
            scores, rows = self._merge_retrieval(parts, len(group), rinfo)
            merge_ms = (time.perf_counter() - t_m) * 1e3
            ps.retrieve_ms += merge_ms
            if rank_busy:
                ps.overlapped_ms += merge_ms
            self._retrieval_stats_entry(
                len(group), b_q, t0g, tel,
                [filts[owners[u][0]] for u in group])
            entries = []
            for j, u in enumerate(group):
                ids_full = self.index.item_ids(rows[j])
                for i in owners[u]:
                    r = requests[i]
                    ids = ids_full[:r.k]
                    meta[i] = (ids, scores[j, :r.k])
                    # non-None: the flush gate validated before lanes ran
                    feats_fn = r.cand_feats_fn or self._features_fn
                    feats = np.asarray(feats_fn(ids), np.float32)
                    ident = request_key(r)      # full identity, not key_fn
                    # a k beyond the candidate bucket splits by slice,
                    # exactly like the rank lane's _split_candidates
                    for o in range(0, len(ids), self.max_candidates):
                        sl = slice(o, o + self.max_candidates)
                        entries.append((i, ids[sl], feats[sl], emb[j],
                                        r.user_feats, ident))
            cur, cur_keys, cur_c = [], set(), 0
            for e in entries:
                n, new_u = len(e[1]), e[5] not in cur_keys
                if cur and (cur_c + n > self.max_candidates
                            or len(cur_keys) + new_u > self.max_unique):
                    launch_rank(cur)
                    cur, cur_keys, cur_c = [], set(), 0
                cur.append(e)
                cur_keys.add(e[5])
                cur_c += n
            if cur:
                launch_rank(cur)

        pending = None
        for rconf, group in groups:
            t0g = time.perf_counter()
            emb, tel = self._user_embeddings(
                [requests[owners[u][0]] for u in group],
                [keys[owners[u][0]] for u in group])
            rank_busy = infl is not None and not _is_ready(infl["out"])
            t_d = time.perf_counter()
            parts, b_q, rinfo = self._dispatch_retrieval(
                emb, len(group), [filts[owners[u][0]] for u in group],
                route=rconf)
            disp_ms = (time.perf_counter() - t_d) * 1e3
            ps.retrieve_ms += disp_ms
            if rank_busy:   # dispatch hidden behind the previous rank chunk
                ps.overlapped_ms += disp_ms
            state = (group, parts, b_q, t0g, tel,
                     emb, rinfo)
            if self.pipeline_depth >= 2:
                if pending is not None:
                    absorb(pending)
                pending = state
            else:
                absorb(state)
        if pending is not None:
            absorb(pending)
        if infl is not None:
            ps.wait_ms += finalize(infl)
        ps.total_ms = (time.perf_counter() - t_all) * 1e3
        self.pipeline_stats.append(ps)
        if self._obs_on:
            ps.record_to(self.obs.metrics)

        return [TwoStageResult(
                    item_ids=meta[i][0], retrieval_scores=meta[i][1],
                    probs=(probs_parts[i][0] if len(probs_parts[i]) == 1
                           else np.concatenate(probs_parts[i])))
                for i in range(len(requests))]

    # -- telemetry snapshot -------------------------------------------------
    def stats(self) -> dict:
        """One read-atomic telemetry snapshot: engine-side counters
        mutate only under the engine RLock (which every flush holds),
        registry counters under the registry RLock, scheduler counters
        under the scheduler queue lock — and this method holds all three,
        so no counter can be read torn or mid-update.  Covers executor
        compile/hit counts, ContextCache + pack-memo counters, retrieval
        mask-cache counters, per-lane request totals, scheduler flush
        counters, and the last pipeline record.  This is
        THE way to read engine telemetry — the per-chunk ``call_stats``
        list and the raw counters remain for tests/debugging, but only
        this method reads them consistently under concurrency."""
        sched = self._scheduler
        # scheduler counters mutate under the scheduler queue lock (never
        # held while acquiring the engine lock, so the order is safe)
        with self._engine_lock, self.registry.lock, sched._lock:
            snap = {
                "executors": self.registry.telemetry(),
                "cache": (self.cache.stats() if self.cache is not None
                          else None),
                "memo_perm_hits": self.memo_perm_hits,
                "slab": (dict(self._slab.stats(),
                              fallbacks=self.slab_fallbacks,
                              gather_hits=(self.cache.memo_hits
                                           if self.cache is not None else 0))
                         if self._slab is not None else None),
                "masks": {"hits": self.mask_hits,
                          "misses": self.mask_misses,
                          "entries": len(self._mask_cache)},
                "lanes": dict(self._lane_counts),
                "shared_encode_users": self.shared_encode_users,
                # contract: the historical keys ("flushes", "coalesced")
                # never change meaning; SLO additions only EXTEND the dict
                "scheduler": {
                    "flushes": sched.flushes,
                    "coalesced": sched.coalesced,
                    "shed": sched.shed_total,
                    "isolate_lanes": sched.isolate_lanes,
                    "lane_detail": sched._lane_stats_locked(),
                },
                "chunks_executed": len(self.call_stats),
                "pipeline_calls": len(self.pipeline_stats),
                "last_pipeline": (self.pipeline_stats[-1].as_dict()
                                  if self.pipeline_stats else None),
                "retrieval": {
                    "attached": self._chunks is not None,
                    "k": self.retrieve_k,
                    "corpus_items": (self.index.n_items
                                     if self.index is not None else 0),
                    "corpus_chunks": (len(self._chunks)
                                      if self._chunks is not None else 0),
                    # sub-entries of "retrieval" are NOT pinned by the
                    # stats-key contract (only the top level is)
                    "ivf": (None if self._ivf is None else {
                        "clusters": self._ivf["data"].n_clusters,
                        "nprobe_levels": list(self._ivf["levels"]),
                        "slice_rows": self._ivf["sr"],
                        "clusters_probed": self.ivf_clusters_probed,
                        "rows_scanned": self.ivf_rows_scanned,
                        "widened": self.ivf_widened,
                        "last_fill": self.ivf_last_fill,
                        "appended_unclustered":
                            self._ivf["data"].appended_unclustered,
                    }),
                },
            }
        return snap

    def _collect_obs(self) -> None:
        """Export-time collector (registered when obs is enabled): mirrors
        the engine's ad-hoc telemetry — executor registry, ContextCache +
        pack memo, retrieval mask cache, KV slab, lane totals, scheduler
        counters — into the obs registry, Prometheus-scrape style.  The
        source of truth stays the engine counters and the :meth:`stats`
        dict (whose key set is pinned by test); this reads ONE consistent
        ``stats()`` snapshot so the exported values are exactly what
        ``stats()`` would have returned at export time.  Runs outside the
        metrics registry lock; the per-metric locks it then takes are
        leaves, so the only lock order is engine -> metric."""
        m = self.obs.metrics
        s = self.stats()
        ex = s["executors"]
        m.gauge("serving_executors",
                "jitted executors instantiated").set(ex["executors"])
        m.counter("serving_executor_compiles_total",
                  "first executions (each paid an XLA compile)"
                  ).set_total(ex["compiles"])
        m.counter("serving_executor_hits_total",
                  "executions of an already-compiled executor"
                  ).set_total(ex["hits"])
        m.gauge("serving_executor_warmed",
                "executors precompiled by warmup()").set(ex["warmed"])
        m.gauge("serving_executor_compiles_after_warmup",
                "compiles outside warmup — the zero-recompile contract "
                "pins this at 0").set(ex["compiles_after_warmup"])
        calls: Dict[str, int] = {}
        for (kind, _), n in self.registry.call_counts().items():
            calls[kind] = calls.get(kind, 0) + n
        for kind, n in sorted(calls.items()):
            m.counter("serving_executor_calls_total",
                      "executor executions by kind",
                      kind=kind).set_total(n)
        if s["cache"] is not None:
            c = s["cache"]
            m.counter("serving_cache_hits_total",
                      "ContextCache hits").set_total(c["hits"])
            m.counter("serving_cache_misses_total",
                      "ContextCache misses").set_total(c["misses"])
            m.gauge("serving_cache_entries",
                    "ContextCache resident entries").set(c["entries"])
            m.gauge("serving_cache_bytes",
                    "ContextCache resident bytes").set(c["nbytes"])
            m.counter("serving_memo_hits_total",
                      "pack-memo hits (assembly skipped)"
                      ).set_total(c["memo_hits"])
            m.counter("serving_memo_misses_total",
                      "pack-memo misses").set_total(c["memo_misses"])
            m.counter("serving_memo_invalidations_total",
                      "pack-memo entries dropped by cache churn"
                      ).set_total(c["memo_invalidations"])
            m.counter("serving_memo_perm_hits_total",
                      "pack-memo hits served via host row remap"
                      ).set_total(s["memo_perm_hits"])
        if s["slab"] is not None:
            sl = s["slab"]
            m.gauge("serving_slab_occupancy",
                    "KV slab slots in use").set(sl["occupancy"])
            m.gauge("serving_slab_capacity",
                    "KV slab slots total").set(sl["capacity"])
            m.gauge("serving_slab_bytes_resident",
                    "KV slab arena bytes").set(sl["bytes_resident"])
            m.counter("serving_slab_puts_total",
                      "users quantized+scattered into the slab"
                      ).set_total(sl["puts"])
            m.counter("serving_slab_gathers_total",
                      "fused slab batch gathers").set_total(sl["gathers"])
            m.counter("serving_slab_evictions_total",
                      "slab slots recycled via cache eviction"
                      ).set_total(sl["evictions"])
            m.counter("serving_slab_fallbacks_total",
                      "flushes at an L the slab is not sized for"
                      ).set_total(sl["fallbacks"])
        m.counter("serving_mask_hits_total",
                  "retrieval filter-mask memo hits"
                  ).set_total(s["masks"]["hits"])
        m.counter("serving_mask_misses_total",
                  "retrieval filter-mask memo misses"
                  ).set_total(s["masks"]["misses"])
        m.gauge("serving_mask_entries",
                "memoized filter-mask rows").set(s["masks"]["entries"])
        for lane, n in s["lanes"].items():
            m.counter("serving_lane_requests_total",
                      "requests served, by lane", lane=lane).set_total(n)
        m.counter("serving_shared_encode_users_total",
                  "users encoded by the cross-lane shared pass"
                  ).set_total(s["shared_encode_users"])
        m.counter("serving_scheduler_flushes_total",
                  "scheduler flushes executed"
                  ).set_total(s["scheduler"]["flushes"])
        m.counter("serving_scheduler_coalesced_total",
                  "requests drained across all flushes"
                  ).set_total(s["scheduler"]["coalesced"])
        m.counter("serving_scheduler_shed_total",
                  "requests shed across all lanes (each future carries a "
                  "typed ShedError)").set_total(s["scheduler"]["shed"])
        m.counter("serving_chunks_executed_total",
                  "executor chunks executed"
                  ).set_total(s["chunks_executed"])
        if s["retrieval"]["attached"]:
            m.gauge("serving_retrieval_corpus_items",
                    "items in the attached corpus"
                    ).set(s["retrieval"]["corpus_items"])
            m.gauge("serving_retrieval_corpus_chunks",
                    "fixed-shape device chunks covering the corpus"
                    ).set(s["retrieval"]["corpus_chunks"])
        ivf = s["retrieval"].get("ivf")
        if ivf is not None:
            m.counter("serving_retrieval_clusters_probed_total",
                      "IVF clusters probed across all requests"
                      ).set_total(ivf["clusters_probed"])
            m.counter("serving_retrieval_rows_scanned_total",
                      "corpus rows scanned by IVF probes (incl. exact "
                      "unclustered-tail scans)"
                      ).set_total(ivf["rows_scanned"])
            m.counter("serving_retrieval_ivf_widened_total",
                      "recall-floor nprobe widenings"
                      ).set_total(ivf["widened"])
            m.gauge("serving_retrieval_ivf_fill",
                    "last IVF fill fraction (finite slots / k — the "
                    "recall proxy)").set(ivf["last_fill"])
            m.gauge("serving_retrieval_ivf_appended_unclustered",
                    "rows appended since the last IVF build (staleness)"
                    ).set(ivf["appended_unclustered"])

    # ------------------------------------------------------------------
    def warmup(self, *, seq_len: Optional[int] = None) -> dict:
        """Precompile every executor reachable from the bucket ladder, so
        steady-state traffic never pays an XLA compile.  Returns registry
        telemetry (incl. wall time)."""
        with self._engine_lock:     # not under a flush on another thread
            with self._tracer.span("warmup", "engine",
                                   tid=self._tracer.tid("engine")):
                return self._warmup_locked(seq_len)

    def _warmup_locked(self, seq_len: Optional[int]) -> dict:
        L = int(seq_len if seq_len is not None else self.model.cfg.seq_len)
        t0 = time.perf_counter()
        params = self.params
        zi = lambda *s: jnp.zeros(s, jnp.int32)

        for b_u in self.ladder_u.sizes():
            if self.cache is not None or (self.lite and
                                          self._chunks is not None):
                kind = "encode" if self.lite else "context"
                ctxs = self.registry.warm(kind, (b_u, L), params,
                                          zi(b_u, L), zi(b_u, L), zi(b_u, L))
                slab = None if self.lite else self._ensure_slab(L)
                if slab is not None:
                    # warm put + gather at every bucket against the shared
                    # scratch slot — zero-recompile covers the slab path
                    vec = jnp.full((b_u,), slab.scratch, jnp.int32)
                    slab.arenas = self.registry.warm(
                        "slab_put", (b_u, L), slab.arenas, ctxs, vec)
                    self.registry.warm("slab_gather", (b_u, L),
                                       slab.arenas, vec)
                if self._ctx_rot and not self.lite:
                    # the cross executors consume the PRE-ROTATED layout
                    ctxs = ctx_rotate(ctxs, self._n_new, L)
            if self._chunks is not None:
                d = self.model.pcfg.id_dim
                self.registry.warm("retrieve", (b_u,),
                                   jnp.zeros((b_u, d), jnp.float32),
                                   *self._chunks[0][:5], self._zero_mask(b_u))
                if self._ivf is not None:
                    self._warm_ivf(b_u)
            for b_c in self.ladder_c.sizes():
                if self.cache is None:
                    self.registry.warm(
                        "rank", (b_u, b_c, L), params,
                        self._device(self._dummy_batch(b_u, b_c, L)))
                elif not self.lite:
                    self.registry.warm(
                        "cross", (b_u, b_c, L), params,
                        self._device(self._cross_batch(
                            self._dummy_batch(b_u, b_c, L))), ctxs)
                if self.lite and (self.cache is not None
                                  or self._chunks is not None):
                    self._warm_score_emb(b_u, b_c, L)
        self._warmed_up, self._warm_L = True, L
        tel = self.registry.telemetry()
        tel["warmup_s"] = time.perf_counter() - t0
        return tel

    def _warm_score_emb(self, b_u: int, b_c: int, L: int) -> None:
        """Warm the pooled-embedding ranker for one bucket — shared by the
        lite cached path and the fused two-stage rank stage (which scores
        from pooled embeddings even on cache-less engines)."""
        self.registry.warm(
            "score_emb", (b_u, b_c), self.params,
            jnp.zeros((b_c, self.model.pcfg.id_dim), jnp.float32),
            self._device(self._cross_batch(self._dummy_batch(b_u, b_c, L))))

    def _dummy_batch(self, b_u: int, b_c: int, L: int) -> dict:
        cfg = self.model.cfg
        batch = {
            "seq_ids": np.zeros((b_u, L), np.int32),
            "seq_actions": np.zeros((b_u, L), np.int32),
            "seq_surfaces": np.zeros((b_u, L), np.int32),
            "seq_valid": np.ones((b_u, L), bool),
            "seq_user_id": np.zeros(b_u, np.int32),
            "inverse_idx": np.zeros(b_c, np.int32),
            "cand_ids": np.zeros(b_c, np.int32),
            "cand_feats": np.zeros((b_c, cfg.cand_feat_dim), np.float32),
            "user_feats": np.zeros((b_u, cfg.user_feat_dim), np.float32),
            "cand_age_days": np.zeros(b_c, np.float32),
        }
        if self.use_graphsage:
            batch["graphsage"] = np.zeros((b_c, cfg.graphsage_dim), np.float32)
        return batch
