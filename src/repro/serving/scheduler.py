"""Request scheduler: the engine's single async front door.

``ServingEngine.submit(req) -> Future`` enqueues a typed request
(:class:`~repro.serving.plan.RankRequest`,
:class:`~repro.serving.plan.RetrieveRequest`,
:class:`~repro.serving.plan.RetrieveThenRankRequest`,
:class:`~repro.serving.plan.GenerateRequest`) into one queue regardless of
workload; a single flush hands the whole mixed batch to the engine, which
partitions it into per-workload lanes that SHARE one user-encode pass (see
``ServingEngine._flush_requests``).  This generalizes what the PR-1
``MicroBatcher`` did for rank-only traffic — coalescing, cross-caller
dedup, background flush — across every request type, which is why
``MicroBatcher`` is now a deprecation shim over this class.

Operating modes (unchanged semantics from the MicroBatcher):

  * synchronous (default, ``max_wait_ms=None``) — no threads: the queue
    flushes when ``max_requests`` requests or ``max_candidates`` worth of
    work has accumulated, on demand (``flush()`` / ``future.result()``),
    or when a server loop calls ``poll()`` past ``max_wait_s``.
    Deterministic for tests.
  * background flusher (``max_wait_ms=<float>``) — a daemon thread bounds
    the age of the oldest pending request, feeding the engine's pipeline
    continuously without any caller blocking in ``result()``; ``close()``
    (or the context manager) stops the thread.

Flush/result race contract: a future whose request was already picked up
by an in-flight flush (another caller's, or the background flusher's) must
NOT trigger a redundant flush from ``result()`` — the membership check and
the queue swap happen atomically under the queue lock, so ``result()``
either drains the batch its request is actually in, or just waits for the
in-flight one to land.

``submit_many`` enqueues a request list ATOMICALLY (thresholds are checked
once, after the whole list is queued), so a caller's batch is never split
across two flushes by its own size — ``ServingEngine.score`` relies on
this to keep its chunking identical to the pre-submit() engine.
"""
from __future__ import annotations

import threading
import time
from typing import List, Optional, Sequence


def request_cost(r) -> int:
    """Weight of one request toward the ``max_candidates`` flush threshold:
    candidates for rank requests, k for retrieve / two-stage requests,
    prompt rows for generate requests, else 1."""
    cand = getattr(r, "cand_ids", None)
    if cand is not None:
        return len(cand)
    k = getattr(r, "k", None)
    if k is not None:
        return int(k)
    prompts = getattr(r, "prompts", None)
    if prompts is not None:
        return len(prompts)
    return 1


class Future:
    """Handle for one submitted request; ``result()`` flushes only if the
    request is still queued — if an in-flight flush already picked it up,
    it waits for that batch instead of triggering a redundant one."""

    def __init__(self, scheduler: "RequestScheduler"):
        self._scheduler = scheduler
        self._done = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._done.is_set()

    def result(self):
        if not self._done.is_set():
            # targeted flush: atomically checks whether THIS request is
            # still pending; a no-op when another flush has it in flight
            self._scheduler._flush(only_if_pending=self)
            self._done.wait()
        if self._error is not None:
            raise self._error
        return self._value

    def _set(self, value):
        self._value = value
        self._done.set()

    def _set_error(self, exc: BaseException):
        self._error = exc
        self._done.set()


class RequestScheduler:
    """Queue-and-coalesce front end over a flush function.

    Args:
      flush_fn: ``flush_fn(requests) -> results`` — one result per request,
        same order.  For a ``ServingEngine`` this is ``_flush_requests``
        (the mixed-workload lane partitioner); anything exposing the same
        shape works (tests use fakes).
      max_requests / max_candidates: flush thresholds (``max_candidates``
        counts :func:`request_cost` units; ``None`` disables that bound).
      max_wait_s: age bound enforced by ``poll()``.
      max_wait_ms: when set, starts the BACKGROUND FLUSHER (overrides
        ``max_wait_s``).
      lock: optional lock serializing ``flush_fn`` executions; defaults to
        a private one.  The engine passes its own RLock so scheduler-driven
        flushes and any direct engine calls serialize together.
      obs: optional ``repro.obs.Observability`` — when enabled, the
        scheduler records the per-request QUEUE WAIT (submit -> flush
        start) and coalesced batch-size histograms, keeps a queue-depth
        gauge, and emits one trace span per flush plus one per-request
        lifecycle span (submit -> result resolution, with the queue wait
        and request type as args).

    Invariant: every submitted request's future resolves exactly once —
    with the result, or with the flush function's exception if a flush
    fails.
    """

    def __init__(self, flush_fn, *, max_requests: int = 32,
                 max_candidates: Optional[int] = None,
                 max_wait_s: float = 0.01,
                 max_wait_ms: Optional[float] = None,
                 lock=None, obs=None):
        self._flush_fn = flush_fn
        self.max_requests = max_requests
        self.max_candidates = max_candidates
        self.max_wait_s = (max_wait_ms / 1e3 if max_wait_ms is not None
                           else max_wait_s)
        self._lock = threading.Lock()
        # serializes flush_fn execution across flushing callers + the
        # background flusher; public so direct users of the underlying
        # engine can join the serialization
        self.engine_lock = lock if lock is not None else threading.Lock()
        self._pending: List = []
        self._futures: List[Future] = []
        self._enq_t: List[float] = []    # per-pending submit timestamps
        self._oldest: Optional[float] = None
        self.flushes = 0
        self.coalesced = 0
        # -- observability (all handles are no-ops when obs is off) --------
        self._obs_on = obs is not None and obs.enabled
        if self._obs_on:
            m, self._tracer = obs.metrics, obs.tracer
            self._h_wait = m.histogram(
                "serving_queue_wait_ms",
                "request age at flush start (submit -> flush), ms")
            self._h_coalesced = m.histogram(
                "serving_flush_coalesced_requests",
                "requests drained per flush", lo=1.0, hi=1e4, per_decade=10)
            self._g_depth = m.gauge(
                "serving_queue_depth", "pending requests after last submit")
            self._c_failures = m.counter(
                "serving_flush_failures_total",
                "flushes that raised (every member future carries the "
                "exception)")
            self._req_tid = self._tracer.tid("requests")
            self._flush_tid = self._tracer.tid("scheduler")
        self._stop = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        if max_wait_ms is not None:
            tick = min(max(self.max_wait_s / 4, 5e-4), 0.05)
            self._flusher = threading.Thread(
                target=self._flusher_loop, args=(tick,),
                name="serving-scheduler-flusher", daemon=True)
            self._flusher.start()

    # -- background flusher -------------------------------------------------
    def _flusher_loop(self, tick: float):
        while not self._stop.wait(tick):
            try:
                self.poll()
            except BaseException:
                # the failing batch's futures already carry the exception
                # (flush resolves them before re-raising); the flusher
                # itself must survive to serve subsequent batches
                pass

    def close(self):
        """Stop the background flusher (if any) after draining the queue.
        Idempotent; the scheduler remains usable in synchronous mode."""
        self._stop.set()
        if self._flusher is not None:
            self._flusher.join()
            self._flusher = None
        try:
            self.flush()
        except BaseException:
            pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- submit / flush -----------------------------------------------------
    def _enqueue(self, request) -> Future:
        f = Future(self)
        self._pending.append(request)
        self._futures.append(f)
        if self._obs_on:
            self._enq_t.append(time.perf_counter())
        if self._oldest is None:
            self._oldest = time.time()
        return f

    def _over_threshold(self) -> bool:
        if len(self._pending) >= self.max_requests:
            return True
        return (self.max_candidates is not None
                and sum(request_cost(r) for r in self._pending)
                >= self.max_candidates)

    def submit(self, request) -> Future:
        """Enqueue one request -> future.  Flushes inline when a size
        threshold trips; otherwise the batch waits for the background
        flusher, ``poll()``, ``flush()``, or a ``future.result()``."""
        with self._lock:
            f = self._enqueue(request)
            full = self._over_threshold()
            depth = len(self._pending)
        if self._obs_on:
            self._g_depth.set(depth)
        if full:
            self.flush()
        return f

    def submit_many(self, requests: Sequence) -> List[Future]:
        """Enqueue a request list atomically -> one future per request.
        Thresholds are checked once, AFTER the whole list is queued, so the
        resulting flush sees the complete batch (never a size-split prefix
        of it)."""
        with self._lock:
            futures = [self._enqueue(r) for r in requests]
            full = self._over_threshold()
            depth = len(self._pending)
        if self._obs_on:
            self._g_depth.set(depth)
        if full:
            self.flush()
        return futures

    def poll(self):
        """Flush if the oldest pending request has waited past max_wait_s."""
        with self._lock:
            expired = (self._oldest is not None
                       and time.time() - self._oldest >= self.max_wait_s)
        if expired:
            self.flush()

    def flush(self):
        """Drain the queue through one flush_fn call (for an engine: one
        mixed-workload flush sharing a single user-encode pass) and resolve
        the futures."""
        self._flush()

    def _flush(self, only_if_pending: Optional[Future] = None):
        with self._lock:
            if (only_if_pending is not None
                    and only_if_pending not in self._futures):
                return      # picked up by an in-flight flush: just wait
            pending, futures = self._pending, self._futures
            enq_t = self._enq_t
            self._pending, self._futures, self._oldest = [], [], None
            self._enq_t = []
            if pending:
                self.flushes += 1
                self.coalesced += len(pending)
        if not pending:
            return
        obs = self._obs_on
        if obs:
            t_flush = time.perf_counter()
            for t in enq_t:
                self._h_wait.record((t_flush - t) * 1e3)
            self._h_coalesced.record(len(pending))
            self._g_depth.set(0)
        try:
            with self.engine_lock:
                results = self._flush_fn(pending)
        except BaseException as exc:
            # never orphan a future: a caller blocked in result() must see
            # the failure, not hang
            if obs:
                self._c_failures.inc()
            for f in futures:
                f._set_error(exc)
            raise
        for f, r in zip(futures, results):
            f._set(r)
        if obs:
            t_done = time.perf_counter()
            self._tracer.event(
                "flush", "scheduler", t_flush, t_done - t_flush,
                tid=self._flush_tid,
                args={"requests": len(pending),
                      "max_queue_wait_ms":
                          round((t_flush - min(enq_t)) * 1e3, 3)
                          if enq_t else 0.0})
            # one lifecycle span per request: submit -> result resolution
            for r, t in zip(pending, enq_t):
                self._tracer.event(
                    type(r).__name__, "request", t, t_done - t,
                    tid=self._req_tid,
                    args={"queue_wait_ms": round((t_flush - t) * 1e3, 3)})
