"""Request scheduler: the engine's single async front door, with
PER-LANE SLO policies.

``ServingEngine.submit(req) -> Future`` enqueues a typed request
(:class:`~repro.serving.plan.RankRequest`,
:class:`~repro.serving.plan.RetrieveRequest`,
:class:`~repro.serving.plan.RetrieveThenRankRequest`,
:class:`~repro.serving.plan.GenerateRequest`).  Requests queue PER LANE
(:func:`~repro.serving.plan.lane_of` — the same rank / retrieve /
two-stage / generate partition the engine's flush applies), and each lane
carries its own :class:`~repro.serving.plan.LanePolicy`: independent size
thresholds, age bound, latency budget with a typed shed path, admission
control, and an optional auto-tuner adapting the wait to observed flush
latency.  A size- or age-triggered flush drains ONLY its lane, so a slow
large-k corpus pass on the retrieve lane never delays a rank flush;
an explicit ``flush()`` still drains every lane through ONE flush_fn
call — the engine's mixed-workload flush with its shared user-encode
pass — which is also the bit-parity baseline (``isolate_lanes=False``
makes every trigger behave that way, reproducing the pre-SLO one-queue
scheduler exactly).

Operating modes (unchanged from the one-queue scheduler):

  * synchronous (default, ``max_wait_ms=None``) — no threads: a lane
    flushes when its ``max_requests`` / ``max_candidates`` threshold
    trips, on demand (``flush()`` / ``future.result()``), or when a
    server loop calls ``poll()`` past the lane's wait.  Deterministic
    for tests.
  * background flusher (``max_wait_ms=<float>``) — a daemon thread bounds
    the age of each lane's oldest pending request, feeding the engine's
    pipeline continuously without any caller blocking in ``result()``;
    ``close()`` (or the context manager) stops the thread.

SHED CONTRACT: a shed request's future resolves with a typed
:class:`ShedError` — never a silent drop, never a hang — and a request is
never both shed and served.  Shedding happens in exactly two places, both
operating only on STILL-QUEUED requests under the queue lock:

  * flush pickup — a sheddable request whose queue wait exceeds its
    lane's ``shed_ms`` budget is resolved with ``ShedError`` during the
    atomic queue swap instead of joining the batch (``shed_expired()``
    runs the same check without flushing);
  * admission — a submit into a lane at its ``max_queue`` bound sheds the
    lowest-priority sheddable request (incoming or queued) immediately.

FLUSH MEMBERSHIP BEATS SHED: the queue swap removes a batch from the
pending lists before flush_fn runs, so a request another caller's flush
already picked up is invisible to every shed path — it deterministically
resolves with its result (or the flush's error), even if its budget
expires while the flush is in flight.

Flush/result race contract: a future whose request was already picked up
by an in-flight flush (another caller's, or the background flusher's)
must NOT trigger a redundant flush from ``result()`` — the membership
check and the queue swap happen atomically under the queue lock, so
``result()`` either drains the lane its request is actually in, or just
waits for the in-flight flush to land.

``submit_many`` enqueues a request list ATOMICALLY (thresholds are
checked once, after the whole list is queued), so a caller's batch is
never split across two flushes of its lane by its own size —
``ServingEngine.score`` relies on this to keep its chunking identical to
the pre-submit() engine.
"""
from __future__ import annotations

import math
import threading
import time
from typing import Dict, List, Optional, Sequence

from repro.serving.plan import LanePolicy, lane_of

LANE_NAMES = ("rank", "retrieve", "two_stage", "generate")


def request_cost(r) -> int:
    """Weight of one request toward the ``max_candidates`` flush threshold:
    candidates for rank requests, k for retrieve / two-stage requests,
    prompt rows for generate requests, else 1."""
    cand = getattr(r, "cand_ids", None)
    if cand is not None:
        return len(cand)
    k = getattr(r, "k", None)
    if k is not None:
        return int(k)
    prompts = getattr(r, "prompts", None)
    if prompts is not None:
        return len(prompts)
    return 1


def _priority(r) -> int:
    return int(getattr(r, "priority", 0) or 0)


class ShedError(RuntimeError):
    """A request was shed by admission control or a lane latency budget —
    carried on the request's future (``result()`` raises it), NEVER a
    silent drop.  ``reason`` is ``"deadline"`` (queued past the lane's
    ``shed_ms`` budget) or ``"admission"`` (lane at ``max_queue``, this
    request lost the priority comparison)."""

    def __init__(self, lane: str, reason: str, wait_ms: float,
                 budget_ms: Optional[float], priority: int = 0):
        self.lane = lane
        self.reason = reason
        self.wait_ms = wait_ms
        self.budget_ms = budget_ms
        self.priority = priority
        budget = (f"{budget_ms:.1f}ms budget" if budget_ms is not None
                  else "admission bound")
        super().__init__(
            f"request shed from lane {lane!r} ({reason}): waited "
            f"{wait_ms:.1f}ms against {budget} at priority {priority}")


class Future:
    """Handle for one submitted request; ``result()`` flushes only if the
    request is still queued — if an in-flight flush already picked it up,
    it waits for that batch instead of triggering a redundant one.  A
    shed request's ``result()`` raises the :class:`ShedError`."""

    def __init__(self, scheduler: "RequestScheduler", lane: str = "rank"):
        self._scheduler = scheduler
        self._lane = lane
        self._done = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None

    @property
    def lane(self) -> str:
        """The scheduler lane this request queued on."""
        return self._lane

    def done(self) -> bool:
        return self._done.is_set()

    def shed(self) -> bool:
        """True once the request has been shed (resolved with a
        :class:`ShedError`)."""
        return self._done.is_set() and isinstance(self._error, ShedError)

    def result(self):
        if not self._done.is_set():
            # targeted flush: atomically checks whether THIS request is
            # still pending; a no-op when another flush has it in flight
            self._scheduler._flush(only_if_pending=self)
            self._done.wait()
        if self._error is not None:
            raise self._error
        return self._value

    def _set(self, value):
        if self._done.is_set():      # first resolution wins (exactly-once)
            return
        self._value = value
        self._done.set()

    def _set_error(self, exc: BaseException):
        if self._done.is_set():
            return
        self._error = exc
        self._done.set()


class _Lane:
    """One lane's queue + resolved policy + counters.  Mutated only under
    the scheduler queue lock."""
    __slots__ = ("name", "policy", "pending", "futures", "enq_t", "oldest",
                 "wait_s", "max_requests", "max_candidates", "flushes",
                 "shed", "deadline_misses", "ewma_ms",
                 "h_latency", "c_shed", "c_miss", "g_wait", "g_depth")

    def __init__(self, name: str, policy: LanePolicy, *,
                 default_requests: int, default_candidates: Optional[int],
                 default_wait_s: float):
        self.name = name
        self.policy = policy
        self.pending: List = []
        self.futures: List[Future] = []
        self.enq_t: List[float] = []    # perf_counter at submit, per pending
        self.oldest: Optional[float] = None     # wall time of oldest pending
        self.max_requests = (policy.max_requests
                             if policy.max_requests is not None
                             else default_requests)
        self.max_candidates = (policy.max_candidates
                               if policy.max_candidates is not None
                               else default_candidates)
        self.wait_s = (policy.max_wait_ms / 1e3
                       if policy.max_wait_ms is not None else default_wait_s)
        self.flushes = 0
        self.shed = 0
        self.deadline_misses = 0
        self.ewma_ms = 0.0              # lane flush latency, obs-independent
        self.h_latency = None           # obs flush-latency histogram (p50)
        self.c_shed = None
        self.c_miss = None
        self.g_wait = None
        self.g_depth = None

    def over_threshold(self) -> bool:
        if len(self.pending) >= self.max_requests:
            return True
        return (self.max_candidates is not None
                and sum(request_cost(r) for r in self.pending)
                >= self.max_candidates)


class RequestScheduler:
    """Per-lane queue-and-coalesce front end over a flush function.

    Args:
      flush_fn: ``flush_fn(requests) -> results`` — one result per request,
        same order.  For a ``ServingEngine`` this is ``_flush_requests``
        (the mixed-workload lane partitioner); anything exposing the same
        shape works (tests use fakes).
      max_requests / max_candidates / max_wait_s: scheduler-wide defaults
        a lane inherits unless its :class:`~repro.serving.plan.LanePolicy`
        overrides them (``max_candidates`` counts :func:`request_cost`
        units; ``None`` disables that bound).
      max_wait_ms: when set, starts the BACKGROUND FLUSHER (and overrides
        ``max_wait_s`` as the default lane wait).
      lane_fn: ``request -> lane name`` (default
        :func:`~repro.serving.plan.lane_of`; untyped test fakes all land
        on the rank lane, reproducing one-queue behaviour).
      lane_policies: ``{lane: LanePolicy}`` — lanes not named get a
        default policy (pure inherit, no shed, no admission bound).
      isolate_lanes: True (default) — size/age/result-triggered flushes
        drain only the triggering lane; False — ANY trigger drains every
        lane through one combined flush_fn call (the pre-SLO shared-flush
        behaviour, kept as the bit-parity baseline).  ``flush()`` with no
        lane always drains everything in one call either way.
      lock: optional lock serializing ``flush_fn`` executions; defaults to
        a private one.  The engine passes its own RLock so scheduler-driven
        flushes and any direct engine calls serialize together.
      obs: optional ``repro.obs.Observability`` — when enabled, the
        scheduler records per-request QUEUE WAIT and coalesced batch-size
        histograms, queue-depth gauges (total + per lane), shed /
        deadline-miss counters per lane, the tuned per-lane wait gauge,
        and emits one trace span per flush plus one per-request lifecycle
        span.

    Invariant: every submitted request's future resolves EXACTLY ONCE —
    with the result, with the flush function's exception if its flush
    fails, or with a typed :class:`ShedError` if it is shed; and never
    both shed and served.
    """

    def __init__(self, flush_fn, *, max_requests: int = 32,
                 max_candidates: Optional[int] = None,
                 max_wait_s: float = 0.01,
                 max_wait_ms: Optional[float] = None,
                 lane_fn=None,
                 lane_policies: Optional[Dict[str, LanePolicy]] = None,
                 isolate_lanes: bool = True,
                 lock=None, obs=None):
        self._flush_fn = flush_fn
        self.max_requests = max_requests
        self.max_candidates = max_candidates
        self.max_wait_s = (max_wait_ms / 1e3 if max_wait_ms is not None
                           else max_wait_s)
        self._lane_fn = lane_fn if lane_fn is not None else lane_of
        self._policies = dict(lane_policies or {})
        self.isolate_lanes = bool(isolate_lanes)
        self._lock = threading.Lock()
        # serializes flush_fn execution across flushing callers + the
        # background flusher; public so direct users of the underlying
        # engine can join the serialization
        self.engine_lock = lock if lock is not None else threading.Lock()
        self._lanes: Dict[str, _Lane] = {}   # created on first submit
        self.flushes = 0        # flush_fn calls (a combined drain counts 1)
        self.coalesced = 0      # requests SERVED through flush_fn
        self.shed_total = 0     # requests resolved with ShedError
        # -- observability (all handles are no-ops when obs is off) --------
        self._obs_on = obs is not None and obs.enabled
        self._metrics = obs.metrics if self._obs_on else None
        if self._obs_on:
            m, self._tracer = obs.metrics, obs.tracer
            self._h_wait = m.histogram(
                "serving_queue_wait_ms",
                "request age at flush start (submit -> flush), ms")
            self._h_coalesced = m.histogram(
                "serving_flush_coalesced_requests",
                "requests drained per flush", lo=1.0, hi=1e4, per_decade=10)
            self._g_depth = m.gauge(
                "serving_queue_depth", "pending requests after last submit")
            self._c_failures = m.counter(
                "serving_flush_failures_total",
                "flushes that raised (every member future carries the "
                "exception)")
            self._req_tid = self._tracer.tid("requests")
            self._flush_tid = self._tracer.tid("scheduler")
        self._stop = threading.Event()
        self._flusher: Optional[threading.Thread] = None
        if max_wait_ms is not None:
            waits = [self.max_wait_s] + [
                p.max_wait_ms / 1e3 for p in self._policies.values()
                if p.max_wait_ms is not None]
            tick = min(max(min(waits) / 4, 5e-4), 0.05)
            self._flusher = threading.Thread(
                target=self._flusher_loop, args=(tick,),
                name="serving-scheduler-flusher", daemon=True)
            self._flusher.start()

    # -- lanes --------------------------------------------------------------
    def _lane(self, name: str) -> _Lane:
        """Get-or-create a lane's state.  Caller holds ``self._lock``."""
        st = self._lanes.get(name)
        if st is None:
            st = _Lane(name, self._policies.get(name, LanePolicy()),
                       default_requests=self.max_requests,
                       default_candidates=self.max_candidates,
                       default_wait_s=self.max_wait_s)
            if self._obs_on:
                m = self._metrics
                # shares the handle the engine records into (registry
                # get-or-creates per (name, labels)), so the auto-tuner
                # reads real flush latency even though the ENGINE measures
                # it; these creations nest scheduler-lock -> registry-lock
                # only (both leaves of the engine/stats lock order)
                st.h_latency = m.histogram(
                    "serving_flush_latency_ms",
                    "per-lane wall time of one flush, ms", lane=name)
                st.c_shed = m.counter(
                    "serving_shed_total",
                    "requests shed (future carries ShedError)", lane=name)
                st.c_miss = m.counter(
                    "serving_deadline_miss_total",
                    "served requests that overstayed the lane's shed_ms "
                    "budget (shed-exempt priorities)", lane=name)
                st.g_wait = m.gauge(
                    "serving_lane_wait_ms",
                    "current (possibly auto-tuned) lane flush wait, ms",
                    lane=name)
                st.g_wait.set(st.wait_s * 1e3)
                st.g_depth = m.gauge(
                    "serving_lane_queue_depth",
                    "pending requests in this lane", lane=name)
            self._lanes[name] = st
        return st

    def lane_stats(self) -> Dict[str, dict]:
        """Per-lane snapshot: pending depth, flush / shed / deadline-miss
        counts, and the current (possibly auto-tuned) wait in ms."""
        with self._lock:
            return self._lane_stats_locked()

    def _lane_stats_locked(self) -> Dict[str, dict]:
        """``lane_stats`` body for callers already holding ``_lock`` (the
        engine's ``stats()`` snapshot)."""
        return {name: {"pending": len(st.pending),
                       "flushes": st.flushes,
                       "shed": st.shed,
                       "deadline_misses": st.deadline_misses,
                       "wait_ms": st.wait_s * 1e3}
                for name, st in sorted(self._lanes.items())}

    # -- background flusher -------------------------------------------------
    def _flusher_loop(self, tick: float):
        while not self._stop.wait(tick):
            try:
                self.poll()
            except BaseException:
                # the failing batch's futures already carry the exception
                # (flush resolves them before re-raising); the flusher
                # itself must survive to serve subsequent batches
                pass

    def close(self):
        """Stop the background flusher (if any) after draining the queue.
        Idempotent; the scheduler remains usable in synchronous mode."""
        self._stop.set()
        if self._flusher is not None:
            self._flusher.join()
            self._flusher = None
        try:
            self.flush()
        except BaseException:
            pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    # -- submit -------------------------------------------------------------
    def _enqueue(self, st: _Lane, request) -> Future:
        f = Future(self, st.name)
        st.pending.append(request)
        st.futures.append(f)
        st.enq_t.append(time.perf_counter())
        if st.oldest is None:
            st.oldest = time.time()
        return f

    def _admit(self, st: _Lane, request, shed_out: List) -> Future:
        """Enqueue under admission control.  Caller holds ``self._lock``;
        any admission-shed (future, error) pairs are appended to
        ``shed_out`` for resolution AFTER the lock is released."""
        pol = st.policy
        if pol.max_queue is None or len(st.pending) < pol.max_queue:
            return self._enqueue(st, request)
        prio_in = _priority(request)
        # lowest-priority sheddable queued request, oldest first
        victim, v_prio = None, None
        for j, r in enumerate(st.pending):
            p = _priority(r)
            if p <= pol.shed_max_priority and (v_prio is None or p < v_prio):
                victim, v_prio = j, p
        now = time.perf_counter()
        if victim is not None and v_prio < prio_in:
            # evict the queued loser, seat the incoming request
            st.pending.pop(victim)
            vf = st.futures.pop(victim)
            vt = st.enq_t.pop(victim)
            if not st.pending:
                st.oldest = None
            shed_out.append((st, vf, ShedError(
                st.name, "admission", (now - vt) * 1e3, None, v_prio)))
            return self._enqueue(st, request)
        if prio_in <= pol.shed_max_priority:
            # incoming loses: shed it without ever queueing it
            f = Future(self, st.name)
            shed_out.append((st, f, ShedError(
                st.name, "admission", 0.0, None, prio_in)))
            return f
        # protected priority with no lower-priority victim: the bound is
        # soft for it — admit past max_queue rather than shed or block
        return self._enqueue(st, request)

    def _resolve_shed(self, shed: List) -> None:
        """Resolve shed futures + bump counters; call WITHOUT the queue
        lock (the futures are already off the pending lists, so no flush
        can race them back in)."""
        if not shed:
            return
        with self._lock:
            for st, _, _ in shed:
                st.shed += 1
            self.shed_total += len(shed)
        for st, f, err in shed:
            if self._obs_on:
                st.c_shed.inc()
            f._set_error(err)

    def submit(self, request) -> Future:
        """Enqueue one request on its lane -> future.  Flushes the lane
        inline when a lane size threshold trips (every lane when
        ``isolate_lanes=False``); otherwise the batch waits for the
        background flusher, ``poll()``, ``flush()``, or a
        ``future.result()``."""
        shed: List = []
        lane = self._lane_fn(request)
        with self._lock:
            st = self._lane(lane)
            f = self._admit(st, request, shed)
            full = st.over_threshold()
            depth = sum(len(s.pending) for s in self._lanes.values())
            lane_depth = len(st.pending)
        self._resolve_shed(shed)
        if self._obs_on:
            self._g_depth.set(depth)
            st.g_depth.set(lane_depth)
        if full:
            self._flush(lane=lane if self.isolate_lanes else None)
        return f

    def submit_many(self, requests: Sequence) -> List[Future]:
        """Enqueue a request list atomically -> one future per request.
        Thresholds are checked once, AFTER the whole list is queued, so a
        lane's flush sees the caller's complete batch for that lane
        (never a size-split prefix of it)."""
        shed: List = []
        with self._lock:
            futures = []
            touched: Dict[str, _Lane] = {}
            for r in requests:
                st = self._lane(self._lane_fn(r))
                touched[st.name] = st
                futures.append(self._admit(st, r, shed))
            full = [name for name, st in touched.items()
                    if st.over_threshold()]
            depth = sum(len(s.pending) for s in self._lanes.values())
        self._resolve_shed(shed)
        if self._obs_on:
            self._g_depth.set(depth)
            for st in touched.values():
                st.g_depth.set(len(st.pending))
        if full:
            if self.isolate_lanes:
                for name in full:
                    self._flush(lane=name)
            else:
                self._flush()
        return futures

    # -- shed / poll / flush ------------------------------------------------
    def shed_expired(self) -> int:
        """Shed every STILL-QUEUED sheddable request past its lane's
        ``shed_ms`` budget, without flushing.  Requests an in-flight flush
        already drained are untouchable here (flush membership beats
        shed).  -> number of requests shed."""
        shed: List = []
        now = time.perf_counter()
        with self._lock:
            for st in self._lanes.values():
                budget = st.policy.shed_ms
                if budget is None or not st.pending:
                    continue
                keep_r, keep_f, keep_t = [], [], []
                for r, f, t in zip(st.pending, st.futures, st.enq_t):
                    wait_ms = (now - t) * 1e3
                    if (wait_ms > budget
                            and _priority(r) <= st.policy.shed_max_priority):
                        shed.append((st, f, ShedError(
                            st.name, "deadline", wait_ms, budget,
                            _priority(r))))
                    else:
                        keep_r.append(r)
                        keep_f.append(f)
                        keep_t.append(t)
                if len(keep_r) != len(st.pending):
                    st.pending, st.futures, st.enq_t = keep_r, keep_f, keep_t
                    if not keep_r:
                        st.oldest = None
        self._resolve_shed(shed)
        return len(shed)

    def poll(self):
        """Flush every lane whose oldest pending request has waited past
        that lane's (possibly auto-tuned) wait; also sheds any request
        past its lane's latency budget."""
        self.shed_expired()
        now = time.time()
        with self._lock:
            expired = [name for name, st in self._lanes.items()
                       if st.oldest is not None
                       and now - st.oldest >= st.wait_s]
        if not expired:
            return
        if self.isolate_lanes:
            for name in expired:
                self._flush(lane=name)
        else:
            self._flush()

    def flush(self, lane: Optional[str] = None):
        """Drain the queue through one flush_fn call (for an engine: one
        mixed-workload flush sharing a single user-encode pass) and
        resolve the futures.  ``lane`` restricts the drain to one lane;
        the default drains every lane TOGETHER in a single call."""
        self._flush(lane=lane)

    def _drain_locked(self, lanes: List[_Lane]):
        """Atomic queue swap + deadline shed for the given lanes.  Caller
        holds ``self._lock``.  Sheddable requests over their lane's
        ``shed_ms`` budget are diverted to the shed list INSTEAD of the
        batch; protected requests over budget are served and counted as
        deadline misses.
        -> (batch, futures, enq_t, shed, misses, contributors)."""
        now = time.perf_counter()
        batch: List = []
        futures: List[Future] = []
        enq_t: List[float] = []
        shed: List = []
        misses: List[_Lane] = []
        contributors: List[_Lane] = []
        for st in lanes:
            if not st.pending:
                continue
            budget = st.policy.shed_ms
            served = 0
            for r, f, t in zip(st.pending, st.futures, st.enq_t):
                wait_ms = (now - t) * 1e3
                if budget is not None and wait_ms > budget:
                    if _priority(r) <= st.policy.shed_max_priority:
                        shed.append((st, f, ShedError(
                            st.name, "deadline", wait_ms, budget,
                            _priority(r))))
                        continue
                    st.deadline_misses += 1
                    misses.append(st)
                batch.append(r)
                futures.append(f)
                enq_t.append(t)
                served += 1
            if served:
                st.flushes += 1
                contributors.append(st)
            st.pending, st.futures, st.enq_t = [], [], []
            st.oldest = None
        return batch, futures, enq_t, shed, misses, contributors

    def _flush(self, only_if_pending: Optional[Future] = None,
               lane: Optional[str] = None):
        with self._lock:
            if only_if_pending is not None:
                st = self._lanes.get(only_if_pending._lane)
                if st is None or only_if_pending not in st.futures:
                    return      # picked up by an in-flight flush: just wait
                lanes = ([st] if self.isolate_lanes
                         else list(self._lanes.values()))
            elif lane is not None:
                st = self._lanes.get(lane)
                if st is None:
                    return
                lanes = [st]
            else:
                lanes = list(self._lanes.values())
            batch, futures, enq_t, shed, misses, contributors = \
                self._drain_locked(lanes)
            if batch:
                self.flushes += 1
                self.coalesced += len(batch)
            if shed:
                for st, _, _ in shed:
                    st.shed += 1
                self.shed_total += len(shed)
        # shed futures resolve OUTSIDE the lock; they are already off the
        # pending lists, so no concurrent flush can serve them
        for st, f, err in shed:
            if self._obs_on:
                st.c_shed.inc()
            f._set_error(err)
        if self._obs_on and misses:
            for st in misses:
                st.c_miss.inc()
        if not batch:
            return
        obs = self._obs_on
        if obs:
            t_flush = time.perf_counter()
            for t in enq_t:
                self._h_wait.record((t_flush - t) * 1e3)
            self._h_coalesced.record(len(batch))
            self._g_depth.set(0)
            for st in lanes:
                st.g_depth.set(0)
        t0 = time.perf_counter()
        try:
            with self.engine_lock:
                results = self._flush_fn(batch)
        except BaseException as exc:
            # never orphan a future: a caller blocked in result() must see
            # the failure, not hang
            if obs:
                self._c_failures.inc()
            for f in futures:
                f._set_error(exc)
            raise
        for f, r in zip(futures, results):
            f._set(r)
        flush_ms = (time.perf_counter() - t0) * 1e3
        if len(contributors) == 1:
            self._autotune(contributors[0], flush_ms)
        if obs:
            t_done = time.perf_counter()
            self._tracer.event(
                "flush", "scheduler", t_flush, t_done - t_flush,
                tid=self._flush_tid,
                args={"requests": len(batch),
                      "max_queue_wait_ms":
                          round((t_flush - min(enq_t)) * 1e3, 3)
                          if enq_t else 0.0})
            # one lifecycle span per request: submit -> result resolution
            for r, t in zip(batch, enq_t):
                self._tracer.event(
                    type(r).__name__, "request", t, t_done - t,
                    tid=self._req_tid,
                    args={"queue_wait_ms": round((t_flush - t) * 1e3, 3)})

    # -- auto-tuner ---------------------------------------------------------
    def _autotune(self, st: _Lane, flush_ms: float) -> None:
        """After a SINGLE-lane flush, adapt the lane's wait toward its
        observed flush latency (combined multi-lane flushes are skipped —
        their wall time conflates every lane).  The obs histogram — the
        same ``serving_flush_latency_ms{lane=}`` handle the engine records
        into — supplies the p50 when available; otherwise the scheduler's
        own EWMA of flush_fn wall time stands in, so the tuner also works
        on obs-off engines and fake flush functions."""
        # EWMA always updates (cheap, lock-free: single-writer per flush
        # is good enough for a tuning signal)
        st.ewma_ms = (flush_ms if st.ewma_ms == 0.0
                      else 0.7 * st.ewma_ms + 0.3 * flush_ms)
        pol = st.policy
        if not pol.auto_tune:
            return
        p50 = float("nan")
        if st.h_latency is not None:
            p50 = st.h_latency.quantile(0.5)
        if math.isnan(p50) or p50 <= 0:
            p50 = st.ewma_ms
        if p50 <= 0:
            return
        wait_ms = min(max(pol.autotune_ratio * p50, pol.autotune_min_ms),
                      pol.autotune_max_ms)
        st.wait_s = wait_ms / 1e3
        if self._obs_on:
            st.g_wait.set(wait_ms)
