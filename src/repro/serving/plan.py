"""Batch planning for the serving engine (paper §4.3, Figure 2).

The planner is the pure host-side layer of the engine: it takes a list of
:class:`RankRequest`, performs Ψ over the request batch (vectorized — the
``first_of`` provenance comes straight out of ``np.unique``, no per-unique
``np.argmax`` loop), and pads everything into a SHAPE BUCKET from a small
powers-of-two ladder.  Because the ladder is finite, the set of jitted
executors downstream is finite and can be fully precompiled by
``ServingEngine.warmup()`` — a new (B_u, B_c) never triggers a fresh XLA
compile in steady state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dcat import dedup_with_first


@dataclasses.dataclass
class RankRequest:
    """One caller's scoring request: a user activity sequence plus the
    candidate set to score against it.  Requests sharing the exact same
    (ids, actions, surfaces) sequence are Ψ-deduplicated by the planner
    and share one context encode / cache entry.

    ``priority`` feeds the scheduler's admission/shed path (higher wins;
    requests above a lane's ``shed_max_priority`` are never shed) — it
    does not change scoring."""
    seq_ids: np.ndarray          # (L,)
    seq_actions: np.ndarray
    seq_surfaces: np.ndarray
    cand_ids: np.ndarray         # (N_b,)
    cand_feats: np.ndarray       # (N_b, F_c)
    user_feats: np.ndarray       # (F_u,)
    graphsage: Optional[np.ndarray] = None
    priority: int = 0


@dataclasses.dataclass
class RetrieveRequest:
    """Candidate-generation request: top-k corpus retrieval for one user
    sequence (no candidates — the corpus IS the candidate set).

    ``exclude_ids`` (typically the user's already-seen items) and
    ``allow_surfaces`` (serve only items of these surfaces; needs an index
    built with per-item surface metadata) are converted by the engine into
    packed per-chunk row bitmasks and applied inside the corpus-chunk
    executors — excluded items can never appear in the result, and two
    requests from the same user with different filters are planned as
    distinct retrieval groups (the pooled-embedding cache entry is still
    shared: filters do not enter the ContextCache key).

    ``route`` selects the scorer machinery: ``"exact"`` scans the whole
    corpus through the chunk executors; ``"ivf"`` (needs an index built by
    ``retrieval.ivf.build_ivf``) probes the ``nprobe`` nearest clusters —
    approximate, with recall loss only from cluster pruning.  ``nprobe``
    is served at the nearest attach-time level >= the requested value
    (levels are precompiled executor shapes), ``None`` = the attach
    default; it is an error outside ``route="ivf"``."""
    seq_ids: np.ndarray          # (L,)
    seq_actions: np.ndarray
    seq_surfaces: np.ndarray
    k: int = 100
    exclude_ids: Optional[np.ndarray] = None
    allow_surfaces: Optional[Tuple[int, ...]] = None
    route: str = "exact"
    nprobe: Optional[int] = None
    priority: int = 0


@dataclasses.dataclass
class RetrieveThenRankRequest:
    """The paper's flagship two-stage workload as ONE request: corpus
    retrieval whose top-k feeds the ranking path of the same engine flush.

    Submitted through ``ServingEngine.submit``, the engine executes the
    fused schedule: the pooled user embedding is looked up / encoded once
    (shared with any rank or retrieve request for the same user in the
    same flush), the retrieval top-k runs through the warmed corpus-chunk
    executors, and the retrieved ids become the candidate set of an
    internal :class:`RankRequest` scored on the rank lane of the same
    pipeline — with the next group's retrieval overlapping this group's
    ranking.  Resolves to a :class:`TwoStageResult`.

    ``cand_feats_fn(item_ids) -> (n, F_c) float32`` supplies the ranking
    features of the retrieved candidates; when ``None`` the engine's
    ``attach_features`` provider is used (one of the two must exist).
    Filters behave exactly as on :class:`RetrieveRequest`; when fewer than
    ``k`` items survive, the -inf tail is still ranked (identical to what
    the sequential retrieve-then-rank path would do).  ``route`` /
    ``nprobe`` behave exactly as on :class:`RetrieveRequest`; on the IVF
    route an unfilled tail slot carries item id -1 (the probe never
    visited a row for it), and ``cand_feats_fn`` must tolerate it."""
    seq_ids: np.ndarray          # (L,)
    seq_actions: np.ndarray
    seq_surfaces: np.ndarray
    user_feats: np.ndarray       # (F_u,) — the rank stage needs it
    k: int = 100
    exclude_ids: Optional[np.ndarray] = None
    allow_surfaces: Optional[Tuple[int, ...]] = None
    route: str = "exact"
    nprobe: Optional[int] = None
    cand_feats_fn: Optional[Callable] = None
    priority: int = 0


@dataclasses.dataclass
class TwoStageResult:
    """What a :class:`RetrieveThenRankRequest` future resolves to."""
    item_ids: np.ndarray          # (k,) retrieved ids, retrieval order
    retrieval_scores: np.ndarray  # (k,) corpus dot-product scores
    probs: np.ndarray             # (k, n_tasks) ranking probabilities


@dataclasses.dataclass
class GenerateRequest:
    """Autoregressive LM generation routed through the same ``submit``
    front door (the ``serving/generate.py`` workload as a typed request).
    Requires ``ServingEngine.attach_generator``; resolves to a
    (B, max_new_tokens) int32 numpy array."""
    prompts: np.ndarray           # (B, S) int32
    rng: Optional[Any] = None
    priority: int = 0


def lane_of(request) -> str:
    """Scheduler lane of a typed request: ``"rank"`` / ``"retrieve"`` /
    ``"two_stage"`` / ``"generate"`` — the same partition
    ``ServingEngine._flush_requests`` applies inside a flush, now visible
    at SUBMIT time so each lane can queue (and flush, and shed) on its own
    policy.  Unknown request types fall into the rank lane: the scheduler
    is generic over request shapes (concurrency tests drive it with
    fakes), and a single-lane view of untyped traffic reproduces the old
    one-queue behaviour exactly."""
    if isinstance(request, RetrieveThenRankRequest):
        return "two_stage"
    if isinstance(request, RetrieveRequest):
        return "retrieve"
    if isinstance(request, GenerateRequest):
        return "generate"
    return "rank"


@dataclasses.dataclass
class LanePolicy:
    """Per-lane SLO policy for the :class:`~repro.serving.scheduler.
    RequestScheduler` — how one lane queues, flushes, sheds, and adapts,
    independently of every other lane (a slow large-k corpus pass on the
    retrieve lane must never delay a latency-sensitive rank flush).

    Threshold fields default to ``None`` = inherit the scheduler-wide
    knob (``max_requests`` / ``max_candidates`` / ``max_wait_s``), so a
    policy only has to name what differs:

      max_requests / max_candidates — size thresholds tripping an inline
        flush of THIS lane only (candidates in
        :func:`~repro.serving.scheduler.request_cost` units).
      max_wait_ms — age bound for this lane, enforced by ``poll()`` / the
        background flusher; the auto-tuner (below) retunes it live.

    SLO fields (all off by default — a default-constructed policy changes
    nothing):

      shed_ms — queue-wait latency budget: a request still queued after
        ``shed_ms`` ms is SHED at flush pickup — its future resolves with
        a typed :class:`~repro.serving.scheduler.ShedError` (never a
        silent drop), and it never reaches the engine.  ``None`` disables
        shedding.
      shed_max_priority — only requests with ``priority <=`` this are
        sheddable; higher-priority requests are always served (and count
        a deadline miss instead when they exceed ``shed_ms``).
      max_queue — admission bound: a submit into a lane already holding
        ``max_queue`` pending requests sheds the LOWEST-priority sheddable
        request (the incoming one, unless a strictly-lower-priority queued
        request can be evicted in its place).  Protected priorities
        (> ``shed_max_priority``) always enter, even past the bound.
      auto_tune — adapt ``max_wait_ms`` to the lane's OBSERVED flush
        latency: after each flush the wait is set to ``autotune_ratio`` x
        the lane's flush-latency p50 (from the engine's
        ``serving_flush_latency_ms{lane=}`` obs histogram when available,
        else the scheduler's own EWMA), clamped to
        [``autotune_min_ms``, ``autotune_max_ms``].  Waiting much less
        than one flush's service time buys no batching; waiting much more
        adds queue latency for nothing — tying the two together keeps the
        wait proportionate as load and corpus size shift.
    """
    max_requests: Optional[int] = None
    max_candidates: Optional[int] = None
    max_wait_ms: Optional[float] = None
    shed_ms: Optional[float] = None
    shed_max_priority: int = 0
    max_queue: Optional[int] = None
    auto_tune: bool = False
    autotune_ratio: float = 0.5
    autotune_min_ms: float = 0.5
    autotune_max_ms: float = 50.0


def request_key(r) -> bytes:
    """ContextCache key: the full user-sequence identity (ids + actions +
    surfaces) — anything that feeds the context component.  Shared between
    Rank and Retrieve requests, so a user encoded for ranking is a cache
    hit for retrieval and vice versa."""
    return (np.asarray(r.seq_ids).tobytes()
            + np.asarray(r.seq_actions).tobytes()
            + np.asarray(r.seq_surfaces).tobytes())


# ---------------------------------------------------------------------------
# Shape buckets
# ---------------------------------------------------------------------------

def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


@dataclasses.dataclass(frozen=True)
class BucketLadder:
    """Powers-of-two sizes in [min_size, max_size].  ``fit(n)`` returns the
    smallest bucket that holds n rows; n > max_size is a planning error (the
    request stream must be chunked first — see :func:`split_requests`)."""
    max_size: int
    min_size: int = 1

    def __post_init__(self):
        assert 1 <= self.min_size <= self.max_size

    def sizes(self) -> Tuple[int, ...]:
        out, s = [], _next_pow2(self.min_size)
        while s < self.max_size:
            out.append(s)
            s *= 2
        out.append(self.max_size)
        return tuple(out)

    def fit(self, n: int) -> int:
        for s in self.sizes():
            if n <= s:
                return s
        raise ValueError(f"{n} rows exceed the bucket ladder max "
                         f"{self.max_size}")


# ---------------------------------------------------------------------------
# BatchPlan
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineStats:
    """Telemetry for one ``ServingEngine.score`` call under the depth-2
    host/device pipeline (host prepares chunk k+1 while the device runs
    chunk k).  All times are milliseconds of HOST wall clock:

      prepare_ms — plan build, cache lookups, ctx pack / memo, H2D dispatch
      launch_ms  — executor dispatch (async; returns before device work)
      wait_ms    — blocked on device output in finalize (device->host sync)
      overlapped_ms — the subset of prepare_ms spent while a previous
        chunk's executor was still in flight on the device; 0 at
        ``pipeline_depth=1`` and for single-chunk calls.  A prepare whose
        predecessor already finished (output ready) counts zero; one whose
        predecessor is still running counts in full, so this is an UPPER
        bound when the predecessor completes mid-prepare.

    The fused two-stage path records one of these per flush too, with
    ``lane="two_stage"`` and the retrieval stage broken out:
    ``retrieve_ms`` is host time spent dispatching corpus-chunk executors
    and merging their top-k partials (the merge is the retrieval
    finalize — under the fused schedule it overlaps the previous group's
    ranking).
    """
    depth: int
    chunks: int = 0
    prepare_ms: float = 0.0
    launch_ms: float = 0.0
    wait_ms: float = 0.0
    overlapped_ms: float = 0.0
    total_ms: float = 0.0
    memo_hits: int = 0
    memo_misses: int = 0
    lane: str = "rank"
    retrieve_ms: float = 0.0

    @property
    def overlap_fraction(self) -> float:
        """Share of host work (prepare, plus retrieval dispatch+merge on
        the two-stage lane) hidden behind device execution."""
        host = self.prepare_ms + self.retrieve_ms
        return self.overlapped_ms / host if host > 0 else 0.0

    def as_dict(self) -> dict:
        return {**dataclasses.asdict(self),
                "overlap_fraction": self.overlap_fraction}

    def record_to(self, metrics) -> None:
        """Mirror this flush's stage timings into an obs
        ``MetricsRegistry`` (``repro.obs``) as per-lane histograms plus
        the overlap-fraction gauge.  Called by the engine once per flush
        (per lane) — per-flush get-or-create lookups, not per-chunk."""
        lane = self.lane
        metrics.histogram("serving_stage_prepare_ms",
                          "host prepare per flush, ms",
                          lane=lane).record(self.prepare_ms)
        metrics.histogram("serving_stage_launch_ms",
                          "executor dispatch per flush, ms",
                          lane=lane).record(self.launch_ms)
        metrics.histogram("serving_stage_wait_ms",
                          "device->host sync per flush, ms",
                          lane=lane).record(self.wait_ms)
        metrics.histogram("serving_stage_total_ms",
                          "whole lane batch wall time, ms",
                          lane=lane).record(self.total_ms)
        if self.retrieve_ms:
            metrics.histogram("serving_stage_retrieve_ms",
                              "retrieval dispatch+merge per flush, ms",
                              lane=lane).record(self.retrieve_ms)
        metrics.histogram("serving_pipeline_chunks",
                          "executor chunks per flush",
                          lo=1.0, hi=1e4, per_decade=10,
                          lane=lane).record(self.chunks)
        metrics.gauge("serving_pipeline_overlap_fraction",
                      "share of host work hidden behind device execution "
                      "(last flush)", lane=lane).set(self.overlap_fraction)


@dataclasses.dataclass
class BatchPlan:
    """One fixed-shape device batch plus the host-side bookkeeping needed to
    route results back to requests and to key the ContextCache."""
    batch: Dict[str, np.ndarray]   # padded to (b_u, ...) / (b_c, ...)
    b_u: int                       # unique-user bucket size
    b_c: int                       # candidate bucket size
    n_unique: int                  # actual unique users (<= b_u)
    n_candidates: int              # actual candidates (<= b_c)
    counts: List[int]              # candidates per request
    inv_req: np.ndarray            # (R,) request -> unique row
    first_of: np.ndarray           # (n_unique,) request index of first occur.
    user_keys: List[bytes]         # per unique row, ContextCache key
    seq_len: int

    @property
    def dedup_ratio(self) -> float:
        return self.n_candidates / max(self.n_unique, 1)

    @property
    def user_set(self) -> frozenset:
        """The UNORDERED unique-user identity — the pack-memo key
        component: two plans with equal ``user_set`` (and bucket shape)
        pack permutations of the same per-user contexts, so a memoized
        batch serves both via a host-side row remap."""
        return frozenset(self.user_keys)


def _pad_rows(x: np.ndarray, n: int, fill=0) -> np.ndarray:
    out = np.full((n, *x.shape[1:]), fill, x.dtype)
    out[:len(x)] = x
    return out


def build_plan(requests: Sequence[RankRequest], ladder_u: BucketLadder,
               ladder_c: BucketLadder, key_fn=request_key) -> BatchPlan:
    """Ψ over the request batch + padding into the bucket ladder.
    ``key_fn(request) -> bytes`` derives the ContextCache key of each
    unique user (default: full sequence identity)."""
    assert len(requests) > 0
    all_ids = np.stack([np.asarray(r.seq_ids) for r in requests])
    all_actions = np.stack([np.asarray(r.seq_actions) for r in requests])
    all_surfaces = np.stack([np.asarray(r.seq_surfaces) for r in requests])
    # Ψ over the FULL context input (ids+actions+surfaces): rows may only
    # share a context when everything feeding the context component matches
    identity = np.concatenate([all_ids, all_actions, all_surfaces], axis=1)
    _, inv_req, first_of = dedup_with_first(identity)
    uniq_seq = all_ids[first_of]
    counts = [len(r.cand_ids) for r in requests]
    # Ψ⁻¹ index per candidate, vectorized over the request->unique mapping
    inverse_idx = np.repeat(inv_req, counts).astype(np.int32)

    n_unique, n_cand = len(uniq_seq), len(inverse_idx)
    b_u, b_c = ladder_u.fit(n_unique), ladder_c.fit(n_cand)
    L = uniq_seq.shape[1]

    seq_actions = all_actions[first_of]
    seq_surfaces = all_surfaces[first_of]
    batch = {
        "seq_ids": _pad_rows(uniq_seq.astype(np.int32), b_u),
        "seq_actions": _pad_rows(seq_actions.astype(np.int32), b_u),
        "seq_surfaces": _pad_rows(seq_surfaces.astype(np.int32), b_u),
        "seq_valid": _pad_rows(np.ones_like(uniq_seq, bool), b_u),
        "seq_user_id": _pad_rows(np.arange(n_unique, dtype=np.int32), b_u),
        "inverse_idx": _pad_rows(inverse_idx, b_c),
        "cand_ids": _pad_rows(np.concatenate(
            [np.asarray(r.cand_ids) for r in requests]).astype(np.int32), b_c),
        "cand_feats": _pad_rows(np.concatenate(
            [np.asarray(r.cand_feats) for r in requests]).astype(np.float32),
            b_c),
        "user_feats": _pad_rows(np.stack(
            [np.asarray(r.user_feats) for r in requests])[first_of]
            .astype(np.float32), b_u),
        "cand_age_days": np.zeros(b_c, np.float32),
    }
    if requests[0].graphsage is not None:
        batch["graphsage"] = _pad_rows(np.concatenate(
            [np.asarray(r.graphsage) for r in requests]).astype(np.float32),
            b_c)

    user_keys = [key_fn(requests[i]) for i in first_of]
    return BatchPlan(batch=batch, b_u=b_u, b_c=b_c, n_unique=n_unique,
                     n_candidates=n_cand, counts=counts, inv_req=inv_req,
                     first_of=first_of, user_keys=user_keys, seq_len=L)


def split_requests(requests: Sequence[RankRequest], max_unique: int,
                   max_candidates: int) -> List[List[int]]:
    """Greedily chunk a request list so every chunk fits the bucket maxima
    (<= max_unique distinct user sequences, <= max_candidates total
    candidates).  Returns lists of request indices; order is preserved.
    Uniqueness is counted on FULL sequence identity (``request_key``) so it
    mirrors ``build_plan``'s Ψ exactly — a custom engine cache ``key_fn``
    never changes how many unique rows the planner will emit."""
    chunks: List[List[int]] = []
    cur: List[int] = []
    cur_keys: set = set()
    cur_cands = 0
    for i, r in enumerate(requests):
        n = len(r.cand_ids)
        if n > max_candidates:
            raise ValueError(f"request {i} has {n} candidates > "
                             f"max_candidates={max_candidates}")
        key = request_key(r)
        new_user = key not in cur_keys
        if cur and (cur_cands + n > max_candidates
                    or len(cur_keys) + new_user > max_unique):
            chunks.append(cur)
            cur, cur_keys, cur_cands = [], set(), 0
        cur.append(i)
        cur_keys.add(key)
        cur_cands += n
    if cur:
        chunks.append(cur)
    return chunks
