"""Executor registry: one jitted function per (executor kind, shape bucket).

Shapes are fixed per bucket, so each executor compiles exactly once; after
``ServingEngine.warmup()`` walks the whole ladder, steady-state traffic runs
with ZERO fresh XLA compiles.  The registry keeps the telemetry that proves
it: ``compiles`` counts first executions (each one paid a compile),
``hits`` counts executions against an already-compiled executor.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Hashable, Tuple

import jax


class ExecutorRegistry:
    """Lazily builds and caches jitted executors.

    A *kind* is registered with a factory ``factory(key) -> callable``; the
    key is the shape-bucket tuple (plus any static config such as the
    context length), so the factory can close over static values instead of
    threading them through jit as traced arguments.

    Bookkeeping (executor dicts, compile/hit counters) is guarded by an
    RLock: with a scheduler background flusher, executions arrive from
    the flusher thread as well as from callers blocked in ``result()``.  The jitted call itself runs OUTSIDE the lock — jit
    dispatch is thread-safe and holding the lock across device dispatch
    would serialize the very overlap the pipeline exists for.
    """

    def __init__(self):
        self._factories: Dict[str, Callable] = {}
        self._jit_kwargs: Dict[str, dict] = {}
        self._jitted: Dict[Tuple[str, Hashable], Callable] = {}
        self._executed: set = set()
        self._warmed: set = set()
        self._calls: Dict[Tuple[str, Hashable], int] = {}
        self._lock = threading.RLock()
        self.compiles = 0
        self.hits = 0

    @property
    def lock(self):
        """The registry RLock — the engine's telemetry mutations and the
        ``ServingEngine.stats()`` snapshot read take it so concurrent
        submitters can never observe torn counters."""
        return self._lock

    def register(self, kind: str, factory: Callable, *,
                 jit_kwargs: dict = None):
        """``jit_kwargs`` are forwarded to ``jax.jit`` for every executor
        of this kind — e.g. ``{"donate_argnums": 0}`` lets the KV-slab put
        executor update its arena buffers in place instead of copying the
        whole arena per call."""
        with self._lock:
            self._factories[kind] = factory
            self._jit_kwargs[kind] = dict(jit_kwargs or {})

    def invalidate(self, kind: str):
        """Drop every jitted executor of ``kind`` — required when a factory
        is re-registered with new closed-over state (e.g. a refreshed
        retrieval index), otherwise stale executors keep serving.  The
        cumulative compile/hit counters are left untouched; dropped keys
        count as fresh compiles again until re-warmed."""
        with self._lock:
            for k in [k for k in self._jitted if k[0] == kind]:
                del self._jitted[k]
                self._executed.discard(k)
                self._warmed.discard(k)

    @property
    def kinds(self):
        return tuple(self._factories)

    def executors(self):
        """-> tuple of (kind, key) instantiated so far."""
        return tuple(self._jitted)

    def __call__(self, kind: str, key: Hashable, *args):
        """Execute executor ``(kind, key)`` on ``args``, jitting it on
        first use.  First executions count toward ``compiles`` (and, if
        outside :meth:`warm`, toward ``compiles_after_warmup`` — the
        number the zero-recompile serving contract pins at 0)."""
        return self._execute(kind, key, args, warming=False)

    def _execute(self, kind: str, key: Hashable, args, *, warming: bool):
        k = (kind, key)
        with self._lock:
            fn = self._jitted.get(k)
            if fn is None:
                fn = jax.jit(self._factories[kind](key),
                             **self._jit_kwargs.get(kind, {}))
                self._jitted[k] = fn
            if k in self._executed:
                self.hits += 1
            else:
                self._executed.add(k)
                self.compiles += 1
            if warming:
                # marked in the SAME critical section as the executed set:
                # a concurrent telemetry()/stats() reader interleaving with
                # warmup() must never observe the executed-but-not-yet-
                # warmed gap as a phantom nonzero compiles_after_warmup
                self._warmed.add(k)
            self._calls[k] = self._calls.get(k, 0) + 1
        return fn(*args)

    def warm(self, kind: str, key: Hashable, *args):
        """Execute once for compilation and tag the executor as warmed; the
        warmup compile is excluded from steady-state telemetry questions via
        ``compiles_after_warmup``.  The warmed mark is applied atomically
        with the execution bookkeeping (one lock section), so concurrent
        telemetry readers see warmup compiles as warmed from the start."""
        return self._execute(kind, key, args, warming=True)

    def call_counts(self) -> Dict[Tuple[str, Hashable], int]:
        """-> consistent {(kind, key): executions} snapshot (taken under
        the registry lock).  Not part of :meth:`telemetry` — the
        ``stats()`` dict contract is pinned; the obs registry exports
        per-kind aggregates of this via a collector."""
        with self._lock:
            return dict(self._calls)

    @property
    def compiles_after_warmup(self) -> int:
        """Executors that compiled OUTSIDE warmup — the number a production
        deployment wants pinned at zero."""
        with self._lock:
            return len(self._executed - self._warmed)

    def telemetry(self) -> dict:
        with self._lock:
            return {"executors": len(self._jitted),
                    "compiles": self.compiles,
                    "hits": self.hits, "warmed": len(self._warmed),
                    "compiles_after_warmup": self.compiles_after_warmup}
