"""Serving subsystem public surface — exactly the typed request types and
the engine with its front-door collaborators; a test pins ``__all__`` to
this list.

One front door: build a :class:`ServingEngine`, then ``submit`` typed
requests — :class:`RankRequest`, :class:`RetrieveRequest`,
:class:`RetrieveThenRankRequest` (the fused two-stage path, resolving to
a :class:`TwoStageResult`), :class:`GenerateRequest` — and read each
:class:`Future`.  ``engine.score`` / ``engine.retrieve`` are batch shims
over ``submit_many``; ``engine.stats()`` is the telemetry snapshot.  For
serving beyond one process, :mod:`repro.cluster` puts N engines behind an
affinity-routing ``ClusterRouter`` with the same submit contract.

Internals (``BatchPlan``/``build_plan``, ``BucketLadder``,
``ExecutorRegistry``, ``PipelineStats``, ``RequestScheduler``) stay
importable from their modules (``repro.serving.plan`` etc.) but are not
part of this package's public surface.  The PR-1-era ``MicroBatcher`` /
``InferenceRouter`` deprecation shims are gone — callers use the
``submit`` front door (or ``RequestScheduler`` directly for a custom
flush function).  See docs/architecture.md for lifecycles and the
zero-recompile contract.
"""
from repro.serving.context_cache import ContextCache
from repro.serving.engine import ServingEngine
from repro.serving.plan import (GenerateRequest, LanePolicy, RankRequest,
                                RetrieveRequest, RetrieveThenRankRequest,
                                TwoStageResult)
from repro.serving.scheduler import Future, ShedError

__all__ = [
    # typed requests (+ the two-stage result they resolve to)
    "RankRequest", "RetrieveRequest", "RetrieveThenRankRequest",
    "GenerateRequest", "TwoStageResult",
    # the engine and its front-door collaborators
    "ServingEngine", "ContextCache", "Future",
    # SLO scheduling: per-lane policies + the typed shed error
    "LanePolicy", "ShedError",
]
