"""Serving subsystem public surface.

Layer map (request flow order): ``MicroBatcher`` -> ``build_plan`` /
``BatchPlan`` -> ``ServingEngine`` dispatching jitted executors from the
``ExecutorRegistry``, with ``ContextCache`` short-circuiting repeat users.
``RankRequest`` / ``RetrieveRequest`` are the request types;
``InferenceRouter`` is the legacy PR-0 facade kept for compatibility.
See docs/architecture.md for lifecycles and the zero-recompile contract.
"""
from repro.serving.context_cache import ContextCache
from repro.serving.engine import ServingEngine
from repro.serving.executors import ExecutorRegistry
from repro.serving.generate import GenerateConfig, Generator
from repro.serving.microbatch import MicroBatcher, Ticket
from repro.serving.plan import (BatchPlan, BucketLadder, PipelineStats,
                                RankRequest, RetrieveRequest, build_plan,
                                request_key, split_requests)
from repro.serving.router import InferenceRouter, UserEmbeddingCache
