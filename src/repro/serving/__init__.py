from repro.serving.context_cache import ContextCache
from repro.serving.engine import ServingEngine
from repro.serving.executors import ExecutorRegistry
from repro.serving.generate import GenerateConfig, Generator
from repro.serving.microbatch import MicroBatcher, Ticket
from repro.serving.plan import (BatchPlan, BucketLadder, RankRequest,
                                RetrieveRequest, build_plan, request_key,
                                split_requests)
from repro.serving.router import InferenceRouter, UserEmbeddingCache
