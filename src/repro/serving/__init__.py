from repro.serving.router import InferenceRouter, RankRequest
from repro.serving.generate import GenerateConfig, Generator
