"""DEPRECATED back-compat facade over the serving engine (paper §4.3,
Figure 2).

The seed's monolithic ``InferenceRouter`` grew into a layered engine —
see :mod:`repro.serving.engine`.  This module keeps the original public
surface (``InferenceRouter``, ``RankRequest``, ``UserEmbeddingCache``)
as thin wrappers so existing callers and tests keep working: ``score`` /
``score_cached`` forward to ``ServingEngine.score``, itself a shim over
the ``submit_many`` front door, so the router is two hops from the real
path and emits a :class:`DeprecationWarning` once per process.  New code
should construct a :class:`~repro.serving.engine.ServingEngine` and call
``submit`` / ``submit_many`` (or the ``score`` batch shim) directly.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.serving._deprecation import warn_once
from repro.serving.context_cache import ContextCache
from repro.serving.engine import LITE_VARIANTS, ServingEngine
from repro.serving.plan import RankRequest                     # re-export

__all__ = ["InferenceRouter", "RankRequest", "UserEmbeddingCache"]


class UserEmbeddingCache(ContextCache):
    """LRU of pooled user embeddings for late-fusion (lite) variants —
    the paper's §3.2 point that late fusion makes the PinFM output cacheable
    across requests.  Kept as a named subclass of the generalized
    :class:`ContextCache` for backward compatibility (including the
    inherited seed-style ``key(seq_ids, seq_actions)``)."""


class InferenceRouter:
    """Batches requests, dedups sequences, pads to fixed shapes, scores.

    ``score`` runs the monolithic ranking executor; ``score_cached`` runs
    the cached path (pooled embeddings for lite variants — unchanged
    behavior, now dedup-aware across requests within a call)."""

    def __init__(self, model, params, *, max_unique: int = 8,
                 max_candidates: int = 64,
                 user_cache: Optional[UserEmbeddingCache] = None):
        warn_once(
            "router",
            "InferenceRouter is deprecated: construct a ServingEngine and "
            "use submit()/submit_many() (or the score() batch shim)")
        self.model, self.params = model, params
        self.max_unique, self.max_candidates = max_unique, max_candidates
        self.user_cache = user_cache
        self._engine = ServingEngine(model, params, max_unique=max_unique,
                                     max_candidates=max_candidates)
        self._cached_engine = None
        if user_cache is not None:
            assert model.cfg.variant in LITE_VARIANTS, \
                "user-embedding caching requires a late-fusion variant"
            self._cached_engine = ServingEngine(
                model, params, max_unique=max_unique,
                max_candidates=max_candidates, cache=user_cache,
                # seed semantics: the lite LRU keys by ids+actions only
                key_fn=lambda r: UserEmbeddingCache.key(r.seq_ids,
                                                        r.seq_actions))
            # one chronological stats stream across both paths, like the
            # seed's single list
            self._cached_engine.call_stats = self._engine.call_stats

    @property
    def stats(self) -> List[dict]:
        return self._engine.call_stats

    def score(self, requests: Sequence[RankRequest]) -> List[np.ndarray]:
        """-> per-request (N_b, n_tasks) probabilities."""
        return self._engine.score(requests)

    def score_cached(self, requests: Sequence[RankRequest]) -> List[np.ndarray]:
        """Lite-variant scoring: pooled user embeddings come from the LRU
        when the same user sequence was seen before (any earlier request),
        so repeat traffic skips the transformer entirely."""
        assert self._cached_engine is not None
        return self._cached_engine.score(requests)
