"""Serving path (paper §4.3, Figure 2).

The inference router receives ranking requests (user sequence + N candidate
items), fetches quantized id-embedding rows from the "CPU host" table shard,
DEDUPLICATES the sequence batch (Ψ — pointers, host-side), and hands fixed-
shape batches to the jitted rank step.  PinFM's context is computed once per
unique user and crossed with every candidate (DCAT).

On this container the "CPU host" and the "accelerator" are both the CPU; the
structural split (packed int4 table + gather on host, dequant + transformer
on device) is preserved.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.dcat import dedup, dedup_stats
from repro.core.finetune import PinFMRankingModel


@dataclasses.dataclass
class RankRequest:
    seq_ids: np.ndarray          # (L,)
    seq_actions: np.ndarray
    seq_surfaces: np.ndarray
    cand_ids: np.ndarray         # (N_b,)
    cand_feats: np.ndarray       # (N_b, F_c)
    user_feats: np.ndarray       # (F_u,)
    graphsage: Optional[np.ndarray] = None


class UserEmbeddingCache:
    """LRU of pooled user embeddings for late-fusion (lite) variants —
    the paper's §3.2 point that late fusion makes the PinFM output cacheable
    across requests (the candidate never enters the sequence)."""

    def __init__(self, capacity: int = 4096):
        from collections import OrderedDict
        self.capacity = capacity
        self._d = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(seq_ids, seq_actions):
        return (np.asarray(seq_ids).tobytes(),
                np.asarray(seq_actions).tobytes())

    def get(self, key):
        if key in self._d:
            self._d.move_to_end(key)
            self.hits += 1
            return self._d[key]
        self.misses += 1
        return None

    def put(self, key, emb):
        self._d[key] = emb
        self._d.move_to_end(key)
        if len(self._d) > self.capacity:
            self._d.popitem(last=False)


class InferenceRouter:
    """Batches requests, dedups sequences, pads to fixed shapes, scores."""

    def __init__(self, model: PinFMRankingModel, params, *,
                 max_unique: int = 8, max_candidates: int = 64,
                 user_cache: Optional[UserEmbeddingCache] = None):
        self.model, self.params = model, params
        self.max_unique, self.max_candidates = max_unique, max_candidates
        self._rank = jax.jit(self._rank_step)
        self.user_cache = user_cache
        if user_cache is not None:
            assert model.cfg.variant in ("lite-mean", "lite-last"), \
                "user-embedding caching requires a late-fusion variant"
            self._encode = jax.jit(self.model.encode_user)
            self._score = jax.jit(
                lambda p, emb, b: jax.nn.sigmoid(
                    self.model.score_with_user_emb(p, emb, b)
                    .astype(jnp.float32)))
        self.stats: List[dict] = []

    def _rank_step(self, params, batch):
        logits, _, _ = self.model.forward(params, batch, train=False)
        return jax.nn.sigmoid(logits.astype(jnp.float32))

    def score(self, requests: Sequence[RankRequest]) -> List[np.ndarray]:
        """-> per-request (N_b, n_tasks) probabilities."""
        t0 = time.time()
        # assemble the candidate-level batch
        all_seq = np.stack([r.seq_ids for r in requests])
        uniq_seq, inv_req = dedup(all_seq)                    # Ψ over requests
        seq_actions = np.stack([r.seq_actions for r in requests])
        seq_surfaces = np.stack([r.seq_surfaces for r in requests])
        first_of = np.array([np.argmax(inv_req == u)
                             for u in range(len(uniq_seq))])
        counts = [len(r.cand_ids) for r in requests]
        inverse_idx = np.concatenate(
            [np.full(c, inv_req[i], np.int32) for i, c in enumerate(counts)])

        B_u = self._pad_to(len(uniq_seq), self.max_unique)
        B_c = self._pad_to(len(inverse_idx), self.max_candidates)
        L = uniq_seq.shape[1]

        def padu(x, fill=0):
            out = np.full((B_u, *x.shape[1:]), fill, x.dtype)
            out[:len(x)] = x
            return out

        def padc(x, fill=0):
            out = np.full((B_c, *x.shape[1:]), fill, x.dtype)
            out[:len(x)] = x
            return out

        batch = {
            "seq_ids": padu(uniq_seq.astype(np.int32)),
            "seq_actions": padu(seq_actions[first_of].astype(np.int32)),
            "seq_surfaces": padu(seq_surfaces[first_of].astype(np.int32)),
            "seq_valid": padu(np.ones_like(uniq_seq, bool)),
            "seq_user_id": padu(np.arange(len(uniq_seq), dtype=np.int32)),
            "inverse_idx": padc(inverse_idx),
            "cand_ids": padc(np.concatenate([r.cand_ids for r in requests])
                             .astype(np.int32)),
            "cand_feats": padc(np.concatenate(
                [r.cand_feats for r in requests]).astype(np.float32)),
            "user_feats": padu(np.stack(
                [r.user_feats for r in requests])[first_of]
                .astype(np.float32)),
        }
        if requests[0].graphsage is not None:
            batch["graphsage"] = padc(np.concatenate(
                [r.graphsage for r in requests]).astype(np.float32))
        batch["cand_age_days"] = padc(
            np.zeros(len(inverse_idx), np.float32))
        probs = np.asarray(self._rank(self.params,
                                      jax.tree.map(jnp.asarray, batch)))
        self.stats.append({**dedup_stats(inverse_idx),
                           "latency_s": time.time() - t0})
        # split back per request
        out, off = [], 0
        for c in counts:
            out.append(probs[off:off + c])
            off += c
        return out

    @staticmethod
    def _pad_to(n: int, quantum: int) -> int:
        return max(quantum, -(-n // quantum) * quantum)

    # -- late-fusion path with the user-embedding cache ----------------------
    def score_cached(self, requests: Sequence[RankRequest]) -> List[np.ndarray]:
        """Lite-variant scoring: pooled user embeddings come from the LRU
        when the same user sequence was seen before (any earlier request),
        so repeat traffic skips the transformer entirely."""
        assert self.user_cache is not None
        t0 = time.time()
        cache = self.user_cache
        embs = []
        to_encode, enc_slots = [], []
        for i, r in enumerate(requests):
            key = cache.key(r.seq_ids, r.seq_actions)
            hit = cache.get(key)
            embs.append(hit)
            if hit is None:
                to_encode.append(r)
                enc_slots.append((i, key))
        if to_encode:
            B_u = self._pad_to(len(to_encode), self.max_unique)
            L = len(to_encode[0].seq_ids)

            def pad(xs):
                out = np.zeros((B_u, L), np.int32)
                out[:len(xs)] = np.stack(xs)
                return jnp.asarray(out)

            fresh = np.asarray(self._encode(
                self.params,
                pad([r.seq_ids for r in to_encode]),
                pad([r.seq_actions for r in to_encode]),
                pad([r.seq_surfaces for r in to_encode])))
            for j, (i, key) in enumerate(enc_slots):
                cache.put(key, fresh[j])
                embs[i] = fresh[j]

        counts = [len(r.cand_ids) for r in requests]
        B_c = self._pad_to(sum(counts), self.max_candidates)
        user_emb = np.zeros((B_c, embs[0].shape[-1]), np.float32)
        cand_ids = np.zeros(B_c, np.int32)
        cand_feats = np.zeros((B_c, requests[0].cand_feats.shape[1]),
                              np.float32)
        user_feats = np.zeros((B_c, len(requests[0].user_feats)), np.float32)
        off = 0
        for r, e in zip(requests, embs):
            n = len(r.cand_ids)
            user_emb[off:off + n] = e
            cand_ids[off:off + n] = r.cand_ids
            cand_feats[off:off + n] = r.cand_feats
            user_feats[off:off + n] = r.user_feats
            off += n
        batch = {"cand_ids": jnp.asarray(cand_ids),
                 "cand_feats": jnp.asarray(cand_feats),
                 "user_feats": jnp.asarray(user_feats),
                 "inverse_idx": jnp.arange(B_c)}
        probs = np.asarray(self._score(self.params, jnp.asarray(user_emb),
                                       batch))
        self.stats.append({
            "candidates": sum(counts), "unique_users": len(requests),
            "cache_hits": cache.hits, "cache_misses": cache.misses,
            "latency_s": time.time() - t0})
        out, off = [], 0
        for c in counts:
            out.append(probs[off:off + c])
            off += c
        return out
