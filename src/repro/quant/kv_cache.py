"""Quantized KV cache — beyond-paper extension of §4.2's PTQ idea to the
decode memory bottleneck.

The roofline table (EXPERIMENTS.md) shows every decode shape is
memory-bound, dominated by KV-cache reads.  Storing K/V as int8 with a
per-(slot, head) fp16 scale (symmetric min-max, zero-preserving) halves the
dominant term vs bf16 at ~0.4% relative L2 on the attention output — the
same trade the paper validated for the embedding tables.

Drop-in replacement for nn.attention.KVCache (same ring-buffer semantics).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def quantize_kv(x, *, bits: int = 8):
    """x: (..., D) -> (codes, fp16 scale (..., 1)).  Symmetric min-max,
    zero-preserving, per-(slot, head) along the last axis.

    bits=8: codes are int8 in [-127, 127], shape (..., D).
    bits=4: codes are int8 nibble pairs in [-7, 7], PACKED two-per-byte
    (code d lives in byte d//2, nibble d%2) -> shape (..., D//2)."""
    assert bits in (4, 8), bits
    qmax = 127.0 if bits == 8 else 7.0
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = (amax / qmax).astype(jnp.float16)
    sf = jnp.maximum(scale.astype(jnp.float32), 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / sf), -qmax, qmax)
    codes = codes.astype(jnp.int8)
    if bits == 4:
        codes = pack_int4(codes)
    return codes, scale


def dequantize_kv(codes, scale, dtype, *, bits: int = 8):
    """Inverse of :func:`quantize_kv` (int4 codes are unpacked first)."""
    assert bits in (4, 8), bits
    if bits == 4:
        codes = unpack_int4(codes)
    return (codes.astype(jnp.float32)
            * scale.astype(jnp.float32)).astype(dtype)


def pack_int4(codes):
    """(..., D) int8 codes in [-8, 7] -> (..., D//2) int8, two codes per
    byte: code d -> byte d//2, nibble d%2 (low nibble = even d)."""
    assert codes.shape[-1] % 2 == 0, codes.shape
    w = codes.astype(jnp.int32)
    lo, hi = w[..., 0::2] & 0xF, w[..., 1::2] & 0xF
    packed = lo | (hi << 4)                      # [0, 255]
    return (packed - jnp.where(packed > 127, 256, 0)).astype(jnp.int8)


def unpack_int4(packed):
    """(..., W) int8 -> (..., 2W) int8 sign-extended nibble codes."""
    w = packed.astype(jnp.int32) & 0xFF
    sext = lambda n: (n ^ 8) - 8
    both = jnp.stack([sext(w & 0xF), sext((w >> 4) & 0xF)], axis=-1)
    return both.reshape(*packed.shape[:-1], packed.shape[-1] * 2) \
               .astype(jnp.int8)


# back-compat aliases (original int8-only spellings)
def _quantize(x):
    return quantize_kv(x, bits=8)


def _dequantize(codes, scale, dtype):
    return dequantize_kv(codes, scale, dtype, bits=8)


@dataclasses.dataclass
class QuantizedKVCache:
    """Ring-buffer cache with int8 storage (per-slot-per-head scales)."""
    k8: jax.Array          # (B, size, K, D) int8
    v8: jax.Array
    k_scale: jax.Array     # (B, size, K, 1) fp16
    v_scale: jax.Array
    pos: jax.Array         # (B,)
    # dequantized view dtype
    dtype: str = "bfloat16"

    @property
    def size(self):
        return self.k8.shape[1]

    @staticmethod
    def zeros(batch, size, n_kv, head_dim, dtype=jnp.bfloat16):
        shape = (batch, size, n_kv, head_dim)
        sshape = (batch, size, n_kv, 1)
        return QuantizedKVCache(
            k8=jnp.zeros(shape, jnp.int8), v8=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros(sshape, jnp.float16),
            v_scale=jnp.zeros(sshape, jnp.float16),
            pos=jnp.zeros((batch,), jnp.int32),
            dtype=jnp.dtype(dtype).name)

    # ring-buffer bookkeeping identical to KVCache -------------------------
    def slot_positions(self):
        B, size = self.k8.shape[0], self.size
        slots = jnp.arange(size)[None, :]
        n = self.pos[:, None]
        last = n - 1 - (n - 1 - slots) % size
        valid = (slots < n) & (last >= 0)
        return jnp.where(valid, last, 0), valid

    def update(self, k_new, v_new):
        """k_new/v_new: (B, 1, K, D) full precision."""
        b = jnp.arange(self.k8.shape[0])
        slot = self.pos % self.size
        k8, ks = _quantize(k_new[:, 0])
        v8, vs = _quantize(v_new[:, 0])
        return QuantizedKVCache(
            k8=self.k8.at[b, slot].set(k8),
            v8=self.v8.at[b, slot].set(v8),
            k_scale=self.k_scale.at[b, slot].set(ks),
            v_scale=self.v_scale.at[b, slot].set(vs),
            pos=self.pos + 1, dtype=self.dtype)

    @property
    def k(self):
        return _dequantize(self.k8, self.k_scale, jnp.dtype(self.dtype))

    @property
    def v(self):
        return _dequantize(self.v8, self.v_scale, jnp.dtype(self.dtype))

    @property
    def nbytes(self) -> int:
        return (self.k8.size + self.v8.size
                + 2 * self.k_scale.size + 2 * self.v_scale.size)


jax.tree_util.register_dataclass(
    QuantizedKVCache,
    data_fields=["k8", "v8", "k_scale", "v_scale", "pos"],
    meta_fields=["dtype"])
