"""Post-training min-max quantization of embedding tables (paper §4.2).

FBGEMM-style per-row min-max: each D-dim fp16/fp32 row becomes D intN codes
+ one fp16 scale + one fp16 bias, bitpacked into int32 words:

    scale = (max - min) / (2^bits - 1);  code = round((x - min) / scale)
    dequant = code * scale + min

int4 compresses a 32-dim fp16 row from 512 bit to 32*4 + 16 + 16 = 160 bit
= 31.25% of the original (paper's number).  Paper-measured relative L2
errors: ~0.45% (int8), ~7.8% (int4) — asserted in tests/test_quant.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class QuantizedTable:
    packed: jax.Array    # (R, D*bits/32) int32
    scale: jax.Array     # (R, 1) fp16
    bias: jax.Array      # (R, 1) fp16
    bits: int
    dim: int

    @property
    def nbytes(self) -> int:
        return (self.packed.size * 4 + self.scale.size * 2
                + self.bias.size * 2)


jax.tree_util.register_dataclass(
    QuantizedTable, data_fields=["packed", "scale", "bias"],
    meta_fields=["bits", "dim"])


_F16_MAX = 65504.0   # largest finite float16 — scale/bias are stored fp16


def quantize_table(table, bits: int = 4) -> QuantizedTable:
    """table: (R, D) float.  D*bits must be a multiple of 32.

    Degenerate rows are handled exactly: a constant row has ``mx == mn``,
    so ``scale == 0`` and every code is forced to 0 — dequantization then
    returns ``bias`` == the fp16-rounded row value (exact round-trip at
    serving precision).  Row extrema are clamped into the finite fp16
    range first so scale/bias never overflow to inf (which would turn the
    whole dequantized row into inf/nan)."""
    assert bits in (4, 8)
    R, D = table.shape
    per_word = 32 // bits
    assert D % per_word == 0
    x = table.astype(jnp.float32)
    mn = jnp.clip(jnp.min(x, axis=1, keepdims=True), -_F16_MAX, _F16_MAX)
    mx = jnp.clip(jnp.max(x, axis=1, keepdims=True), -_F16_MAX, _F16_MAX)
    # fp16 scale/bias, exactly as served (paper stores fp16 scale + bias)
    scale = ((mx - mn) / (2 ** bits - 1)).astype(jnp.float16)
    bias = mn.astype(jnp.float16)
    sf = scale.astype(jnp.float32)
    codes = jnp.where(
        sf > 0,
        jnp.clip(jnp.round((x - bias.astype(jnp.float32))
                           / jnp.where(sf > 0, sf, 1.0)),
                 0, 2 ** bits - 1),
        0.0).astype(jnp.int32)                                 # (R, D)
    codes = codes.reshape(R, D // per_word, per_word)
    shifts = jnp.arange(per_word, dtype=jnp.int32) * bits
    packed = jnp.sum(codes << shifts[None, None, :], axis=-1,
                     dtype=jnp.int32)
    return QuantizedTable(packed=packed, scale=scale, bias=bias,
                          bits=bits, dim=D)


def dequantize_table(qt: QuantizedTable, *, use_kernel: bool = False,
                     out_dtype=jnp.float32):
    if use_kernel:
        from repro.kernels.int4_dequant import dequant_embedding
        return dequant_embedding(qt.packed, qt.scale, qt.bias, bits=qt.bits,
                                 out_dtype=out_dtype)
    from repro.kernels.ref import int4_dequant_ref, int8_dequant_ref
    ref = int4_dequant_ref if qt.bits == 4 else int8_dequant_ref
    return ref(qt.packed, qt.scale, qt.bias).astype(out_dtype)


def relative_l2_error(table, qt: QuantizedTable) -> float:
    """Paper §4.2's metric: ||x - dq(q(x))||_2 / ||x||_2."""
    deq = dequantize_table(qt).astype(jnp.float32)
    x = table.astype(jnp.float32)
    return float(jnp.linalg.norm(deq - x) / jnp.linalg.norm(x))


def compression_ratio(table, qt: QuantizedTable, *,
                      source_bytes_per_el: int = 2) -> float:
    """Serving-size ratio vs the fp16 table (paper: int4 -> 31.25%)."""
    return qt.nbytes / (table.size * source_bytes_per_el)


def quantized_lookup(qt: QuantizedTable, rows, *, use_kernel: bool = False,
                     out_dtype=jnp.float32):
    """Gather packed rows then dequantize only the gathered slice (the
    serving path: CPU host gathers packed bytes, accelerator dequantizes)."""
    sub = QuantizedTable(packed=jnp.take(qt.packed, rows, axis=0),
                         scale=jnp.take(qt.scale, rows, axis=0),
                         bias=jnp.take(qt.bias, rows, axis=0),
                         bits=qt.bits, dim=qt.dim)
    return dequantize_table(sub, use_kernel=use_kernel, out_dtype=out_dtype)
