from repro.quant.ptq import (QuantizedTable, quantize_table, dequantize_table,
                             relative_l2_error, compression_ratio,
                             quantized_lookup)
from repro.quant.kv_cache import (QuantizedKVCache, dequantize_kv, pack_int4,
                                  quantize_kv, unpack_int4)
