"""Pallas TPU kernel: fused bit-unpack + min-max dequantization of int4/int8
embedding rows (paper §4.2).

The paper packs each quantized 32-dim fp16 sub-embedding as 32 int4 codes +
fp16 scale + fp16 bias, bitpacked into words, and dequantizes on the
accelerator with a custom Triton kernel that fuses unpacking and FBGEMM
dequantization.  TPU adaptation: the same layout (codes d -> word d//8,
nibble d%8 for int4), unpacked with vector shifts/masks in VMEM and fused
with the scale/bias multiply-add — one HBM read of the packed table slice,
one HBM write of the dequantized block.

Row tiles of 512 keep the block ≥(8,128)-shaped after unpacking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dequant_kernel(packed_ref, scale_ref, bias_ref, o_ref, *, bits: int,
                    per_word: int):
    words = packed_ref[...]                                   # (TR, W) int32
    tr, w = words.shape
    mask = (1 << bits) - 1
    cols = []
    for n in range(per_word):
        cols.append((words >> (bits * n)) & mask)             # (TR, W)
    codes = jnp.stack(cols, axis=-1).reshape(tr, w * per_word)
    out = codes.astype(jnp.float32) * scale_ref[...] + bias_ref[...]
    o_ref[...] = out.astype(o_ref.dtype)


def dequant_embedding(packed, scale, bias, *, bits: int = 4, rows_per_block:
                      int = 512, out_dtype=jnp.float32, interpret: bool = True):
    """packed: (R, D*bits/32) int32; scale/bias: (R, 1).  -> (R, D)."""
    assert bits in (4, 8)
    per_word = 32 // bits
    R, W = packed.shape
    D = W * per_word
    tr = min(rows_per_block, R)
    pad = -R % tr
    packed = jnp.pad(packed, ((0, pad), (0, 0)))
    scale = jnp.pad(scale.astype(jnp.float32), ((0, pad), (0, 0)),
                    constant_values=1.0)
    bias = jnp.pad(bias.astype(jnp.float32), ((0, pad), (0, 0)))
    nr = packed.shape[0] // tr

    kernel = functools.partial(_dequant_kernel, bits=bits, per_word=per_word)
    out = pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((tr, W), lambda r: (r, 0)),
            pl.BlockSpec((tr, 1), lambda r: (r, 0)),
            pl.BlockSpec((tr, 1), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((tr, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((packed.shape[0], D), out_dtype),
        interpret=interpret,
    )(packed, scale, bias)
    return out[:R]
