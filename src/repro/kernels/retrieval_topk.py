"""Pallas TPU kernel: fused int4/int8 corpus scoring + running top-k
(retrieval subsystem, PinnerFormer-style corpus dot-product retrieval).

One grid step processes one block of packed corpus rows:

  HBM -> VMEM:  packed codes (TR, W) int32, fp16 scale/bias (TR, 1),
                and optionally one (Q, TR/32) block of the packed
                per-query row bitmask (seen-item / surface filtering)
  in-register:  unpack nibbles/bytes -> codes (TR, D), dequantize
                (FBGEMM min-max: code * scale + bias), score the block
                against the resident query block:  s = Q . deq^T; rows
                whose filter bit is set are pinned to -inf before select
  carry:        the (Q, K) running top-k scores + global row indices live
                in the output block (constant index map), merged with the
                freshly scored block each step.

The merge is an explicitly LEXICOGRAPHIC order on (-score, row index), so
the global tie-break contract "equal scores -> lower row index wins" holds
even when -inf ties are common (a fully filtered corpus block ties with
the carry's -inf init sentinel; the sentinel's INT32_MAX index makes it
lose to every real row).  Two bit-identical implementations of that order
live here:

  * ``merge="bitonic"`` (default) — :func:`bitonic_topk_merge`, an
    in-register bitonic compare-exchange network.  Every stage is a
    last-axis reshape + ``where`` (no gathers, no variadic sort), the
    Mosaic-friendly formulation: element p pairs with p^stride under a
    ``(..., n/(2*stride), 2, stride)`` reshape, and the per-group
    direction bit is constant because each group spans one aligned
    2*stride block.  This is the shared device-side merge — the IVF route
    (``retrieval/ivf.py``) scans its probed cluster slices through the
    SAME helper, so the top-k merge lives in exactly one place (the host
    counterpart is ``retrieval.scorer.merge_topk``).
  * ``merge="sort"`` — the original two-operand ``jax.lax.sort``
    lexicographic sort, kept as the parity escape hatch and the benchmark
    baseline (``bench_retrieval.py`` asserts the bitonic network beats it
    with bit-identical results).

One HBM read of the packed corpus, no (Q, R) score matrix in HBM — the
score block never leaves VMEM.  The pure-jnp oracle (dequantize the whole
corpus, one big top_k) is ``kernels.ref.retrieval_topk_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SENTINEL_IDX = 2**31 - 1   # carry init: loses every (-score, index) tie


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _compare_swap(s, i, size: int, stride: int):
    """One bitonic compare-exchange substage over the last axis.

    Element p pairs with p ^ stride: reshape the last axis (length n) to
    (n / (2*stride), 2, stride) and the partners land in the two middle
    slots of each group.  The sort direction of a pair depends only on
    bit ``size`` of p, which is constant within a group (a group spans
    positions [g*2*stride, (g+1)*2*stride), an aligned block of length
    2*stride <= size), so it is a per-group scalar, not a gather."""
    lead = s.shape[:-1]
    n = s.shape[-1]
    g = n // (2 * stride)
    s2 = s.reshape(*lead, g, 2, stride)
    i2 = i.reshape(*lead, g, 2, stride)
    lo_s, hi_s = s2[..., 0, :], s2[..., 1, :]
    lo_i, hi_i = i2[..., 0, :], i2[..., 1, :]
    # descending groups have bit `size` of their first position clear:
    # the final stage (size == n) is then one all-descending merge
    g_first = jax.lax.broadcasted_iota(jnp.int32, (g, 1), 0) * (2 * stride)
    desc = (g_first & size) == 0
    # "lo wins" under the contract order: higher score, ties -> lower index
    lo_wins = (lo_s > hi_s) | ((lo_s == hi_s) & (lo_i < hi_i))
    swap = jnp.where(desc, ~lo_wins, lo_wins)
    new_lo_s = jnp.where(swap, hi_s, lo_s)
    new_hi_s = jnp.where(swap, lo_s, hi_s)
    new_lo_i = jnp.where(swap, hi_i, lo_i)
    new_hi_i = jnp.where(swap, lo_i, hi_i)
    s = jnp.stack([new_lo_s, new_hi_s], axis=-2).reshape(*lead, n)
    i = jnp.stack([new_lo_i, new_hi_i], axis=-2).reshape(*lead, n)
    return s, i


def _bitonic_sort_desc(s, i):
    """Full bitonic sorting network over the last axis (power-of-two
    length): sorts by score DESCENDING, equal scores by index ASCENDING —
    the retrieval contract order.  Static python loop over the
    O(log^2 n) substages; every substage is reshape + where only."""
    n = s.shape[-1]
    assert n & (n - 1) == 0, f"bitonic length {n} must be a power of two"
    size = 2
    while size <= n:
        stride = size // 2
        while stride >= 1:
            s, i = _compare_swap(s, i, size, stride)
            stride //= 2
        size *= 2
    return s, i


def bitonic_topk_merge(carry_s, carry_i, block_s, block_i, *, k: int = None):
    """Merge a running (…, K) top-k carry with a freshly scored (…, N)
    block: exact top-k of the union by (score desc, index asc).

    The single device-side partial top-k merge of the retrieval
    subsystem — the Pallas kernel's carry merge and the IVF route's
    cluster-slice scan both call this.  Padding slots are
    (-inf, INT32_MAX), the same sentinel the kernel carry initializes
    with, so they lose every comparison (including -inf score ties, where
    the lower index wins).  Bit-compatible with the two-operand
    ``jax.lax.sort`` on (-score, index): both realize the same total
    order, and selection of the top k from a total order is unique."""
    if k is None:
        k = carry_s.shape[-1]
    cat_s = jnp.concatenate([carry_s, block_s], axis=-1)
    cat_i = jnp.concatenate([carry_i.astype(jnp.int32),
                             block_i.astype(jnp.int32)], axis=-1)
    n = cat_s.shape[-1]
    pad = _next_pow2(n) - n
    if pad:
        shp = cat_s.shape[:-1] + (pad,)
        cat_s = jnp.concatenate(
            [cat_s, jnp.full(shp, -jnp.inf, cat_s.dtype)], axis=-1)
        cat_i = jnp.concatenate(
            [cat_i, jnp.full(shp, _SENTINEL_IDX, jnp.int32)], axis=-1)
    s, i = _bitonic_sort_desc(cat_s, cat_i)
    return s[..., :k], i[..., :k]


def _topk_kernel(packed_ref, scale_ref, bias_ref, q_ref, *rest, merge: str,
                 bits: int, per_word: int, n_items: int, block_rows: int):
    if len(rest) == 3:
        mask_ref, os_ref, oi_ref = rest
    else:
        mask_ref, (os_ref, oi_ref) = None, rest
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        os_ref[...] = jnp.full_like(os_ref, -jnp.inf)
        oi_ref[...] = jnp.full_like(oi_ref, _SENTINEL_IDX)

    words = packed_ref[...]                                  # (TR, W) int32
    tr, w = words.shape
    mask = (1 << bits) - 1
    cols = [(words >> (bits * n)) & mask for n in range(per_word)]
    codes = jnp.stack(cols, axis=-1).reshape(tr, w * per_word)
    deq = (codes.astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
           + bias_ref[...].astype(jnp.float32))              # (TR, D)
    s = jnp.dot(q_ref[...], deq.T,
                preferred_element_type=jnp.float32)          # (Q, TR)
    ridx = r * block_rows + jax.lax.broadcasted_iota(jnp.int32, (1, tr), 1)
    s = jnp.where(ridx < n_items, s, -jnp.inf)
    if mask_ref is not None:
        mwords = mask_ref[...]                               # (Q, TR/32)
        mbits = ((mwords[:, :, None]
                  >> jax.lax.broadcasted_iota(jnp.int32, (1, 1, 32), 2)) & 1)
        s = jnp.where(mbits.reshape(s.shape[0], tr) == 1, -jnp.inf, s)

    k = os_ref.shape[1]
    if merge == "bitonic":
        top_s, top_i = bitonic_topk_merge(
            os_ref[...], oi_ref[...], s, jnp.broadcast_to(ridx, s.shape),
            k=k)
        os_ref[...] = top_s
        oi_ref[...] = top_i
    else:
        cat_s = jnp.concatenate([os_ref[...], s], axis=1)    # (Q, K+TR)
        cat_i = jnp.concatenate(
            [oi_ref[...], jnp.broadcast_to(ridx, s.shape)], axis=1)
        # lexicographic (-score asc, index asc) == (score desc, index asc)
        neg_s, idx = jax.lax.sort((-cat_s, cat_i), num_keys=2)
        os_ref[...] = -neg_s[:, :k]
        oi_ref[...] = idx[:, :k]


def retrieval_topk(packed, scale, bias, queries, *, k: int, bits: int = 4,
                   block_rows: int = 512, interpret: bool = True,
                   mask=None, merge: str = "bitonic"):
    """Fused dequant + score + running top-k over a packed corpus.

    packed: (R, D*bits/32) int32; scale/bias: (R, 1) fp16;
    queries: (Q, D) fp32; mask: optional (Q, >= ceil(R/32)) int32 packed
    per-query row bitmask (bit r&31 of word r>>5; 1 = row excluded — see
    ``retrieval.filters``), streamed blockwise alongside the corpus and
    applied in-register.  -> (scores (Q, k) fp32, rows (Q, k) int32),
    sorted by score descending, ties broken by lower row index; rows that
    survive the mask fewer than k deep are filled with (-inf, lowest
    excluded row index), matching ``retrieval_topk_ref``.
    ``block_rows`` must be a multiple of 32 when a mask is passed (one
    mask word covers 32 corpus rows).  ``merge`` picks the carry merge:
    the bitonic network (default) or the legacy two-operand ``lax.sort``
    — bit-identical results, see the module docstring.
    """
    assert bits in (4, 8)
    assert merge in ("bitonic", "sort"), merge
    per_word = 32 // bits
    R, W = packed.shape
    D = W * per_word
    assert queries.shape[-1] == D, (queries.shape, D)
    assert 0 < k <= R, f"k={k} must be in (0, {R}]"
    Q = queries.shape[0]
    if mask is None:
        tr = min(block_rows, R)
    else:
        tr = min(block_rows, R + (-R % 32))
        assert tr % 32 == 0, \
            f"block_rows={block_rows} must be a multiple of 32 with a mask"
    pad = -R % tr
    packed = jnp.pad(packed, ((0, pad), (0, 0)))
    scale = jnp.pad(scale.astype(jnp.float16), ((0, pad), (0, 0)))
    bias = jnp.pad(bias.astype(jnp.float16), ((0, pad), (0, 0)))
    nr = packed.shape[0] // tr

    kernel = functools.partial(_topk_kernel, merge=merge, bits=bits,
                               per_word=per_word, n_items=R, block_rows=tr)
    in_specs = [
        pl.BlockSpec((tr, W), lambda r: (r, 0)),
        pl.BlockSpec((tr, 1), lambda r: (r, 0)),
        pl.BlockSpec((tr, 1), lambda r: (r, 0)),
        pl.BlockSpec((Q, D), lambda r: (0, 0)),
    ]
    operands = [packed, scale, bias, queries.astype(jnp.float32)]
    if mask is not None:
        mw = nr * tr // 32
        mask = jnp.asarray(mask, jnp.int32)
        assert mask.shape == (Q, mask.shape[1]) and mask.shape[1] * 32 >= R, \
            (mask.shape, R)
        mask = jnp.pad(mask, ((0, 0), (0, mw - mask.shape[1])))
        in_specs.append(pl.BlockSpec((Q, tr // 32), lambda r: (0, r)))
        operands.append(mask)
    return pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((Q, k), lambda r: (0, 0)),
            pl.BlockSpec((Q, k), lambda r: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
