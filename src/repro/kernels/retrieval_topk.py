"""Pallas TPU kernel: fused int4/int8 corpus scoring + running top-k
(retrieval subsystem, PinnerFormer-style corpus dot-product retrieval).

One grid step processes one block of packed corpus rows:

  HBM -> VMEM:  packed codes (TR, W) int32, fp16 scale/bias (TR, 1),
                and optionally one (Q, TR/32) block of the packed
                per-query row bitmask (seen-item / surface filtering)
  in-register:  unpack nibbles/bytes -> codes (TR, D), dequantize
                (FBGEMM min-max: code * scale + bias), score the block
                against the resident query block:  s = Q . deq^T; rows
                whose filter bit is set are pinned to -inf before select
  carry:        the (Q, K) running top-k scores + global row indices live
                in the output block (constant index map), merged with the
                freshly scored block each step.

The merge is an explicitly LEXICOGRAPHIC sort on (-score, row index), so
the global tie-break contract "equal scores -> lower row index wins" holds
even when -inf ties are common (a fully filtered corpus block ties with
the carry's -inf init sentinel; the sentinel's INT32_MAX index makes it
lose to every real row).  ``jax.lax.sort`` with two operands is the
Mosaic-portable way to express this; replacing it with an in-register
bitonic merge is tracked on the ROADMAP.

One HBM read of the packed corpus, no (Q, R) score matrix in HBM — the
score block never leaves VMEM.  The pure-jnp oracle (dequantize the whole
corpus, one big top_k) is ``kernels.ref.retrieval_topk_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SENTINEL_IDX = 2**31 - 1   # carry init: loses every (-score, index) tie


def _topk_kernel(packed_ref, scale_ref, bias_ref, q_ref, *rest,
                 bits: int, per_word: int, n_items: int, block_rows: int):
    if len(rest) == 3:
        mask_ref, os_ref, oi_ref = rest
    else:
        mask_ref, (os_ref, oi_ref) = None, rest
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        os_ref[...] = jnp.full_like(os_ref, -jnp.inf)
        oi_ref[...] = jnp.full_like(oi_ref, _SENTINEL_IDX)

    words = packed_ref[...]                                  # (TR, W) int32
    tr, w = words.shape
    mask = (1 << bits) - 1
    cols = [(words >> (bits * n)) & mask for n in range(per_word)]
    codes = jnp.stack(cols, axis=-1).reshape(tr, w * per_word)
    deq = (codes.astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
           + bias_ref[...].astype(jnp.float32))              # (TR, D)
    s = jnp.dot(q_ref[...], deq.T,
                preferred_element_type=jnp.float32)          # (Q, TR)
    ridx = r * block_rows + jax.lax.broadcasted_iota(jnp.int32, (1, tr), 1)
    s = jnp.where(ridx < n_items, s, -jnp.inf)
    if mask_ref is not None:
        mwords = mask_ref[...]                               # (Q, TR/32)
        mbits = ((mwords[:, :, None]
                  >> jax.lax.broadcasted_iota(jnp.int32, (1, 1, 32), 2)) & 1)
        s = jnp.where(mbits.reshape(s.shape[0], tr) == 1, -jnp.inf, s)

    cat_s = jnp.concatenate([os_ref[...], s], axis=1)        # (Q, K+TR)
    cat_i = jnp.concatenate(
        [oi_ref[...], jnp.broadcast_to(ridx, s.shape)], axis=1)
    k = os_ref.shape[1]
    # lexicographic (-score asc, index asc) == (score desc, index asc)
    neg_s, idx = jax.lax.sort((-cat_s, cat_i), num_keys=2)
    os_ref[...] = -neg_s[:, :k]
    oi_ref[...] = idx[:, :k]


def retrieval_topk(packed, scale, bias, queries, *, k: int, bits: int = 4,
                   block_rows: int = 512, interpret: bool = True,
                   mask=None):
    """Fused dequant + score + running top-k over a packed corpus.

    packed: (R, D*bits/32) int32; scale/bias: (R, 1) fp16;
    queries: (Q, D) fp32; mask: optional (Q, >= ceil(R/32)) int32 packed
    per-query row bitmask (bit r&31 of word r>>5; 1 = row excluded — see
    ``retrieval.filters``), streamed blockwise alongside the corpus and
    applied in-register.  -> (scores (Q, k) fp32, rows (Q, k) int32),
    sorted by score descending, ties broken by lower row index; rows that
    survive the mask fewer than k deep are filled with (-inf, lowest
    excluded row index), matching ``retrieval_topk_ref``.
    ``block_rows`` must be a multiple of 32 when a mask is passed (one
    mask word covers 32 corpus rows).
    """
    assert bits in (4, 8)
    per_word = 32 // bits
    R, W = packed.shape
    D = W * per_word
    assert queries.shape[-1] == D, (queries.shape, D)
    assert 0 < k <= R, f"k={k} must be in (0, {R}]"
    Q = queries.shape[0]
    if mask is None:
        tr = min(block_rows, R)
    else:
        tr = min(block_rows, R + (-R % 32))
        assert tr % 32 == 0, \
            f"block_rows={block_rows} must be a multiple of 32 with a mask"
    pad = -R % tr
    packed = jnp.pad(packed, ((0, pad), (0, 0)))
    scale = jnp.pad(scale.astype(jnp.float16), ((0, pad), (0, 0)))
    bias = jnp.pad(bias.astype(jnp.float16), ((0, pad), (0, 0)))
    nr = packed.shape[0] // tr

    kernel = functools.partial(_topk_kernel, bits=bits, per_word=per_word,
                               n_items=R, block_rows=tr)
    in_specs = [
        pl.BlockSpec((tr, W), lambda r: (r, 0)),
        pl.BlockSpec((tr, 1), lambda r: (r, 0)),
        pl.BlockSpec((tr, 1), lambda r: (r, 0)),
        pl.BlockSpec((Q, D), lambda r: (0, 0)),
    ]
    operands = [packed, scale, bias, queries.astype(jnp.float32)]
    if mask is not None:
        mw = nr * tr // 32
        mask = jnp.asarray(mask, jnp.int32)
        assert mask.shape == (Q, mask.shape[1]) and mask.shape[1] * 32 >= R, \
            (mask.shape, R)
        mask = jnp.pad(mask, ((0, 0), (0, mw - mask.shape[1])))
        in_specs.append(pl.BlockSpec((Q, tr // 32), lambda r: (0, r)))
        operands.append(mask)
    return pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((Q, k), lambda r: (0, 0)),
            pl.BlockSpec((Q, k), lambda r: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
