"""Pallas TPU kernel: fused int4/int8 corpus scoring + running top-k
(retrieval subsystem, PinnerFormer-style corpus dot-product retrieval).

One grid step processes one block of packed corpus rows:

  HBM -> VMEM:  packed codes (TR, W) int32, fp16 scale/bias (TR, 1)
  in-register:  unpack nibbles/bytes -> codes (TR, D), dequantize
                (FBGEMM min-max: code * scale + bias), score the block
                against the resident query block:  s = Q . deq^T
  carry:        the (Q, K) running top-k scores + global row indices live
                in the output block (constant index map), merged with the
                freshly scored block via a stable top_k each step.

The merge preserves the global tie-break contract "equal scores -> lower
row index wins": corpus blocks arrive in index order, every carried entry
comes from an earlier (lower-index) block, and ``jax.lax.top_k`` is stable,
so equal-score entries keep carried-before-fresh == index order.

One HBM read of the packed corpus, no (Q, R) score matrix in HBM — the
score block never leaves VMEM.  The pure-jnp oracle (dequantize the whole
corpus, one big top_k) is ``kernels.ref.retrieval_topk_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_kernel(packed_ref, scale_ref, bias_ref, q_ref, os_ref, oi_ref, *,
                 bits: int, per_word: int, n_items: int, block_rows: int):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        os_ref[...] = jnp.full_like(os_ref, -jnp.inf)
        oi_ref[...] = jnp.zeros_like(oi_ref)

    words = packed_ref[...]                                  # (TR, W) int32
    tr, w = words.shape
    mask = (1 << bits) - 1
    cols = [(words >> (bits * n)) & mask for n in range(per_word)]
    codes = jnp.stack(cols, axis=-1).reshape(tr, w * per_word)
    deq = (codes.astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
           + bias_ref[...].astype(jnp.float32))              # (TR, D)
    s = jnp.dot(q_ref[...], deq.T,
                preferred_element_type=jnp.float32)          # (Q, TR)
    ridx = r * block_rows + jax.lax.broadcasted_iota(jnp.int32, (1, tr), 1)
    s = jnp.where(ridx < n_items, s, -jnp.inf)

    cat_s = jnp.concatenate([os_ref[...], s], axis=1)        # (Q, K+TR)
    cat_i = jnp.concatenate(
        [oi_ref[...], jnp.broadcast_to(ridx, s.shape)], axis=1)
    k = os_ref.shape[1]
    top_s, top_p = jax.lax.top_k(cat_s, k)                   # stable
    os_ref[...] = top_s
    oi_ref[...] = jnp.take_along_axis(cat_i, top_p, axis=1)


def retrieval_topk(packed, scale, bias, queries, *, k: int, bits: int = 4,
                   block_rows: int = 512, interpret: bool = True):
    """Fused dequant + score + running top-k over a packed corpus.

    packed: (R, D*bits/32) int32; scale/bias: (R, 1) fp16;
    queries: (Q, D) fp32.  -> (scores (Q, k) fp32, rows (Q, k) int32),
    sorted by score descending, ties broken by lower row index.
    """
    assert bits in (4, 8)
    per_word = 32 // bits
    R, W = packed.shape
    D = W * per_word
    assert queries.shape[-1] == D, (queries.shape, D)
    assert 0 < k <= R, f"k={k} must be in (0, {R}]"
    Q = queries.shape[0]
    tr = min(block_rows, R)
    pad = -R % tr
    packed = jnp.pad(packed, ((0, pad), (0, 0)))
    scale = jnp.pad(scale.astype(jnp.float16), ((0, pad), (0, 0)))
    bias = jnp.pad(bias.astype(jnp.float16), ((0, pad), (0, 0)))
    nr = packed.shape[0] // tr

    kernel = functools.partial(_topk_kernel, bits=bits, per_word=per_word,
                               n_items=R, block_rows=tr)
    return pl.pallas_call(
        kernel,
        grid=(nr,),
        in_specs=[
            pl.BlockSpec((tr, W), lambda r: (r, 0)),
            pl.BlockSpec((tr, 1), lambda r: (r, 0)),
            pl.BlockSpec((tr, 1), lambda r: (r, 0)),
            pl.BlockSpec((Q, D), lambda r: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((Q, k), lambda r: (0, 0)),
            pl.BlockSpec((Q, k), lambda r: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Q, k), jnp.float32),
            jax.ShapeDtypeStruct((Q, k), jnp.int32),
        ],
        interpret=interpret,
    )(packed, scale, bias, queries.astype(jnp.float32))
