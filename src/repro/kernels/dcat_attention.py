"""Pallas TPU kernel for DCAT crossing attention (paper §4.1, eq. 4).

Computes, for every candidate b:

    o_b = softmax( q_b [K_u[inv[b]] ‖ K_c[b]]^T ) [V_u[inv[b]] ‖ V_c[b]]

The paper implements Ψ⁻¹ (the dedup broadcast) as a Triton gather kernel on
GPU.  TPU adaptation (DESIGN.md §3): ``inv`` is a **scalar-prefetch operand**
and the gather happens in the K/V BlockSpec ``index_map`` — each grid step
DMAs the context block of the right unique user straight from HBM to VMEM.
Ψ⁻¹ therefore never materializes: no (B_c, L, K, D) tensor is ever written,
which is exactly the "pointer" semantics the paper's inference server uses.

Grid: (B, H, nL) with the context-length dimension innermost (sequential
online-softmax reduction).  The candidate KV block (S_c tokens) is folded in
at the last grid step with a causal mask among candidates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dcat_kernel(inv_ref, q_ref, ku_ref, vu_ref, kc_ref, vc_ref, o_ref,
                 m_ref, l_ref, acc_ref, *, scale: float, bl: int, nl: int,
                 ctx_len: int, sc: int):
    il = pl.program_id(2)

    @pl.when(il == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                       # (SC, D)
    k = ku_ref[0, 0].astype(jnp.float32)                      # (BL, D)
    v = vu_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    k_pos = il * bl + jax.lax.broadcasted_iota(jnp.int32, (sc, bl), 1)
    mask = k_pos < ctx_len                                    # context padding
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...], acc_ref[...] = m_new, l_new, acc

    @pl.when(il == nl - 1)
    def _candidates_and_finish():
        kc = kc_ref[0, 0].astype(jnp.float32)                 # (SC, D)
        vc = vc_ref[0, 0].astype(jnp.float32)
        sck = jax.lax.dot_general(q, kc, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32) * scale
        # causal among the S_c candidate tokens (positions L..L+S_c-1)
        qi = jax.lax.broadcasted_iota(jnp.int32, (sc, sc), 0)
        kj = jax.lax.broadcasted_iota(jnp.int32, (sc, sc), 1)
        cmask = kj <= qi
        sck = jnp.where(cmask, sck, NEG_INF)

        m_prev2, l_prev2 = m_ref[...], l_ref[...]
        m_fin = jnp.maximum(m_prev2, jnp.max(sck, axis=1))
        pc = jnp.exp(sck - m_fin[:, None]) * cmask.astype(jnp.float32)
        alpha2 = jnp.exp(m_prev2 - m_fin)
        l_fin = l_prev2 * alpha2 + jnp.sum(pc, axis=1)
        acc_fin = acc_ref[...] * alpha2[:, None] + jax.lax.dot_general(
            pc, vc, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[0, 0] = (acc_fin / jnp.maximum(l_fin, 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def dcat_cross_attention(q, k_u, v_u, k_c, v_c, inv, *, bl: int = 128,
                         interpret: bool = True):
    """q: (B, S_c, H, D); k_u/v_u: (B_u, L, K, D); k_c/v_c: (B, S_c, K, D);
    inv: (B,) int32 mapping candidates to unique users.  -> (B, S_c, H, D).
    """
    B, SC, H, D = q.shape
    Bu, L, K = k_u.shape[0], k_u.shape[1], k_u.shape[2]
    G = H // K
    scale = D ** -0.5

    bl_ = min(bl, L)
    pad_l = -L % bl_
    # kernel operates head-major; S_c rides in the block's sublane dim
    qt = q.transpose(0, 2, 1, 3)                              # (B, H, SC, D)
    kut = jnp.pad(k_u.transpose(0, 2, 1, 3),
                  ((0, 0), (0, 0), (0, pad_l), (0, 0)))       # (Bu, K, L', D)
    vut = jnp.pad(v_u.transpose(0, 2, 1, 3),
                  ((0, 0), (0, 0), (0, pad_l), (0, 0)))
    kct = k_c.transpose(0, 2, 1, 3)                           # (B, K, SC, D)
    vct = v_c.transpose(0, 2, 1, 3)
    nl = kut.shape[2] // bl_

    kernel = functools.partial(_dcat_kernel, scale=scale, bl=bl_, nl=nl,
                               ctx_len=L, sc=SC)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nl),
        in_specs=[
            pl.BlockSpec((1, 1, SC, D), lambda b, h, il, inv: (b, h, 0, 0)),
            # Ψ⁻¹ fused here: the unique-user row comes from the prefetched inv
            pl.BlockSpec((1, 1, bl_, D),
                         lambda b, h, il, inv: (inv[b], h // G, il, 0)),
            pl.BlockSpec((1, 1, bl_, D),
                         lambda b, h, il, inv: (inv[b], h // G, il, 0)),
            pl.BlockSpec((1, 1, SC, D), lambda b, h, il, inv: (b, h // G, 0, 0)),
            pl.BlockSpec((1, 1, SC, D), lambda b, h, il, inv: (b, h // G, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, SC, D), lambda b, h, il, inv: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((SC,), jnp.float32),
            pltpu.VMEM((SC,), jnp.float32),
            pltpu.VMEM((SC, D), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        interpret=interpret,
    )(inv.astype(jnp.int32), qt, kut, vut, kct, vct)
    return out.transpose(0, 2, 1, 3)
