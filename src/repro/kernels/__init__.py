"""Pallas TPU kernels for PinFM's compute hot spots (paper §4):
flash attention (baseline), DCAT crossing attention (fused Ψ⁻¹ gather),
int4/int8 embedding dequantization.  Validated in interpret mode against
the pure-jnp oracles in ref.py.
"""
