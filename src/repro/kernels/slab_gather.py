"""Pallas TPU kernel: fused slot-gather + dequantization over a KV slab
arena (serving tentpole — see ``serving/kv_slab.py``).

The device-resident ContextCache stores each user's context KV as one SLOT
of a preallocated quantized arena, ``codes (S, R, Wq) + fp16 scale
(S, R, 1)`` per leaf (R = reps*L*K rows per user, Wq = packed code words
per row).  Assembling a request batch is a gather by slot id fused with
the per-row dequantize — one HBM read of exactly the b_u needed slots, one
HBM write of the fp batch, never touching the other million resident
users.

The slot ids ride as a SCALAR-PREFETCH operand
(``pltpu.PrefetchScalarGridSpec``): the grid walks the batch axis and the
index map reads ``slots[i]`` to aim each block DMA at the right arena
slot, so the gather is expressed in the block pipeline itself rather than
as a separate materialized ``jnp.take``.  int4 codes are bit-unpacked in
VMEM with the same shift/mask scheme as ``kernels/int4_dequant.py``
(code d -> byte d//2, nibble d%2, sign-extended).

``slab_gather(..., impl="jnp")`` is the pure-jnp fallback (the default
inside the serving executors on CPU hosts); ``impl="pallas"`` runs the
kernel (``interpret=True`` everywhere in this repo).  Both match
``kernels.ref.slab_gather_ref`` exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.quant.kv_cache import dequantize_kv


def _gather_dequant_kernel(slots_ref, codes_ref, scale_ref, o_ref, *,
                           bits: int):
    del slots_ref            # consumed by the index maps, not the body
    codes = codes_ref[...].astype(jnp.int32)              # (1, R, Wq)
    if bits == 4:
        one, r, w = codes.shape
        sext = lambda n: (n ^ 8) - 8
        codes = jnp.stack([sext(codes & 0xF),
                           sext((codes >> 4) & 0xF)],
                          axis=-1).reshape(one, r, w * 2)
    out = codes.astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
    o_ref[...] = out.astype(o_ref.dtype)


def slab_gather(codes, scale, slots, *, bits: int = 8,
                out_dtype=jnp.float32, impl: str = "jnp",
                interpret: bool = True):
    """codes: (S, R, Wq) int8 arena (Wq = D for int8, D//2 packed for
    int4); scale: (S, R, 1) fp16; slots: (N,) int32 slot ids.
    -> (N, R, D) dequantized rows, ``out[i] = dequant(codes[slots[i]])``."""
    assert bits in (4, 8), bits
    assert impl in ("jnp", "pallas"), impl
    S, R, Wq = codes.shape
    D = Wq * (2 if bits == 4 else 1)
    if impl == "jnp":
        c = jnp.take(codes, slots, axis=0)                # (N, R, Wq)
        s = jnp.take(scale, slots, axis=0)                # (N, R, 1)
        return dequantize_kv(c, s, out_dtype, bits=bits)
    N = slots.shape[0]
    kernel = functools.partial(_gather_dequant_kernel, bits=bits)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, R, Wq), lambda i, s: (s[i], 0, 0)),
                  pl.BlockSpec((1, R, 1), lambda i, s: (s[i], 0, 0))],
        out_specs=pl.BlockSpec((1, R, D), lambda i, s: (i, 0, 0)))
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, R, D), out_dtype),
        interpret=interpret,
    )(slots, codes, scale)
