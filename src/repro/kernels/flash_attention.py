"""Pallas TPU flash attention (causal / sliding-window), GQA-aware.

Tiling: grid (B, H, Sq/BQ, Sk/BK) with the key dimension innermost
(sequential reduction).  Blocks live in VMEM; BQ/BK default to 128 so the
QK^T and PV matmuls hit the 128x128 MXU natively.  K/V are indexed by
kv-head = h // (H/K) in the BlockSpec index_map, so GQA never materializes
repeated KV in HBM.  Online softmax carries (m, l, acc) in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window, bq: int, bk: int,
                  nk: int, seq_k: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
    k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_k                          # padding
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None]) * mask.astype(jnp.float32)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...], l_ref[...] = m_new, l_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: (B, S, H, D); k/v: (B, T, K, D), H % K == 0.  -> (B, S, H, D).

    Contiguous positions (0..S-1 / 0..T-1) are assumed — the ring-buffer /
    arbitrary-position cases go through the XLA reference path.
    """
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = D ** -0.5

    bq_ = min(bq, S)
    bk_ = min(bk, T)
    sq_pad = -S % bq_
    sk_pad = -T % bk_
    qt = jnp.pad(q.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, sq_pad), (0, 0)))
    kt = jnp.pad(k.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, sk_pad), (0, 0)))
    vt = jnp.pad(v.transpose(0, 2, 1, 3), ((0, 0), (0, 0), (0, sk_pad), (0, 0)))
    nq, nk = qt.shape[2] // bq_, kt.shape[2] // bk_

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        bq=bq_, bk=bk_, nk=nk, seq_k=T)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq_, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk_, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, bk_, D), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq_, D), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(qt.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :S].transpose(0, 2, 1, 3)
