"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.nn.attention import attend


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q: (B, S, H, D); k/v: (B, S, K, D) — mask-general reference."""
    return attend(q, k, v, causal=causal, window=window)


def dcat_cross_attention_ref(q, k_u, v_u, k_c, v_c, inv):
    """DCAT crossing (eq. 4): gather Ψ⁻¹, concat candidate KV, attend.

    q: (B, Sc, H, D); k_u/v_u: (B_u, L, K, D); k_c/v_c: (B, Sc, K, D);
    inv: (B,) int32.  Candidates sit at positions L..L+Sc-1 (causal among
    themselves, full visibility of the context).
    """
    B, Sc = q.shape[0], q.shape[1]
    L = k_u.shape[1]
    k_full = jnp.concatenate([jnp.take(k_u, inv, axis=0), k_c], axis=1)
    v_full = jnp.concatenate([jnp.take(v_u, inv, axis=0), v_c], axis=1)
    q_pos = jnp.broadcast_to(jnp.arange(L, L + Sc), (B, Sc))
    k_pos = jnp.broadcast_to(jnp.arange(L + Sc), (B, L + Sc))
    return attend(q, k_full, v_full, q_pos=q_pos, k_pos=k_pos, causal=True)


def int4_dequant_ref(packed, scale, bias):
    """packed: (R, D//8) int32 — 8 x int4 codes per word, code d lives in
    word d//8, nibble d%8; scale/bias: (R, 1).  -> (R, D) float32."""
    R, W = packed.shape
    shifts = jnp.arange(8, dtype=jnp.int32) * 4
    nib = (packed[:, :, None] >> shifts[None, None, :]) & 0xF   # (R, W, 8)
    codes = nib.reshape(R, W * 8).astype(jnp.float32)
    return codes * scale.astype(jnp.float32) + bias.astype(jnp.float32)


def int8_dequant_ref(packed, scale, bias):
    """packed: (R, D//4) int32 — 4 x int8 codes per word."""
    R, W = packed.shape
    shifts = jnp.arange(4, dtype=jnp.int32) * 8
    b = (packed[:, :, None] >> shifts[None, None, :]) & 0xFF
    codes = b.reshape(R, W * 4).astype(jnp.float32)
    return codes * scale.astype(jnp.float32) + bias.astype(jnp.float32)


def slab_gather_ref(codes, scale, slots, *, bits=8, out_dtype=jnp.float32):
    """KV-slab slot gather + dequant oracle (``kernels.slab_gather``).

    codes: (S, R, Wq) int8 arena rows — Wq = D for int8; for int4, Wq =
    D//2 with code d in byte d//2, nibble d%2, sign-extended; scale:
    (S, R, 1) fp16; slots: (N,) int32.  -> (N, R, D) with
    ``out[i] = codes[slots[i]] * scale[slots[i]]``."""
    c = jnp.take(jnp.asarray(codes), jnp.asarray(slots), axis=0)
    s = jnp.take(jnp.asarray(scale), jnp.asarray(slots), axis=0)
    if bits == 4:
        w = c.astype(jnp.int32) & 0xFF
        sext = lambda n: (n ^ 8) - 8
        c = jnp.stack([sext(w & 0xF), sext((w >> 4) & 0xF)],
                      axis=-1).reshape(c.shape[0], c.shape[1],
                                       c.shape[2] * 2)
    return (c.astype(jnp.float32)
            * s.astype(jnp.float32)).astype(out_dtype)


def retrieval_topk_ref(packed, scale, bias, queries, *, k, bits=4,
                       mask=None):
    """Corpus retrieval oracle: dequantize the WHOLE packed corpus to fp32,
    score every row against every query, one big stable top_k.

    packed: (R, D*bits/32) int32; scale/bias: (R, 1); queries: (Q, D);
    mask: optional (Q, ceil(R/32)) int32 packed row bitmask (bit r&31 of
    word r>>5; bit 1 = row excluded — see ``retrieval.filters``), whose
    scores are pinned to -inf before selection.
    -> (scores (Q, k) fp32, rows (Q, k) int32), ties broken by lower row
    index (``jax.lax.top_k`` is stable); when fewer than k rows survive a
    mask, the tail is (-inf, lowest excluded row indices)."""
    ref = int4_dequant_ref if bits == 4 else int8_dequant_ref
    deq = ref(packed, scale, bias)                           # (R, D)
    s = jnp.dot(queries.astype(jnp.float32), deq.T,
                preferred_element_type=jnp.float32)          # (Q, R)
    if mask is not None:
        r = jnp.arange(s.shape[1], dtype=jnp.int32)
        bit = (jnp.asarray(mask, jnp.int32)[:, r >> 5] >> (r & 31)) & 1
        s = jnp.where(bit == 1, -jnp.inf, s)
    scores, rows = jax.lax.top_k(s, k)
    return scores, rows.astype(jnp.int32)
