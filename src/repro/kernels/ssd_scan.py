"""Pallas TPU kernel for the Mamba-2 SSD chunk scan (arXiv:2405.21060).

Grid (B, H, n_chunks) with the CHUNK dimension innermost and sequential:
the (N, P) inter-chunk state lives in VMEM scratch across grid steps, so
HBM sees only the chunked inputs once and the outputs once — the quadratic
intra-chunk piece (Q x Q) and both state contractions run on the MXU from
VMEM-resident blocks.  B/C group projections are de-duplicated via the
BlockSpec index_map (kv-group g = h // (H/G)), mirroring the GQA trick in
flash_attention.py.

Oracle: repro.nn.ssd.ssd_chunked (pure jnp, scan over chunks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, hout_ref,
                h_ref, *, nc: int, q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)             # scalar (negative)
    bm = b_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0, 0, 0].astype(jnp.float32)         # (Q, N)

    la = a * dt                                     # (Q,)
    cs = jnp.cumsum(la)                             # inclusive
    bx = x * dt[:, None]                            # (Q, P)

    # intra-chunk: M_ij = (C_i . B_j) exp(cs_i - cs_j) for j <= i
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    diff = cs[:, None] - cs[None, :]
    qi = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    causal = kj <= qi
    diff = jnp.where(causal, diff, 0.0)     # avoid inf in the masked region
    m = jnp.where(causal, scores * jnp.exp(diff), 0.0)
    y = jax.lax.dot_general(m, bx, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    h = h_ref[...]                                  # (N, P)
    y += jax.lax.dot_general(cm * jnp.exp(cs)[:, None], h,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update: h = h * exp(cs_Q) + sum_j exp(cs_Q - cs_j) B_j (dt_j x_j)^T
    to_end = jnp.exp(cs[-1] - cs)                   # (Q,)
    s_c = jax.lax.dot_general(bm * to_end[:, None], bx,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    h_ref[...] = h * jnp.exp(cs[-1]) + s_c

    @pl.when(ic == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...]


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 64, interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,); Bm/Cm: (B,S,G,N).
    -> (y: (B,S,H,P), h_last: (B,H,N,P)).  S % chunk == 0."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    assert S % chunk == 0
    nc, q = S // chunk, chunk

    xt = x.transpose(0, 2, 1, 3).reshape(Bsz, H, nc, q, P)
    dtt = dt.transpose(0, 2, 1).reshape(Bsz, H, nc, q)
    bt = Bm.transpose(0, 2, 1, 3).reshape(Bsz, G, nc, q, N)
    ct = Cm.transpose(0, 2, 1, 3).reshape(Bsz, G, nc, q, N)
    a2 = A.reshape(H, 1).astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, nc=nc, q=q)
    y, h_last = pl.pallas_call(
        kernel,
        grid=(Bsz, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, 1, q, N),
                         lambda b, h, c: (b, h // rep, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, q, N),
                         lambda b, h, c: (b, h // rep, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, H, nc, q, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, a2, bt, ct)
    return y.reshape(Bsz, H, S, P).transpose(0, 2, 1, 3), h_last
