"""Jit'd public wrappers for the Pallas kernels (interpret=True on CPU;
on real TPU hardware set REPRO_PALLAS_INTERPRET=0)."""
import functools
import os

import jax

from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.dcat_attention import dcat_cross_attention as _dcat
from repro.kernels.int4_dequant import dequant_embedding as _dequant

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, window=None, bq=128, bk=128):
    return _flash(q, k, v, causal=causal, window=window, bq=bq, bk=bk,
                  interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("bl",))
def dcat_cross_attention(q, k_u, v_u, k_c, v_c, inv, *, bl=128):
    return _dcat(q, k_u, v_u, k_c, v_c, inv, bl=bl, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("bits", "out_dtype"))
def int_dequant(packed, scale, bias, *, bits=4, out_dtype=None):
    import jax.numpy as jnp
    return _dequant(packed, scale, bias, bits=bits,
                    out_dtype=out_dtype or jnp.float32, interpret=_INTERPRET)


@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk=64):
    from repro.kernels.ssd_scan import ssd_scan as _ssd
    return _ssd(x, dt, A, Bm, Cm, chunk=chunk, interpret=_INTERPRET)
