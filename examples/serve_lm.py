"""4th example: serve an assigned LM architecture with batched requests —
prefill + jitted ring-buffer decode (the serving loop behind decode_32k).

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-4b]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import smoke_config
from repro.models.config import get_config
from repro.models.transformer import TransformerLM
from repro.serving.generate import GenerateConfig, Generator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = smoke_config(get_config(args.arch))
    model = TransformerLM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    gen = Generator(model, params,
                    GenerateConfig(max_new_tokens=args.new_tokens,
                                   temperature=0.8, top_k=50))
    rng = np.random.RandomState(0)
    prompts = rng.randint(0, cfg.vocab, (args.batch, args.prompt_len))
    t0 = time.time()
    out = gen.generate(prompts, rng=jax.random.PRNGKey(1))
    dt = time.time() - t0
    n_tok = args.batch * args.new_tokens
    print(f"arch={args.arch} (reduced): generated {n_tok} tokens in "
          f"{dt:.1f}s (incl. compile) — {n_tok / dt:.1f} tok/s")
    print("sample:", np.asarray(out[0])[:12], "...")


if __name__ == "__main__":
    main()
