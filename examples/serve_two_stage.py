"""Fused two-stage serving demo: the paper's flagship retrieve-then-rank
workload as ONE typed request through the engine's async front door.

A ``RetrieveThenRankRequest`` submitted via ``engine.submit`` runs the
fused schedule inside the engine: the pooled user embedding is resolved
once (ContextCache), the int4 corpus-chunk executors produce the exact
filtered top-k, and the retrieved ids become the candidate set of an
internal rank request scored on the SAME pipeline — with the next group's
retrieval overlapping this group's ranking.  Candidate ranking features
come from the ``attach_features`` provider (a real deployment would back
it with a feature store).

The demo also mixes workloads in one flush — a rank request, a retrieve
request, and two-stage requests from an overlapping user set — showing
the shared encode pass (each unique user encoded once for the whole
flush), and checks the fused results against the sequential
retrieve()-then-score() path bit for bit.

With ``--trace-out PATH`` the run also exports the engine's
observability artifacts (``repro.obs``): the Chrome trace-event JSON of
the whole session to PATH (drop it into https://ui.perfetto.dev) and the
Prometheus text exposition — per-lane flush-latency histograms with
p50/p99, cache/memo/slab counters — to PATH + ".prom".  Pretty-print
both with ``python tools/dump_obs.py PATH PATH.prom``.

Run:  PYTHONPATH=src python examples/serve_two_stage.py [--smoke]
          [--trace-out /tmp/serve_trace.json]
"""
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

import numpy as np
import jax

from benchmarks.common import default_fcfg, pinfm_cfg, small_ranking_model
from repro.retrieval import IndexBuilder
from repro.serving import (ContextCache, RankRequest, RetrieveRequest,
                           RetrieveThenRankRequest, ServingEngine)

SMOKE = "--smoke" in sys.argv
TRACE_OUT = (sys.argv[sys.argv.index("--trace-out") + 1]
             if "--trace-out" in sys.argv else None)
N_ITEMS = 1024 if SMOKE else 4096
TOP_K = 8 if SMOKE else 16
N_USERS = 6 if SMOKE else 12


def main():
    pcfg = pinfm_cfg()
    fcfg = default_fcfg(variant="lite-last")       # late fusion: cacheable
    model = small_ranking_model(pcfg, fcfg)
    params = model.init(jax.random.PRNGKey(0))
    L = fcfg.seq_len

    index = IndexBuilder(model, params, batch_size=1024, bits=4) \
        .build(start_id=0, n_items=N_ITEMS)

    def item_features(item_ids):
        """Deterministic per-item ranking features (feature-store stand-in:
        the same id always produces the same bytes, so the fused path and
        the sequential reference rank identical inputs)."""
        return np.stack(
            [np.random.RandomState(int(i) % 99991).randn(fcfg.cand_feat_dim)
             for i in np.asarray(item_ids)]).astype(np.float32)

    engine = ServingEngine(model, params, max_unique=4,
                           max_candidates=4 * TOP_K,
                           cache=ContextCache(capacity=1024))
    engine.attach_index(index, k=TOP_K, chunk_rows=2048)
    engine.attach_features(item_features)
    tel = engine.warmup()
    print(f"warmup: {tel['executors']} executors precompiled in "
          f"{tel['warmup_s']:.1f}s")

    def user(seed):
        r = np.random.RandomState(seed)
        return (r.randint(0, N_ITEMS, L), r.randint(0, 6, L),
                r.randint(0, 3, L),
                r.randn(fcfg.user_feat_dim).astype(np.float32))

    # -- fused two-stage: one request, both stages, one submit --------------
    users = [user(s) for s in range(1, N_USERS + 1)]
    reqs = [RetrieveThenRankRequest(
        seq_ids=i, seq_actions=a, seq_surfaces=srf, user_feats=uf,
        k=TOP_K, exclude_ids=np.unique(i))          # never re-serve seen
        for i, a, srf, uf in users]
    futures = engine.submit_many(reqs)
    engine.flush()
    results = [f.result() for f in futures]
    ps = engine.pipeline_stats[-1]
    print(f"fused two-stage: {len(reqs)} requests -> top-{TOP_K} of "
          f"{N_ITEMS} items retrieved, filtered, and ranked in "
          f"{ps.total_ms:.1f} ms ({ps.chunks} rank chunks, retrieval "
          f"{ps.retrieve_ms:.1f} ms, overlap "
          f"{ps.overlap_fraction * 100:.0f}%, recompiles "
          f"{engine.registry.compiles_after_warmup})")
    r0 = results[0]
    order = np.argsort(-r0.probs[:, 0])
    print(f"  user 0: retrieved {r0.item_ids[:5]}..., final ranking "
          f"{r0.item_ids[order][:5]} p={np.round(r0.probs[order, 0][:5], 3)}")
    assert engine.registry.compiles_after_warmup == 0

    # -- parity: fused == sequential retrieve() + score() -------------------
    retrieved = engine.retrieve([RetrieveRequest(
        seq_ids=i, seq_actions=a, seq_surfaces=srf, k=TOP_K,
        exclude_ids=np.unique(i)) for i, a, srf, _ in users])
    probs = engine.score([RankRequest(
        seq_ids=i, seq_actions=a, seq_surfaces=srf, cand_ids=ids,
        cand_feats=item_features(ids), user_feats=uf)
        for (i, a, srf, uf), (ids, _) in zip(users, retrieved)])
    for r, (ids, scores), p in zip(results, retrieved, probs):
        np.testing.assert_array_equal(r.item_ids, ids)
        np.testing.assert_array_equal(r.retrieval_scores, scores)
        np.testing.assert_array_equal(r.probs, p)
    print(f"parity: fused results == sequential retrieve()+score() "
          f"bit-for-bit ({len(reqs)} requests)")

    # -- mixed-workload flush: rank + retrieve + two-stage, shared encode ---
    fresh = ServingEngine(model, params, max_unique=4,
                          max_candidates=4 * TOP_K,
                          cache=ContextCache(capacity=1024))
    fresh.attach_index(index, k=TOP_K, chunk_rows=2048)
    fresh.attach_features(item_features)
    fresh.warmup()
    i, a, srf, uf = users[0]                       # ONE user, three lanes
    cand = np.arange(TOP_K, dtype=np.int64)
    mixed = [RankRequest(seq_ids=i, seq_actions=a, seq_surfaces=srf,
                         cand_ids=cand, cand_feats=item_features(cand),
                         user_feats=uf),
             RetrieveRequest(seq_ids=i, seq_actions=a, seq_surfaces=srf,
                             k=TOP_K),
             RetrieveThenRankRequest(seq_ids=i, seq_actions=a,
                                     seq_surfaces=srf, user_feats=uf,
                                     k=TOP_K)]
    futs = fresh.submit_many(mixed)
    fresh.flush()
    for f in futs:
        f.result()
    snap = fresh.stats()
    print(f"mixed flush: lanes {snap['lanes']} shared one encode pass — "
          f"{snap['shared_encode_users']} unique user(s) encoded for "
          f"{len(mixed)} requests across 3 lanes "
          f"(cache {snap['cache']['hits']} hits / "
          f"{snap['cache']['misses']} misses, recompiles "
          f"{snap['executors']['compiles_after_warmup']})")
    assert snap["shared_encode_users"] == 1
    assert snap["executors"]["compiles_after_warmup"] == 0

    # -- observability export: the whole session as one trace + metrics ----
    if TRACE_OUT:
        engine.obs.export_trace(TRACE_OUT)
        engine.obs.export_prometheus(TRACE_OUT + ".prom")
        n_ev = len(engine.obs.chrome_trace()["traceEvents"])
        print(f"trace: {n_ev} events -> {TRACE_OUT} (Perfetto-loadable), "
              f"metrics -> {TRACE_OUT}.prom (per-lane p50/p99 flush "
              "latency, cache/memo counters)")


if __name__ == "__main__":
    main()
