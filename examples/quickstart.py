"""Quickstart: the three PinFM mechanisms in ~a minute on CPU.

1. Pretrain a tiny PinFM on a synthetic activity stream (InfoNCE losses).
2. Score candidates with DCAT and verify it matches full self-attention.
3. Quantize the id-embedding tables to int4 and check the error matches
   the paper's §4.2 numbers.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import smoke_config
from repro.core.dcat import DCAT, dedup
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.data.synthetic import DataConfig, SyntheticActivity
from repro.models.config import get_config
from repro.quant import quantize_table, relative_l2_error
from repro.training.optim import AdamWConfig, adamw_init
from repro.training.train import make_train_step, train_loop

print("== 1. pretraining a tiny PinFM (L_ntl + L_mtl + L_ftl) ==")
data = SyntheticActivity(DataConfig(n_users=200, n_items=800, seq_len=32))
pcfg = PinFMConfig(rows=2048, n_tables=2, sub_dim=16, seq_len=32,
                   loss=LossConfig(window=4, downstream_len=16, n_negatives=0))
bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2, d_model=64,
                                                   d_ff=128)
model = PinFMPretrain(pcfg, bb)
params = model.init(jax.random.PRNGKey(0))
opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=40)
step = jax.jit(make_train_step(model.loss, opt_cfg))
params, _, hist = train_loop(step, params, adamw_init(params),
                             data.pretrain_batches(8, 40), log_every=10)
print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

print("\n== 2. DCAT: dedup context + crossing == full self-attention ==")
batch = next(data.ranking_batches(3, 4, 1))
seqs = batch["seq_ids"]
uniq, inv_u = dedup(np.repeat(seqs, 4, axis=0))    # simulate duplicated batch
print(f"   Ψ: {len(inv_u)} rows -> {len(uniq)} unique (ratio "
      f"{len(inv_u) / len(uniq):.0f}:1)")
x_u = model.input_tokens(params, jnp.asarray(uniq),
                         jnp.repeat(batch["seq_actions"], 1, 0),
                         batch["seq_surfaces"])
x_c = model.phi_in(params["phi_in"],
                   model.id_embed(params["id_embed"],
                                  jnp.asarray(batch["cand_ids"])))[:, None]
dcat = DCAT(model.body)
_, _, ctxs = dcat.context(params["body"], x_u)
y_dcat, _ = dcat.crossing(params["body"], x_c, batch["inverse_idx"], ctxs,
                          ctx_len=32)
y_ref, _ = dcat.reference_scores(params["body"], x_u, x_c,
                                 batch["inverse_idx"])
print(f"   max |DCAT - full| = {float(jnp.max(jnp.abs(y_dcat - y_ref))):.2e}")

print("\n== 3. int4/int8 PTQ of the id-embedding tables (paper §4.2) ==")
table = params["id_embed"]["tables"].reshape(-1, pcfg.sub_dim)
for bits, paper in ((8, "0.45%"), (4, "7.8%")):
    qt = quantize_table(table, bits)
    err = relative_l2_error(table, qt)
    print(f"   int{bits}: rel-L2 {err * 100:.2f}%  (paper: {paper}), "
          f"size {qt.nbytes / (table.size * 2) * 100:.2f}% of fp16")
print("\nquickstart OK")
