"""Serving demo (paper §4.3 / Figure 2): the serving engine receives
ranking requests through its async ``submit()`` front door, deduplicates
user sequences (Ψ), serves int4-quantized embedding rows, and scores
candidates through DCAT crossing.

The engine is layered: a RequestScheduler (coalescing + futures behind
``submit``/``submit_many``), a BatchPlan builder (Ψ + shape buckets), an
ExecutorRegistry (one jitted fn per variant×bucket, precompiled by
``warmup()``), and a ContextCache holding per-user context KV so
repeat-user traffic skips the context transformer entirely.
``engine.score`` remains as the batch shim over the same path.  The
final section demos SLO scheduling: a per-lane latency budget shedding a
low-priority request with a typed ``ShedError`` while a protected
priority rides the same flush to a real score.

Run:  PYTHONPATH=src python examples/serve_ranking.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import (data_cfg, default_fcfg, pinfm_cfg,
                               small_ranking_model)
from repro.core.dcat import DCATOptions
from repro.data.synthetic import SyntheticActivity
from repro.quant import quantize_table, quantized_lookup, relative_l2_error
from repro.serving import (ContextCache, LanePolicy, RankRequest,
                           ServingEngine, ShedError)


def main():
    data = SyntheticActivity(data_cfg())
    pcfg = pinfm_cfg()
    fcfg = default_fcfg(
        dcat=DCATOptions(rotate_replace=False, skip_last_self_attn=True))
    model = small_ranking_model(pcfg, fcfg)
    params = model.init(jax.random.PRNGKey(0))

    # -- int4 PTQ of the embedding tables, served from the "CPU host" ------
    tables = params["pinfm"]["id_embed"]["tables"]
    flat = tables.reshape(-1, pcfg.sub_dim)
    qt = quantize_table(flat, bits=4)
    print(f"quantized tables: {flat.size * 4 / 2**20:.1f} MiB fp32 -> "
          f"{qt.nbytes / 2**20:.1f} MiB int4 "
          f"(rel-L2 {relative_l2_error(flat, qt) * 100:.1f}%)")
    deq = quantized_lookup(qt, jnp.arange(flat.shape[0]),
                           use_kernel=True).reshape(tables.shape)
    params["pinfm"]["id_embed"]["tables"] = deq.astype(tables.dtype)

    # -- the engine: context-KV cache + precompiled shape buckets -----------
    engine = ServingEngine(model, params, max_unique=4, max_candidates=32,
                           cache=ContextCache(capacity=1024))
    tel = engine.warmup()
    print(f"warmup: {tel['executors']} executors precompiled in "
          f"{tel['warmup_s']:.1f}s")

    rng = np.random.RandomState(0)
    L = pcfg.seq_len

    def mk_request(user_seed):
        r = np.random.RandomState(user_seed)
        return RankRequest(
            seq_ids=r.randint(0, 1500, L),
            seq_actions=r.randint(0, 6, L),
            seq_surfaces=r.randint(0, 3, L),
            cand_ids=rng.randint(0, 1500, 5),
            cand_feats=rng.randn(5, fcfg.cand_feat_dim).astype(np.float32),
            user_feats=r.randn(fcfg.user_feat_dim).astype(np.float32),
            graphsage=rng.randn(5, fcfg.graphsage_dim).astype(np.float32))

    # -- submit(): async front door, one future per request -----------------
    # 6 requests, 3 distinct users (duplicates dedup via Ψ); they coalesce
    # in the engine's scheduler until a flush serves them as ONE batch
    futures = [engine.submit(mk_request(s)) for s in (1, 2, 3, 1, 2, 1)]
    engine.flush()
    probs = [f.result() for f in futures]
    stats = engine.call_stats[-1]
    print(f"scored {stats['candidates']} candidates for "
          f"{stats['unique_users']} unique users "
          f"(dedup ratio {stats['dedup_ratio']:.1f}:1) "
          f"in {stats['latency_s'] * 1e3:.1f} ms "
          f"(bucket {stats['b_u']}x{stats['b_c']}, "
          f"recompiles {stats['exec_compiles_after_warmup']})")
    print(f"request 0 save-probabilities: {np.round(probs[0][:, 0], 3)}")

    # repeat traffic: pure ContextCache hits -> no context transformer;
    # engine.score is the batch shim over the same submit_many path
    engine.score([mk_request(s) for s in (1, 2, 3, 1, 2, 1)])
    stats = engine.call_stats[-1]
    print(f"repeat pass: {stats['latency_s'] * 1e3:.1f} ms, "
          f"cache {engine.cache.hits} hits / {engine.cache.misses} misses "
          f"({engine.cache.nbytes / 2**10:.0f} KiB ctx KV cached)")

    # one read-atomic telemetry snapshot for everything above
    snap = engine.stats()
    print(f"stats(): {snap['scheduler']['coalesced']} requests in "
          f"{snap['scheduler']['flushes']} flush(es), lanes {snap['lanes']}, "
          f"{snap['executors']['compiles_after_warmup']} recompiles")

    # -- SLO scheduling: per-lane policies, priorities, typed shedding ------
    # a rank lane with a 0 ms latency budget sheds every priority-0
    # request at flush pickup (its future carries a typed ShedError —
    # never a silent drop), while priority-1 requests are shed-exempt
    # and ride the same flush to a real score
    slo = ServingEngine(model, params, max_unique=4, max_candidates=32,
                        cache=ContextCache(capacity=1024),
                        lane_policies={"rank": LanePolicy(
                            shed_ms=0.0, shed_max_priority=0)})
    slo.warmup()
    f_shed = slo.submit(mk_request(7))                        # priority 0
    req = mk_request(8)
    req.priority = 1                                          # protected
    f_kept = slo.submit(req)
    slo.flush()
    try:
        f_shed.result()
    except ShedError as e:
        print(f"shed: lane={e.lane} reason={e.reason} "
              f"waited {e.wait_ms:.2f} ms against a {e.budget_ms:.0f} ms "
              f"budget at priority {e.priority}")
    print(f"protected request served: "
          f"{np.round(f_kept.result()[:, 0], 3)}")
    lane = slo.stats()["scheduler"]["lane_detail"]["rank"]
    print(f"rank lane: {lane['shed']} shed, "
          f"{lane['deadline_misses']} deadline miss(es), "
          f"wait {lane['wait_ms']:.1f} ms")


if __name__ == "__main__":
    main()
