"""End-to-end driver (deliverable b): pretrain PinFM on the synthetic
activity stream for a few hundred steps, fine-tune the Home-Feed-style
ranking model with early fusion + cold-start techniques, evaluate HIT@3
lifts vs a no-PinFM baseline, and write checkpoints.

Run:  PYTHONPATH=src python examples/pretrain_finetune.py [--steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

import jax

from benchmarks.common import (baseline_eval, data_cfg, default_fcfg,
                               finetune_and_eval, lift, pinfm_cfg, pretrain)
from repro.data.synthetic import SyntheticActivity
from repro.training.checkpoint import save_checkpoint


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--outdir", default="experiments/e2e")
    args = ap.parse_args()

    data = SyntheticActivity(data_cfg())
    pcfg = pinfm_cfg()

    print(f"== pretraining PinFM for {args.steps} steps ==")
    model, pre_params, hist = pretrain(pcfg, steps=args.steps, data=data)
    print(f"   InfoNCE: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    save_checkpoint(os.path.join(args.outdir, "pinfm_pretrained"),
                    pre_params, step=args.steps)

    print("== training the no-PinFM baseline ranker ==")
    base = baseline_eval(data=data)
    print(f"   baseline save HIT@3: overall {base['save_overall']:.4f}, "
          f"fresh {base['save_fresh']:.4f}")

    print("== fine-tuning the ranking model with PinFM "
          "(graphsage-lt + CIR + IDD) ==")
    metrics, ft_params = finetune_and_eval(
        pcfg, default_fcfg(), pre_params, steps=args.steps, data=data)
    save_checkpoint(os.path.join(args.outdir, "ranking_finetuned"),
                    ft_params, step=args.steps)

    print("\n== results (HIT@3 Save) ==")
    print(f"   overall: {metrics['save_overall']:.4f} "
          f"({lift(metrics['save_overall'], base['save_overall']):+.1f}% "
          f"vs baseline; paper HF: +3.76%)")
    print(f"   fresh:   {metrics['save_fresh']:.4f} "
          f"({lift(metrics['save_fresh'], base['save_fresh']):+.1f}% "
          f"vs baseline; paper HF 28d: +17.7%)")
    print(f"   checkpoints in {args.outdir}/")


if __name__ == "__main__":
    main()
