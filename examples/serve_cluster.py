"""Cluster serving demo: N subprocess engine workers behind one
affinity-routing ``ClusterRouter``.

Each :class:`~repro.cluster.SubprocessWorker` spawns a child process that
builds its OWN engine (model init is deterministic, so every worker holds
identical weights — nothing heavyweight crosses the pipe).  The router is
the engine's ``submit`` contract one tier up:

  * rank traffic routes to each user's rendezvous (HRW) owner, so a
    repeat user always lands on the worker whose ContextCache already
    holds their encoded sequence — the second wave below is pure cache
    hits on every worker;
  * retrieval scatter/gathers: each worker owns one contiguous-row shard
    of the quantized corpus and runs the engine's own chunk executors
    over it; the router merges partials with the retrieval stack's
    lower-index-wins contract, so cluster results are BIT-IDENTICAL to a
    single engine serving the whole index (asserted below);
  * killing a worker never hangs a future: in-flight requests re-route
    to the survivors, the corpus re-shards, and traffic keeps matching
    the single-engine reference (also asserted).

With ``--obs-out DIR`` the run additionally exports each worker's
metrics snapshot (``obs_snapshot`` RPC) as JSON plus the cluster-wide
Prometheus exposition produced by ``tools/dump_obs.py --merge`` — the
offline half of :meth:`ClusterRouter.merged_metrics`.

Run:  PYTHONPATH=src python examples/serve_cluster.py [--smoke]
          [--obs-out /tmp/cluster_obs]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

import numpy as np

SMOKE = "--smoke" in sys.argv
OBS_OUT = (sys.argv[sys.argv.index("--obs-out") + 1]
           if "--obs-out" in sys.argv else None)
N_ITEMS = 1024 if SMOKE else 4096
TOP_K = 8
N_USERS = 8 if SMOKE else 24
N_WORKERS = 2
MAX_UNIQUE = 4          # engine rank grouping == router fan-out ladder cap


def build_model():
    """Deterministic tiny ranking model — same bytes in every process."""
    import jax
    from benchmarks.common import default_fcfg, pinfm_cfg, \
        small_ranking_model
    pcfg = pinfm_cfg()
    fcfg = default_fcfg(variant="lite-last")       # late fusion: cacheable
    model = small_ranking_model(pcfg, fcfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params, fcfg


def item_features(item_ids, dim=8):
    """Feature-store stand-in: the same id always produces the same
    bytes, so every process ranks identical inputs."""
    return np.stack([np.random.RandomState(int(i) % 99991).randn(dim)
                     for i in np.asarray(item_ids)]).astype(np.float32)


def make_engine():
    from repro.serving import ContextCache, ServingEngine
    model, params, fcfg = build_model()
    engine = ServingEngine(model, params, max_unique=MAX_UNIQUE,
                           max_candidates=4 * TOP_K,
                           cache=ContextCache(capacity=512))
    engine.attach_features(item_features)
    return engine


def make_core():
    """Top-level picklable factory: each spawned child builds its own
    engine locally (``SubprocessWorker`` ships the factory, not state)."""
    from repro.cluster import WorkerCore
    return WorkerCore(make_engine())


def main():
    from repro.cluster import ClusterRouter, SubprocessWorker
    from repro.retrieval import IndexBuilder
    from repro.serving import RankRequest, RetrieveRequest

    model, params, fcfg = build_model()
    L = fcfg.seq_len
    index = IndexBuilder(model, params, batch_size=1024, bits=4) \
        .build(start_id=0, n_items=N_ITEMS)

    print(f"starting {N_WORKERS} subprocess workers "
          "(each builds its own engine)...")
    workers = {f"w{i}": SubprocessWorker(f"w{i}", make_core)
               for i in range(N_WORKERS)}
    router = ClusterRouter(workers, fanout_unique=MAX_UNIQUE)
    router.attach_index(index, k=TOP_K, chunk_rows=2048)
    router.attach_features(item_features)
    tel = router.warmup()
    print("warmup: " + ", ".join(
        f"{n}: {t['executors']} executors in {t['warmup_s']:.1f}s"
        for n, t in sorted(tel.items())))

    # the single-engine reference the cluster must match bit for bit
    ref = make_engine()
    ref.attach_index(index, k=TOP_K, chunk_rows=2048)
    ref.warmup()

    def user(seed):
        r = np.random.RandomState(seed)
        return (r.randint(0, N_ITEMS, L), r.randint(0, 6, L),
                r.randint(0, 3, L),
                r.randn(fcfg.user_feat_dim).astype(np.float32))

    def rank_req(seed):
        i, a, srf, uf = user(seed)
        r = np.random.RandomState(1000 + seed)
        ids = r.randint(0, N_ITEMS, 3)
        return RankRequest(seq_ids=i, seq_actions=a, seq_surfaces=srf,
                           cand_ids=ids, cand_feats=item_features(ids),
                           user_feats=uf)

    # -- affinity: repeat users land on the worker holding their cache --
    rank_reqs = [rank_req(s) for s in range(N_USERS)]
    owners = [router.owner_of(r) for r in rank_reqs]
    for wave in (1, 2):
        futs = router.submit_many(rank_reqs)
        router.flush()
        probs = [f.result() for f in futs]
    per_worker = router.stats()["per_worker"]
    hits = {n: s["engine"]["cache"]["hits"] for n, s in per_worker.items()}
    print(f"affinity: {N_USERS} users -> "
          + ", ".join(f"{n}: {owners.count(n)} owned, "
                      f"{hits[n]} cache hits" for n in sorted(hits)))
    ref_probs = ref.score(rank_reqs)
    for p, rp in zip(probs, ref_probs):
        np.testing.assert_array_equal(p, rp)
    print("parity: cluster rank results == single engine bit-for-bit")

    # -- retrieval fan-out: shard scatter/gather == whole-corpus scan ---
    ret_reqs = [RetrieveRequest(seq_ids=i, seq_actions=a, seq_surfaces=srf,
                                k=TOP_K, exclude_ids=np.unique(i))
                for i, a, srf, _ in (user(100 + s) for s in range(N_USERS))]
    futs = router.submit_many(ret_reqs)
    router.flush()
    got = [f.result() for f in futs]
    want = ref.retrieve(ret_reqs)
    for (ids, scores), (rids, rscores) in zip(got, want):
        np.testing.assert_array_equal(ids, rids)
        np.testing.assert_array_equal(scores, rscores)
    st = router.stats()
    print(f"fan-out: top-{TOP_K} of {N_ITEMS} items across "
          f"{st['n_alive']} shards ({st['rows_per_shard']} rows each), "
          f"{st['fanout_groups']} dispatch groups — results bit-identical "
          "to the single-engine scan")
    for name, w in workers.items():
        assert w.call("compiles_after_warmup") == 0, name
    print("zero post-warmup compiles on every worker")

    if OBS_OUT:        # per-worker snapshots + the offline merge
        os.makedirs(OBS_OUT, exist_ok=True)
        paths = []
        for name, w in workers.items():
            import json
            p = os.path.join(OBS_OUT, f"{name}.json")
            with open(p, "w") as f:
                json.dump(w.call("obs_snapshot"), f)
            paths.append(p)
        import subprocess
        merged = os.path.join(OBS_OUT, "cluster.prom")
        tool = os.path.join(os.path.dirname(__file__), "..", "tools",
                            "dump_obs.py")
        subprocess.run([sys.executable, tool, "--merge", *paths,
                        "-o", merged], check=True, stdout=subprocess.DEVNULL)
        print(f"observability: per-worker snapshots + merged exposition "
              f"in {OBS_OUT}/")

    # -- kill one worker: futures drain, traffic re-routes --------------
    victim = sorted(workers)[-1]
    futs = router.submit_many(rank_reqs + ret_reqs)
    router.kill_worker(victim)
    router.flush()
    out = [f.result() for f in futs]       # never hangs, never poisoned
    for p, rp in zip(out[:N_USERS], ref_probs):
        np.testing.assert_array_equal(p, rp)
    for (ids, scores), (rids, rscores) in zip(out[N_USERS:], want):
        np.testing.assert_array_equal(ids, rids)
        np.testing.assert_array_equal(scores, rscores)
    st = router.stats()
    assert router.check_health() == [] and st["n_alive"] == N_WORKERS - 1
    print(f"drain: killed {victim} with {len(futs)} requests in flight — "
          f"all resolved bit-identically on the survivors "
          f"(reroutes={st['reroutes']}, deaths={st['deaths']})")

    router.close()
    print("OK")


if __name__ == "__main__":
    main()
