"""Two-stage serving demo: filtered corpus retrieval feeding the ranking
engine, plus a live index refresh.

Stage 1 — candidate generation: the user's pooled PinFM embedding (lite
variant, ContextCache-shared with ranking) is scored against an int4-packed
ItemIndex of the WHOLE item corpus; the engine's bucketed corpus-chunk
executors return the exact top-k item ids.  Each request also carries the
user's already-seen items as ``exclude_ids`` (and optionally an
``allow_surfaces`` constraint) — the engine packs them into per-chunk
bitmasks so seen items can never be retrieved again.

Stage 2 — ranking: the retrieved ids become the candidate set of a
RankRequest and go through the usual scoring path (same engine, same cache,
so the user's embedding is encoded exactly once across both stages).

Refresh — new items are appended to the index with ``IndexBuilder.append``
(only the new rows are quantized) and re-attached to the warmed engine with
ZERO new XLA compiles; the fresh items are immediately retrievable.

IVF route — the corpus is clustered (``build_ivf``) and re-attached; a
``RetrieveRequest(route="ivf", nprobe=...)`` then scans only the probed
clusters through the same scorer machinery, side by side with exact
requests in one flush.

Run:  PYTHONPATH=src python examples/retrieve_topk.py [--smoke]
"""
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

import numpy as np
import jax

from benchmarks.common import default_fcfg, pinfm_cfg, small_ranking_model
from repro.retrieval import IndexBuilder, build_ivf
from repro.serving import (ContextCache, RankRequest, RetrieveRequest,
                           ServingEngine)

SMOKE = "--smoke" in sys.argv
N_ITEMS = 1024 if SMOKE else 4096
N_NEW = 256 if SMOKE else 1024
TOP_K = 8 if SMOKE else 16
N_SURFACES = 3


def main():
    pcfg = pinfm_cfg()
    fcfg = default_fcfg(variant="lite-last")       # late fusion: cacheable
    model = small_ranking_model(pcfg, fcfg)
    params = model.init(jax.random.PRNGKey(0))
    L = fcfg.seq_len

    # -- stage 0: build the int4 item index from the candidate tower -------
    builder = IndexBuilder(model, params, batch_size=1024, bits=4)
    surfaces = np.arange(N_ITEMS) % N_SURFACES     # per-item surface tag
    index = builder.build(start_id=0, n_items=N_ITEMS, surfaces=surfaces)
    fp32_bytes = N_ITEMS * index.dim * 4
    print(f"item index: {N_ITEMS} items x {index.dim} dims, "
          f"{index.nbytes / 2**10:.0f} KiB int4 "
          f"({index.nbytes / fp32_bytes * 100:.1f}% of fp32)")

    engine = ServingEngine(model, params, max_unique=4,
                           max_candidates=4 * TOP_K,
                           cache=ContextCache(capacity=1024))
    engine.attach_index(index, k=TOP_K, chunk_rows=2048)
    tel = engine.warmup()
    print(f"warmup: {tel['executors']} executors precompiled in "
          f"{tel['warmup_s']:.1f}s")

    rng = np.random.RandomState(0)

    def user_seq(seed):
        r = np.random.RandomState(seed)
        return (r.randint(0, N_ITEMS, L), r.randint(0, 6, L),
                r.randint(0, 3, L))

    # -- stage 1: filtered retrieval ---------------------------------------
    # each user excludes their own sequence ids (already-seen items);
    # user 2 additionally only accepts surface-0 items
    users = [user_seq(s) for s in (1, 2, 3)]
    reqs = [RetrieveRequest(seq_ids=i, seq_actions=a, seq_surfaces=srf,
                            k=TOP_K, exclude_ids=np.unique(i))
            for i, a, srf in users]
    reqs[2] = dataclasses.replace(reqs[2], allow_surfaces=(0,))
    retrieved = engine.retrieve(reqs)
    stats = engine.call_stats[-1]
    print(f"retrieved top-{TOP_K} of {stats['corpus_items']} items for "
          f"{stats['retrieve_users']} users "
          f"({stats['filtered_users']} filtered) in "
          f"{stats['latency_s'] * 1e3:.1f} ms "
          f"({stats['corpus_chunks']} corpus chunks, "
          f"recompiles {stats['exec_compiles_after_warmup']})")
    for u, (ids, scores) in enumerate(retrieved):
        seen = np.isin(ids, np.unique(users[u][0])).sum()
        print(f"  user {u}: items {ids[:5]}... "
              f"scores {np.round(scores[:5], 3)} (seen-overlap: {seen})")
        assert seen == 0, "a seen item leaked through the filter"
    assert (retrieved[2][0] % N_SURFACES == 0).all(), \
        "surface constraint violated"

    # -- stage 2: rank the retrieved candidates (cache hit on the user) ----
    requests = [RankRequest(
        seq_ids=i, seq_actions=a, seq_surfaces=srf, cand_ids=ids,
        cand_feats=rng.randn(len(ids), fcfg.cand_feat_dim).astype(np.float32),
        user_feats=rng.randn(fcfg.user_feat_dim).astype(np.float32))
        for (i, a, srf), (ids, _) in zip(users, retrieved)]
    probs = engine.score(requests)
    stats = engine.call_stats[-1]
    print(f"ranked {stats['candidates']} retrieved candidates in "
          f"{stats['latency_s'] * 1e3:.1f} ms — cache "
          f"{engine.cache.hits} hits / {engine.cache.misses} misses "
          f"(users encoded once across retrieve+rank)")
    order = np.argsort(-probs[0][:, 0])
    print(f"user 0 final ranking (by save-prob): items "
          f"{retrieved[0][0][order][:5]} "
          f"p={np.round(probs[0][order, 0][:5], 3)}")

    # -- refresh: append new items, re-attach, retrieve them ---------------
    grown = builder.append(index, N_NEW,
                           surfaces=np.arange(N_NEW) % N_SURFACES)
    engine.attach_index(grown, k=TOP_K, chunk_rows=2048)
    fresh_only = engine.retrieve([RetrieveRequest(
        seq_ids=users[0][0], seq_actions=users[0][1],
        seq_surfaces=users[0][2], k=TOP_K,
        exclude_ids=np.arange(N_ITEMS))])[0]     # old corpus excluded
    assert (fresh_only[0] >= N_ITEMS).all()
    print(f"refresh: appended {N_NEW} items "
          f"({grown.n_items} total, only new rows quantized), "
          f"re-attach recompiles: "
          f"{engine.registry.compiles_after_warmup} — fresh items "
          f"{fresh_only[0][:5]}... retrievable immediately")

    # -- IVF-ANN route: cluster the corpus, probe a handful of clusters ----
    n_clusters = max(8, grown.n_items // 80)
    ividx = build_ivf(grown, n_clusters, seed=0)
    engine.attach_index(ividx, k=TOP_K, chunk_rows=2048, ivf_nprobe=4,
                        ivf_widen=2)
    engine.warmup()
    i, a, srf = users[0]
    exact_req = RetrieveRequest(seq_ids=i, seq_actions=a, seq_surfaces=srf,
                                k=TOP_K)
    ann_req = dataclasses.replace(exact_req, route="ivf")
    (ann_ids, _), (exact_ids, _) = engine.retrieve([ann_req, exact_req])
    ivf_stats = engine.stats()["retrieval"]["ivf"]
    overlap = len(set(ann_ids.tolist()) & set(exact_ids.tolist())) / TOP_K
    print(f"ivf route: {n_clusters} clusters, probed "
          f"{ivf_stats['clusters_probed']} — scanned "
          f"{ivf_stats['rows_scanned']} of {grown.n_items} rows, "
          f"recall@{TOP_K} vs exact in the same flush: {overlap:.2f} "
          f"(recompiles {engine.registry.compiles_after_warmup})")
    assert engine.registry.compiles_after_warmup == 0


if __name__ == "__main__":
    main()
