"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dcat_attention import dcat_cross_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int4_dequant import dequant_embedding
from repro.kernels import ref as kref
from repro.quant import quantize_table


@pytest.mark.parametrize("B,S,H,K,D", [
    (2, 128, 4, 2, 64), (1, 256, 4, 4, 64), (2, 100, 4, 1, 32),
    (1, 64, 8, 8, 128), (2, 192, 2, 1, 16),
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 64),
                                           (False, None)])
def test_flash_attention_sweep(B, S, H, K, D, causal, window):
    key = jax.random.PRNGKey(S + H)
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D))
    out = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    ref = kref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 64, 2, 32)).astype(dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 64, 2, 32)).astype(dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 64, 2, 32)).astype(dtype)
    out = flash_attention(q, k, v, bq=32, bk=32)
    ref = kref.flash_attention_ref(q, k, v)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=2e-2)


@pytest.mark.parametrize("B,Bu,L,SC,H,K,D", [
    (8, 3, 256, 2, 4, 2, 64), (16, 2, 100, 1, 8, 8, 32),
    (4, 4, 64, 2, 2, 1, 128), (32, 2, 256, 1, 4, 4, 64),
])
def test_dcat_kernel_sweep(B, Bu, L, SC, H, K, D):
    key = jax.random.PRNGKey(B + L)
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, SC, H, D))
    ku = jax.random.normal(ks[1], (Bu, L, K, D))
    vu = jax.random.normal(ks[2], (Bu, L, K, D))
    kc = jax.random.normal(ks[3], (B, SC, K, D))
    vc = jax.random.normal(ks[4], (B, SC, K, D))
    inv = jnp.asarray(np.random.RandomState(0).randint(0, Bu, B), jnp.int32)
    out = dcat_cross_attention(q, ku, vu, kc, vc, inv, bl=64)
    ref = kref.dcat_cross_attention_ref(q, ku, vu, kc, vc, inv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_dcat_kernel_every_candidate_sees_its_own_user():
    """Make user contexts wildly different; outputs must track inv exactly."""
    Bu, L, H, K, D = 4, 32, 2, 2, 16
    ku = jnp.stack([jnp.full((L, K, D), float(u)) for u in range(Bu)])
    vu = ku
    q = jnp.ones((Bu * 2, 1, H, D))
    kc = jnp.zeros((Bu * 2, 1, K, D))
    vc = jnp.zeros((Bu * 2, 1, K, D))
    inv = jnp.asarray([0, 1, 2, 3, 3, 2, 1, 0], jnp.int32)
    out = dcat_cross_attention(q, ku, vu, kc, vc, inv, bl=32)
    ref = kref.dcat_cross_attention_ref(q, ku, vu, kc, vc, inv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("R,D", [(100, 32), (513, 32), (64, 64), (8, 256)])
def test_dequant_kernel_sweep(bits, R, D):
    key = jax.random.PRNGKey(R)
    table = 0.05 * jax.random.normal(key, (R, D))
    qt = quantize_table(table, bits)
    out = dequant_embedding(qt.packed, qt.scale, qt.bias, bits=bits,
                            rows_per_block=128)
    ref = (kref.int4_dequant_ref if bits == 4 else kref.int8_dequant_ref)(
        qt.packed, qt.scale, qt.bias)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("B,S,H,P,G,N,chunk", [
    (2, 128, 4, 8, 2, 16, 32), (1, 64, 2, 16, 1, 8, 16),
    (2, 256, 8, 64, 1, 128, 64), (1, 96, 4, 32, 4, 16, 32),
])
def test_ssd_scan_kernel_sweep(B, S, H, P, G, N, chunk):
    from repro.kernels.ssd_scan import ssd_scan
    from repro.nn.ssd import ssd_chunked
    key = jax.random.PRNGKey(S + H)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y, h = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, hr = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=5e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr), atol=5e-5)


def test_ssd_scan_kernel_bf16():
    from repro.kernels.ssd_scan import ssd_scan
    from repro.nn.ssd import ssd_chunked
    key = jax.random.PRNGKey(9)
    ks = jax.random.split(key, 5)
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 16
    x = jax.random.normal(ks[0], (B, S, H, P)).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y, _ = ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    yr, _ = ssd_chunked(x.astype(jnp.float32), dt, A, Bm, Cm, chunk=16)
    assert y.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr),
                               atol=0.15)
