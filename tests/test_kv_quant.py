"""int8 KV-cache quantization (beyond-paper §Perf extension): ring-buffer
parity with the fp cache and bounded decode-output error."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.attention import Attention, KVCache, attend5
from repro.quant import QuantizedKVCache


def test_ring_semantics_match_fp_cache():
    B, size, K, D = 2, 4, 2, 8
    fp = KVCache.zeros(B, size, K, D, jnp.float32)
    q8 = QuantizedKVCache.zeros(B, size, K, D, jnp.float32)
    key = jax.random.PRNGKey(0)
    for t in range(7):
        kn = jax.random.normal(jax.random.fold_in(key, t), (B, 1, K, D))
        fp = fp.update(kn, kn)
        q8 = q8.update(kn, kn)
    np.testing.assert_array_equal(np.asarray(fp.pos), np.asarray(q8.pos))
    p1, v1 = fp.slot_positions()
    p2, v2 = q8.slot_positions()
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    # dequantized contents close to fp contents
    err = float(jnp.max(jnp.abs(fp.k - q8.k)))
    assert err < 0.05


def test_decode_output_error_bounded_and_memory_halved():
    key = jax.random.PRNGKey(1)
    att = Attention(64, 4, 2, 16, rope=True)
    p = att.init(key)
    B, S = 2, 24
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, S, 64))
    fp = KVCache.zeros(B, 32, 2, 16, jnp.float32)
    q8 = QuantizedKVCache.zeros(B, 32, 2, 16, jnp.float32)
    outs_fp, outs_q8 = [], []
    for t in range(S):
        pos = jnp.full((B, 1), t, jnp.int32)
        q, k, v = att.qkv(p, x[:, t:t + 1], pos)
        fp = fp.update(k, v)
        q8 = q8.update(k, v)
        for cache, outs in ((fp, outs_fp), (q8, outs_q8)):
            kp, kv = cache.slot_positions()
            o = attend5(q, cache.k, cache.v, q_pos=pos, k_pos=kp,
                        causal=True, k_valid=kv)
            outs.append(att.out(p, o))
    a = np.asarray(jnp.concatenate(outs_fp, 1))
    b = np.asarray(jnp.concatenate(outs_q8, 1))
    rel = np.linalg.norm(a - b) / np.linalg.norm(a)
    assert rel < 0.01, rel          # <1% relative L2 on attention outputs
    fp_bytes = fp.k.size * 4 * 2
    assert q8.nbytes < 0.35 * fp_bytes   # int8 + scales vs fp32
