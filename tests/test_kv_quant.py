"""int8 KV-cache quantization (beyond-paper §Perf extension): ring-buffer
parity with the fp cache, bounded decode-output error, and direct unit
tests of the quantize/dequantize primitives the serving KV slab reuses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.attention import Attention, KVCache, attend5
from repro.quant import QuantizedKVCache, dequantize_kv, quantize_kv


# ---------------------------------------------------------------------------
# quantize_kv / dequantize_kv primitives (shared with serving/kv_slab.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,wq,tol", [(8, 64, 1 / 127), (4, 32, 1 / 7)])
def test_round_trip_within_scale_tolerance(bits, wq, tol):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(5, 3, 64).astype(np.float32))
    codes, scale = quantize_kv(x, bits=bits)
    assert codes.shape == (5, 3, wq) and codes.dtype == jnp.int8
    assert scale.shape == (5, 3, 1) and scale.dtype == jnp.float16
    y = dequantize_kv(codes, scale, jnp.float32, bits=bits)
    # symmetric min-max: per-row error bounded by half a quantization step
    # (scale itself is fp16-rounded, so allow a full step)
    amax = np.max(np.abs(np.asarray(x)), axis=-1, keepdims=True)
    assert np.all(np.abs(np.asarray(y) - np.asarray(x)) <= tol * amax + 1e-6)


@pytest.mark.parametrize("bits", [8, 4])
def test_zero_row_scale_guard(bits):
    """An all-zero row quantizes to scale 0 and dequantizes to EXACT zeros
    (no 0/0 NaNs from the scale floor)."""
    x = jnp.zeros((2, 4, 16), jnp.float32)
    codes, scale = quantize_kv(x, bits=bits)
    assert float(jnp.max(jnp.abs(scale))) == 0.0
    y = dequantize_kv(codes, scale, jnp.float32, bits=bits)
    np.testing.assert_array_equal(np.asarray(y), np.zeros((2, 4, 16)))


def test_quantization_preserves_zero_and_sign():
    x = jnp.asarray([[0.0, 1.0, -1.0, 0.5]])
    codes, scale = quantize_kv(x, bits=8)
    c = np.asarray(codes)[0]
    assert c[0] == 0 and c[1] == 127 and c[2] == -127 and c[3] > 0


def test_ring_update_wraps_and_overwrites():
    """After size+1 updates the oldest slot is overwritten in place: slot
    (pos % size) holds the newest step, pos keeps counting monotonically."""
    B, size, K, D = 1, 3, 1, 8
    q8 = QuantizedKVCache.zeros(B, size, K, D, jnp.float32)
    steps = [jnp.full((B, 1, K, D), float(t + 1)) for t in range(size + 1)]
    for s in steps:
        q8 = q8.update(s, s)
    assert int(q8.pos[0]) == size + 1
    got = np.asarray(q8.k)[0, :, 0, 0]
    np.testing.assert_allclose(got, [size + 1.0, 2.0, 3.0], rtol=1e-2)
    kp, kv = q8.slot_positions()
    np.testing.assert_array_equal(np.asarray(kv)[0], [True] * size)
    np.testing.assert_array_equal(np.asarray(kp)[0], [3, 1, 2])


def test_nbytes_formula():
    B, size, K, D = 2, 16, 4, 32
    q8 = QuantizedKVCache.zeros(B, size, K, D, jnp.float32)
    n = B * size * K * D
    # k8 + v8 (1 byte each) + k_scale + v_scale (fp16, one per (slot, head))
    assert q8.nbytes == 2 * n + 2 * 2 * (n // D)
    fp = KVCache.zeros(B, size, K, D, jnp.float32)
    assert q8.nbytes / (fp.k.nbytes + fp.v.nbytes) < 0.27


def test_ring_semantics_match_fp_cache():
    B, size, K, D = 2, 4, 2, 8
    fp = KVCache.zeros(B, size, K, D, jnp.float32)
    q8 = QuantizedKVCache.zeros(B, size, K, D, jnp.float32)
    key = jax.random.PRNGKey(0)
    for t in range(7):
        kn = jax.random.normal(jax.random.fold_in(key, t), (B, 1, K, D))
        fp = fp.update(kn, kn)
        q8 = q8.update(kn, kn)
    np.testing.assert_array_equal(np.asarray(fp.pos), np.asarray(q8.pos))
    p1, v1 = fp.slot_positions()
    p2, v2 = q8.slot_positions()
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))
    # dequantized contents close to fp contents
    err = float(jnp.max(jnp.abs(fp.k - q8.k)))
    assert err < 0.05


def test_decode_output_error_bounded_and_memory_halved():
    key = jax.random.PRNGKey(1)
    att = Attention(64, 4, 2, 16, rope=True)
    p = att.init(key)
    B, S = 2, 24
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, S, 64))
    fp = KVCache.zeros(B, 32, 2, 16, jnp.float32)
    q8 = QuantizedKVCache.zeros(B, 32, 2, 16, jnp.float32)
    outs_fp, outs_q8 = [], []
    for t in range(S):
        pos = jnp.full((B, 1), t, jnp.int32)
        q, k, v = att.qkv(p, x[:, t:t + 1], pos)
        fp = fp.update(k, v)
        q8 = q8.update(k, v)
        for cache, outs in ((fp, outs_fp), (q8, outs_q8)):
            kp, kv = cache.slot_positions()
            o = attend5(q, cache.k, cache.v, q_pos=pos, k_pos=kp,
                        causal=True, k_valid=kv)
            outs.append(att.out(p, o))
    a = np.asarray(jnp.concatenate(outs_fp, 1))
    b = np.asarray(jnp.concatenate(outs_q8, 1))
    rel = np.linalg.norm(a - b) / np.linalg.norm(a)
    assert rel < 0.01, rel          # <1% relative L2 on attention outputs
    fp_bytes = fp.k.size * 4 * 2
    assert q8.nbytes < 0.35 * fp_bytes   # int8 + scales vs fp32
