"""Serving observability (``repro.obs``): histogram quantile math,
registry/export semantics, tracer ring buffer, the ExecutorRegistry
warmup-vs-telemetry atomicity regression, and engine integration.

Acceptance points covered:
  * histogram bucket-boundary exactness (a value exactly on an inclusive
    upper bound lands in that bound's bucket), empty/one-sample edges,
    merge + layout-mismatch rejection, and an 8-thread record hammer
    losing no counts;
  * Prometheus text exposition is well-formed (+Inf bucket == count,
    derived _p50/_p99) and the JSON snapshot runs collectors;
  * the tracer keeps the newest ``capacity`` events, counts drops, and
    exports loadable Chrome trace-event JSON;
  * warmup() marks executors warmed atomically with the executed
    bookkeeping — a concurrent telemetry reader never observes a phantom
    nonzero ``compiles_after_warmup`` (regression);
  * engine integration: ``stats()`` key set UNCHANGED by obs, per-lane
    histograms + request spans present after traffic, ``obs_enabled=False``
    scores bit-identically with empty exports, zero recompiles either way.
"""
import json
import math
import threading

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.dcat import DCAT
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.models.config import get_config
from repro.obs import (NULL_REGISTRY, NULL_TRACER, Histogram,
                       MetricsRegistry, Observability, Tracer)
from repro.retrieval import IndexBuilder
from repro.serving import (ContextCache, RankRequest, RetrieveRequest,
                           RetrieveThenRankRequest, ServingEngine)
from repro.serving.executors import ExecutorRegistry

L = 16
N_ITEMS = 300
TOP_K = 8
CAND_DIM = 32


# ---------------------------------------------------------------------------
# Histogram quantile math
# ---------------------------------------------------------------------------

def test_histogram_bucket_boundary_inclusive():
    """A value exactly equal to a bucket's inclusive upper bound counts in
    THAT bucket: quantile() reports the bound itself, not the next one."""
    h = Histogram(lo=1.0, hi=100.0, per_decade=1)     # bounds [1, 10, 100]
    assert h.bounds == [1.0, 10.0, 100.0]
    h.record(10.0)                                    # exactly on a bound
    assert h.quantile(0.5) == 10.0
    assert h.quantile(1.0) == 10.0
    h2 = Histogram(lo=1.0, hi=100.0, per_decade=1)
    h2.record(1.0)                                    # exactly lo
    assert h2.quantile(0.5) == 1.0
    h3 = Histogram(lo=1.0, hi=100.0, per_decade=1)
    h3.record(10.000001)                              # just over the bound
    assert h3.quantile(0.5) == 100.0


def test_histogram_under_and_overflow():
    h = Histogram(lo=1.0, hi=100.0, per_decade=1)
    h.record(0.001)                   # underflow -> first bucket (<= lo)
    assert h.quantile(0.5) == 1.0
    h.record(1e9)                     # overflow -> reported as top bound
    assert h.quantile(0.99) == 100.0
    assert h.count == 2


def test_histogram_empty_and_one_sample():
    h = Histogram()
    assert math.isnan(h.quantile(0.5))
    assert math.isnan(h.quantile(0.99))
    h.record(3.7)
    # one sample: every quantile is that sample's bucket bound
    assert h.quantile(0.5) == h.quantile(0.99) == h.quantile(1.0)
    assert h.quantile(0.5) >= 3.7                 # upper bound property
    assert h.quantile(0.5) <= 3.7 * 10 ** (1 / 20)    # tight to one bucket


def test_histogram_quantile_bounds_sample_population():
    """pXX is >= at least XX% of samples and within one bucket ratio of
    the true quantile — the determinism/accuracy contract."""
    h = Histogram(lo=1e-2, hi=1e5, per_decade=20)
    vals = [float(v) for v in range(1, 101)]          # 1..100
    for v in vals:
        h.record(v)
    ratio = 10 ** (1 / 20)
    for q in (0.5, 0.95, 0.99):
        true_q = vals[max(0, math.ceil(q * len(vals)) - 1)]
        got = h.quantile(q)
        assert got >= true_q                          # never understates
        assert got <= true_q * ratio                  # one bucket width
        assert h.quantile(q) == got                   # deterministic


def test_histogram_merge_adds_counts():
    a = Histogram(lo=1.0, hi=100.0, per_decade=2)
    b = Histogram(lo=1.0, hi=100.0, per_decade=2)
    for v in (1.0, 5.0, 50.0):
        a.record(v)
    for v in (2.0, 5.0):
        b.record(v)
    m = a.merge(b)
    assert m.count == 5
    assert m.sum == pytest.approx(63.0)
    assert sum(m.counts) == 5
    # merge is a copy: mutating the merged histogram leaves inputs alone
    m.record(99.0)
    assert a.count == 3 and b.count == 2


def test_histogram_merge_layout_mismatch_raises():
    a = Histogram(lo=1.0, hi=100.0, per_decade=2)
    b = Histogram(lo=1.0, hi=100.0, per_decade=4)
    with pytest.raises(ValueError, match="layout mismatch"):
        a.merge(b)


def test_histogram_eight_thread_hammer():
    """8 threads x 4000 records: per-metric lock loses no counts and the
    sum is exact (each thread records a distinct constant)."""
    h = Histogram()
    N, T = 4000, 8

    def work(val):
        for _ in range(N):
            h.record(val)

    threads = [threading.Thread(target=work, args=(float(t + 1),))
               for t in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert h.count == N * T
    assert sum(h.counts) == N * T
    assert h.sum == pytest.approx(N * sum(range(1, T + 1)))


# ---------------------------------------------------------------------------
# Registry + exports
# ---------------------------------------------------------------------------

def test_registry_get_or_create_and_conflicts():
    r = MetricsRegistry()
    c1 = r.counter("serving_thing_total", lane="rank")
    assert r.counter("serving_thing_total", lane="rank") is c1
    c2 = r.counter("serving_thing_total", lane="retrieve")
    assert c2 is not c1
    with pytest.raises(ValueError, match="conflicting"):
        r.gauge("serving_thing_total")                # type conflict
    r.histogram("serving_lat_ms", lo=1.0, hi=10.0, per_decade=2)
    with pytest.raises(ValueError, match="conflicting"):
        r.histogram("serving_lat_ms", lo=1.0, hi=10.0, per_decade=4)
    with pytest.raises(ValueError, match="bad metric name"):
        r.counter("Bad-Name")


def test_registry_prometheus_text_format():
    r = MetricsRegistry(namespace="repro")
    r.counter("serving_hits_total", help="cache hits").inc(7)
    h = r.histogram("serving_lat_ms", lane="rank")
    for v in (0.5, 2.0, 2.0, 40.0):
        h.record(v)
    text = r.prometheus_text()
    assert "# TYPE repro_serving_hits_total counter" in text
    assert "repro_serving_hits_total 7" in text
    assert "# TYPE repro_serving_lat_ms histogram" in text
    assert 'repro_serving_lat_ms_bucket{lane="rank",le="+Inf"} 4' in text
    assert 'repro_serving_lat_ms_count{lane="rank"} 4' in text
    assert 'repro_serving_lat_ms_p50{lane="rank"}' in text
    assert 'repro_serving_lat_ms_p99{lane="rank"}' in text
    # cumulative buckets never decrease and end at the total count
    cums = [int(ln.rsplit(" ", 1)[1]) for ln in text.splitlines()
            if ln.startswith("repro_serving_lat_ms_bucket")]
    assert cums == sorted(cums) and cums[-1] == 4


def test_registry_collector_runs_at_export():
    r = MetricsRegistry()
    pulls = []

    def collect():
        pulls.append(1)
        r.counter("serving_pulled_total").set_total(42)

    r.register_collector(collect)
    snap = r.snapshot()
    assert snap["repro_serving_pulled_total"] == 42
    assert "repro_serving_pulled_total 42" in r.prometheus_text()
    assert len(pulls) == 2                            # once per export


def test_histogram_snapshot_shape():
    r = MetricsRegistry()
    h = r.histogram("serving_lat_ms")
    h.record(1.0)
    h.record(2.0)
    snap = r.snapshot()["repro_serving_lat_ms"]
    assert snap["count"] == 2 and snap["sum"] == pytest.approx(3.0)
    assert set(snap) == {"count", "sum", "p50", "p95", "p99", "buckets"}
    assert max(snap["buckets"].values()) == 2         # cumulative


def test_null_registry_and_tracer_are_inert():
    assert NULL_REGISTRY.snapshot() == {}
    assert NULL_REGISTRY.prometheus_text() == ""
    m = NULL_REGISTRY.histogram("serving_x_ms", lane="rank")
    m.record(5.0)
    m.inc()
    assert m.get() == 0 and math.isnan(m.quantile(0.5))
    assert NULL_TRACER.chrome_trace()["traceEvents"] == []
    with NULL_TRACER.span("x"):
        pass
    assert NULL_TRACER.tid("anything") == 0
    obs = Observability(enabled=False)
    assert obs.metrics is NULL_REGISTRY and obs.tracer is NULL_TRACER


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------

def test_tracer_ring_buffer_and_dropped():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.event(f"ev{i}", "test", 0.0, 0.001, tid=tr.tid("t"))
    assert tr.dropped == 6
    doc = tr.chrome_trace()
    names = [e["name"] for e in doc["traceEvents"] if e["ph"] == "X"]
    assert names == ["ev6", "ev7", "ev8", "ev9"]      # newest window wins
    assert doc["otherData"] == {"dropped_events": 6, "capacity": 4}


def test_tracer_chrome_trace_shape(tmp_path):
    tr = Tracer(capacity=64)
    with tr.span("work", "stage", tid=tr.tid("lane:rank"),
                 args={"requests": 3}):
        pass
    tr.instant("mark", "stage", tid=tr.tid("lane:rank"))
    path = tmp_path / "t.json"
    tr.export(str(path))
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta[0]["args"]["name"] == "lane:rank"
    spans = [e for e in evs if e["ph"] == "X"]
    assert spans[0]["name"] == "work" and spans[0]["dur"] >= 0
    assert spans[0]["args"] == {"requests": 3}
    inst = [e for e in evs if e["ph"] == "i"]
    assert inst[0]["name"] == "mark" and "dur" not in inst[0]
    assert tr.tid("lane:rank") == tr.tid("lane:rank")  # stable
    assert tr.tid("lane:rank") != tr.tid("other")


# ---------------------------------------------------------------------------
# ExecutorRegistry warmup atomicity (regression)
# ---------------------------------------------------------------------------

def test_warm_vs_telemetry_concurrent_never_phantom_compiles():
    """warmup() in one thread, telemetry readers in others: the warmed
    mark is applied in the same critical section as the executed
    bookkeeping, so ``compiles_after_warmup`` never flickers above 0
    mid-warmup (regression: it used to be marked after the fact)."""
    reg = ExecutorRegistry()
    reg.register("id", lambda key: lambda x: x + key[0])
    phantom, stop = [], threading.Event()

    def reader():
        while not stop.is_set():
            v = reg.compiles_after_warmup
            if v:
                phantom.append(v)
            t = reg.telemetry()
            if t["compiles_after_warmup"]:
                phantom.append(t)

    readers = [threading.Thread(target=reader) for _ in range(3)]
    for t in readers:
        t.start()
    x = np.float32(1.0)
    for i in range(60):
        reg.warm("id", (i,), x)
    stop.set()
    for t in readers:
        t.join()
    assert phantom == []
    tel = reg.telemetry()
    assert tel["compiles"] == 60 and tel["warmed"] == 60
    assert tel["compiles_after_warmup"] == 0
    # call_counts is a side snapshot, NOT part of the pinned telemetry dict
    assert set(tel) == {"executors", "compiles", "hits", "warmed",
                        "compiles_after_warmup"}
    assert sum(reg.call_counts().values()) == 60


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def lite_model():
    pcfg = PinFMConfig(rows=512, n_tables=2, sub_dim=8, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=8,
                                       n_negatives=0))
    bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2,
                                                       d_model=64, d_ff=128)
    cfg = FinetuneConfig(variant="lite-last", seq_len=L)
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, cfg)
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, cfg.dcat)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def item_index(lite_model):
    model, params = lite_model
    return IndexBuilder(model, params, batch_size=256).build(0, N_ITEMS)


def _feats(ids):
    return np.stack([np.random.RandomState(int(i) % 4999).randn(CAND_DIM)
                     for i in np.asarray(ids)]).astype(np.float32)


def _user(seed):
    r = np.random.RandomState(seed)
    return (r.randint(0, N_ITEMS, L), r.randint(0, 6, L),
            r.randint(0, 3, L), r.randn(32).astype(np.float32))


def _mk_rank(seed, n_cand=3):
    i, a, s, uf = _user(seed)
    ids = np.random.RandomState(seed + 7000).randint(0, N_ITEMS, n_cand)
    return RankRequest(seq_ids=i, seq_actions=a, seq_surfaces=s,
                       cand_ids=ids, cand_feats=_feats(ids), user_feats=uf)


def _mk_engine(lite_model, item_index, **kw):
    model, params = lite_model
    kw.setdefault("cache", ContextCache(capacity=256))
    engine = ServingEngine(model, params, max_unique=4, max_candidates=32,
                           **kw)
    engine.attach_index(item_index, k=TOP_K, chunk_rows=256)
    engine.attach_features(_feats)
    engine.warmup()
    return engine


def _traffic(engine):
    i, a, s, uf = _user(3)
    reqs = [_mk_rank(1), _mk_rank(2),
            RetrieveRequest(seq_ids=i, seq_actions=a, seq_surfaces=s,
                            k=TOP_K),
            RetrieveThenRankRequest(seq_ids=i, seq_actions=a,
                                    seq_surfaces=s, user_feats=uf, k=TOP_K)]
    futs = engine.submit_many(reqs)
    engine.flush()
    return [f.result() for f in futs]


STATS_KEYS = {"executors", "cache", "memo_perm_hits", "slab", "masks",
              "lanes", "shared_encode_users", "scheduler",
              "chunks_executed", "pipeline_calls", "last_pipeline",
              "retrieval"}


def test_engine_stats_contract_unchanged_by_obs(lite_model, item_index):
    """The pinned stats() dict carries NO obs keys — obs reads stats,
    never the other way around."""
    engine = _mk_engine(lite_model, item_index)
    _traffic(engine)
    snap = engine.stats()
    assert set(snap) == STATS_KEYS
    assert set(snap["executors"]) == {"executors", "compiles", "hits",
                                      "warmed", "compiles_after_warmup"}
    assert snap["executors"]["compiles_after_warmup"] == 0


def test_engine_obs_traffic_metrics_and_trace(lite_model, item_index):
    engine = _mk_engine(lite_model, item_index)
    _traffic(engine)
    text = engine.obs.prometheus_text()
    assert 'repro_serving_flush_latency_ms_bucket{lane="rank"' in text
    assert 'repro_serving_flush_latency_ms_p50{lane="rank"}' in text
    assert "repro_serving_queue_wait_ms_count" in text
    assert "repro_serving_executor_compiles_after_warmup 0" in text
    assert "repro_serving_memo_hits_total" in text
    assert 'repro_serving_lane_requests_total{lane="rank"} 2' in text
    assert 'repro_serving_executor_calls_total{kind=' in text
    names = {e["name"] for e in engine.obs.chrome_trace()["traceEvents"]}
    assert {"warmup", "flush", "lane:rank", "prepare", "launch", "wait",
            "RankRequest", "RetrieveRequest",
            "RetrieveThenRankRequest"} <= names
    # snapshot mirrors stats() through the collector
    snap, stats = engine.obs.snapshot(), engine.stats()
    assert snap["repro_serving_cache_hits_total"] == stats["cache"]["hits"]
    assert (snap["repro_serving_scheduler_flushes_total"]
            == stats["scheduler"]["flushes"])


def test_engine_obs_disabled_bit_identical_and_empty(lite_model, item_index):
    on = _mk_engine(lite_model, item_index, obs_enabled=True)
    off = _mk_engine(lite_model, item_index, obs_enabled=False)
    reqs = [_mk_rank(11), _mk_rank(12)]
    p_on = on.score(reqs)
    p_off = off.score(reqs)
    np.testing.assert_array_equal(np.asarray(p_on), np.asarray(p_off))
    assert off.obs.prometheus_text() == ""
    assert off.obs.snapshot() == {}
    assert off.obs.chrome_trace()["traceEvents"] == []
    assert off.stats()["executors"]["compiles_after_warmup"] == 0
    assert set(off.stats()) == STATS_KEYS


def test_engine_obs_export_files(lite_model, item_index, tmp_path):
    engine = _mk_engine(lite_model, item_index)
    _traffic(engine)
    tpath, ppath = tmp_path / "t.json", tmp_path / "m.prom"
    engine.obs.export_trace(str(tpath))
    engine.obs.export_prometheus(str(ppath))
    doc = json.loads(tpath.read_text())
    assert doc["traceEvents"] and doc["otherData"]["dropped_events"] == 0
    assert "repro_serving_flush_latency_ms" in ppath.read_text()
    # and the bundled dump tool accepts both (CI gates on this)
    import subprocess
    import sys as _sys
    import os as _os
    r = subprocess.run(
        [_sys.executable,
         _os.path.join(_os.path.dirname(__file__), "..", "tools",
                       "dump_obs.py"), str(tpath), str(ppath)],
        capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    assert "named tracks" in r.stdout and "histogram series" in r.stdout

def test_dump_obs_merge_quantiles_match_histogram_merge(tmp_path):
    """``dump_obs --merge`` over per-worker snapshot JSONs recomputes
    histogram quantiles exactly the way ``Histogram.merge`` + ``quantile``
    would — including an overflow rank reporting the top bound (a sample
    sits in the top-bound bucket, so the layout's top bound is observed)
    — and sums counters into an unlabelled aggregate series next to the
    ``worker=``-labelled per-input series."""
    import os
    import subprocess
    import sys
    waves = {"w0": ((1.0, 2.0, 5.0, 40.0, 100.0, 5000.0), 3),
             "w1": ((3.0, 3.0, 8.0, 70.0, 9999.0), 4)}
    hists, paths = {}, []
    for worker, (vals, n_reqs) in waves.items():
        reg = MetricsRegistry()
        h = reg.histogram("serving_lat_ms", lo=1.0, hi=100.0, per_decade=2,
                          lane="rank")
        hists[worker] = h
        for v in vals:
            h.record(v)
        reg.counter("serving_requests_total").inc(n_reqs)
        p = tmp_path / f"{worker}.json"
        p.write_text(json.dumps(reg.snapshot()))
        paths.append(str(p))
    merged = hists["w0"].merge(hists["w1"])
    tool = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "dump_obs.py")
    out = str(tmp_path / "all.prom")
    r = subprocess.run([sys.executable, tool, "--merge", *paths, "-o", out],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stderr
    samples = {}
    for line in open(out):
        if line.strip() and not line.startswith("#"):
            key, value = line.rsplit(" ", 1)
            samples[key] = float(value)
    # aggregate histogram == Histogram.merge, quantiles and all; the p99
    # rank lands in the overflow bucket on both sides (-> top bound)
    base = 'repro_serving_lat_ms'
    assert samples[f'{base}_count{{lane="rank"}}'] == merged.count
    assert samples[f'{base}_sum{{lane="rank"}}'] == pytest.approx(merged.sum)
    assert samples[f'{base}_p50{{lane="rank"}}'] == merged.quantile(0.5)
    assert samples[f'{base}_p99{{lane="rank"}}'] == merged.quantile(0.99)
    assert merged.quantile(0.99) == merged.bounds[-1]       # overflow rank
    # per-worker series keep each input's own distribution
    for worker, h in hists.items():
        lk = f'{{lane="rank",worker="{worker}"}}'
        assert samples[f'{base}_count{lk}'] == h.count
        assert samples[f'{base}_p50{lk}'] == h.quantile(0.5)
    # counters: aggregate sums, per-worker series carry their own totals
    assert samples["repro_serving_requests_total"] == 7
    assert samples['repro_serving_requests_total{worker="w0"}'] == 3
    assert samples['repro_serving_requests_total{worker="w1"}'] == 4
    # the exposition round-trips through the tool's own validator
    r2 = subprocess.run([sys.executable, tool, out],
                        capture_output=True, text=True)
    assert r2.returncode == 0, r2.stderr
    assert "histogram series" in r2.stdout
