"""The trip-count-aware HLO analyzer (launch/hlo_analysis.py) against
hand-computable programs."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_flat_matmul():
    x = jax.ShapeDtypeStruct((128, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    a = analyze(_compile(lambda x, w: x @ w, x, w))
    assert a.flops == 2 * 128 * 64 * 32


def test_scan_multiplies_trip_count():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 64, 64), jnp.float32)

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        return jax.lax.scan(body, x, ws)[0]

    a = analyze(_compile(f, x, ws))
    assert a.flops == pytest.approx(12 * 2 * 64 ** 3)


def test_nested_scans_multiply():
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 32, 32), jnp.float32)

    def f(x, ws):
        def outer(c, w):
            def inner(c2, _):
                return c2 @ w, None
            return jax.lax.scan(inner, c, None, length=7)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    a = analyze(_compile(f, x, ws))
    assert a.flops == pytest.approx(21 * 2 * 32 ** 3)


def test_batch_dot():
    x = jax.ShapeDtypeStruct((4, 16, 8), jnp.float32)
    y = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    a = analyze(_compile(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), x, y))
    assert a.flops == 2 * 4 * 16 * 8 * 16


def test_hbm_bytes_counts_dot_traffic():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    a = analyze(_compile(lambda x, w: x @ w, x, w))
    # operands + result of the dot
    assert a.hbm_bytes >= 3 * 256 * 256 * 4
    assert a.hbm_bytes < 10 * 256 * 256 * 4
