"""ContextCache unit tests: LRU eviction order, hit/miss telemetry, and
byte accounting under capacity pressure (previously only exercised
indirectly through the engine tests)."""
import numpy as np
import pytest

from repro.serving.context_cache import ContextCache


def _val(i, n=4):
    return np.full(n, i, np.float32)


def test_eviction_is_lru_ordered():
    c = ContextCache(capacity=3)
    for i in range(3):
        c.put(i, _val(i))
    c.put(3, _val(3))                       # evicts 0 (oldest insert)
    assert c.peek(0) is None
    assert [k for k in (1, 2, 3) if c.peek(k) is not None] == [1, 2, 3]
    # a get() refreshes recency: 1 survives the next eviction, 2 does not
    assert c.get(1) is not None
    c.put(4, _val(4))
    assert c.peek(2) is None
    assert c.peek(1) is not None and c.peek(4) is not None
    assert len(c) == 3


def test_put_refreshes_recency_and_updates_value():
    c = ContextCache(capacity=2)
    c.put("a", _val(1))
    c.put("b", _val(2))
    c.put("a", _val(7))                     # update -> most recent
    c.put("c", _val(3))                     # evicts "b"
    assert c.peek("b") is None
    np.testing.assert_array_equal(c.peek("a"), _val(7))
    assert len(c) == 2


def test_hit_miss_telemetry_under_pressure():
    c = ContextCache(capacity=2)
    assert c.get("x") is None
    assert (c.hits, c.misses) == (0, 1)
    c.put("x", _val(0))
    assert c.get("x") is not None
    assert (c.hits, c.misses) == (1, 1)
    c.put("y", _val(1))
    c.put("z", _val(2))                     # "x" evicted
    assert c.get("x") is None               # post-eviction lookup is a miss
    assert (c.hits, c.misses) == (1, 2)
    # peek never touches the counters or the LRU order
    c.peek("y")
    c.peek("nope")
    assert (c.hits, c.misses) == (1, 2)
    stats = c.stats()
    assert stats["entries"] == 2 and stats["hits"] == 1
    assert stats["misses"] == 2 and stats["nbytes"] == c.nbytes
    assert stats["memo_entries"] == 0          # memo untouched so far


def test_nbytes_tracks_evictions_and_updates():
    c = ContextCache(capacity=2)
    c.put("a", _val(0, n=8))                # 32 bytes
    c.put("b", _val(1, n=16))               # 64 bytes
    assert c.nbytes == 32 + 64
    c.put("a", _val(2, n=2))                # update shrinks to 8 bytes
    assert c.nbytes == 8 + 64
    c.put("c", _val(3, n=4))                # evicts "b"
    assert c.peek("b") is None
    assert c.nbytes == 8 + 16
    # pytree values (the early-fusion ctx case) are byte-counted too
    c.put("d", {"k": _val(0, n=4), "v": _val(1, n=4)})   # 32 bytes
    assert c.peek("a") is None              # evicted (capacity 2)
    assert c.nbytes == 16 + 32


# ---------------------------------------------------------------------------
# device-side pack memo
# ---------------------------------------------------------------------------

def test_pack_memo_hit_miss_and_lru():
    c = ContextCache(capacity=8, memo_capacity=2)
    for u in ("u1", "u2", "u3"):
        c.put(u, _val(1))
    assert c.memo_get(("b", 4)) is None         # cold -> miss
    c.memo_put(("b", 4), ["u1", "u2"], {"k": _val(9)})
    got = c.memo_get(("b", 4))
    np.testing.assert_array_equal(got["k"], _val(9))
    assert (c.memo_hits, c.memo_misses) == (1, 1)
    assert c.memo_nbytes > 0
    # LRU bound: a third entry evicts the least-recently-used one
    c.memo_put(("b2", 4), ["u2", "u3"], _val(2))
    c.memo_get(("b", 4))                        # refresh ("b",4)
    c.memo_put(("b3", 4), ["u3"], _val(3))      # evicts ("b2",4)
    assert c.memo_get(("b2", 4)) is None
    assert c.memo_get(("b", 4)) is not None
    assert c.memo_get(("b3", 4)) is not None


def test_pack_memo_invalidated_by_user_eviction():
    """The core staleness invariant: evicting a user from the per-user LRU
    must drop EVERY memoized packed batch containing that user — a memo hit
    may never serve context for a user the cache no longer holds."""
    c = ContextCache(capacity=2, memo_capacity=8)
    c.put("u1", _val(1))
    c.put("u2", _val(2))
    c.memo_put(("batch12",), ["u1", "u2"], _val(12))
    c.memo_put(("batch2",), ["u2"], _val(2))
    c.put("u3", _val(3))                        # evicts u1 (capacity 2)
    assert c.peek("u1") is None
    assert c.memo_get(("batch12",)) is None     # contained u1 -> dropped
    assert c.memo_get(("batch2",)) is not None  # u2 still cached -> survives
    assert c.memo_invalidations == 1
    assert c.stats()["memo_entries"] == 1


def test_pack_memo_invalidated_by_user_put():
    """A put (re-insert/update) of a user also drops its memo entries —
    conservative, but guarantees a memoized batch never disagrees with the
    per-user store it was packed from."""
    c = ContextCache(capacity=8, memo_capacity=8)
    c.put("u1", _val(1))
    c.memo_put(("b",), ["u1"], _val(5))
    c.put("u1", _val(7))
    assert c.memo_get(("b",)) is None
    # byte gauge returns to zero once everything is invalidated
    assert c.memo_nbytes == 0


def test_pack_memo_capacity_zero_disables():
    c = ContextCache(capacity=4, memo_capacity=0)
    c.memo_put(("b",), ["u"], _val(1))
    assert c.memo_get(("b",)) is None
    assert (c.memo_hits, c.memo_misses) == (0, 0)   # fully inert


def test_key_helper_distinguishes_sequences():
    ids = np.arange(8, dtype=np.int32)
    act = np.ones(8, np.int32)
    k1 = ContextCache.key(ids, act)
    k2 = ContextCache.key(ids, act + 1)
    k3 = ContextCache.key(ids, act, np.zeros(8, np.int32))
    assert k1 != k2 and k1 != k3
    assert k1 == ContextCache.key(ids.copy(), act.copy())
