"""The unified async serving API (this PR's tentpole): one ``submit()``
front door, mixed-workload flushes with a shared encode pass, the fused
retrieve->rank lane, and the read-atomic ``engine.stats()`` snapshot.

Acceptance points covered:
  * ``RetrieveThenRankRequest`` via ``submit()`` == sequential
    ``retrieve()`` then ``score()`` (bit-identical), with fewer encoder
    invocations for overlapping users and zero post-warmup compiles;
  * one flush mixing rank + retrieve + two-stage requests with
    overlapping users encodes each unique user exactly once and matches
    the per-lane sequential paths;
  * ``score()``/``retrieve()`` are bit-identical shims over
    ``submit_many``; a ``RequestScheduler`` driven directly over the
    engine flush matches them too;
  * ``repro.serving.__all__`` is pinned;
  * concurrent ``submit`` + ``stats()`` readers never observe torn or
    negative counters.
"""
import threading

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.dcat import DCAT
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.models.config import get_config
from repro.retrieval import IndexBuilder
from repro.serving import (ContextCache, GenerateRequest, RankRequest,
                           RetrieveRequest, RetrieveThenRankRequest,
                           ServingEngine, TwoStageResult)

L = 16
N_ITEMS = 500
TOP_K = 8
CAND_DIM = 32


@pytest.fixture(scope="module")
def lite_model():
    pcfg = PinFMConfig(rows=512, n_tables=2, sub_dim=8, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=8,
                                       n_negatives=0))
    bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2,
                                                       d_model=64, d_ff=128)
    cfg = FinetuneConfig(variant="lite-last", seq_len=L)
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, cfg)
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, cfg.dcat)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def item_index(lite_model):
    model, params = lite_model
    return IndexBuilder(model, params, batch_size=256).build(0, N_ITEMS)


def _feats(ids):
    """Deterministic per-item ranking features — both the fused path and
    the sequential reference must feed the ranker identical bytes."""
    return np.stack([np.random.RandomState(int(i) % 4999).randn(CAND_DIM)
                     for i in np.asarray(ids)]).astype(np.float32)


def _user(seed):
    r = np.random.RandomState(seed)
    return (r.randint(0, N_ITEMS, L), r.randint(0, 6, L),
            r.randint(0, 3, L), r.randn(32).astype(np.float32))


def _mk_rank(seed, cand_rng, n_cand=3):
    i, a, s, uf = _user(seed)
    ids = cand_rng.randint(0, N_ITEMS, n_cand)
    return RankRequest(seq_ids=i, seq_actions=a, seq_surfaces=s,
                       cand_ids=ids, cand_feats=_feats(ids), user_feats=uf)


def _mk_retrieve(seed, k=TOP_K, exclude=False):
    i, a, s, _ = _user(seed)
    return RetrieveRequest(seq_ids=i, seq_actions=a, seq_surfaces=s, k=k,
                           exclude_ids=np.unique(i) if exclude else None)


def _mk_two_stage(seed, k=TOP_K, exclude=False):
    i, a, s, uf = _user(seed)
    return RetrieveThenRankRequest(
        seq_ids=i, seq_actions=a, seq_surfaces=s, user_feats=uf, k=k,
        exclude_ids=np.unique(i) if exclude else None)


def _mk_engine(lite_model, item_index, *, warm=True, attach=True, **kw):
    model, params = lite_model
    kw.setdefault("cache", ContextCache(capacity=256))
    engine = ServingEngine(model, params, max_unique=4, max_candidates=32,
                           **kw)
    if attach:
        engine.attach_index(item_index, k=TOP_K, chunk_rows=256)
        engine.attach_features(_feats)
    if warm:
        engine.warmup()
    return engine


def _count_encodes(engine):
    """Wrap ``_encode_rows`` to record how many user rows each executor
    invocation encodes; -> the mutable list of per-call row counts."""
    counts = []
    orig = engine._encode_rows

    def counting(kind, ids, acts, surfs):
        counts.append(len(ids))
        return orig(kind, ids, acts, surfs)

    engine._encode_rows = counting
    return counts


# ---------------------------------------------------------------------------
# public surface
# ---------------------------------------------------------------------------

def test_public_surface_pinned():
    """The serving package exports exactly the typed requests and the
    engine (+ front-door collaborators) — the PR-1-era shims are gone."""
    import repro.serving as serving
    assert serving.__all__ == [
        "RankRequest", "RetrieveRequest", "RetrieveThenRankRequest",
        "GenerateRequest", "TwoStageResult",
        "ServingEngine", "ContextCache", "Future",
        "LanePolicy", "ShedError",
    ]
    for name in serving.__all__:
        assert getattr(serving, name) is not None


def test_unknown_request_type_rejected(lite_model, item_index):
    """A bad request fails at submit() — it must never enter the queue
    where its failure would poison other callers' coalesced flush."""
    engine = _mk_engine(lite_model, item_index, warm=False, attach=False)
    with pytest.raises(TypeError, match="not a serving request type"):
        engine.submit(object())
    # traffic that bypasses submit (a custom scheduler driving the flush
    # directly) fails at the flush gate instead
    with pytest.raises(TypeError, match="not a serving request type"):
        engine._flush_requests([object()])


# ---------------------------------------------------------------------------
# submit() front door + batch shims
# ---------------------------------------------------------------------------

def test_submit_resolves_like_score(lite_model, item_index):
    """A submitted RankRequest's future resolves (result() forces the
    flush) to exactly what the batch shim returns."""
    rng = np.random.RandomState(0)
    reqs = [_mk_rank(s, rng) for s in (1, 2, 1)]
    engine = _mk_engine(lite_model, item_index, warm=False, attach=False)
    ref = _mk_engine(lite_model, item_index, warm=False,
                     attach=False).score(reqs)
    futs = [engine.submit(r) for r in reqs]
    assert not any(f.done() for f in futs)
    out = [f.result() for f in futs]            # first result() flushes all
    assert all(f.done() for f in futs)
    for a, b in zip(out, ref):
        np.testing.assert_array_equal(a, b)
    assert engine.scheduler.flushes == 1
    assert engine.stats()["lanes"]["rank"] == 3


def test_score_shim_bit_identical_to_rank_lane(lite_model, item_index):
    """score() is a thin shim over submit_many: same chunking, same
    executors, bit-identical results to calling the rank lane directly."""
    rng = np.random.RandomState(1)
    reqs = [_mk_rank(s, rng, n_cand=4) for s in (1, 2, 3, 1, 4)]
    via_shim = _mk_engine(lite_model, item_index, warm=False,
                          attach=False).score(reqs)
    direct = _mk_engine(lite_model, item_index, warm=False,
                        attach=False)._score_batch(reqs)
    for a, b in zip(via_shim, direct):
        np.testing.assert_array_equal(a, b)


def test_retrieve_shim_bit_identical_to_lane(lite_model, item_index):
    reqs = [_mk_retrieve(s) for s in (1, 2, 1)] + [_mk_retrieve(3, k=5)]
    via_shim = _mk_engine(lite_model, item_index).retrieve(reqs)
    direct = _mk_engine(lite_model, item_index)._retrieve_batch(reqs)
    for (ia, sa), (ib, sb) in zip(via_shim, direct):
        np.testing.assert_array_equal(ia, ib)
        np.testing.assert_array_equal(sa, sb)


def test_engine_background_flusher(lite_model, item_index):
    """max_wait_ms on the ENGINE starts the background flusher: a
    submitted request resolves without anyone calling flush()/result()."""
    rng = np.random.RandomState(2)
    reqs = [_mk_rank(s, rng) for s in (1, 2)]
    ref = _mk_engine(lite_model, item_index, warm=False,
                     attach=False).score(reqs)
    with _mk_engine(lite_model, item_index, warm=False, attach=False,
                    max_wait_ms=5.0) as engine:
        futs = [engine.submit(r) for r in reqs]
        assert all(f._done.wait(30.0) for f in futs)     # no manual flush
        for f, r in zip(futs, ref):
            np.testing.assert_array_equal(f.result(), r)


# ---------------------------------------------------------------------------
# fused two-stage lane
# ---------------------------------------------------------------------------

def _sequential_two_stage(engine, reqs):
    """The unfused reference: retrieve(), build the RankRequests by hand,
    score() — what examples/retrieve_topk.py stage 2 does."""
    retrieved = engine.retrieve([RetrieveRequest(
        seq_ids=r.seq_ids, seq_actions=r.seq_actions,
        seq_surfaces=r.seq_surfaces, k=r.k, exclude_ids=r.exclude_ids,
        allow_surfaces=r.allow_surfaces) for r in reqs])
    probs = engine.score([RankRequest(
        seq_ids=r.seq_ids, seq_actions=r.seq_actions,
        seq_surfaces=r.seq_surfaces, cand_ids=ids, cand_feats=_feats(ids),
        user_feats=r.user_feats)
        for r, (ids, _) in zip(reqs, retrieved)])
    return retrieved, probs


def test_two_stage_matches_sequential(lite_model, item_index):
    """ACCEPTANCE: RetrieveThenRankRequest via submit() == sequential
    retrieve()+score(), bit-identical, with fewer encoder invocations for
    overlapping users and zero post-warmup compiles."""
    # 10 requests, 6 unique users (> max_unique=4 -> several groups)
    seeds = (1, 2, 3, 1, 4, 5, 6, 2, 1, 3)
    reqs = [_mk_two_stage(s, exclude=True) for s in seeds]
    fused = _mk_engine(lite_model, item_index)
    counts = _count_encodes(fused)
    futs = fused.submit_many(reqs)
    fused.flush()
    res = [f.result() for f in futs]
    assert all(isinstance(r, TwoStageResult) for r in res)
    # each of the 6 unique users is encoded exactly once across BOTH
    # stages — fewer invocations than the 10 submitted requests
    assert sum(counts) == len(set(seeds)) < len(reqs)
    assert fused.registry.compiles_after_warmup == 0

    seq_engine = _mk_engine(lite_model, item_index)
    retrieved, probs = _sequential_two_stage(seq_engine, reqs)
    assert seq_engine.registry.compiles_after_warmup == 0
    for r, (ids, scores), p in zip(res, retrieved, probs):
        np.testing.assert_array_equal(r.item_ids, ids)
        np.testing.assert_array_equal(r.retrieval_scores, scores)
        np.testing.assert_array_equal(r.probs, p)
    # per-stage pipeline telemetry for the fused flush
    ps = fused.pipeline_stats[-1]
    assert ps.lane == "two_stage" and ps.chunks >= 2
    assert ps.retrieve_ms > 0
    assert 0 <= ps.overlap_fraction <= 1
    assert ps.as_dict()["lane"] == "two_stage"


def test_two_stage_depth1_bit_identical(lite_model, item_index):
    """The fused schedule's escape hatch: pipeline_depth=1 runs each group
    to completion and must match depth-2 bit-for-bit."""
    reqs = [_mk_two_stage(s) for s in (1, 2, 3, 4, 5, 1)]
    pipe = _mk_engine(lite_model, item_index, pipeline_depth=2)
    sync = _mk_engine(lite_model, item_index, pipeline_depth=1)
    fa, fb = pipe.submit_many(reqs), sync.submit_many(reqs)
    pipe.flush()
    sync.flush()
    a, b = [f.result() for f in fa], [f.result() for f in fb]
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x.item_ids, y.item_ids)
        np.testing.assert_array_equal(x.retrieval_scores, y.retrieval_scores)
        np.testing.assert_array_equal(x.probs, y.probs)
    assert sync.pipeline_stats[-1].depth == 1


def test_two_stage_coarse_key_fn_keeps_user_feats(lite_model, item_index):
    """REGRESSION: a coarse cache ``key_fn`` shares cached embeddings
    across sequences, but the fused rank stage must still dedupe its
    user_feats rows by FULL sequence identity (build_plan's Ψ rule) —
    collapsing them by key_fn would rank one request's candidates with
    another request's user_feats, diverging from the sequential path."""
    model, params = lite_model

    def mk_eng():
        e = ServingEngine(model, params, max_unique=4, max_candidates=32,
                          cache=ContextCache(64),
                          key_fn=lambda r: b"same-user")
        e.attach_index(item_index, k=TOP_K, chunk_rows=256)
        e.attach_features(_feats)
        return e

    reqs = [_mk_two_stage(1), _mk_two_stage(2)]   # distinct seqs + feats
    fused = mk_eng()
    futs = fused.submit_many(reqs)
    fused.flush()
    res = [f.result() for f in futs]
    retrieved, probs = _sequential_two_stage(mk_eng(), reqs)
    for r, (ids, scores), p in zip(res, retrieved, probs):
        np.testing.assert_array_equal(r.item_ids, ids)
        np.testing.assert_array_equal(r.probs, p)


def test_two_stage_needs_features(lite_model, item_index):
    engine = _mk_engine(lite_model, item_index, warm=False)
    engine._features_fn = None
    with pytest.raises(ValueError, match="candidate features"):
        engine.submit(_mk_two_stage(1))          # fail-fast at submit
    # a request-level cand_feats_fn fills the gap
    r = _mk_two_stage(2)
    r.cand_feats_fn = _feats
    out = engine.submit(r).result()
    assert out.probs.shape[0] == TOP_K


# ---------------------------------------------------------------------------
# mixed-workload flush
# ---------------------------------------------------------------------------

def test_mixed_flush_single_encode_and_parity(lite_model, item_index):
    """SATELLITE: one flush containing rank + retrieve + two-stage
    requests with overlapping users encodes each unique user ONCE, matches
    the per-lane sequential results, and compiles nothing after warmup."""
    rng = np.random.RandomState(3)
    # user 1 appears in all three lanes; users 2/3 in two lanes each
    rank_reqs = [_mk_rank(1, rng), _mk_rank(2, rng, n_cand=5)]
    ret_reqs = [_mk_retrieve(1), _mk_retrieve(3), _mk_retrieve(2)]
    two_reqs = [_mk_two_stage(1), _mk_two_stage(3)]
    mixed = [rank_reqs[0], ret_reqs[0], two_reqs[0], ret_reqs[1],
             rank_reqs[1], two_reqs[1], ret_reqs[2]]

    engine = _mk_engine(lite_model, item_index)
    counts = _count_encodes(engine)
    futs = engine.submit_many(mixed)
    engine.flush()
    out = [f.result() for f in futs]
    assert engine.scheduler.flushes == 1
    assert sum(counts) == 3                  # users 1, 2, 3: once each
    assert engine.registry.compiles_after_warmup == 0
    snap = engine.stats()
    assert snap["shared_encode_users"] == 3
    assert snap["lanes"] == {"rank": 2, "retrieve": 3, "two_stage": 2,
                             "generate": 0}

    # parity: each lane against a sequential engine running one lane
    ref = _mk_engine(lite_model, item_index)
    ref_rank = ref.score(rank_reqs)
    ref_ret = ref.retrieve(ret_reqs)
    ref_two, ref_two_probs = _sequential_two_stage(
        _mk_engine(lite_model, item_index), two_reqs)
    np.testing.assert_array_equal(out[0], ref_rank[0])
    np.testing.assert_array_equal(out[4], ref_rank[1])
    for got, (ids, scores) in zip((out[1], out[3], out[6]), ref_ret):
        np.testing.assert_array_equal(got[0], ids)
        np.testing.assert_array_equal(got[1], scores)
    for got, (ids, scores), p in zip((out[2], out[5]), ref_two,
                                     ref_two_probs):
        np.testing.assert_array_equal(got.item_ids, ids)
        np.testing.assert_array_equal(got.retrieval_scores, scores)
        np.testing.assert_array_equal(got.probs, p)


# ---------------------------------------------------------------------------
# generate lane
# ---------------------------------------------------------------------------

class _StubGenerator:
    def __init__(self):
        self.calls = 0

    def generate(self, prompts, *, rng=None):
        self.calls += 1
        return np.asarray(prompts)[:, :4] + (0 if rng is None else 1)


def test_generate_request_routed(lite_model, item_index):
    engine = _mk_engine(lite_model, item_index, warm=False, attach=False)
    with pytest.raises(ValueError, match="attach_generator"):
        engine.submit(GenerateRequest(prompts=np.ones((2, 8), np.int32)))
    gen = _StubGenerator()
    engine.attach_generator(gen)
    prompts = np.arange(16, dtype=np.int32).reshape(2, 8)
    out = engine.submit(GenerateRequest(prompts=prompts)).result()
    np.testing.assert_array_equal(out, prompts[:, :4])
    out_rng = engine.submit(GenerateRequest(prompts=prompts, rng=1)).result()
    np.testing.assert_array_equal(out_rng, prompts[:, :4] + 1)
    assert gen.calls == 2
    assert engine.stats()["lanes"]["generate"] == 2


# ---------------------------------------------------------------------------
# telemetry snapshot under concurrency
# ---------------------------------------------------------------------------

def test_stats_snapshot_concurrent_submits(lite_model, item_index):
    """SATELLITE: concurrent submit() traffic + stats() readers — no
    torn, negative, or non-monotonic counters, and no post-warmup
    compiles.  (Counter writes and the snapshot read share the registry
    RLock.)"""
    engine = _mk_engine(lite_model, item_index)
    rng = np.random.RandomState(4)
    errors = []
    snaps = []
    stop = threading.Event()

    def writer(tid):
        try:
            for i in range(6):
                futs = engine.submit_many(
                    [_mk_rank(1 + (tid + i) % 4, np.random.RandomState(tid)),
                     _mk_retrieve(1 + (tid + i) % 4),
                     _mk_two_stage(1 + (tid + i) % 4)])
                engine.flush()
                for f in futs:
                    f.result()
        except BaseException as e:          # pragma: no cover - diagnostic
            errors.append(e)

    def reader():
        import time as _time
        try:
            while not stop.is_set():
                snaps.append(engine.stats())
                _time.sleep(2e-3)
        except BaseException as e:          # pragma: no cover - diagnostic
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
    r = threading.Thread(target=reader)
    r.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join(120.0)
    stop.set()
    r.join(30.0)
    snaps.append(engine.stats())
    assert not errors
    prev_hits = prev_flushes = -1
    for s in snaps:
        ex, cache, masks = s["executors"], s["cache"], s["masks"]
        assert ex["compiles_after_warmup"] == 0
        # a lite engine has no KV slab; the key is present regardless so
        # dashboards never KeyError (the slab hammer test covers the
        # populated section — see test_kv_slab.py)
        assert s["slab"] is None
        for v in (ex["hits"], ex["compiles"], cache["hits"],
                  cache["misses"], masks["hits"], masks["misses"],
                  s["scheduler"]["flushes"], s["scheduler"]["coalesced"],
                  s["memo_perm_hits"], *s["lanes"].values()):
            assert v >= 0
        # monotonicity: snapshots are taken by one reader thread, so each
        # cumulative counter may only grow between successive snapshots
        assert ex["hits"] >= prev_hits
        assert s["scheduler"]["flushes"] >= prev_flushes
        prev_hits, prev_flushes = ex["hits"], s["scheduler"]["flushes"]
    final = snaps[-1]
    assert final["scheduler"]["coalesced"] == 4 * 6 * 3
    assert final["lanes"]["rank"] == final["lanes"]["retrieve"] == \
        final["lanes"]["two_stage"] == 24


# ---------------------------------------------------------------------------
# RequestScheduler driven directly over the engine flush
# ---------------------------------------------------------------------------

def test_direct_scheduler_matches_batch_shims(lite_model, item_index):
    """A RequestScheduler wired straight to ``engine._flush_requests``
    (the machinery ``submit`` owns, minus the front door) produces
    bit-identical results for rank AND retrieval traffic — the coverage
    the retired MicroBatcher shim test used to pin."""
    from repro.serving.scheduler import RequestScheduler
    rng = np.random.RandomState(5)
    reqs = [_mk_rank(s, rng) for s in (1, 2, 1, 3)]
    ref_engine = _mk_engine(lite_model, item_index, warm=False)
    ref = ref_engine.score(reqs)
    engine = _mk_engine(lite_model, item_index, warm=False)
    sched = RequestScheduler(engine._flush_requests, max_requests=64,
                             max_candidates=engine.max_candidates)
    futures = [sched.submit(r) for r in reqs]
    sched.flush()
    for f, r in zip(futures, ref):
        np.testing.assert_array_equal(f.result(), r)
    assert sched.flushes == 1 and sched.coalesced == 4
    # retrieval rides the same typed-lane flush
    ids_ref, scores_ref = ref_engine.retrieve([_mk_retrieve(1)])[0]
    ids, scores = sched.submit(_mk_retrieve(1)).result()
    np.testing.assert_array_equal(ids, ids_ref)
    np.testing.assert_array_equal(scores, scores_ref)
    sched.close()
