"""HSTU backbone, generation driver, segmentation, retrieval eval."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.dcat import DCAT
from repro.models.config import get_config
from repro.models.transformer import TransformerBody, TransformerLM


def test_hstu_dcat_equivalence():
    cfg = smoke_config(get_config("pinfm-hstu"))
    body = TransformerBody(cfg)
    p = body.init(jax.random.PRNGKey(0))
    Bu, L, Sc = 3, 12, 2
    x_u = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (Bu, L, cfg.d_model))
    inv = np.array([0, 0, 0, 1, 1, 2, 2, 2], np.int32)
    x_c = 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                  (len(inv), Sc, cfg.d_model))
    dcat = DCAT(body)
    _, _, ctxs = dcat.context(p, x_u)
    y_d, _ = dcat.crossing(p, x_c, inv, ctxs, ctx_len=L)
    y_r, _ = dcat.reference_scores(p, x_u, x_c, inv)
    np.testing.assert_allclose(np.asarray(y_d), np.asarray(y_r), atol=5e-5)


def test_hstu_decode_matches_forward():
    cfg = smoke_config(get_config("pinfm-hstu"))
    body = TransformerBody(cfg)
    p = body.init(jax.random.PRNGKey(0))
    B, L = 2, 10
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (B, L, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(L), (B, L))
    full, _, _ = body.forward(p, x, pos)
    caches = body.init_caches(B, 16)
    outs = []
    for t in range(L):
        y, caches = body.decode(p, x[:, t:t + 1], caches,
                                jnp.full((B, 1), t, jnp.int32))
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=2e-5)


def test_hstu_pretrains():
    from repro.core.pretrain import PinFMConfig, PinFMPretrain
    from repro.core.losses import LossConfig
    cfg = smoke_config(get_config("pinfm-hstu"))
    pcfg = PinFMConfig(rows=512, n_tables=2, sub_dim=8, seq_len=16,
                       loss=LossConfig(window=4, downstream_len=8,
                                       n_negatives=0))
    m = PinFMPretrain(pcfg, cfg)
    p = m.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"ids": jax.random.randint(key, (3, 16), 0, 1 << 20),
             "actions": jax.random.randint(key, (3, 16), 0, 6),
             "surfaces": jax.random.randint(key, (3, 16), 0, 3),
             "valid": jnp.ones((3, 16), bool),
             "user_id": jnp.arange(3, dtype=jnp.int32)}
    loss, _ = m.loss(p, batch)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda pp: m.loss(pp, batch)[0])(p)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g))


# -- generation ---------------------------------------------------------------

def test_generator_greedy_matches_argmax_rollout():
    from repro.serving.generate import GenerateConfig, Generator
    cfg = smoke_config(get_config("qwen3-4b"))
    model = TransformerLM(cfg)
    p = model.init(jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 5), 0, cfg.vocab)
    gen = Generator(model, p, GenerateConfig(max_new_tokens=4))
    out = gen.generate(prompts)
    assert out.shape == (2, 4)
    # manual rollout via full forward re-encoding
    toks = prompts
    for _ in range(4):
        logits, _ = model.forward(p, toks)
        nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        toks = jnp.concatenate([toks, nxt[:, None]], 1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(toks[:, 5:]))


def test_generator_topk_sampling_valid_tokens():
    from repro.serving.generate import GenerateConfig, Generator
    cfg = smoke_config(get_config("qwen1.5-0.5b"))
    model = TransformerLM(cfg)
    p = model.init(jax.random.PRNGKey(0))
    gen = Generator(model, p, GenerateConfig(max_new_tokens=3,
                                             temperature=1.0, top_k=5))
    out = gen.generate(jnp.zeros((2, 3), jnp.int32))
    assert out.shape == (2, 3)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab).all()


# -- segmentation -------------------------------------------------------------

def test_segment_history_roundtrip():
    from repro.data.segment import pack_segments, realtime_sequence, \
        segment_history
    rng = np.random.RandomState(0)
    n = 53
    ev = {"ids": rng.randint(0, 100, n),
          "actions": rng.randint(0, 6, n),
          "surfaces": rng.randint(0, 3, n),
          "timestamps": np.sort(rng.rand(n).astype(np.float32))}
    segs = segment_history(ev, 16)
    assert len(segs) == 4                       # 16+16+16+5
    assert segs[-1]["valid"].sum() == 5
    recon = np.concatenate([s["ids"][s["valid"]] for s in segs])
    np.testing.assert_array_equal(recon, ev["ids"])

    rt = realtime_sequence(ev, 16)
    np.testing.assert_array_equal(rt["ids"][rt["valid"]], ev["ids"][-16:])
    rt2 = realtime_sequence({k: v[:4] for k, v in ev.items()}, 16)
    assert rt2["valid"].sum() == 4              # left-padded short history

    batches = list(pack_segments(segs, 2))
    assert len(batches) == 2 and batches[0]["ids"].shape == (2, 16)


def test_segment_unsorted_input_sorted():
    from repro.data.segment import segment_history
    ev = {"ids": np.array([3, 1, 2]), "actions": np.zeros(3, int),
          "surfaces": np.zeros(3, int),
          "timestamps": np.array([3.0, 1.0, 2.0], np.float32)}
    segs = segment_history(ev, 4)
    np.testing.assert_array_equal(segs[0]["ids"][:3], [1, 2, 3])


# -- retrieval eval -----------------------------------------------------------

def test_next_item_recall_perfect_model():
    """A model whose H_i exactly embeds the next item must get recall 1."""
    from repro.core.eval import next_item_recall

    class Oracle:
        def encode(self, params, ids, actions, surfaces, **kw):
            z = self.targets(params, jnp.roll(ids, -1, axis=1))
            return z, None, None

        def targets(self, params, ids):
            return jax.nn.one_hot(ids % 97, 97)

        def pos_action_mask(self, actions):
            return actions == 1

    b = {"ids": np.arange(20).reshape(2, 10) % 97,
         "actions": np.ones((2, 10), np.int32),
         "surfaces": np.zeros((2, 10), np.int32)}
    r = next_item_recall(Oracle(), None, [b], k=1)
    assert r["recall"] == 1.0 and r["n"] == 18
