"""SLO scheduler suite: property-based invariants + targeted unit tests.

The property leg runs the SAME invariant checker two ways:

  * a deterministic loop over >= 250 seeded cases (always runs, no
    third-party deps — the container baseline);
  * a real ``hypothesis`` ``@given`` leg (>= 200 generated examples with
    shrinking) when hypothesis is installed — the CI property job.

The unit tests pin each SLO mechanism on its own: lane isolation,
per-lane thresholds, admission control (evict-lowest / shed-incoming /
soft bound for protected priorities), deadline shed with a fully-typed
:class:`ShedError`, priority exemption (deadline_misses), the
``max_wait_ms`` auto-tuner, ``shed_expired``, and the
flush-membership-beats-shed regression (a request an in-flight flush
already drained must be invisible to every shed path)."""
import threading
import time

import pytest

from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings
from scheduler_strategies import (Case, FakeRequest, case_strategy,
                                  random_case, run_case)

from repro.serving.plan import LanePolicy
from repro.serving.scheduler import RequestScheduler, ShedError

N_SEEDED_CASES = 250        # the no-hypothesis property budget


# ---------------------------------------------------------------------------
# property leg
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("block", range(5))
def test_property_invariants_seeded(block):
    """Deterministic property sweep: 5 x 50 = 250 generated cases, every
    scheduler invariant checked on each (exactly-once resolution, per-lane
    order, shed xor served, shed-only-over-budget, result routing)."""
    per_block = N_SEEDED_CASES // 5
    for seed in range(block * per_block, (block + 1) * per_block):
        try:
            run_case(random_case(seed))
        except AssertionError as e:
            raise AssertionError(f"seed {seed}: {e}") from e


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@settings(max_examples=200, deadline=None)
@given(case=case_strategy())
def test_property_invariants_hypothesis(case):
    run_case(case)


# ---------------------------------------------------------------------------
# unit tests: lane isolation + thresholds
# ---------------------------------------------------------------------------

def _mk(seq_start, lane, n, priority=0, cost=1):
    return [FakeRequest(uid=seq_start + i, lane=lane, priority=priority,
                        cand_ids=list(range(cost))) for i in range(n)]


def _recording_sched(**kw):
    calls = []

    def flush_fn(batch):
        calls.append(list(batch))
        return [("ok", r.uid) for r in batch]

    kw.setdefault("max_wait_s", 1e9)
    sched = RequestScheduler(flush_fn, lane_fn=lambda r: r.lane, **kw)
    return sched, calls


def test_lane_isolation_size_flush_drains_one_lane():
    """A rank-lane size flush must NOT drag the retrieve lane's queue
    with it — that is the whole point of per-lane policies."""
    sched, calls = _recording_sched(
        max_requests=100,
        lane_policies={"rank": LanePolicy(max_requests=2)})
    f_ret = sched.submit(_mk(0, "retrieve", 1)[0])
    f0, f1 = [sched.submit(r) for r in _mk(10, "rank", 2)]
    assert len(calls) == 1                      # rank tripped its threshold
    assert [r.lane for r in calls[0]] == ["rank", "rank"]
    assert f0.done() and f1.done() and not f_ret.done()
    assert sched.lane_stats()["retrieve"]["pending"] == 1
    sched.flush()
    assert f_ret.result() == ("ok", 0)
    assert len(calls) == 2


def test_shared_flush_mode_drains_everything():
    """``isolate_lanes=False`` reproduces the pre-SLO one-queue scheduler:
    any trigger drains every lane through ONE flush_fn call."""
    sched, calls = _recording_sched(
        max_requests=100, isolate_lanes=False,
        lane_policies={"rank": LanePolicy(max_requests=2)})
    sched.submit(_mk(0, "retrieve", 1)[0])
    [sched.submit(r) for r in _mk(10, "rank", 2)]
    assert len(calls) == 1
    assert sorted(r.lane for r in calls[0]) == ["rank", "rank", "retrieve"]
    assert sched.flushes == 1


def test_explicit_flush_is_one_combined_call():
    """``flush()`` with no lane drains every lane together in a single
    flush_fn call — the engine's shared user-encode pass depends on it."""
    sched, calls = _recording_sched(max_requests=100)
    for r in _mk(0, "rank", 2) + _mk(10, "retrieve", 2) + _mk(20, "two_stage", 1):
        sched.submit(r)
    sched.flush()
    assert len(calls) == 1 and len(calls[0]) == 5
    assert sched.flushes == 1 and sched.coalesced == 5


def test_per_lane_candidate_threshold():
    sched, calls = _recording_sched(
        max_requests=100,
        lane_policies={"retrieve": LanePolicy(max_candidates=6)})
    sched.submit(FakeRequest(0, "retrieve", 0, list(range(4))))
    assert not calls
    sched.submit(FakeRequest(1, "retrieve", 0, list(range(4))))
    assert len(calls) == 1 and len(calls[0]) == 2


def test_targeted_result_flushes_only_its_lane():
    sched, calls = _recording_sched(max_requests=100)
    f_rank = sched.submit(_mk(0, "rank", 1)[0])
    f_ret = sched.submit(_mk(10, "retrieve", 1)[0])
    assert f_rank.result() == ("ok", 0)
    assert len(calls) == 1 and [r.lane for r in calls[0]] == ["rank"]
    assert not f_ret.done()
    sched.flush()


# ---------------------------------------------------------------------------
# unit tests: shed paths
# ---------------------------------------------------------------------------

def test_deadline_shed_carries_typed_error():
    sched, calls = _recording_sched(
        max_requests=100,
        lane_policies={"rank": LanePolicy(shed_ms=0.0)})
    f = sched.submit(_mk(0, "rank", 1)[0])
    sched.flush()
    assert not calls                            # shed at pickup, not served
    assert f.done() and f.shed()
    with pytest.raises(ShedError) as ei:
        f.result()
    e = ei.value
    assert e.lane == "rank" and e.reason == "deadline"
    assert e.wait_ms > 0.0 and e.budget_ms == 0.0 and e.priority == 0
    assert "rank" in str(e) and "deadline" in str(e)
    assert sched.shed_total == 1 and sched.coalesced == 0
    assert sched.lane_stats()["rank"]["shed"] == 1


def test_protected_priority_served_and_counted_as_miss():
    """Over-budget requests ABOVE shed_max_priority are served anyway —
    the budget records a deadline miss instead of shedding them."""
    sched, calls = _recording_sched(
        max_requests=100,
        lane_policies={"rank": LanePolicy(shed_ms=0.0,
                                          shed_max_priority=0)})
    f = sched.submit(_mk(0, "rank", 1, priority=1)[0])
    sched.flush()
    assert f.result() == ("ok", 0)
    assert len(calls) == 1
    stats = sched.lane_stats()["rank"]
    assert stats["deadline_misses"] == 1 and stats["shed"] == 0


def test_huge_budget_sheds_nothing():
    sched, _ = _recording_sched(
        max_requests=100,
        lane_policies={"rank": LanePolicy(shed_ms=1e9)})
    f = sched.submit(_mk(0, "rank", 1)[0])
    assert sched.shed_expired() == 0
    sched.flush()
    assert f.result() == ("ok", 0) and sched.shed_total == 0


def test_shed_expired_sheds_without_flushing():
    sched, calls = _recording_sched(
        max_requests=100,
        lane_policies={"rank": LanePolicy(shed_ms=0.0)})
    f = sched.submit(_mk(0, "rank", 1)[0])
    assert sched.shed_expired() == 1
    assert not calls and f.shed()
    assert sched.lane_stats()["rank"]["pending"] == 0
    sched.flush()                               # nothing left: no call
    assert not calls


def test_admission_sheds_incoming_at_bound():
    sched, _ = _recording_sched(
        max_requests=100,
        lane_policies={"rank": LanePolicy(max_queue=1)})
    f0 = sched.submit(_mk(0, "rank", 1)[0])
    f1 = sched.submit(_mk(1, "rank", 1)[0])     # same priority: incoming loses
    assert f1.shed() and not f0.done()
    with pytest.raises(ShedError) as ei:
        f1.result()
    assert ei.value.reason == "admission" and ei.value.budget_ms is None
    sched.flush()
    assert f0.result() == ("ok", 0)


def test_admission_evicts_lower_priority_victim():
    sched, calls = _recording_sched(
        max_requests=100,
        lane_policies={"rank": LanePolicy(max_queue=1,
                                          shed_max_priority=0)})
    f_low = sched.submit(_mk(0, "rank", 1, priority=0)[0])
    f_hi = sched.submit(_mk(1, "rank", 1, priority=1)[0])
    assert f_low.shed() and not f_hi.done()     # queued loser evicted
    sched.flush()
    assert f_hi.result() == ("ok", 1)
    assert [r.uid for r in calls[0]] == [1]


def test_admission_bound_soft_for_protected_priorities():
    """Two protected requests at a max_queue=1 bound: neither is
    sheddable, so the bound is soft — both queue and both are served."""
    sched, _ = _recording_sched(
        max_requests=100,
        lane_policies={"rank": LanePolicy(max_queue=1,
                                          shed_max_priority=0)})
    fs = [sched.submit(r) for r in _mk(0, "rank", 2, priority=2)]
    assert sched.lane_stats()["rank"]["pending"] == 2
    sched.flush()
    assert [f.result() for f in fs] == [("ok", 0), ("ok", 1)]
    assert sched.shed_total == 0


# ---------------------------------------------------------------------------
# unit tests: flush membership beats shed (the Ticket.result()-era gap)
# ---------------------------------------------------------------------------

def test_flush_membership_beats_shed():
    """REGRESSION (satellite 3): once another caller's flush has picked a
    request up, a concurrent ``shed_expired()`` — even with a 0 ms budget
    — must not shed it: the request deterministically resolves with its
    RESULT.  Pre-SLO ``Ticket.result()`` had no such guarantee."""
    gate = threading.Event()
    entered = threading.Event()
    calls = []

    def slow_flush(batch):
        calls.append(list(batch))
        entered.set()
        assert gate.wait(5.0), "test gate never released"
        return [("ok", r.uid) for r in batch]

    sched = RequestScheduler(
        slow_flush, max_requests=100, max_wait_s=1e9,
        lane_fn=lambda r: r.lane,
        lane_policies={"rank": LanePolicy(shed_ms=1e9)})
    futures = [sched.submit(r) for r in _mk(0, "rank", 3)]

    flusher = threading.Thread(target=sched.flush)
    flusher.start()
    assert entered.wait(5.0)                    # batch is off the queue…
    # …so a zero-budget shed pass must find NOTHING to shed
    sched._lanes["rank"].policy = LanePolicy(shed_ms=0.0)
    assert sched.shed_expired() == 0
    for f in futures:
        assert not f.shed()
    gate.set()
    flusher.join(5.0)
    assert not flusher.is_alive()
    assert [f.result() for f in futures] == [("ok", 0), ("ok", 1), ("ok", 2)]
    assert sched.shed_total == 0 and sched.coalesced == 3
    assert len(calls) == 1


def test_result_does_not_reflush_inflight_request():
    """``result()`` on a future whose request is already inside an
    in-flight flush waits for THAT flush instead of calling flush_fn
    again (the membership check under the queue lock)."""
    gate = threading.Event()
    entered = threading.Event()
    calls = []

    def slow_flush(batch):
        calls.append(list(batch))
        entered.set()
        assert gate.wait(5.0)
        return [("ok", r.uid) for r in batch]

    sched = RequestScheduler(slow_flush, max_requests=100, max_wait_s=1e9,
                             lane_fn=lambda r: r.lane)
    f = sched.submit(_mk(0, "rank", 1)[0])
    flusher = threading.Thread(target=sched.flush)
    flusher.start()
    assert entered.wait(5.0)
    waiter_done = []
    waiter = threading.Thread(
        target=lambda: waiter_done.append(f.result()))
    waiter.start()
    time.sleep(0.02)                            # waiter reaches _done.wait()
    gate.set()
    flusher.join(5.0)
    waiter.join(5.0)
    assert waiter_done == [("ok", 0)]
    assert len(calls) == 1                      # no redundant flush


# ---------------------------------------------------------------------------
# unit tests: auto-tuner
# ---------------------------------------------------------------------------

def test_autotune_adapts_lane_wait_to_flush_latency():
    def flush_fn(batch):
        time.sleep(0.004)                       # ~4 ms flush
        return [("ok", r.uid) for r in batch]

    sched = RequestScheduler(
        flush_fn, max_requests=100, max_wait_s=10.0,
        lane_fn=lambda r: r.lane,
        lane_policies={"rank": LanePolicy(auto_tune=True,
                                          autotune_ratio=0.5,
                                          autotune_min_ms=0.5,
                                          autotune_max_ms=50.0)})
    assert sched.submit(_mk(0, "rank", 1)[0]) is not None
    before = sched.lane_stats()["rank"]["wait_ms"]
    assert before == pytest.approx(10_000.0)    # inherited default
    sched.flush()
    tuned = sched.lane_stats()["rank"]["wait_ms"]
    assert 0.5 <= tuned <= 50.0                 # clamped into policy range
    assert tuned < before                       # adapted DOWN from 10 s
    # a second flush keeps tracking via the EWMA, still in range
    sched.submit(_mk(1, "rank", 1)[0])
    sched.flush()
    assert 0.5 <= sched.lane_stats()["rank"]["wait_ms"] <= 50.0


def test_autotune_skips_combined_flushes():
    """A combined multi-lane flush conflates every lane's wall time — the
    tuner must only learn from single-lane flushes."""
    def flush_fn(batch):
        time.sleep(0.002)
        return [("ok", r.uid) for r in batch]

    sched = RequestScheduler(
        flush_fn, max_requests=100, max_wait_s=10.0,
        lane_fn=lambda r: r.lane,
        lane_policies={"rank": LanePolicy(auto_tune=True)})
    sched.submit(_mk(0, "rank", 1)[0])
    sched.submit(_mk(1, "retrieve", 1)[0])
    sched.flush()                               # combined: two contributors
    assert sched.lane_stats()["rank"]["wait_ms"] == pytest.approx(10_000.0)


# ---------------------------------------------------------------------------
# unit tests: background flusher + close
# ---------------------------------------------------------------------------

def test_background_flusher_sheds_and_serves_per_policy():
    served = []

    def flush_fn(batch):
        served.extend(r.uid for r in batch)
        return [("ok", r.uid) for r in batch]

    with RequestScheduler(
            flush_fn, max_requests=100, max_wait_ms=5.0,
            lane_fn=lambda r: r.lane,
            lane_policies={"rank": LanePolicy(shed_ms=1e9),
                           "retrieve": LanePolicy(shed_ms=0.0)}) as sched:
        f_ok = sched.submit(_mk(0, "rank", 1)[0])
        f_shed = sched.submit(_mk(1, "retrieve", 1)[0])
        deadline = time.time() + 5.0
        while not (f_ok.done() and f_shed.done()) and time.time() < deadline:
            time.sleep(0.002)
    assert f_ok.result() == ("ok", 0)
    assert f_shed.shed()
    assert served == [0]
