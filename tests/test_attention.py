"""Attention math: reference vs naive, blocked tiling, ring-buffer cache,
RoPE — including hypothesis property tests on cache slot bookkeeping."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_stub import given, settings, st

from repro.nn.attention import (Attention, KVCache, attend, attend5,
                                attend_blocked)
from repro.nn.rope import apply_rope


def naive_attention(q, k, v, causal=True, window=None):
    """O(S*T) dense softmax attention, fp64-ish reference."""
    B, S, H, D = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    out = np.zeros_like(np.asarray(q, np.float32))
    qn, kn, vn = map(lambda x: np.asarray(x, np.float32), (q, k, v))
    for b in range(B):
        for h in range(H):
            kk = kn[b, :, h // G]
            vv = vn[b, :, h // G]
            s = qn[b, :, h] @ kk.T / np.sqrt(D)
            for i in range(S):
                for j in range(T):
                    if causal and j > i:
                        s[i, j] = -np.inf
                    if window is not None and j <= i - window:
                        s[i, j] = -np.inf
            p = np.exp(s - s.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            out[b, :, h] = p @ vv
    return out


@pytest.mark.parametrize("causal,window", [(True, None), (True, 8),
                                           (False, None)])
@pytest.mark.parametrize("H,K", [(4, 4), (4, 2), (4, 1)])
def test_attend_matches_naive(causal, window, H, K):
    key = jax.random.PRNGKey(0)
    B, S, D = 2, 24, 16
    q = jax.random.normal(key, (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D))
    out = attend(q, k, v, causal=causal, window=window)
    ref = naive_attention(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5)


def test_blocked_equals_direct():
    key = jax.random.PRNGKey(1)
    B, S, K, G, D = 2, 100, 2, 2, 16
    q = jax.random.normal(key, (B, S, K, G, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D))
    for bq in (16, 32, 64, 100, 128):
        out = attend_blocked(q, k, v, bq=bq)
        ref = attend5(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-6)


@given(size=st.integers(2, 16), n=st.integers(0, 40))
@settings(max_examples=30, deadline=None)
def test_cache_slot_positions_property(size, n):
    """After n appends into a ring of `size`, the valid slots hold exactly
    the last min(n, size) positions."""
    cache = KVCache.zeros(1, size, 1, 4)
    cache = KVCache(cache.k, cache.v, jnp.array([n], jnp.int32))
    pos, valid = cache.slot_positions()
    pos, valid = np.asarray(pos[0]), np.asarray(valid[0])
    expect = set(range(max(0, n - size), n))
    got = set(pos[valid].tolist())
    assert got == expect


def test_cache_update_ring_semantics():
    B, size, K, D = 2, 4, 1, 2
    cache = KVCache.zeros(B, size, K, D, jnp.float32)
    for t in range(7):
        k_new = jnp.full((B, 1, K, D), float(t))
        cache = cache.update(k_new, k_new)
    # positions 3..6 live in slots 3,0,1,2
    np.testing.assert_allclose(np.asarray(cache.k[0, :, 0, 0]),
                               [4, 5, 6, 3])
    pos, valid = cache.slot_positions()
    assert valid.all()
    np.testing.assert_array_equal(np.asarray(pos[0]), [4, 5, 6, 3])


def test_decode_equals_full_attention():
    """Ring-buffer decode (size >= S) reproduces full causal attention."""
    key = jax.random.PRNGKey(2)
    att = Attention(32, 4, 2, 8, rope=True)
    p = att.init(key)
    B, S = 2, 10
    x = jax.random.normal(jax.random.fold_in(key, 3), (B, S, 32))
    full = att(p, x)
    cache = KVCache.zeros(B, 16, 2, 8, jnp.float32)
    outs = []
    for t in range(S):
        y, cache = att.decode(p, x[:, t:t + 1], cache,
                              jnp.full((B, 1), t, jnp.int32))
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=1e-5)


def test_sliding_window_decode_ring():
    """size == window ring cache == full cache with window mask."""
    key = jax.random.PRNGKey(4)
    att = Attention(32, 4, 2, 8, rope=True, window=4)
    p = att.init(key)
    B, S = 1, 12
    x = jax.random.normal(key, (B, S, 32))
    full = att(p, x)
    cache = KVCache.zeros(B, 4, 2, 8, jnp.float32)     # ring of window size
    outs = []
    for t in range(S):
        y, cache = att.decode(p, x[:, t:t + 1], cache,
                              jnp.full((B, 1), t, jnp.int32))
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=1e-5)


def test_rope_rotation_invariance():
    """<rope(q,p), rope(k,p)> depends only on relative position."""
    D = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))
    def dot_at(pq, pk):
        qq = apply_rope(q, jnp.array([[pq]]))
        kk = apply_rope(k, jnp.array([[pk]]))
        return float(jnp.sum(qq * kk))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(6, 3)) > 1e-6  # sanity: not constant
