"""Engine-level SLO scheduling: lane isolation bit-parity, deep pipeline
lookahead parity, priority shed through the typed request path, and the
8-thread adversarial hammer.

Acceptance points covered:
  * with NO shed pressure, lane-isolated flushing is BIT-IDENTICAL to the
    shared-flush baseline (``isolate_lanes=False``) on every lane;
  * ``pipeline_depth`` 4 and 8 (deque lookahead with back-pressure)
    reproduce the synchronous depth-1 scores bit-for-bit, and the fused
    two-stage lane is depth-invariant for any depth >= 2;
  * a shed rank request's future raises :class:`ShedError` end-to-end
    through ``ServingEngine.submit`` while protected priorities on the
    same lane are served;
  * 8 threads of mixed lanes + background flusher + deterministic shed
    pressure + a mid-stream compatible ``attach_index`` refresh + a
    ``stats()`` reader: no deadlock, no torn snapshot, every future
    resolves exactly once, zero post-warmup compiles.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.dcat import DCAT
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.models.config import get_config
from repro.retrieval import IndexBuilder
from repro.serving import (ContextCache, LanePolicy, RankRequest,
                           RetrieveRequest, RetrieveThenRankRequest,
                           ServingEngine, ShedError, TwoStageResult)

L = 16
N_ITEMS = 500
TOP_K = 8
CAND_DIM = 32


@pytest.fixture(scope="module")
def lite_model():
    pcfg = PinFMConfig(rows=512, n_tables=2, sub_dim=8, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=8,
                                       n_negatives=0))
    bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2,
                                                       d_model=64, d_ff=128)
    cfg = FinetuneConfig(variant="lite-last", seq_len=L)
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, cfg)
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, cfg.dcat)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def item_index(lite_model):
    model, params = lite_model
    return IndexBuilder(model, params, batch_size=256).build(0, N_ITEMS)


def _feats(ids):
    return np.stack([np.random.RandomState(int(i) % 4999).randn(CAND_DIM)
                     for i in np.asarray(ids)]).astype(np.float32)


def _user(seed):
    r = np.random.RandomState(seed)
    return (r.randint(0, N_ITEMS, L), r.randint(0, 6, L),
            r.randint(0, 3, L), r.randn(32).astype(np.float32))


def _mk_rank(seed, cand_seed=None, n_cand=3, priority=0):
    i, a, s, uf = _user(seed)
    rng = np.random.RandomState(1000 + (cand_seed if cand_seed is not None
                                        else seed))
    ids = rng.randint(0, N_ITEMS, n_cand)
    return RankRequest(seq_ids=i, seq_actions=a, seq_surfaces=s,
                       cand_ids=ids, cand_feats=_feats(ids), user_feats=uf,
                       priority=priority)


def _mk_retrieve(seed, k=TOP_K, priority=0):
    i, a, s, _ = _user(seed)
    return RetrieveRequest(seq_ids=i, seq_actions=a, seq_surfaces=s, k=k,
                           priority=priority)


def _mk_two_stage(seed, k=TOP_K):
    i, a, s, uf = _user(seed)
    return RetrieveThenRankRequest(seq_ids=i, seq_actions=a, seq_surfaces=s,
                                   user_feats=uf, k=k)


def _mk_engine(lite_model, item_index, **kw):
    model, params = lite_model
    kw.setdefault("cache", ContextCache(capacity=256))
    engine = ServingEngine(model, params, max_unique=4, max_candidates=32,
                           **kw)
    engine.attach_index(item_index, k=TOP_K, chunk_rows=256)
    engine.attach_features(_feats)
    engine.warmup()
    return engine


def _assert_same_result(a, b):
    if isinstance(a, TwoStageResult):
        np.testing.assert_array_equal(a.item_ids, b.item_ids)
        np.testing.assert_array_equal(a.retrieval_scores, b.retrieval_scores)
        np.testing.assert_array_equal(a.probs, b.probs)
    elif isinstance(a, tuple):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
    else:
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# bit-parity: lane isolation and deep pipelining change NOTHING numerically
# ---------------------------------------------------------------------------

def test_lane_isolation_bit_parity_with_shared_flush(lite_model, item_index):
    """Per-lane flushing (size-triggered rank drains ALONE, then explicit
    per-lane flushes) must produce bit-identical results to the pre-SLO
    shared-flush baseline draining everything in one combined call."""
    def traffic():
        return ([_mk_rank(s) for s in (1, 2, 3, 1)]
                + [_mk_retrieve(s) for s in (4, 5)]
                + [_mk_two_stage(6)])

    iso = _mk_engine(lite_model, item_index,
                     lane_policies={"rank": LanePolicy(max_requests=4)})
    shared = _mk_engine(lite_model, item_index, isolate_lanes=False,
                        max_pending=1000)

    futs_iso = [iso.submit(r) for r in traffic()]
    # the 4th rank submit tripped the rank lane's threshold on its own…
    assert iso.scheduler.lane_stats()["rank"]["pending"] == 0
    # …without dragging the other lanes' queues with it
    assert iso.scheduler.lane_stats()["retrieve"]["pending"] == 2
    iso.flush(lane="retrieve")
    iso.flush(lane="two_stage")

    futs_shared = [shared.submit(r) for r in traffic()]
    shared.flush()
    assert shared.scheduler.flushes == 1       # one combined drain

    for fi, fs in zip(futs_iso, futs_shared):
        _assert_same_result(fi.result(), fs.result())
    assert iso.registry.compiles_after_warmup == 0
    assert shared.registry.compiles_after_warmup == 0
    assert iso.scheduler.shed_total == 0


@pytest.mark.parametrize("depth", [4, 8])
def test_pipeline_depth_parity(lite_model, item_index, depth):
    """Depth-``d`` lookahead (up to d-1 chunks in flight, oldest finalized
    first) is a pure latency optimization: scores match the synchronous
    depth-1 engine bit-for-bit across a multi-chunk batch, and the fused
    two-stage lane stays depth-invariant."""
    reqs = [_mk_rank(s, n_cand=3 + (s % 4)) for s in range(12)]
    ref = _mk_engine(lite_model, item_index, pipeline_depth=1)
    eng = _mk_engine(lite_model, item_index, pipeline_depth=depth)
    out_ref = ref.score(reqs)
    out = eng.score(reqs)
    for a, b in zip(out, out_ref):
        np.testing.assert_array_equal(a, b)
    # the 12-user batch really exercised the lookahead window
    assert eng.stats()["chunks_executed"] >= 3
    ts_ref = ref.submit(_mk_two_stage(42)).result()
    ts = eng.submit(_mk_two_stage(42)).result()
    _assert_same_result(ts, ts_ref)
    assert eng.registry.compiles_after_warmup == 0


def test_pipeline_depth_validation(lite_model):
    model, params = lite_model
    for bad in (0, 9, -1):
        with pytest.raises(ValueError):
            ServingEngine(model, params, pipeline_depth=bad)


# ---------------------------------------------------------------------------
# shed path through the typed engine front door
# ---------------------------------------------------------------------------

def test_engine_shed_and_priority_exemption(lite_model, item_index):
    """A zero-budget rank lane sheds priority-0 requests with a typed
    ShedError (stats + obs counters agree) while priority-1 requests ride
    the SAME flush to a real, bit-correct score."""
    engine = _mk_engine(
        lite_model, item_index,
        lane_policies={"rank": LanePolicy(shed_ms=0.0,
                                          shed_max_priority=0)})
    ref = _mk_engine(lite_model, item_index)

    f_shed = engine.submit(_mk_rank(1, priority=0))
    f_kept = engine.submit(_mk_rank(2, priority=1))
    engine.flush()
    assert f_shed.shed() and not f_kept.shed()
    with pytest.raises(ShedError) as ei:
        f_shed.result()
    assert ei.value.lane == "rank" and ei.value.reason == "deadline"
    np.testing.assert_array_equal(f_kept.result(),
                                  ref.score([_mk_rank(2, priority=1)])[0])

    snap = engine.stats()
    assert snap["scheduler"]["shed"] == 1
    lane = snap["scheduler"]["lane_detail"]["rank"]
    assert lane["shed"] == 1 and lane["deadline_misses"] == 1
    mirror = engine.obs.snapshot()
    assert mirror["repro_serving_scheduler_shed_total"] == 1
    assert engine.registry.compiles_after_warmup == 0


# ---------------------------------------------------------------------------
# the 8-thread adversarial hammer
# ---------------------------------------------------------------------------

STATS_KEYS = {"executors", "cache", "memo_perm_hits", "slab", "masks",
              "lanes", "shared_encode_users", "scheduler", "chunks_executed",
              "pipeline_calls", "last_pipeline", "retrieval"}

N_PER_THREAD = 12


def test_adversarial_hammer(lite_model, item_index):
    """8 threads against one engine: 3 rank submitters (alternating
    sheddable/protected priorities against a 0 ms rank budget), 2 retrieve
    submitters, 1 two-stage submitter, 1 ``stats()`` reader, and 1 thread
    re-attaching a COMPATIBLE index refresh mid-stream — all over the
    background flusher.  Must not deadlock; every future resolves exactly
    once (shed xor served); snapshots are never torn; zero post-warmup
    compiles survive the whole run."""
    engine = _mk_engine(
        lite_model, item_index, max_wait_ms=3.0, max_pending=6,
        lane_policies={"rank": LanePolicy(shed_ms=0.0,
                                          shed_max_priority=0)})
    results = []                # (kind, priority, future), append-only
    res_lock = threading.Lock()
    errors = []
    stop = threading.Event()

    def submitter(kind, tid):
        try:
            for j in range(N_PER_THREAD):
                seed = tid * 100 + j
                if kind == "rank":
                    r = _mk_rank(seed, priority=j % 2)
                elif kind == "retrieve":
                    r = _mk_retrieve(seed)
                else:
                    r = _mk_two_stage(seed)
                f = engine.submit(r)
                with res_lock:
                    results.append((kind, getattr(r, "priority", 0), f))
                time.sleep(0.001)
        except BaseException as e:          # pragma: no cover - fail path
            errors.append(("submit", kind, e))

    def stats_reader():
        try:
            while not stop.is_set():
                snap = engine.stats()
                assert set(snap) == STATS_KEYS, set(snap) ^ STATS_KEYS
                sched = snap["scheduler"]
                assert sched["flushes"] >= 0 and sched["shed"] >= 0
                assert sched["coalesced"] >= 0
                for lane in sched["lane_detail"].values():
                    assert lane["pending"] >= 0 and lane["shed"] >= 0
                time.sleep(0.0005)
        except BaseException as e:          # pragma: no cover - fail path
            errors.append(("stats", None, e))

    def reattacher():
        try:
            for _ in range(4):
                time.sleep(0.01)
                # same (k, bits, dim, chunk_rows): a live refresh that must
                # keep every warmed executor
                engine.attach_index(item_index, k=TOP_K, chunk_rows=256)
        except BaseException as e:          # pragma: no cover - fail path
            errors.append(("attach", None, e))

    threads = ([threading.Thread(target=submitter, args=("rank", t))
                for t in range(3)]
               + [threading.Thread(target=submitter, args=("retrieve", t))
                  for t in range(3, 5)]
               + [threading.Thread(target=submitter, args=("two_stage", 5))]
               + [threading.Thread(target=stats_reader),
                  threading.Thread(target=reattacher)])
    for t in threads:
        t.start()
    for t in threads[:6] + [threads[-1]]:
        t.join(60.0)
        assert not t.is_alive(), "hammer deadlocked"
    engine.close()                          # drain + stop the flusher
    stop.set()
    threads[-2].join(10.0)
    assert not threads[-2].is_alive()
    assert not errors, errors

    served, shed = [], []
    for kind, prio, f in results:
        assert f.done(), f"{kind} future never resolved"
        try:
            value = f.result()
        except ShedError as e:
            assert kind == "rank" and prio == 0, (kind, prio)
            assert e.lane == "rank" and e.reason == "deadline"
            shed.append(f)
            continue
        served.append(f)
        if kind == "rank":
            assert isinstance(value, np.ndarray) and value.shape[0] == 3
        elif kind == "retrieve":
            ids, scores = value
            assert len(ids) == TOP_K
        else:
            assert isinstance(value, TwoStageResult)

    # the 0 ms budget makes shed deterministic: every sheddable rank
    # request sheds at pickup, every protected one is served
    n_rank = 3 * N_PER_THREAD
    assert len(shed) == n_rank // 2
    assert len(served) == len(results) - len(shed)
    snap = engine.stats()
    assert snap["scheduler"]["shed"] == len(shed)
    assert snap["scheduler"]["coalesced"] == len(served)
    assert snap["scheduler"]["lane_detail"]["rank"]["pending"] == 0
    assert engine.registry.compiles_after_warmup == 0
