"""Optional-hypothesis shim: the property-based tests in this suite are a
bonus, not a requirement, so a container without ``hypothesis`` must still
collect and run the example-based tests in the same files.

Usage (at the top of a test module)::

    from _hypothesis_stub import HAVE_HYPOTHESIS, given, settings, st, hnp

When hypothesis is installed these are the real objects.  When it is not,
``given`` decorates the test with ``pytest.mark.skip`` and the strategy
namespaces become inert stand-ins, so ``@given(st.lists(...))`` still
evaluates at module level without importing hypothesis.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    try:
        from hypothesis.extra import numpy as hnp
    except ImportError:          # hypothesis without the numpy extra
        hnp = None
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _InertStrategies:
        """Accepts any attribute/call chain and returns itself."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _InertStrategies()
    hnp = _InertStrategies()

    def given(*args, **kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (see requirements-dev.txt)"
            )(fn)
        return deco

    def settings(*args, **kwargs):
        return lambda fn: fn
