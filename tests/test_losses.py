"""PinFM pretraining losses (paper §3.1) vs a literal per-anchor reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import (LossConfig, _neg_logsumexp, learnable_tau,
                               pinfm_losses)


def _naive_losses(H, z, pos, valid, users, tau, cfg):
    """Literal eq. 2 + the three sums, python loops."""
    H, z = np.asarray(H, np.float64), np.asarray(z, np.float64)
    pos, valid = np.asarray(pos), np.asarray(valid)
    users = np.asarray(users)
    B, L, D = H.shape

    def pair(b, i, j):
        s = H[b, i] @ z[b, j] / tau
        negs = []
        for b2 in range(B):
            for k in range(L):
                if users[b2] != users[b] and pos[b2, k] and valid[b2, k]:
                    negs.append(H[b, i] @ z[b2, k] / tau)
        m = max([s] + negs)
        denom = np.exp(s - m) + sum(np.exp(n - m) for n in negs)
        return -s + m + np.log(denom)

    ntl, n_ntl = 0.0, 0
    mtl, n_mtl = 0.0, 0
    ftl, n_ftl = 0.0, 0
    ld = min(cfg.downstream_len, L - 1) - 1
    for b in range(B):
        for i in range(L):
            if not valid[b, i]:
                continue
            for j in range(L):
                d = j - i
                tgt = pos[b, j] and valid[b, j]
                if d == 1 and tgt:
                    ntl += pair(b, i, j); n_ntl += 1
                if 1 <= d <= cfg.window and tgt and \
                        (cfg.mtl_stride <= 1 or d % cfg.mtl_stride == 1):
                    mtl += pair(b, i, j); n_mtl += 1
                if i == ld and 1 <= d <= cfg.window and tgt:
                    ftl += pair(b, i, j); n_ftl += 1
    return (ntl / max(n_ntl, 1), mtl / max(n_mtl, 1), ftl / max(n_ftl, 1))


def test_losses_match_naive():
    key = jax.random.PRNGKey(0)
    B, L, D = 3, 10, 8
    H = jax.random.normal(key, (B, L, D))
    H = H / jnp.linalg.norm(H, axis=-1, keepdims=True)
    z = jax.random.normal(jax.random.fold_in(key, 1), (B, L, D))
    z = z / jnp.linalg.norm(z, axis=-1, keepdims=True)
    pos = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (B, L))
    valid = jnp.ones((B, L), bool)
    users = jnp.arange(B, dtype=jnp.int32)
    cfg = LossConfig(window=3, downstream_len=6, mtl_stride=1,
                     n_negatives=0)
    tau = 0.1
    total, m = pinfm_losses(H, z, pos, valid, users, tau, cfg)
    ref = _naive_losses(H, z, pos, valid, users, tau, cfg)
    assert np.allclose(float(m["ntl"]), ref[0], atol=1e-4)
    assert np.allclose(float(m["mtl"]), ref[1], atol=1e-4)
    assert np.allclose(float(m["ftl"]), ref[2], atol=1e-4)
    assert np.allclose(float(total), sum(ref), atol=3e-4)


def test_same_user_negatives_excluded():
    """Duplicated user id in the batch: its items must not be negatives."""
    B, L, D = 2, 4, 4
    H = jnp.ones((B, L, D)) / 2
    z = jnp.ones((B, L, D)) / 2
    pos = jnp.ones((B, L), bool)
    users_same = jnp.zeros((B,), jnp.int32)
    lse_same = _neg_logsumexp(H, z, pos, users_same, 1.0)
    assert np.all(np.asarray(lse_same) < -1e29)      # no valid negatives
    users_diff = jnp.arange(B, dtype=jnp.int32)
    lse_diff = _neg_logsumexp(H, z, pos, users_diff, 1.0)
    assert np.all(np.asarray(lse_diff) > -10)


def test_negative_subsampling_close_to_full():
    key = jax.random.PRNGKey(3)
    B, L, D = 4, 32, 8
    H = jax.random.normal(key, (B, L, D))
    z = jax.random.normal(jax.random.fold_in(key, 1), (B, L, D))
    pos = jnp.ones((B, L), bool)
    users = jnp.arange(B, dtype=jnp.int32)
    full = _neg_logsumexp(H, z, pos, users, 1.0, 0)
    sub = _neg_logsumexp(H, z, pos, users, 1.0, 64)
    # subsampled lse is a lower bound, within log(pool ratio) of full
    assert np.all(np.asarray(sub) <= np.asarray(full) + 1e-5)
    assert np.mean(np.asarray(full) - np.asarray(sub)) < 1.5


def test_loss_flags_disable_terms():
    key = jax.random.PRNGKey(4)
    B, L, D = 2, 8, 4
    H = jax.random.normal(key, (B, L, D))
    z = jax.random.normal(jax.random.fold_in(key, 1), (B, L, D))
    pos = jnp.ones((B, L), bool)
    valid = jnp.ones((B, L), bool)
    users = jnp.arange(B, dtype=jnp.int32)
    cfg = LossConfig(use_mtl=False, use_ftl=False, n_negatives=0)
    total, m = pinfm_losses(H, z, pos, valid, users, 0.1, cfg)
    assert "mtl" not in m and "ftl" not in m
    assert np.allclose(float(total), float(m["ntl"]))


def test_learnable_tau_floor():
    assert float(learnable_tau(jnp.log(0.001), LossConfig())) == \
        pytest.approx(0.01)
    assert float(learnable_tau(jnp.log(0.05), LossConfig())) == \
        pytest.approx(0.05, rel=1e-5)
