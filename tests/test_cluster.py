"""Cluster serving tier (this PR's tentpole): N engine workers behind a
rendezvous-hashing ``ClusterRouter`` with cluster-sharded retrieval
fan-out.

Acceptance points covered:
  * rendezvous membership: balanced ownership, join/leave move ONLY the
    new/dead worker's share of the key space (property-style where
    hypothesis is available, example-based always);
  * affinity routing: repeat users land on the worker whose ContextCache
    already holds them — zero re-encodes on the second wave;
  * bit-identical per-request results vs a single engine for rank,
    exact retrieval, IVF retrieval (level ladder parity), and the
    decomposed two-stage path;
  * ``compiles_after_warmup == 0`` on every worker engine and a stable
    shard-scorer compile count across post-warmup mixed traffic;
  * kill-one-worker drain: every in-flight/queued future resolves (or
    fails typed) — never hangs — the dead worker's keys re-route, the
    corpus re-shards across survivors, and post-death traffic still
    matches the single engine;
  * ``merged_metrics()``: one registry with per-worker labels — the
    first real consumer of ``MetricsRegistry.merge``.
"""
import time

import jax
import numpy as np
import pytest

from _hypothesis_stub import given, settings, st
from repro.cluster import (ClusterFuture, ClusterRouter, EngineWorker,
                           Membership, WorkerCore, WorkerLostError)
from repro.configs import smoke_config
from repro.core.dcat import DCAT
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.models.config import get_config
from repro.retrieval import IndexBuilder, build_ivf
from repro.serving import (ContextCache, RankRequest, RetrieveRequest,
                           RetrieveThenRankRequest, ServingEngine,
                           TwoStageResult)

L = 16
N_ITEMS = 500
TOP_K = 8
CAND_DIM = 32


@pytest.fixture(scope="module")
def lite_model():
    pcfg = PinFMConfig(rows=512, n_tables=2, sub_dim=8, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=8,
                                       n_negatives=0))
    bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2,
                                                       d_model=64, d_ff=128)
    cfg = FinetuneConfig(variant="lite-last", seq_len=L)
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, cfg)
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, cfg.dcat)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def item_index(lite_model):
    model, params = lite_model
    return IndexBuilder(model, params, batch_size=256).build(0, N_ITEMS)


@pytest.fixture(scope="module")
def ivf_index(item_index):
    return build_ivf(item_index, 10, seed=0)


def _feats(ids):
    return np.stack([np.random.RandomState(int(i) % 4999).randn(CAND_DIM)
                     for i in np.asarray(ids)]).astype(np.float32)


def _user(seed):
    r = np.random.RandomState(seed)
    return (r.randint(0, N_ITEMS, L), r.randint(0, 6, L),
            r.randint(0, 3, L), r.randn(32).astype(np.float32))


def _mk_rank(seed, cand_rng, n_cand=3):
    i, a, s, uf = _user(seed)
    ids = cand_rng.randint(0, N_ITEMS, n_cand)
    return RankRequest(seq_ids=i, seq_actions=a, seq_surfaces=s,
                       cand_ids=ids, cand_feats=_feats(ids), user_feats=uf)


def _mk_retrieve(seed, k=TOP_K, exclude=False, route="exact", nprobe=None):
    i, a, s, _ = _user(seed)
    return RetrieveRequest(seq_ids=i, seq_actions=a, seq_surfaces=s, k=k,
                           exclude_ids=np.unique(i) if exclude else None,
                           route=route, nprobe=nprobe)


def _mk_two_stage(seed, k=TOP_K, exclude=False):
    i, a, s, uf = _user(seed)
    return RetrieveThenRankRequest(
        seq_ids=i, seq_actions=a, seq_surfaces=s, user_feats=uf, k=k,
        exclude_ids=np.unique(i) if exclude else None)


def _mk_worker_engine(lite_model):
    model, params = lite_model
    return ServingEngine(model, params, max_unique=4, max_candidates=32,
                         cache=ContextCache(capacity=256))


def _mk_cluster(lite_model, n=2, *, index=None, warm=True, fanout_unique=4,
                worker_cls=EngineWorker, **worker_kw):
    workers = {f"w{i}": worker_cls(f"w{i}",
                                   WorkerCore(_mk_worker_engine(lite_model)),
                                   **worker_kw)
               for i in range(n)}
    router = ClusterRouter(workers, fanout_unique=fanout_unique)
    if index is not None:
        router.attach_index(index, k=TOP_K, chunk_rows=256, ivf_nprobe=3)
        router.attach_features(_feats)
    if warm:
        router.warmup()
    return router


def _mk_ref_engine(lite_model, index):
    model, params = lite_model
    engine = ServingEngine(model, params, max_unique=4, max_candidates=32,
                           cache=ContextCache(capacity=256))
    if index is not None:
        engine.attach_index(index, k=TOP_K, chunk_rows=256, ivf_nprobe=3)
        engine.attach_features(_feats)
    return engine


@pytest.fixture(scope="module")
def ref_engine(lite_model, item_index):
    engine = _mk_ref_engine(lite_model, item_index)
    yield engine
    engine.close()


@pytest.fixture(scope="module")
def cluster2(lite_model, item_index):
    router = _mk_cluster(lite_model, 2, index=item_index)
    yield router
    router.close()


def _results(router_or_engine, reqs, timeout=180.0):
    futs = router_or_engine.submit_many(reqs)
    return [f.result(timeout) if isinstance(f, ClusterFuture)
            else f.result() for f in futs]


# ---------------------------------------------------------------------------
# rendezvous membership
# ---------------------------------------------------------------------------

def test_hrw_balance_and_minimal_movement():
    """Ownership is roughly balanced, and adding a worker moves ~1/N of
    the keys — all of them TO the new worker."""
    keys = [f"user-{i}".encode() for i in range(3000)]
    m3 = Membership(["w0", "w1", "w2"])
    before = {k: m3.owner(k) for k in keys}
    counts = {}
    for o in before.values():
        counts[o] = counts.get(o, 0) + 1
    assert set(counts) == {"w0", "w1", "w2"}
    assert min(counts.values()) > 1000 / 2        # no worker starved

    m4 = Membership(["w0", "w1", "w2", "w3"])
    after = {k: m4.owner(k) for k in keys}
    moved = [k for k in keys if before[k] != after[k]]
    assert all(after[k] == "w3" for k in moved)   # only TO the joiner
    assert 0.15 < len(moved) / len(keys) < 0.35   # ~1/4

    # leave: only the dead worker's keys move, and its share drains fully
    m4.mark_dead("w3")
    again = {k: m4.owner(k) for k in keys}
    assert all(again[k] == before[k] for k in keys)   # HRW is history-free


@given(st.lists(st.integers(min_value=0, max_value=2 ** 40),
                min_size=1, max_size=300, unique=True),
       st.integers(min_value=2, max_value=6))
@settings(max_examples=30, deadline=None)
def test_hrw_stability_property(seeds, n_workers):
    """Property: a join moves keys only onto the joiner; a leave moves
    only the leaver's keys — everyone else's cache affinity survives."""
    keys = [str(s).encode() for s in seeds]
    names = [f"n{i}" for i in range(n_workers)]
    m = Membership(names)
    base = {k: m.owner(k) for k in keys}

    m.add("joiner")
    joined = {k: m.owner(k) for k in keys}
    assert all(joined[k] == base[k] or joined[k] == "joiner" for k in keys)

    m.mark_dead("joiner")
    assert all(m.owner(k) == base[k] for k in keys)

    m.mark_dead(names[0])
    dropped = {k: m.owner(k) for k in keys}
    for k in keys:
        if base[k] != names[0]:
            assert dropped[k] == base[k]
        else:
            assert dropped[k] != names[0]


def test_membership_no_alive_raises():
    m = Membership(["a"])
    m.mark_dead("a")
    with pytest.raises(RuntimeError):
        m.owner(b"k")


# ---------------------------------------------------------------------------
# futures
# ---------------------------------------------------------------------------

def test_cluster_future_first_writer_wins():
    f = ClusterFuture()
    seen = []
    f.add_done_callback(lambda fut: seen.append(fut.result(0)))
    assert f._set(41)
    assert not f._set(99)                         # late duplicate dropped
    assert not f._set_error(RuntimeError("stale"))
    assert f.result(0) == 41 and seen == [41]
    late = []
    f.add_done_callback(lambda fut: late.append(fut.result(0)))
    assert late == [41]                           # immediate when done
    with pytest.raises(TimeoutError):
        ClusterFuture().result(0.01)


# ---------------------------------------------------------------------------
# affinity routing + rank parity
# ---------------------------------------------------------------------------

def _count_encodes(engine):
    counts = []
    orig = engine._encode_rows

    def counting(kind, ids, acts, surfs):
        counts.append(len(ids))
        return orig(kind, ids, acts, surfs)

    engine._encode_rows = counting
    return counts


def test_rank_parity_and_cache_affinity(cluster2, lite_model):
    """Cluster rank == single-engine rank bit-for-bit; the second wave of
    the same users encodes NOTHING (every repeat user landed back on the
    worker whose cache holds it)."""
    rng = np.random.RandomState(0)
    reqs = [_mk_rank(s, rng) for s in range(10)]
    owners = {cluster2.owner_of(r) for r in reqs}
    assert owners == {"w0", "w1"}                 # traffic actually splits

    got = _results(cluster2, reqs)
    rng2 = np.random.RandomState(0)
    ref = _mk_ref_engine(lite_model, None).score(
        [_mk_rank(s, rng2) for s in range(10)])
    for a, b in zip(got, ref):
        np.testing.assert_array_equal(a, b)

    cluster2.flush()
    counts = {n: _count_encodes(w.core.engine)
              for n, w in cluster2._workers.items()}
    rng3 = np.random.RandomState(0)
    again = _results(cluster2, [_mk_rank(s, rng3) for s in range(10)])
    for a, b in zip(again, ref):
        np.testing.assert_array_equal(a, b)
    assert all(not c for c in counts.values()), counts   # all cache hits


# ---------------------------------------------------------------------------
# retrieval fan-out parity
# ---------------------------------------------------------------------------

def test_exact_fanout_matches_single_engine(cluster2, ref_engine):
    """Scatter/gather over 2 corpus shards == one engine over the whole
    corpus, bit for bit — including filters, per-request k, and dedup of
    identical (user, filter) rows."""
    reqs = ([_mk_retrieve(s) for s in (20, 21, 22, 23, 24)] +
            [_mk_retrieve(s, exclude=True) for s in (20, 25)] +
            [_mk_retrieve(26, k=4), _mk_retrieve(20)])   # dup of seed 20
    got = _results(cluster2, reqs)
    ref = ref_engine.retrieve(reqs)
    for (ids_a, sc_a), (ids_b, sc_b) in zip(got, ref):
        np.testing.assert_array_equal(ids_a, ids_b)
        np.testing.assert_array_equal(sc_a, sc_b)
    assert cluster2.stats()["fanout_coalesced"] >= 1


def test_ivf_fanout_matches_single_engine(lite_model, ivf_index):
    """IVF fan-out: the router plans probes on the full index, workers
    score their shard's slices, and the merged result matches a single
    engine attach-for-attach across the nprobe level ladder."""
    router = _mk_cluster(lite_model, 2, index=ivf_index, warm=False)
    ref = _mk_ref_engine(lite_model, ivf_index)
    try:
        reqs = ([_mk_retrieve(s, route="ivf") for s in (30, 31, 32)] +
                [_mk_retrieve(33, route="ivf", nprobe=5),
                 _mk_retrieve(34, route="ivf", nprobe=10),
                 _mk_retrieve(30, route="ivf", exclude=True),
                 _mk_retrieve(35, route="ivf", k=4)])
        got = _results(router, reqs)
        expect = ref.retrieve(reqs)
        for (ids_a, sc_a), (ids_b, sc_b) in zip(got, expect):
            np.testing.assert_array_equal(ids_a, ids_b)
            np.testing.assert_array_equal(sc_a, sc_b)
    finally:
        router.close()
        ref.close()


def test_two_stage_decomposed_matches_fused(cluster2, ref_engine):
    """Decomposed two-stage (fan-out retrieval -> owner-ranked second
    stage) composes the same TwoStageResult as the engine's fused lane."""
    reqs = [_mk_two_stage(s) for s in (40, 41)] + \
           [_mk_two_stage(42, exclude=True)]
    got = _results(cluster2, reqs)
    ref = _results(ref_engine, reqs)
    for a, b in zip(got, ref):
        assert isinstance(a, TwoStageResult)
        np.testing.assert_array_equal(a.item_ids, b.item_ids)
        np.testing.assert_array_equal(a.retrieval_scores,
                                      b.retrieval_scores)
        np.testing.assert_array_equal(a.probs, b.probs)


def test_zero_compiles_after_warmup(cluster2):
    """After ``router.warmup()``, mixed post-warmup traffic compiles
    NOTHING anywhere: every worker engine's pinned counter stays 0 and
    the shard scorers' compile counts are unchanged."""
    shard_before = {n: w.core.shard.compiles
                    for n, w in cluster2._workers.items()}
    rng = np.random.RandomState(5)
    reqs = ([_mk_rank(s, rng) for s in (50, 51)] +
            [_mk_retrieve(52), _mk_retrieve(53, exclude=True),
             _mk_retrieve(54, k=4), _mk_two_stage(55)])
    for r in _results(cluster2, reqs):
        assert r is not None
    for n, w in cluster2._workers.items():
        assert w.call("compiles_after_warmup") == 0, n
        assert w.core.shard.compiles == shard_before[n], n


# ---------------------------------------------------------------------------
# death + drain
# ---------------------------------------------------------------------------

class _SlowWorker(EngineWorker):
    """Holds each batch long enough for a kill to land mid-flight."""

    def __init__(self, name, core, delay=0.03):
        self._delay = delay
        super().__init__(name, core)

    def _exec_batch(self, requests):
        time.sleep(self._delay)
        return super()._exec_batch(requests)


def test_kill_one_worker_drains_and_reroutes(lite_model, item_index,
                                             ref_engine):
    """The acceptance drain test: kill a worker with work queued and in
    flight — every future resolves (requests are pure, so re-routing to
    the survivor is safe; first-writer-wins absorbs the race with any
    late result), the corpus re-shards onto the survivor, and post-death
    traffic still matches the single engine."""
    router = _mk_cluster(lite_model, 2, warm=False, index=item_index,
                         worker_cls=_SlowWorker)
    try:
        rng = np.random.RandomState(3)
        rank_reqs = [_mk_rank(s, rng) for s in range(10)]
        ret_reqs = [_mk_retrieve(100 + s) for s in range(4)]
        futs = router.submit_many(rank_reqs + ret_reqs)
        victim = router.owner_of(rank_reqs[0])
        survivor = "w1" if victim == "w0" else "w0"
        time.sleep(0.01)                          # let batches start
        router.kill_worker(victim)

        got = [f.result(180.0) for f in futs]     # NEVER hangs
        ref = (ref_engine.score(rank_reqs) + ref_engine.retrieve(ret_reqs))
        for a, b in zip(got[:10], ref[:10]):
            np.testing.assert_array_equal(a, b)
        for (ids_a, sc_a), (ids_b, sc_b) in zip(got[10:], ref[10:]):
            np.testing.assert_array_equal(ids_a, ids_b)
            np.testing.assert_array_equal(sc_a, sc_b)

        snap = router.stats()
        assert snap["workers"][victim] == "dead"
        assert snap["n_alive"] == 1 and snap["deaths"] == 1
        assert snap["reroutes"] >= 1    # the victim's pending were counted
        assert not router._workers[victim].healthy()
        assert router.check_health() == []        # already handled

        # the dead worker's key range fell to the survivor; fresh traffic
        # (1-shard corpus included) still matches the single engine
        assert all(router.owner_of(r) == survivor
                   for r in rank_reqs + ret_reqs)
        again = _results(router, ret_reqs + rank_reqs[:3])
        for (ids_a, sc_a), (ids_b, sc_b) in zip(again[:4], ref[10:]):
            np.testing.assert_array_equal(ids_a, ids_b)
            np.testing.assert_array_equal(sc_a, sc_b)
        for a, b in zip(again[4:], ref[:3]):
            np.testing.assert_array_equal(a, b)
    finally:
        router.close()


def test_all_workers_dead_fails_typed(lite_model):
    """With nobody left, submission fails with WorkerLostError — the
    typed never-hang terminal, not a timeout."""
    router = _mk_cluster(lite_model, 1, warm=False)
    try:
        router.kill_worker("w0")
        fut = router.submit(_mk_rank(0, np.random.RandomState(0)))
        with pytest.raises(WorkerLostError):
            fut.result(10.0)
    finally:
        router.close()


def test_join_rebalances_and_reshards(lite_model, item_index, ref_engine):
    """add_worker: the joiner takes over only its rendezvous share, the
    corpus re-cuts to 3 shards (one possibly short), and retrieval stays
    bit-identical."""
    router = _mk_cluster(lite_model, 2, warm=False, index=item_index)
    try:
        keys = [f"u{i}".encode() for i in range(200)]
        before = {k: router._membership.owner(k) for k in keys}
        router.add_worker(
            "w2", EngineWorker("w2",
                               WorkerCore(_mk_worker_engine(lite_model))))
        after = {k: router._membership.owner(k) for k in keys}
        moved = [k for k in keys if before[k] != after[k]]
        assert moved and all(after[k] == "w2" for k in moved)
        assert len(router._shard_order) == 3

        reqs = [_mk_retrieve(s) for s in (60, 61, 62)] + \
               [_mk_retrieve(63, exclude=True)]
        got = _results(router, reqs)
        ref = ref_engine.retrieve(reqs)
        for (ids_a, sc_a), (ids_b, sc_b) in zip(got, ref):
            np.testing.assert_array_equal(ids_a, ids_b)
            np.testing.assert_array_equal(sc_a, sc_b)
    finally:
        router.close()


# ---------------------------------------------------------------------------
# robustness regressions: the futures-never-hang contract under errors
# ---------------------------------------------------------------------------

def test_fanout_thread_survives_generic_error(lite_model, item_index,
                                              ref_engine):
    """A non-WorkerLostError escaping a fan-out group (here: the owner's
    encode_users raising) resolves that group's futures typed and leaves
    the fan-out daemon alive — later retrieval traffic still works
    instead of hanging forever."""
    router = _mk_cluster(lite_model, 1, warm=False, index=item_index)
    try:
        core = router._workers["w0"].core
        orig = core.encode_users

        def boom(requests):
            raise ValueError("encode exploded")
        core.encode_users = boom
        fut = router.submit(_mk_retrieve(90))
        with pytest.raises(ValueError, match="encode exploded"):
            fut.result(60.0)                  # typed, not a hang
        core.encode_users = orig
        got = _results(router, [_mk_retrieve(91)])   # thread survived
        (ids_b, sc_b), = ref_engine.retrieve([_mk_retrieve(91)])
        np.testing.assert_array_equal(got[0][0], ids_b)
        np.testing.assert_array_equal(got[0][1], sc_b)
    finally:
        router.close()


def test_reshard_mid_scatter_discards_and_retries(lite_model, item_index,
                                                  ref_engine):
    """A shard-generation bump between the scatter snapshot and the
    merge invalidates the partials: the group retries on the fresh
    layout instead of returning a silently incomplete top-k."""
    router = _mk_cluster(lite_model, 2, warm=False, index=item_index)
    try:
        w = router._workers["w0"]
        orig_call = w.call_async
        state = {"bumped": False}

        def bumping(method, *a, **k):
            if method == "shard_topk" and not state["bumped"]:
                state["bumped"] = True
                with router._lock:          # what a concurrent join does
                    router._shard_gen += 1
            return orig_call(method, *a, **k)
        w.call_async = bumping
        attempts = []
        orig_once = router._fan_group_once

        def counting(conf, group):
            attempts.append(1)
            return orig_once(conf, group)
        router._fan_group_once = counting
        reqs = [_mk_retrieve(s) for s in (92, 93)]
        got = _results(router, reqs)
        ref = ref_engine.retrieve(reqs)
        for (ids_a, sc_a), (ids_b, sc_b) in zip(got, ref):
            np.testing.assert_array_equal(ids_a, ids_b)
            np.testing.assert_array_equal(sc_a, sc_b)
        assert len(attempts) == 2           # first discarded, second clean
    finally:
        router.close()


def test_close_timeout_resolves_stranded_futures(lite_model):
    """close() whose graceful drain times out resolves every queued +
    in-flight future with the typed WorkerLostError — a caller blocked
    in result() with no timeout must not hang on teardown."""
    core = WorkerCore(_mk_worker_engine(lite_model))
    w = _SlowWorker("w0", core, delay=1.5)
    rng = np.random.RandomState(7)
    futs = [ClusterFuture() for _ in range(3)]
    assert w.submit_batch([(_mk_rank(s, rng), f)
                           for s, f in enumerate(futs)])
    time.sleep(0.05)                        # let the batch start
    t0 = time.monotonic()
    w.close(timeout=0.1)
    for f in futs:
        with pytest.raises(WorkerLostError, match="close timeout"):
            f.result(30.0)
    assert time.monotonic() - t0 < 10.0
    core.engine.close()


def test_stats_survives_mid_snapshot_death(lite_model):
    """A worker dying between the stats() snapshot and its reply yields
    an error entry for that worker, not an exception — telemetry stays
    available exactly during a death window."""
    router = _mk_cluster(lite_model, 2, warm=False)
    try:
        w = router._workers["w1"]
        orig = w.call_async

        def dying(method, *a, **k):
            if method == "stats":           # simulate death-after-snapshot
                fut = ClusterFuture()
                fut._set_error(WorkerLostError("w1", "death window"))
                return fut
            return orig(method, *a, **k)
        w.call_async = dying
        snap = router.stats()
        assert "error" in snap["per_worker"]["w1"]
        assert "engine" in snap["per_worker"]["w0"]
    finally:
        router.close()


# ---------------------------------------------------------------------------
# merged metrics
# ---------------------------------------------------------------------------

def test_merged_metrics_per_worker_labels(cluster2):
    """merged_metrics() folds the router's and every in-process engine's
    registry into one, each series tagged with its worker."""
    _results(cluster2, [_mk_retrieve(70), _mk_rank(71,
                                                   np.random.RandomState(1))])
    reg = cluster2.merged_metrics()
    snap = reg.snapshot()
    for who in ('worker="router"', 'worker="w0"', 'worker="w1"'):
        assert any(who in k for k in snap), who
    routed = [k for k in snap if "cluster_requests_total" in k
              and 'lane="rank"' in k]
    assert routed and all('worker="router"' in k for k in routed)
    text = reg.prometheus_text()
    assert "cluster_requests_total" in text
    assert 'worker="w0"' in text
