"""Deliverable (f): per-assigned-architecture smoke tests — a REDUCED variant
of the same family (<=3 layers, d_model<=512, <=4 experts) runs one forward
and one train step on CPU; output shapes + no NaNs asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, smoke_config
from repro.models.config import get_config
from repro.models.encdec import EncDecLM
from repro.models.transformer import TransformerLM
from repro.training.optim import AdamWConfig, adamw_init, adamw_update

B, S = 2, 32


def _batch(cfg, key):
    if cfg.family == "audio":
        return {"frames": jax.random.normal(key, (B, S, cfg.d_model)),
                "tokens": jax.random.randint(key, (B, 8), 0, cfg.vocab),
                "labels": jax.random.randint(key, (B, 8), 0, cfg.vocab)}
    if cfg.family == "vlm":
        return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
                "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
                "embeds": jax.random.normal(key, (B, 4, cfg.frontend_dim))}
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(get_config(arch))
    assert cfg.n_layers <= 3 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    model = EncDecLM(cfg) if cfg.family == "audio" else TransformerLM(cfg)
    key = jax.random.PRNGKey(hash(arch) % 2**31)
    params = model.init(key)
    batch = jax.tree.map(jnp.asarray, _batch(cfg, key))

    # forward: shapes + finiteness
    if cfg.family == "audio":
        logits, _ = model.forward(params, batch)
        assert logits.shape == (B, 8, cfg.vocab)
    elif cfg.family == "vlm":
        logits, _ = model.forward(params, batch["tokens"],
                                  embeds=batch["embeds"])
        assert logits.shape == (B, S + 4, cfg.vocab)
    else:
        logits, _ = model.forward(params, batch["tokens"])
        assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one train step: loss finite, params move, no NaNs anywhere
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    opt = adamw_init(params)
    (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
        params, batch)
    assert bool(jnp.isfinite(loss))
    new_params, opt, _ = adamw_update(opt_cfg, params, grads, opt)
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda a, b: bool(jnp.any(a != b)), params, new_params))
    assert moved
    leaves_ok = all(bool(jnp.all(jnp.isfinite(l)))
                    for l in jax.tree.leaves(new_params)
                    if jnp.issubdtype(l.dtype, jnp.floating))
    assert leaves_ok


@pytest.mark.parametrize("arch", [a for a in ASSIGNED if a != "whisper-base"])
def test_smoke_decode_step(arch):
    """serve_step smoke: one token against a warm cache, finite outputs."""
    cfg = smoke_config(get_config(arch)).replace(capacity_factor=8.0)
    model = TransformerLM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    caches = model.init_caches(B, 16)
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    logits, caches2 = model.decode_step(params, tok, caches,
                                        jnp.zeros((B, 1), jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_whisper_decode_smoke():
    cfg = smoke_config(get_config("whisper-base"))
    model = EncDecLM(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    enc = model.encode(params, jax.random.normal(key, (B, 12, cfg.d_model)))
    caches = model.prefill_cross(params, enc,
                                 model.init_caches(params, B, 16, 12))
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab)
    logits, _ = model.decode_step(params, tok, caches,
                                  jnp.zeros((B, 1), jnp.int32))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_all_assigned_configs_registered():
    assert len(ASSIGNED) == 10
    families = {get_config(a).family for a in ASSIGNED}
    assert families == {"dense", "moe", "hybrid", "ssm", "vlm", "audio"}


def test_config_dims_match_assignment():
    """Exact dims from the assignment brief."""
    c = get_config("command-r-plus-104b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff, c.vocab) == \
        (64, 12288, 96, 8, 33792, 256000)
    c = get_config("mixtral-8x7b")
    assert (c.n_experts, c.top_k, c.d_ff, c.vocab) == (8, 2, 14336, 32000)
    c = get_config("qwen2-moe-a2.7b")
    assert (c.n_experts, c.top_k, c.n_shared, c.moe_d_ff) == (60, 4, 4, 1408)
    c = get_config("mamba2-2.7b")
    assert (c.n_layers, c.d_model, c.ssm_state) == (64, 2560, 128)
    c = get_config("recurrentgemma-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv, c.d_ff) == \
        (26, 2560, 10, 1, 7680)
    c = get_config("whisper-base")
    assert (c.n_layers, c.encoder_layers, c.d_model, c.n_heads, c.d_ff,
            c.vocab) == (6, 6, 512, 8, 2048, 51865)
    c = get_config("pixtral-12b")
    assert (c.n_layers, c.d_model, c.vocab) == (40, 5120, 131072)
    c = get_config("qwen3-8b")
    assert (c.n_layers, c.d_model, c.d_ff, c.qk_norm) == (36, 4096, 12288, True)
    c = get_config("qwen3-4b")
    assert (c.n_layers, c.d_model, c.d_ff) == (36, 2560, 9728)
    c = get_config("qwen1.5-0.5b")
    assert (c.n_layers, c.d_model, c.qkv_bias) == (24, 1024, True)
