"""Retrieval subsystem: int4 item index, fused score/top-k kernel, sharded
retriever, and the engine's RetrieveRequest path.

Parity tests use LATTICE data — every table value and query coordinate is
an exact multiple of a power of two, so all fp32 arithmetic is exact and
any summation order yields bit-identical scores.  That makes "exact top-k
parity, ties broken by index" a meaningful assertion (ties genuinely occur
on a lattice) instead of an accident of float rounding.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.dcat import DCAT
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.kernels.ref import retrieval_topk_ref
from repro.kernels.retrieval_topk import retrieval_topk
from repro.models.config import get_config
from repro.quant import quantize_table
from repro.retrieval import (CorpusScorer, IndexBuilder, ItemIndex,
                             ShardedRetriever)
from repro.serving import ContextCache, RankRequest, RetrieveRequest, \
    ServingEngine

L = 16


def lattice_corpus(R, D, seed=0, bits=4):
    """Quantization-exact corpus + queries: codes already on the intN grid,
    scale/bias powers of two -> quantize_table round-trips exactly."""
    rng = np.random.RandomState(seed)
    hi = 2 ** bits
    table = rng.randint(0, hi, (R, D)).astype(np.float32) / hi - 0.5
    qt = quantize_table(jnp.asarray(table), bits)
    q = rng.randint(-8, 8, (8, D)).astype(np.float32) / 16
    return qt, jnp.asarray(q)


# ---------------------------------------------------------------------------
# kernel vs jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_kernel_parity_64k():
    """Acceptance: exact top-k parity on a >= 64k-row corpus."""
    qt, q = lattice_corpus(65536, 32)
    rs, rr = retrieval_topk_ref(qt.packed, qt.scale, qt.bias, q, k=64)
    ks, kr = retrieval_topk(qt.packed, qt.scale, qt.bias, q, k=64,
                            block_rows=2048)
    np.testing.assert_array_equal(np.asarray(kr), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))


@pytest.mark.parametrize("bits,R,k,block_rows", [
    (4, 4096, 37, 256), (4, 3001, 17, 512), (8, 2048, 100, 256),
    (4, 100, 100, 64),
])
def test_kernel_parity_sweep(bits, R, k, block_rows):
    qt, q = lattice_corpus(R, 32, seed=R, bits=bits)
    rs, rr = retrieval_topk_ref(qt.packed, qt.scale, qt.bias, q, k=k,
                                bits=bits)
    ks, kr = retrieval_topk(qt.packed, qt.scale, qt.bias, q, k=k, bits=bits,
                            block_rows=block_rows)
    np.testing.assert_array_equal(np.asarray(kr), np.asarray(rr))
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(rs))


def test_tie_break_by_index():
    """Duplicate rows score identically; every path must return the LOWEST
    row indices, in index order."""
    rng = np.random.RandomState(3)
    base = rng.randint(0, 16, (64, 32)).astype(np.float32) / 16
    table = np.tile(base, (8, 1))                   # row r == row r % 64
    qt = quantize_table(jnp.asarray(table), 4)
    q = jnp.asarray(rng.randint(-8, 8, (4, 32)).astype(np.float32) / 16)
    k = 96                                          # forces tied groups
    rs, rr = retrieval_topk_ref(qt.packed, qt.scale, qt.bias, q, k=k)
    ks, kr = retrieval_topk(qt.packed, qt.scale, qt.bias, q, k=k,
                            block_rows=128)
    np.testing.assert_array_equal(np.asarray(kr), np.asarray(rr))
    idx = ItemIndex(qt=qt, start_id=0, n_items=512)
    for mode in ("fused", "ref"):
        sc = CorpusScorer(idx, mode=mode, chunk_rows=128, block_rows=16)
        _, r = sc.topk(q, k)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(rr))
    sh = ShardedRetriever(idx, chunk_rows=128, block_rows=16)
    np.testing.assert_array_equal(sh.topk(q, k)[1], np.asarray(rr))
    # within a tied score group the indices must be ascending
    rr_np, rs_np = np.asarray(rr), np.asarray(rs)
    for qi in range(rr_np.shape[0]):
        for j in range(1, k):
            if rs_np[qi, j] == rs_np[qi, j - 1]:
                assert rr_np[qi, j] > rr_np[qi, j - 1]


# ---------------------------------------------------------------------------
# CorpusScorer / ShardedRetriever
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("R,chunk,block", [(4096, 1024, 32), (3001, 512, 16),
                                           (777, 4096, 32), (50, 64, 8)])
def test_scorer_modes_agree(R, chunk, block):
    qt, q = lattice_corpus(R, 32, seed=R)
    idx = ItemIndex(qt=qt, start_id=10, n_items=R)
    k = min(40, R)
    rs, rr = retrieval_topk_ref(qt.packed, qt.scale, qt.bias, q, k=k)
    for mode in ("fused", "pallas"):
        sc = CorpusScorer(idx, mode=mode, chunk_rows=chunk, block_rows=block)
        s, r = sc.topk(q, k)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(rr))
        np.testing.assert_array_equal(np.asarray(s), np.asarray(rs))
    sh = ShardedRetriever(idx, chunk_rows=chunk, block_rows=block)
    ss, sr = sh.topk(q, k)
    np.testing.assert_array_equal(sr, np.asarray(rr))
    # id mapping
    s, ids = CorpusScorer(idx, mode="fused", chunk_rows=chunk,
                          block_rows=block).retrieve(q, k)
    np.testing.assert_array_equal(ids, np.asarray(rr) + 10)


def test_sharded_matches_single_device_multihost():
    """Sharded == single-device on a virtual 2-device mesh (subprocess: the
    device count must be set before jax initializes)."""
    src = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys; sys.path.insert(0, r"%s")
import numpy as np, jax, jax.numpy as jnp
from repro.quant import quantize_table
from repro.retrieval import CorpusScorer, ItemIndex, ShardedRetriever
assert jax.device_count() == 2
rng = np.random.RandomState(0)
R, D, k = 3333, 32, 50
table = rng.randint(0, 16, (R, D)).astype(np.float32) / 16 - 0.5
qt = quantize_table(jnp.asarray(table), 4)
q = jnp.asarray(rng.randint(-8, 8, (4, D)).astype(np.float32) / 16)
idx = ItemIndex(qt=qt, start_id=0, n_items=R)
s1, r1 = CorpusScorer(idx, mode="fused", chunk_rows=512,
                      block_rows=16).topk(q, k)
sh = ShardedRetriever(idx, chunk_rows=512, block_rows=16)
assert sh.n_shards == 2
s2, r2 = sh.topk(q, k)
assert np.array_equal(np.asarray(r1), r2), (np.asarray(r1), r2)
assert np.array_equal(np.asarray(s1), s2)
# k larger than rows_per_shard: per-shard k clips, merge stays exact
small = ItemIndex(qt=quantize_table(jnp.asarray(table[:120]), 4),
                  start_id=0, n_items=120)
s3, r3 = CorpusScorer(small, mode="ref").topk(q, 96)
shs = ShardedRetriever(small, chunk_rows=64, block_rows=16)
assert shs.rows_per_shard < 96
s4, r4 = shs.topk(q, 96)
assert np.array_equal(np.asarray(r3), r4), (np.asarray(r3), r4)
# filtered: per-shard mask slices + merge must match the masked oracle,
# including exclusions that straddle the shard boundary
from repro.retrieval import ItemFilter
filts = [ItemFilter(exclude_ids=rng.choice(R, 800, replace=False))
         for _ in range(4)]
s5, r5 = CorpusScorer(idx, mode="ref").topk(q, k, filters=filts)
s6, r6 = sh.topk(q, k, filters=filts)
assert np.array_equal(np.asarray(r5), r6), (np.asarray(r5), r6)
assert np.array_equal(np.asarray(s5), s6)
print("OK")
""" % __import__("os").path.join(__import__("os").path.dirname(__file__),
                                 "..", "src")
    out = subprocess.run([sys.executable, "-c", src], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# IndexBuilder + ItemIndex persistence
# ---------------------------------------------------------------------------

def _lite_model():
    pcfg = PinFMConfig(rows=512, n_tables=2, sub_dim=8, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=8,
                                       n_negatives=0))
    bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2,
                                                       d_model=64, d_ff=128)
    cfg = FinetuneConfig(variant="lite-last", seq_len=L)
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, cfg)
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, cfg.dcat)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def lite_model():
    return _lite_model()


def test_index_builder(lite_model, tmp_path):
    model, params = lite_model
    builder = IndexBuilder(model, params, batch_size=128, bits=4)
    index = builder.build(start_id=5, n_items=300)     # forces a padded tail
    assert index.n_items == 300 and index.dim == model.pcfg.id_dim
    assert index.qt.packed.shape[0] == 300
    # embeddings match the candidate tower directly
    ids = np.asarray([5, 50, 304], np.int32)
    emb = builder.item_embeddings(ids)
    _, e_c, _ = model._candidate_tokens(params, jnp.asarray(ids), None)
    np.testing.assert_allclose(emb, np.asarray(e_c, np.float32), atol=1e-6)
    # int4 packing is lossy but close after the l2-normalized embed
    deq = np.asarray(index.dequantize())
    assert np.abs(deq - builder.item_embeddings(5 + np.arange(300))).max() < 0.1
    # round-trip through npz
    p = str(tmp_path / "index.npz")
    index.save(p)
    back = ItemIndex.load(p)
    assert back.start_id == 5 and back.n_items == 300
    assert back.bits == 4 and back.dim == index.dim
    np.testing.assert_array_equal(np.asarray(back.qt.packed),
                                  np.asarray(index.qt.packed))
    np.testing.assert_array_equal(np.asarray(back.qt.scale),
                                  np.asarray(index.qt.scale))


# ---------------------------------------------------------------------------
# ServingEngine retrieval path
# ---------------------------------------------------------------------------

def _mk_retrieve(seed, k=10):
    r = np.random.RandomState(seed)
    return RetrieveRequest(seq_ids=r.randint(0, 500, L),
                           seq_actions=r.randint(0, 6, L),
                           seq_surfaces=r.randint(0, 3, L), k=k)


def test_engine_retrieve(lite_model):
    model, params = lite_model
    builder = IndexBuilder(model, params, batch_size=256)
    index = builder.build(0, 1000)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=ContextCache(capacity=64))
    engine.attach_index(index, k=20, chunk_rows=256)
    tel = engine.warmup()
    assert tel["compiles_after_warmup"] == 0

    reqs = [_mk_retrieve(1), _mk_retrieve(2), _mk_retrieve(1, k=5),
            _mk_retrieve(3), _mk_retrieve(4), _mk_retrieve(5),
            _mk_retrieve(6)]                 # 6 unique users > max_unique
    res = engine.retrieve(reqs)
    assert engine.registry.compiles_after_warmup == 0
    assert all(len(ids) == r.k and len(s) == r.k
               for (ids, s), r in zip(res, reqs))
    # duplicate user -> identical prefix
    np.testing.assert_array_equal(res[0][0][:5], res[2][0])

    # parity with the reference scorer fed by encode_user directly
    emb = np.stack([np.asarray(model.encode_user(
        params, jnp.asarray(r.seq_ids)[None], jnp.asarray(r.seq_actions)[None],
        jnp.asarray(r.seq_surfaces)[None]))[0] for r in reqs[:2]])
    s_ref, ids_ref = CorpusScorer(index, mode="ref").retrieve(emb, 10)
    np.testing.assert_array_equal(res[0][0], ids_ref[0])
    np.testing.assert_array_equal(res[1][0], ids_ref[1])
    np.testing.assert_allclose(res[0][1], s_ref[0], atol=1e-5)

    # steady state: repeat traffic is all cache hits, zero fresh compiles
    before = engine.cache.misses
    engine.retrieve(reqs)
    assert engine.cache.misses == before
    assert engine.registry.compiles_after_warmup == 0


def test_engine_retrieve_shares_cache_with_ranking(lite_model):
    """A user encoded for ranking must be a ContextCache hit for retrieval
    (same key), and retrieval without a cache still works."""
    model, params = lite_model
    index = IndexBuilder(model, params, batch_size=256).build(0, 500)
    engine = ServingEngine(model, params, max_unique=2, max_candidates=8,
                           cache=ContextCache(capacity=16))
    engine.attach_index(index, k=8, chunk_rows=256)
    u = _mk_retrieve(7, k=8)
    rng = np.random.RandomState(0)
    rank = RankRequest(
        seq_ids=u.seq_ids, seq_actions=u.seq_actions,
        seq_surfaces=u.seq_surfaces, cand_ids=rng.randint(0, 500, 4),
        cand_feats=rng.randn(4, 32).astype(np.float32),
        user_feats=rng.randn(32).astype(np.float32))
    engine.score([rank])
    misses = engine.cache.misses
    engine.retrieve([u])                     # same sequence -> hit
    assert engine.cache.misses == misses

    bare = ServingEngine(model, params, max_unique=2, max_candidates=8)
    bare.attach_index(index, k=8, chunk_rows=256)
    ids_a, _ = bare.retrieve([u])[0]
    ids_b, _ = engine.retrieve([u])[0]
    np.testing.assert_array_equal(ids_a, ids_b)


def test_engine_reattach_invalidates_executors(lite_model):
    """A refreshed index (different k / bits) must not be served by stale
    jitted executors that closed over the old parameters."""
    model, params = lite_model
    builder = IndexBuilder(model, params, batch_size=256)
    engine = ServingEngine(model, params, max_unique=2, max_candidates=8)
    engine.attach_index(builder.build(0, 200), k=8, chunk_rows=256)
    req = _mk_retrieve(9, k=8)
    ids_a, _ = engine.retrieve([req])[0]
    assert len(ids_a) == 8

    builder8 = IndexBuilder(model, params, batch_size=256, bits=8)
    engine.attach_index(builder8.build(0, 200), k=12, chunk_rows=256)
    ids_b, scores_b = engine.retrieve([_mk_retrieve(9, k=12)])[0]
    assert len(ids_b) == 12                 # new k actually served
    # int8 index scored as int8: matches the reference scorer exactly
    import jax.numpy as jnp
    emb = np.asarray(model.encode_user(
        params, jnp.asarray(req.seq_ids)[None],
        jnp.asarray(req.seq_actions)[None],
        jnp.asarray(req.seq_surfaces)[None]))
    _, ids_ref = CorpusScorer(builder8.build(0, 200),
                              mode="ref").retrieve(emb, 12)
    np.testing.assert_array_equal(ids_b, ids_ref[0])

    # oversized per-request k is an error, not a silent truncation
    with pytest.raises(ValueError, match="k<=12"):
        engine.retrieve([_mk_retrieve(9, k=13)])


def test_engine_attach_after_warmup_stays_warm(lite_model):
    """warmup() then attach_index() (and re-attach) must keep steady-state
    recompiles at zero — attach re-warms the retrieval ladder itself."""
    model, params = lite_model
    builder = IndexBuilder(model, params, batch_size=256)
    engine = ServingEngine(model, params, max_unique=2, max_candidates=8)
    engine.warmup()                          # no index yet, cache is None
    engine.attach_index(builder.build(0, 200), k=8, chunk_rows=256)
    engine.retrieve([_mk_retrieve(11, k=8)])
    assert engine.registry.compiles_after_warmup == 0
    engine.attach_index(builder.build(0, 300), k=8, chunk_rows=256)
    engine.retrieve([_mk_retrieve(12, k=8)])
    assert engine.registry.compiles_after_warmup == 0


def test_engine_retrieve_respects_key_fn(lite_model):
    """A custom key_fn (the router-style ids+actions key) must key the
    retrieval cache too, or rank/retrieve stop sharing entries."""
    model, params = lite_model
    index = IndexBuilder(model, params, batch_size=256).build(0, 200)
    cache = ContextCache(capacity=16)
    engine = ServingEngine(
        model, params, max_unique=2, max_candidates=8, cache=cache,
        key_fn=lambda r: ContextCache.key(r.seq_ids, r.seq_actions))
    engine.attach_index(index, k=8, chunk_rows=256)
    u = _mk_retrieve(13, k=8)
    rng = np.random.RandomState(0)
    engine.score([RankRequest(
        seq_ids=u.seq_ids, seq_actions=u.seq_actions,
        seq_surfaces=u.seq_surfaces, cand_ids=rng.randint(0, 200, 4),
        cand_feats=rng.randn(4, 32).astype(np.float32),
        user_feats=rng.randn(32).astype(np.float32))])
    misses, entries = cache.misses, len(cache)
    engine.retrieve([u])                     # same user -> same key -> hit
    assert cache.misses == misses and len(cache) == entries


def test_engine_retrieve_requires_lite(lite_model):
    pcfg = PinFMConfig(rows=512, n_tables=2, sub_dim=8, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=8,
                                       n_negatives=0))
    bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2,
                                                       d_model=64, d_ff=128)
    cfg = FinetuneConfig(variant="base", seq_len=L)
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, cfg)
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, cfg.dcat)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params)
    qt, _ = lattice_corpus(64, 16)
    with pytest.raises(ValueError, match="lite"):
        engine.attach_index(ItemIndex(qt=qt, start_id=0, n_items=64))
    lmodel, lparams = lite_model
    with pytest.raises(ValueError, match="attach_index"):
        ServingEngine(lmodel, lparams).retrieve([_mk_retrieve(0)])
