"""Optimizer, checkpointing, and end-to-end training convergence."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.configs import smoke_config
from repro.models.config import get_config
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optim import (AdamWConfig, adamw_init, adamw_update,
                                  make_schedule)
from repro.training.train import make_train_step, train_loop


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      schedule="constant", grad_clip=0)
    state = adamw_init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2)(params)
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 1e-2
    assert abs(float(params["b"])) < 1e-2


def test_lr_multiplier_slows_subtree():
    """The PinFM 1/10-LR rule: the 'pinfm' subtree must move ~10x less."""
    params = {"pinfm": {"w": jnp.ones(4)}, "ranker": {"w": jnp.ones(4)}}
    cfg = AdamWConfig(lr=0.01, weight_decay=0.0, warmup_steps=0,
                      schedule="constant", grad_clip=0,
                      lr_mults={"pinfm": 0.1})
    state = adamw_init(params)
    grads = jax.tree.map(jnp.ones_like, params)
    new, _, _ = adamw_update(cfg, params, grads, state)
    d_pinfm = float(jnp.abs(new["pinfm"]["w"] - 1).mean())
    d_ranker = float(jnp.abs(new["ranker"]["w"] - 1).mean())
    assert d_pinfm == pytest.approx(d_ranker * 0.1, rel=1e-3)


def test_schedule_shapes():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule="cosine", min_lr_ratio=0.1)
    s = make_schedule(cfg)
    assert float(s(jnp.array(0))) == 0.0
    assert float(s(jnp.array(10))) == pytest.approx(1.0)
    assert float(s(jnp.array(100))) == pytest.approx(0.1, abs=1e-3)


def test_grad_clip_applied():
    params = {"w": jnp.zeros(3)}
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0,
                      schedule="constant")
    state = adamw_init(params)
    _, _, m = adamw_update(cfg, params, {"w": jnp.full(3, 100.0)}, state)
    assert float(m["grad_norm"]) > 100


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16),
                  "d": jnp.array(3, jnp.int32)}}
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, tree, step=7)
    restored = load_checkpoint(path, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_checkpoint_structure_mismatch_raises(tmp_path):
    path = os.path.join(tmp_path, "ckpt")
    save_checkpoint(path, {"a": jnp.ones(2)})
    with pytest.raises(ValueError):
        load_checkpoint(path, {"b": jnp.ones(2)})


@pytest.mark.slow
def test_pinfm_pretraining_converges():
    """30 steps of pretraining on structured synthetic data reduce the
    InfoNCE loss materially (the model learns interest structure)."""
    from repro.data.synthetic import DataConfig, SyntheticActivity
    dcfg = DataConfig(n_users=64, n_items=256, n_topics=8, seq_len=32,
                      seed=0)
    data = SyntheticActivity(dcfg)
    pcfg = PinFMConfig(rows=2048, n_tables=2, sub_dim=16, seq_len=32,
                       loss=LossConfig(window=4, downstream_len=16,
                                       n_negatives=0))
    bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2)
    model = PinFMPretrain(pcfg, bb)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60,
                          schedule="constant", weight_decay=0.0)
    step = jax.jit(make_train_step(model.loss, opt_cfg))
    opt = adamw_init(params)
    params, opt, hist = train_loop(step, params, opt,
                                   data.pretrain_batches(16, 60),
                                   log_every=0)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first * 0.8, f"no convergence: {first} -> {last}"
