"""Device-resident quantized KV slab (serving tentpole).

Covers the acceptance points:
  * ``slab_dtype="fp16"`` escape hatch BIT-IDENTICAL to the host-pack
    path on the same buckets;
  * int8 / int4 slab scores within documented quantization tolerance of
    the escape hatch;
  * zero fresh compiles across put/evict/gather at every bucket of a
    mixed-shape stream (``compiles_after_warmup == 0``);
  * slot lifecycle: LRU eviction recycles slots through the ContextCache
    ``on_evict`` hook, occupancy never exceeds capacity, re-encoded users
    re-quantize deterministically;
  * the fused gather kernel (``kernels/slab_gather.py``) matches its
    ``ref.py`` oracle, jnp == pallas(interpret);
  * a torn-counter hammer over the slab stats section.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dcat import DCATOptions, ctx_slice, ctx_slice_batch
from repro.kernels.ref import slab_gather_ref
from repro.kernels.slab_gather import slab_gather
from repro.quant.kv_cache import pack_int4, quantize_kv, unpack_int4
from repro.serving.context_cache import ContextCache
from repro.serving.engine import ServingEngine
from repro.serving.kv_slab import KVSlab

from test_serving_engine import L, _make_model, _mk_request


@pytest.fixture(scope="module")
def early_model():
    return _make_model(
        "graphsage-lt",
        dcat=DCATOptions(rotate_replace=False, skip_last_self_attn=True))


@pytest.fixture(scope="module")
def rotate_model():
    return _make_model(
        "graphsage-lt",
        dcat=DCATOptions(rotate_replace=True, skip_last_self_attn=True))


def _engine(model_params, *, slab=0, dtype="int8", cache_cap=64, **kw):
    model, params = model_params
    return ServingEngine(model, params, max_unique=4, max_candidates=16,
                         cache=ContextCache(capacity=cache_cap),
                         slab_slots=slab, slab_dtype=dtype, **kw)


# ---------------------------------------------------------------------------
# fused gather kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
def test_slab_gather_kernel_matches_ref(bits):
    rng = np.random.RandomState(0)
    S, R, D = 7, 12, 32
    x = jnp.asarray(rng.randn(S, R, D).astype(np.float32))
    codes, scale = quantize_kv(x, bits=bits)
    slots = jnp.asarray(rng.randint(0, S, size=5).astype(np.int32))
    ref = slab_gather_ref(codes, scale, slots, bits=bits)
    for impl in ("jnp", "pallas"):
        got = slab_gather(codes, scale, slots, bits=bits, impl=impl)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    # the oracle itself dequantizes back to the right neighbourhood
    err = np.max(np.abs(np.asarray(ref) - np.asarray(x)[np.asarray(slots)]))
    assert err <= (1.0 if bits == 4 else 0.05)


def test_int4_pack_unpack_roundtrip():
    rng = np.random.RandomState(1)
    codes = jnp.asarray(rng.randint(-7, 8, size=(3, 10)).astype(np.int8))
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(codes))),
                                  np.asarray(codes))


# ---------------------------------------------------------------------------
# escape hatch: fp16 slab == host pack, bit for bit
# ---------------------------------------------------------------------------

def test_fp16_slab_bit_identical_to_host_pack(early_model):
    host = _engine(early_model)
    slab = _engine(early_model, slab=8, dtype="fp16")
    host.warmup()
    slab.warmup()
    rng = np.random.RandomState(2)
    reqs = [_mk_request(i, rng) for i in range(6)]
    for a, b in zip(host.score(reqs), slab.score(reqs)):
        np.testing.assert_array_equal(a, b)
    # repeat traffic (memo + pure slab hits) stays bit-identical too
    rng = np.random.RandomState(2)
    reqs2 = [_mk_request(i, rng) for i in range(6)]
    for a, b in zip(host.score(reqs2), slab.score(reqs2)):
        np.testing.assert_array_equal(a, b)
    assert slab.registry.compiles_after_warmup == 0
    assert slab.stats()["slab"]["dtype"] == "fp16"


def test_rotated_layout_slab_matches_host(rotate_model):
    """rotate_replace engines store the pre-rotated fixed-L layout in the
    slab (rotation happens inside the put executor) — escape hatch still
    bit-identical, int8 still within tolerance."""
    host = _engine(rotate_model)
    fp = _engine(rotate_model, slab=8, dtype="fp16")
    q8 = _engine(rotate_model, slab=8, dtype="int8")
    rng = np.random.RandomState(3)
    reqs = [_mk_request(i, rng) for i in range(5)]
    ref = host.score(reqs)
    for a, b in zip(ref, fp.score(reqs)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(ref, q8.score(reqs)):
        np.testing.assert_allclose(a, b, atol=5e-3)


# ---------------------------------------------------------------------------
# quantized tolerance (documented: int8 |Δp| < 5e-3, int4 < 5e-2)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,atol", [("int8", 5e-3), ("int4", 5e-2)])
def test_quantized_slab_within_tolerance(early_model, dtype, atol):
    fp = _engine(early_model, slab=8, dtype="fp16")
    q = _engine(early_model, slab=8, dtype=dtype)
    rng = np.random.RandomState(4)
    reqs = [_mk_request(i, rng) for i in range(6)]
    a_all, b_all = fp.score(reqs), q.score(reqs)
    for a, b in zip(a_all, b_all):
        np.testing.assert_allclose(a, b, atol=atol)
    # the quantized store is byte-for-byte deterministic on re-encode:
    # evict everything, re-score, same probabilities
    q.cache.evict_lru(n=64)
    for a, b in zip(b_all, q.score(reqs)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# zero-recompile contract across put/evict/gather
# ---------------------------------------------------------------------------

def test_mixed_shape_stream_zero_recompiles(early_model):
    eng = _engine(early_model, slab=8, dtype="int8", cache_cap=8)
    eng.warmup()
    rng = np.random.RandomState(5)
    for n_req, n_cand, seed0 in ((1, 2, 0), (3, 5, 10), (4, 4, 20),
                                 (2, 16, 0), (4, 8, 30), (1, 1, 40)):
        eng.score([_mk_request(seed0 + i, rng, n_cand=n_cand)
                   for i in range(n_req)])
    assert eng.registry.compiles_after_warmup == 0
    s = eng.stats()["slab"]
    assert s["puts"] > 0 and s["gathers"] > 0
    assert 0 <= s["occupancy"] <= s["capacity"] == 8
    kinds = {k for k, _ in eng.registry.executors()}
    assert {"slab_put", "slab_gather", "context", "cross"} <= kinds


# ---------------------------------------------------------------------------
# slot lifecycle: eviction recycles, capacity pressure, byte accounting
# ---------------------------------------------------------------------------

def test_slot_recycling_under_capacity_pressure(early_model):
    eng = _engine(early_model, slab=4, dtype="int8", cache_cap=4)
    eng.warmup()
    rng = np.random.RandomState(6)
    reqs = [_mk_request(i, rng) for i in range(3)]
    first = eng.score(reqs)
    # 6 more distinct users through a 4-slot slab: eviction must recycle
    eng.score([_mk_request(100 + i, rng) for i in range(6)])
    s = eng.stats()["slab"]
    assert s["evictions"] >= 5
    assert s["occupancy"] <= s["capacity"] == 4
    assert sorted(eng._slab.free + [v[2] for v in eng.cache._d.values()
                                    if isinstance(v, tuple)
                                    and v[0] == "slab"]) == [0, 1, 2, 3]
    # evicted users re-seat on fresh slots with identical quantized scores
    again = eng.score(reqs)
    for a, b in zip(first, again):
        np.testing.assert_array_equal(a, b)
    assert eng.registry.compiles_after_warmup == 0


def test_slab_byte_accounting(early_model):
    model, params = early_model
    slabs = {d: KVSlab(model, params, seq_len=L, slots=4, dtype=d)
             for d in ("fp16", "int8", "int4")}
    # native fp32 leaves: (reps=2, L=16, K=4, D=64) x 2 leaves
    per_user_fp = sum(int(np.prod(s)) * 4
                      for s in slabs["fp16"].leaf_shapes)
    assert slabs["fp16"].bytes_per_user == per_user_fp
    # int8 = 1 byte/elem + fp16 scale per (slot, head) row of D elems
    n_elems = sum(int(np.prod(s)) for s in slabs["int8"].leaf_shapes)
    n_rows = n_elems // 64
    assert slabs["int8"].bytes_per_user == n_elems + 2 * n_rows
    assert slabs["int4"].bytes_per_user == n_elems // 2 + 2 * n_rows
    for slab in slabs.values():
        assert slab.nbytes == (slab.capacity + 1) * slab.bytes_per_user
    # quantization wins the documented resident-user multiplier at fixed
    # arena bytes vs the unquantized escape hatch
    ratio8 = per_user_fp / slabs["int8"].bytes_per_user
    ratio4 = per_user_fp / slabs["int4"].bytes_per_user
    assert ratio8 >= 3.0 and ratio4 >= 4.0


def test_slab_validation_errors(early_model):
    model, params = early_model
    with pytest.raises(ValueError, match="ContextCache"):
        ServingEngine(model, params, slab_slots=8)
    with pytest.raises(ValueError, match="max_unique"):
        ServingEngine(model, params, max_unique=8, slab_slots=4,
                      cache=ContextCache())
    with pytest.raises(ValueError, match="slab_dtype"):
        ServingEngine(model, params, slab_slots=8, slab_dtype="int2",
                      cache=ContextCache())
    lm, lp = _make_model("lite-last")
    with pytest.raises(ValueError, match="early-fusion"):
        ServingEngine(lm, lp, slab_slots=8, cache=ContextCache())


def test_wrong_seq_len_falls_back_to_host_pack(early_model):
    """Traffic at an L the slab wasn't sized for runs the host-pack path
    (counted in slab_fallbacks) instead of mis-gathering — and matches a
    plain host-pack engine bit for bit."""
    eng = _engine(early_model, slab=8, dtype="int8")
    eng.warmup()                      # builds the slab for L=16
    host = _engine(early_model)
    rng = np.random.RandomState(7)
    short = []
    for i in range(2):
        r = _mk_request(50 + i, rng)
        short.append(type(r)(seq_ids=r.seq_ids[:8],
                             seq_actions=r.seq_actions[:8],
                             seq_surfaces=r.seq_surfaces[:8],
                             cand_ids=r.cand_ids, cand_feats=r.cand_feats,
                             user_feats=r.user_feats, graphsage=r.graphsage))
    for a, b in zip(eng.score(short), host.score(short)):
        np.testing.assert_array_equal(a, b)
    assert eng.slab_fallbacks > 0
    assert eng.stats()["slab"]["fallbacks"] == eng.slab_fallbacks


# ---------------------------------------------------------------------------
# vectorized miss-path slicing (satellite): one sync, same bytes
# ---------------------------------------------------------------------------

def test_ctx_slice_batch_matches_per_user_loop(early_model):
    model, params = early_model
    rng = np.random.RandomState(8)
    ids = jnp.asarray(rng.randint(0, 1000, (3, L)).astype(np.int32))
    acts = jnp.asarray(rng.randint(0, 6, (3, L)).astype(np.int32))
    surf = jnp.asarray(rng.randint(0, 3, (3, L)).astype(np.int32))
    _, ctxs, _ = model.encode_context(params, ids, acts, surf, serving=True)
    batch = ctx_slice_batch(ctxs, 2)
    assert len(batch) == 2
    for i, sl in enumerate(batch):
        ref = ctx_slice(ctxs, i)
        for a, b in zip(jax.tree.leaves(sl), jax.tree.leaves(ref)):
            np.testing.assert_array_equal(a, b)
            assert a.flags["C_CONTIGUOUS"]


# ---------------------------------------------------------------------------
# torn-counter hammer over the slab stats section (satellite)
# ---------------------------------------------------------------------------

def test_slab_stats_hammer(early_model):
    eng = _engine(early_model, slab=8, dtype="int8", cache_cap=8)
    eng.warmup()
    errors, snaps = [], []
    stop = threading.Event()

    def writer(tid):
        try:
            rng = np.random.RandomState(tid)
            for i in range(4):
                futs = eng.submit_many(
                    [_mk_request(20 * tid + i + j, rng) for j in range(2)])
                eng.flush()
                for f in futs:
                    f.result()
        except BaseException as e:      # pragma: no cover - diagnostic
            errors.append(e)

    def reader():
        import time
        try:
            while not stop.is_set():
                snaps.append(eng.stats())
                time.sleep(2e-3)
        except BaseException as e:      # pragma: no cover - diagnostic
            errors.append(e)

    threads = [threading.Thread(target=writer, args=(t,)) for t in range(4)]
    r = threading.Thread(target=reader)
    r.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join(120.0)
    stop.set()
    r.join(30.0)
    snaps.append(eng.stats())
    assert not errors
    prev = -1
    for s in snaps:
        assert s["executors"]["compiles_after_warmup"] == 0
        sl = s["slab"]
        for key in ("capacity", "occupancy", "puts", "evictions",
                    "gathers", "gather_hits", "bytes_resident",
                    "bytes_per_user", "fallbacks"):
            assert sl[key] >= 0
        assert sl["occupancy"] <= sl["capacity"] == 8
        assert sl["bytes_resident"] == 9 * sl["bytes_per_user"]
        # cumulative counters only grow between one reader's snapshots
        assert sl["puts"] >= prev
        prev = sl["puts"]
    assert snaps[-1]["slab"]["puts"] >= 8
