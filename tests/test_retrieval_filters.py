"""Filtered retrieval + incremental index refresh.

Parity strategy mirrors tests/test_retrieval.py: LATTICE corpora make all
fp32 arithmetic exact, so "every path matches the masked oracle bit-for-bit,
ties broken by lower row index" is a meaningful assertion.  Filters add two
new tie regimes the unfiltered tests never hit — -inf ties from excluded
rows, and k exceeding the surviving-row count — both pinned here against
``retrieval_topk_ref`` with the same mask.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.ref import retrieval_topk_ref
from repro.quant import quantize_table
from repro.retrieval import (CorpusScorer, IndexBuilder, ItemFilter,
                             ItemIndex, ShardedRetriever, filter_masks,
                             pack_bits, unpack_bits)
from repro.serving import ContextCache, RetrieveRequest, ServingEngine

from test_retrieval import _lite_model, _mk_retrieve, lattice_corpus


@pytest.fixture(scope="module")
def lite_model():
    return _lite_model()


# ---------------------------------------------------------------------------
# mask packing + ItemFilter basics
# ---------------------------------------------------------------------------

def test_pack_bits_round_trip():
    rng = np.random.RandomState(0)
    for n in (1, 31, 32, 33, 100, 777):
        b = rng.rand(n) < 0.3
        words = pack_bits(b)
        assert words.dtype == np.int32 and len(words) == -(-n // 32)
        np.testing.assert_array_equal(unpack_bits(words, n), b)
        # bit r of word r>>5 — the layout every scorer path assumes
        for r in np.flatnonzero(b)[:5]:
            assert (words[r >> 5] >> (r & 31)) & 1


def test_filter_masks_windows():
    """Window-local coordinates: the same filter resolved per shard/chunk
    window must tile the whole-corpus mask."""
    idx = ItemIndex(qt=quantize_table(jnp.zeros((96, 32)), 4),
                    start_id=50, n_items=96,
                    surfaces=np.arange(96) % 4)
    f = ItemFilter(exclude_ids=[50, 83, 145, 9999], allow_surfaces=(0, 1))
    full = filter_masks([f], idx)
    assert full.shape == (1, 3)
    parts = [unpack_bits(filter_masks([f], idx, row_start=s, n_rows=32)[0], 32)
             for s in (0, 32, 64)]
    np.testing.assert_array_equal(np.concatenate(parts),
                                  unpack_bits(full[0], 96))
    excl = unpack_bits(full[0], 96)
    assert excl[0] and excl[33] and excl[95]        # ids 50, 83, 145
    assert excl[2] and not excl[1]                  # surface 2 out, 1 in
    assert filter_masks([None, ItemFilter()], idx) is None


def test_filter_fingerprint():
    a = ItemFilter(exclude_ids=[3, 1, 2], allow_surfaces=(1, 0))
    b = ItemFilter(exclude_ids=[1, 2, 3, 3], allow_surfaces=(0, 1))
    assert a.fingerprint() == b.fingerprint() != b""
    assert ItemFilter().is_empty() and ItemFilter().fingerprint() == b""
    assert a.fingerprint() != ItemFilter(exclude_ids=[1, 2, 3]).fingerprint()


def test_surface_filter_requires_metadata():
    idx = ItemIndex(qt=quantize_table(jnp.zeros((64, 32)), 4),
                    start_id=0, n_items=64)
    with pytest.raises(ValueError, match="surfaces"):
        filter_masks([ItemFilter(allow_surfaces=(1,))], idx)


# ---------------------------------------------------------------------------
# cross-path parity under random masks (incl. the edge regimes)
# ---------------------------------------------------------------------------

def _assert_all_paths_match(idx, q, k, filts, *, chunk=128, block=16,
                            kernel_block=64):
    mask = filter_masks(filts, idx)
    rs, rr = retrieval_topk_ref(
        idx.qt.packed, idx.qt.scale, idx.qt.bias, q, k=k, bits=idx.bits,
        mask=None if mask is None else jnp.asarray(mask))
    for mode in ("fused", "pallas", "ref"):
        sc = CorpusScorer(idx, mode=mode, chunk_rows=chunk, block_rows=block,
                          kernel_block_rows=kernel_block)
        s, r = sc.topk(q, k, filters=filts)
        np.testing.assert_array_equal(np.asarray(r), np.asarray(rr),
                                      err_msg=mode)
        np.testing.assert_array_equal(np.asarray(s), np.asarray(rs),
                                      err_msg=mode)
    sh = ShardedRetriever(idx, chunk_rows=chunk, block_rows=block)
    ss, sr = sh.topk(q, k, filters=filts)
    np.testing.assert_array_equal(sr, np.asarray(rr), err_msg="sharded")
    np.testing.assert_array_equal(ss, np.asarray(rs), err_msg="sharded")
    return np.asarray(rs), np.asarray(rr)


@pytest.mark.parametrize("R,k,frac", [(777, 40, 0.3), (3001, 17, 0.7),
                                      (512, 96, 0.5)])
def test_random_mask_parity(R, k, frac):
    qt, q = lattice_corpus(R, 32, seed=R)
    idx = ItemIndex(qt=qt, start_id=7, n_items=R)
    rng = np.random.RandomState(R)
    filts = [ItemFilter(exclude_ids=7 + rng.choice(
        R, int(frac * R), replace=False)) for _ in range(q.shape[0])]
    _, rr = _assert_all_paths_match(idx, q, k, filts, chunk=256, block=32)
    for qi, f in enumerate(filts):
        assert not np.isin(rr[qi], np.asarray(f.exclude_ids) - 7).any()


def test_whole_chunk_filtered():
    """Every row of an entire scan chunk excluded — the block-max select
    must skip it without disturbing neighbours."""
    qt, q = lattice_corpus(512, 32, seed=2)
    idx = ItemIndex(qt=qt, start_id=0, n_items=512)
    filts = [ItemFilter(exclude_ids=np.arange(128, 256))] * q.shape[0]
    _, rr = _assert_all_paths_match(idx, q, 50, filts)
    assert not ((rr >= 128) & (rr < 256)).any()


def test_k_exceeds_survivors():
    """Fewer surviving rows than k: the tail is (-inf, lowest excluded
    row index) in every path — identical to the oracle."""
    qt, q = lattice_corpus(300, 32, seed=3)
    idx = ItemIndex(qt=qt, start_id=0, n_items=300)
    filts = [ItemFilter(exclude_ids=np.arange(10, 300))] * q.shape[0]
    rs, rr = _assert_all_paths_match(idx, q, 40, filts)
    assert (rs[:, :10] > -np.inf).all() and (rr[:, :10] < 10).all()
    assert (rs[:, 10:] == -np.inf).all()


def test_everything_filtered():
    qt, q = lattice_corpus(200, 32, seed=4)
    idx = ItemIndex(qt=qt, start_id=0, n_items=200)
    filts = [ItemFilter(exclude_ids=np.arange(200))] * q.shape[0]
    rs, rr = _assert_all_paths_match(idx, q, 25, filts)
    assert (rs == -np.inf).all()
    np.testing.assert_array_equal(rr, np.tile(np.arange(25),
                                              (q.shape[0], 1)))


def test_surface_targeting_parity():
    qt, q = lattice_corpus(400, 32, seed=5)
    idx = ItemIndex(qt=qt, start_id=0, n_items=400,
                    surfaces=np.arange(400) % 3)
    filts = [ItemFilter(allow_surfaces=(0,)),
             ItemFilter(allow_surfaces=(1, 2), exclude_ids=[1, 4, 7]),
             None] + [ItemFilter()] * (q.shape[0] - 3)
    _, rr = _assert_all_paths_match(idx, q, 30, filts)
    assert (rr[0] % 3 == 0).all()
    assert (rr[1] % 3 != 0).all()
    assert not np.isin(rr[1], [1, 4, 7]).any()


def test_single_filter_broadcasts():
    qt, q = lattice_corpus(256, 32, seed=6)
    idx = ItemIndex(qt=qt, start_id=0, n_items=256)
    f = ItemFilter(exclude_ids=np.arange(0, 256, 2))
    sc = CorpusScorer(idx, mode="fused", chunk_rows=128, block_rows=16)
    _, r_bcast = sc.topk(q, 20, filters=f)
    _, r_list = sc.topk(q, 20, filters=[f] * q.shape[0])
    np.testing.assert_array_equal(np.asarray(r_bcast), np.asarray(r_list))
    assert (np.asarray(r_bcast) % 2 == 1).all()
    with pytest.raises(ValueError, match="filters"):
        sc.topk(q, 20, filters=[f])


# ---------------------------------------------------------------------------
# incremental refresh: IndexBuilder.append
# ---------------------------------------------------------------------------

def test_append_preserves_existing_rows(lite_model, tmp_path):
    model, params = lite_model
    builder = IndexBuilder(model, params, batch_size=128, bits=4)
    surf = np.arange(300) % 3
    index = builder.build(start_id=5, n_items=300, surfaces=surf)
    grown = builder.append(index, 100, surfaces=np.arange(100) % 3)
    assert grown.n_items == 400 and grown.start_id == 5
    # already-packed rows are byte-identical — nothing was re-quantized
    np.testing.assert_array_equal(np.asarray(grown.qt.packed[:300]),
                                  np.asarray(index.qt.packed))
    np.testing.assert_array_equal(np.asarray(grown.qt.scale[:300]),
                                  np.asarray(index.qt.scale))
    # the appended rows match a from-scratch build of the full range
    full = builder.build(start_id=5, n_items=400)
    np.testing.assert_array_equal(np.asarray(grown.qt.packed),
                                  np.asarray(full.qt.packed))
    # npz round-trip keeps the grown range + surfaces
    p = str(tmp_path / "grown.npz")
    grown.save(p)
    back = ItemIndex.load(p)
    assert back.n_items == 400
    np.testing.assert_array_equal(back.surfaces, grown.surfaces)
    np.testing.assert_array_equal(np.asarray(back.qt.packed),
                                  np.asarray(grown.qt.packed))
    # surfaces bookkeeping is enforced both ways
    with pytest.raises(ValueError, match="surfaces"):
        builder.append(grown, 10)
    plain = builder.build(start_id=0, n_items=50)
    with pytest.raises(ValueError, match="without"):
        builder.append(plain, 10, surfaces=np.zeros(10))


def test_append_then_retrieve_returns_new_items(lite_model):
    """Acceptance: attach -> warmup -> append -> re-attach serves the new
    items with compiles_after_warmup == 0 (the warmed query-bucket ladder
    survives the refresh)."""
    model, params = lite_model
    builder = IndexBuilder(model, params, batch_size=256)
    index = builder.build(0, 300)
    engine = ServingEngine(model, params, max_unique=2, max_candidates=8,
                           cache=ContextCache(capacity=16))
    engine.attach_index(index, k=12, chunk_rows=256)
    tel = engine.warmup()
    assert tel["compiles_after_warmup"] == 0
    req = _mk_retrieve(21, k=12)
    engine.retrieve([req])

    grown = builder.append(index, 200)        # new ids 300..499
    engine.attach_index(grown, k=12, chunk_rows=256)
    res = engine.retrieve([req])[0]
    assert engine.registry.compiles_after_warmup == 0, \
        engine.registry.telemetry()
    # parity with a cold reference scorer over the grown corpus
    import jax.numpy as jnp
    emb = np.asarray(model.encode_user(
        params, jnp.asarray(req.seq_ids)[None],
        jnp.asarray(req.seq_actions)[None],
        jnp.asarray(req.seq_surfaces)[None]))
    _, ids_ref = CorpusScorer(grown, mode="ref").retrieve(emb, 12)
    np.testing.assert_array_equal(res[0], ids_ref[0])

    # force the new items to the top: exclude every original item — every
    # returned id must come from the appended range
    only_new = engine.retrieve([RetrieveRequest(
        seq_ids=req.seq_ids, seq_actions=req.seq_actions,
        seq_surfaces=req.seq_surfaces, k=12,
        exclude_ids=np.arange(300))])[0]
    assert (only_new[0] >= 300).all()
    assert engine.registry.compiles_after_warmup == 0


# ---------------------------------------------------------------------------
# engine filtered-retrieve path
# ---------------------------------------------------------------------------

def test_engine_filtered_retrieve(lite_model):
    model, params = lite_model
    index = IndexBuilder(model, params, batch_size=256).build(
        0, 500, surfaces=np.arange(500) % 2)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=ContextCache(capacity=64))
    engine.attach_index(index, k=16, chunk_rows=256)
    engine.warmup()

    base = _mk_retrieve(31, k=16)
    plain = engine.retrieve([base])[0]
    seen = plain[0][:8]
    filtered = RetrieveRequest(
        seq_ids=base.seq_ids, seq_actions=base.seq_actions,
        seq_surfaces=base.seq_surfaces, k=16, exclude_ids=seen)
    surface = RetrieveRequest(
        seq_ids=base.seq_ids, seq_actions=base.seq_actions,
        seq_surfaces=base.seq_surfaces, k=16, allow_surfaces=(1,))
    # same user three ways in ONE batch: distinct filters must NOT collapse
    # into one retrieval group, but the user embedding is encoded once
    misses0 = engine.cache.misses
    res = engine.retrieve([base, filtered, surface])
    assert engine.cache.misses == misses0      # embedding cache hit all 3
    assert engine.registry.compiles_after_warmup == 0
    np.testing.assert_array_equal(res[0][0], plain[0])
    assert not np.isin(res[1][0], seen).any()
    assert (res[2][0] % 2 == 1).all()

    # exact parity of every variant against the filtered reference scorer
    import jax.numpy as jnp
    emb = np.asarray(model.encode_user(
        params, jnp.asarray(base.seq_ids)[None],
        jnp.asarray(base.seq_actions)[None],
        jnp.asarray(base.seq_surfaces)[None]))
    ref = CorpusScorer(index, mode="ref")
    for got, f in ((res[1], ItemFilter(exclude_ids=seen)),
                   (res[2], ItemFilter(allow_surfaces=(1,)))):
        s_ref, ids_ref = ref.retrieve(emb, 16, filters=f)
        np.testing.assert_array_equal(got[0], ids_ref[0])
        np.testing.assert_allclose(got[1], s_ref[0], atol=1e-5)

    # duplicate (user, filter) pairs dedup into one execution
    before = len(engine.call_stats)
    res2 = engine.retrieve([filtered, filtered])
    np.testing.assert_array_equal(res2[0][0], res2[1][0])
    assert engine.call_stats[-1]["retrieve_users"] == 1
    assert len(engine.call_stats) == before + 1


def test_engine_mask_cache_hits_on_repeat_filters(lite_model):
    """Packed per-chunk filter masks are memoized per ItemFilter
    fingerprint: a session's repeated seen-list pays the host packing cost
    once, and results stay identical.  An index (re-)attach invalidates
    the cached rows (chunk windows / start_id may have moved)."""
    model, params = lite_model
    index = IndexBuilder(model, params, batch_size=256).build(0, 500)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=ContextCache(capacity=64))
    engine.attach_index(index, k=16, chunk_rows=256)    # 500 rows -> 2 chunks
    engine.warmup()
    base = _mk_retrieve(51, k=16)
    seen = np.arange(10, 40)
    filtered = RetrieveRequest(
        seq_ids=base.seq_ids, seq_actions=base.seq_actions,
        seq_surfaces=base.seq_surfaces, k=16, exclude_ids=seen)
    first = engine.retrieve([filtered])[0]
    assert engine.mask_misses == 2 and engine.mask_hits == 0   # one per chunk
    second = engine.retrieve([filtered])[0]
    assert engine.mask_misses == 2 and engine.mask_hits == 2   # pure hits
    np.testing.assert_array_equal(first[0], second[0])
    np.testing.assert_array_equal(first[1], second[1])
    # an equal-fingerprint filter built from a permuted seen-list hits too
    permuted = RetrieveRequest(
        seq_ids=base.seq_ids, seq_actions=base.seq_actions,
        seq_surfaces=base.seq_surfaces, k=16, exclude_ids=seen[::-1].copy())
    engine.retrieve([permuted])
    assert engine.mask_misses == 2 and engine.mask_hits == 4
    assert engine.call_stats[-1]["mask_hits"] == 4             # telemetry
    # re-attach -> cached rows dropped, repacked on next use
    engine.attach_index(index, k=16, chunk_rows=256)
    engine.retrieve([filtered])
    assert engine.mask_misses == 4
    assert engine.registry.compiles_after_warmup == 0


def test_engine_filter_k_exceeds_survivors(lite_model):
    """A filter that leaves fewer than k items: the tail is -inf-scored,
    mirroring the scorer contract, and no recompile happens."""
    model, params = lite_model
    index = IndexBuilder(model, params, batch_size=256).build(0, 200)
    engine = ServingEngine(model, params, max_unique=2, max_candidates=8)
    engine.attach_index(index, k=10, chunk_rows=256)
    engine.warmup()
    req = _mk_retrieve(41, k=10)
    ids, scores = engine.retrieve([RetrieveRequest(
        seq_ids=req.seq_ids, seq_actions=req.seq_actions,
        seq_surfaces=req.seq_surfaces, k=10,
        exclude_ids=np.arange(4, 200))])[0]
    assert engine.registry.compiles_after_warmup == 0
    assert (scores[:4] > -np.inf).all() and (ids[:4] < 4).all()
    assert (scores[4:] == -np.inf).all()
