"""Fine-tuning ranking model: variants, cold-start techniques, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dcat import DCATOptions
from repro.core.finetune import VARIANTS, FinetuneConfig, PinFMRankingModel
from repro.core.metrics import hit_at_k
from repro.core.pretrain import PinFMConfig
from repro.core.losses import LossConfig
from repro.configs import smoke_config
from repro.models.config import get_config

L = 16


@pytest.fixture(scope="module")
def setup():
    pcfg = PinFMConfig(rows=512, n_tables=2, sub_dim=8, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=8,
                                       n_negatives=0))
    bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2,
                                                       d_model=64, d_ff=128)
    return pcfg, bb


def _batch(key, Bu=3, G=4):
    Bc = Bu * G
    ks = jax.random.split(key, 10)
    return {
        "seq_ids": jax.random.randint(ks[0], (Bu, L), 0, 1 << 20),
        "seq_actions": jax.random.randint(ks[1], (Bu, L), 0, 6),
        "seq_surfaces": jax.random.randint(ks[2], (Bu, L), 0, 3),
        "seq_valid": jnp.ones((Bu, L), bool),
        "seq_user_id": jnp.arange(Bu, dtype=jnp.int32),
        "inverse_idx": jnp.repeat(jnp.arange(Bu), G),
        "cand_ids": jax.random.randint(ks[3], (Bc,), 0, 1 << 20),
        "graphsage": jax.random.normal(ks[4], (Bc, 64)),
        "cand_feats": jax.random.normal(ks[5], (Bc, 32)),
        "user_feats": jax.random.normal(ks[6], (Bu, 32)),
        "cand_age_days": jnp.asarray([3.0, 10.0, 40.0] * G + [100.0] * 0)[:Bc],
        "labels": jax.random.bernoulli(ks[7], 0.3, (Bc, 3)).astype(jnp.float32),
    }


@pytest.mark.parametrize("variant", VARIANTS)
def test_variant_runs_and_grads(variant, setup):
    pcfg, bb = setup
    cfg = FinetuneConfig(variant=variant, seq_len=L)
    class _M(PinFMRankingModel):
        pass
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, cfg)
    model.pinfm = type(model.pinfm)(pcfg, bb)       # small backbone
    model.dcat = type(model.dcat)(model.pinfm.body, cfg.dcat)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(jax.random.PRNGKey(1))
    loss, (metrics, logits) = model.loss(params, batch,
                                         rng=jax.random.PRNGKey(2))
    assert logits.shape == (12, 3)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: model.loss(p, batch, rng=jax.random.PRNGKey(2))[0]
                 )(params)
    gn = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def _small_model(pcfg, bb, cfg):
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, cfg)
    from repro.core.pretrain import PinFMPretrain
    from repro.core.dcat import DCAT
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, cfg.dcat)
    return model


def test_cir_changes_training_forward_only(setup):
    """CIR randomizes ids only in training mode (10%); eval is unaffected."""
    pcfg, bb = setup
    cfg = FinetuneConfig(variant="base", seq_len=L, cir_prob=1.0)
    model = _small_model(pcfg, bb, cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(jax.random.PRNGKey(1))
    eval1, _, _ = model.forward(params, batch, train=False)
    eval2, _, _ = model.forward(params, batch, train=False,
                                rng=jax.random.PRNGKey(5))
    np.testing.assert_array_equal(np.asarray(eval1), np.asarray(eval2))
    tr, _, _ = model.forward(params, batch, train=True,
                             rng=jax.random.PRNGKey(5))
    assert float(jnp.max(jnp.abs(tr - eval1))) > 1e-6


def test_idd_dropout_only_on_fresh(setup):
    pcfg, bb = setup
    cfg = FinetuneConfig(variant="base", seq_len=L, use_cir=False,
                         idd_p_fresh=0.9999, idd_p_mid=0.0)
    model = _small_model(pcfg, bb, cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(jax.random.PRNGKey(1))
    batch["cand_age_days"] = jnp.asarray([1.0] * 6 + [100.0] * 6)
    f_train, _, _ = model.pinfm_features(params, batch, train=True,
                                         rng=jax.random.PRNGKey(3))
    f_eval, _, _ = model.pinfm_features(params, batch, train=False)
    fresh_zeroed = np.asarray(jnp.all(f_train[:6] == 0, axis=-1))
    assert fresh_zeroed.all()       # p~1 dropout zeroes fresh rows
    old_same = np.allclose(np.asarray(f_train[6:]), np.asarray(f_eval[6:]))
    assert old_same


def test_hit_at_k():
    scores = jnp.asarray([[0.9, 0.8, 0.7, 0.1], [0.1, 0.2, 0.3, 0.9]])
    labels = jnp.asarray([[1, 0, 1, 1], [0, 0, 0, 1]])
    # group 1 top3 = items 0,1,2 -> 2 hits; group 2 top3 = 3,2,1 -> 1 hit
    assert float(hit_at_k(scores, labels, k=3)) == pytest.approx(0.5)


def test_engine_matches_direct_scoring(setup):
    from repro.serving import RankRequest, ServingEngine
    pcfg, bb = setup
    cfg = FinetuneConfig(variant="graphsage-lt", seq_len=L)
    model = _small_model(pcfg, bb, cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServingEngine(model, params, max_unique=4, max_candidates=8)
    rng = np.random.RandomState(0)
    seq = rng.randint(0, 1000, L)
    reqs = [RankRequest(seq_ids=seq,
                        seq_actions=rng.randint(0, 6, L),
                        seq_surfaces=rng.randint(0, 3, L),
                        cand_ids=rng.randint(0, 1000, 3),
                        cand_feats=rng.randn(3, 32).astype(np.float32),
                        user_feats=rng.randn(32).astype(np.float32),
                        graphsage=rng.randn(3, 64).astype(np.float32))
            for _ in range(2)]
    # identical sequences -> dedup to 1 unique user
    reqs[1].seq_actions = reqs[0].seq_actions
    reqs[1].seq_surfaces = reqs[0].seq_surfaces
    out = engine.score(reqs)
    assert len(out) == 2 and out[0].shape == (3, 3)
    assert engine.call_stats[-1]["unique_users"] == 1
    assert (out[0] >= 0).all() and (out[0] <= 1).all()


def test_engine_user_embedding_cache(setup):
    """Late-fusion serving cache: cached path == uncached path; repeat
    sequences hit the LRU and skip the transformer."""
    from repro.serving import ContextCache, RankRequest, ServingEngine
    pcfg, bb = setup
    cfg = FinetuneConfig(variant="lite-last", seq_len=L)
    model = _small_model(pcfg, bb, cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = ContextCache(capacity=16)
    cached = ServingEngine(
        model, params, max_unique=4, max_candidates=8, cache=cache,
        key_fn=lambda r: ContextCache.key(r.seq_ids, r.seq_actions))
    direct_engine = ServingEngine(model, params, max_unique=4,
                                  max_candidates=8)
    rng = np.random.RandomState(0)

    def mk(seed):
        r = np.random.RandomState(seed)
        return RankRequest(seq_ids=r.randint(0, 1000, L),
                           seq_actions=r.randint(0, 6, L),
                           seq_surfaces=r.randint(0, 3, L),
                           cand_ids=rng.randint(0, 1000, 3),
                           cand_feats=rng.randn(3, 32).astype(np.float32),
                           user_feats=r.randn(32).astype(np.float32))

    reqs = [mk(1), mk(2)]
    out1 = cached.score(reqs)
    assert cache.misses == 2 and cache.hits == 0
    # same users again -> pure cache hits, same scores
    out2 = cached.score(reqs)
    assert cache.hits == 2
    np.testing.assert_allclose(out1[0], out2[0], atol=1e-6)
    # cached path matches the monolithic forward
    direct = direct_engine.score(reqs)
    np.testing.assert_allclose(out1[0], direct[0], atol=1e-4)
    np.testing.assert_allclose(out1[1], direct[1], atol=1e-4)
