"""Model-level invariants: decode == forward per family, MoE capacity,
SSD/RG-LRU chunking and continuation, scan-group structure."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.models.config import get_config
from repro.models.encdec import EncDecLM
from repro.models.transformer import TransformerLM
from repro.nn.recurrent import RecurrentBlock
from repro.nn.ssd import Mamba2Block, ssd_chunked, ssd_step


@pytest.mark.parametrize("arch", ["qwen3-4b", "qwen1.5-0.5b", "mamba2-2.7b",
                                  "recurrentgemma-2b", "mixtral-8x7b"])
def test_decode_matches_forward(arch):
    cfg = smoke_config(get_config(arch)).replace(ssm_chunk=8,
                                                 capacity_factor=8.0)
    m = TransformerLM(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = m.forward(p, toks)
    caches = m.init_caches(B, 64)
    outs = []
    for t in range(S):
        lg, caches = m.decode_step(p, toks[:, t:t + 1], caches,
                                   jnp.full((B, 1), t, jnp.int32))
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=2e-4)


def test_whisper_decode_matches_forward():
    cfg = smoke_config(get_config("whisper-base"))
    m = EncDecLM(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, T, S = 2, 24, 8
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    enc = m.encode(p, frames)
    full = m.decode_fwd(p, toks, enc)
    caches = m.prefill_cross(p, enc, m.init_caches(p, B, 32, T))
    outs = []
    for t in range(S):
        lg, caches = m.decode_step(p, toks[:, t:t + 1], caches,
                                   jnp.full((B, 1), t, jnp.int32))
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full), atol=2e-5)


def test_scan_groups_recurrentgemma_pattern():
    cfg = get_config("recurrentgemma-2b")
    groups = cfg.scan_groups()
    assert groups == [(("rec", "rec", "attn"), 8), (("rec", "rec"), 1)]
    kinds = cfg.block_kinds()
    assert len(kinds) == 26
    assert kinds.count("attn") == 8 and kinds.count("rec") == 18


def test_moe_capacity_drops_are_bounded():
    from repro.nn.moe import MoE
    moe = MoE(16, 32, n_experts=4, top_k=2, capacity_factor=1.0,
              group_size=64)
    p = moe.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 16))
    y, aux = moe(p, x)
    assert y.shape == x.shape
    assert 0.0 <= float(aux["router_overflow"]) < 0.5
    assert float(aux["lb_loss"]) >= 1.0 - 1e-5   # >= 1 by Cauchy-Schwarz


def test_moe_group_size_invariance_without_drops():
    """With generous capacity, grouping must not change outputs."""
    from repro.nn.moe import MoE
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (2, 32, 16))
    outs = []
    for gs in (8, 32, 512):
        moe = MoE(16, 32, n_experts=4, top_k=2, capacity_factor=8.0,
                  group_size=gs)
        p = moe.init(jax.random.PRNGKey(0))
        y, _ = moe(p, x)
        outs.append(np.asarray(y))
    np.testing.assert_allclose(outs[0], outs[1], atol=2e-5)
    np.testing.assert_allclose(outs[0], outs[2], atol=2e-5)


def test_ssd_chunk_invariance():
    key = jax.random.PRNGKey(0)
    B, S, H, P, G, N = 1, 64, 2, 8, 1, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, G, N))
    Cm = jax.random.normal(ks[4], (B, S, G, N))
    y8, h8 = ssd_chunked(x, dt, A, Bm, Cm, chunk=8)
    y32, h32 = ssd_chunked(x, dt, A, Bm, Cm, chunk=32)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y32), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h8), np.asarray(h32), atol=1e-4)


def test_mamba_block_step_matches_seq():
    blk = Mamba2Block(32, expand=2, head_dim=8, d_state=16, chunk=4)
    p = blk.init(jax.random.PRNGKey(0))
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32))
    y_seq, st_seq = blk(p, x)
    st = blk.init_state(2)
    ys = []
    for t in range(12):
        yt, st = blk.step(p, x[:, t:t + 1], st)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_seq), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st.h), np.asarray(st_seq.h),
                               atol=1e-4)


def test_rglru_continuation():
    blk = RecurrentBlock(16, 24)
    p = blk.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y_full, st_full = blk(p, x)
    y1, s1 = blk(p, x[:, :7])
    y2, s2 = blk(p, x[:, 7:], s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2.h), np.asarray(st_full.h),
                               atol=1e-5)


def test_vlm_patch_prefix_changes_text_logits():
    cfg = smoke_config(get_config("pixtral-12b"))
    m = TransformerLM(cfg)
    p = m.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    e1 = jax.random.normal(jax.random.PRNGKey(2), (1, 4, cfg.frontend_dim))
    e2 = jax.random.normal(jax.random.PRNGKey(3), (1, 4, cfg.frontend_dim))
    l1, _ = m.forward(p, toks, embeds=e1)
    l2, _ = m.forward(p, toks, embeds=e2)
    assert float(jnp.max(jnp.abs(l1[:, -8:] - l2[:, -8:]))) > 1e-4
