import os
import sys

# tests run on the single real CPU device — never set the 512-device flag here
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
