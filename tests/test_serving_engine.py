"""Serving engine (paper §4.3): batch planner, shape buckets, executor
registry, context-KV cache, request scheduler.

Covers the acceptance points of the engine refactor:
  * vectorized Ψ/first_of in the planner == the naive per-unique argmax
    loop, on permuted and duplicate request orders (regression);
  * engine.score == per-request single scoring == direct model.forward;
  * cached early-fusion path (ContextCache hit) == uncached pass
    BIT-FOR-BIT on the same bucket;
  * zero fresh compiles on a mixed-shape request stream after warmup();
  * depth-2 pipelined score == pipeline_depth=1 BIT-FOR-BIT, with the
    pack memo / rotated-KV layout riding the same contract;
  * RequestScheduler under concurrency: 8-thread submit hammer,
    background flusher, and the result() double-flush race.
"""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.dcat import DCATOptions, dedup_with_first
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.models.config import get_config
from repro.serving.context_cache import ContextCache
from repro.serving.engine import ServingEngine
from repro.serving.plan import (BucketLadder, RankRequest, build_plan,
                                split_requests)
from repro.serving.scheduler import RequestScheduler

L = 16


def _mk_scheduler(engine, **kw):
    """A RequestScheduler over an engine's mixed-workload flush — the
    machinery ``engine.submit`` owns, driven directly.  Falls back to
    ``score`` for stand-ins that only implement it."""
    flush_fn = getattr(engine, "_flush_requests", None) or engine.score
    kw.setdefault("max_candidates", engine.max_candidates)
    return RequestScheduler(flush_fn, **kw)


def _make_model(variant, **fkw):
    pcfg = PinFMConfig(rows=512, n_tables=2, sub_dim=8, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=8,
                                       n_negatives=0))
    bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2,
                                                       d_model=64, d_ff=128)
    cfg = FinetuneConfig(variant=variant, seq_len=L, **fkw)
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, cfg)
    from repro.core.dcat import DCAT
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, cfg.dcat)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def early_model():
    return _make_model(
        "graphsage-lt",
        dcat=DCATOptions(rotate_replace=False, skip_last_self_attn=True))


@pytest.fixture(scope="module")
def lite_model():
    return _make_model("lite-last")


def _mk_request(user_seed, cand_rng, n_cand=3, graphsage=True):
    r = np.random.RandomState(user_seed)
    return RankRequest(
        seq_ids=r.randint(0, 1000, L),
        seq_actions=r.randint(0, 6, L),
        seq_surfaces=r.randint(0, 3, L),
        cand_ids=cand_rng.randint(0, 1000, n_cand),
        cand_feats=cand_rng.randn(n_cand, 32).astype(np.float32),
        user_feats=r.randn(32).astype(np.float32),
        graphsage=(cand_rng.randn(n_cand, 64).astype(np.float32)
                   if graphsage else None))


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_bucket_ladder():
    lad = BucketLadder(64, 8)
    assert lad.sizes() == (8, 16, 32, 64)
    assert lad.fit(1) == 8 and lad.fit(9) == 16 and lad.fit(64) == 64
    with pytest.raises(ValueError):
        lad.fit(65)
    assert BucketLadder(6, 1).sizes() == (1, 2, 4, 6)


def test_first_of_vectorized_matches_argmax_loop():
    """Regression for the O(B_u*B_c) per-unique np.argmax loop the seed
    router used: the vectorized first_of/inverse must agree on permuted and
    duplicate-heavy request orders."""
    rng = np.random.RandomState(0)
    for trial in range(20):
        n_req = rng.randint(1, 12)
        pattern = rng.randint(0, 5, n_req)            # duplicate-heavy
        rows = np.stack([np.full(L, v) + np.arange(L) for v in pattern])
        uniq, inv, first_of = dedup_with_first(rows)
        # naive reference (the seed implementation)
        ref_first = np.array([np.argmax(inv == u) for u in range(len(uniq))])
        np.testing.assert_array_equal(first_of, ref_first)
        np.testing.assert_array_equal(rows[first_of], uniq)
        np.testing.assert_array_equal(uniq[inv], rows)        # Ψ⁻¹ inverts
        # first-occurrence order is preserved under permutation
        assert (np.diff(first_of) > 0).all()


def test_build_plan_layout():
    rng = np.random.RandomState(0)
    reqs = [_mk_request(s, rng, n_cand=n)
            for s, n in ((1, 3), (2, 2), (1, 4), (3, 1), (1, 2))]
    plan = build_plan(reqs, BucketLadder(8), BucketLadder(32, 4))
    assert plan.n_unique == 3 and plan.b_u == 4
    assert plan.n_candidates == 12 and plan.b_c == 16
    assert plan.counts == [3, 2, 4, 1, 2]
    # candidates of requests 0, 2 and 4 share unique row 0 (same user seed)
    inv = plan.batch["inverse_idx"][:plan.n_candidates]
    np.testing.assert_array_equal(
        inv, [0, 0, 0, 1, 1, 0, 0, 0, 0, 2, 0, 0])
    # padding rows are zero / invalid
    assert not plan.batch["seq_valid"][plan.n_unique:].any()
    assert (plan.batch["cand_ids"][plan.n_candidates:] == 0).all()
    assert len(plan.user_keys) == plan.n_unique
    assert plan.dedup_ratio == pytest.approx(4.0)


def test_plan_dedups_on_full_identity():
    """Ψ may only merge requests whose ENTIRE context input matches —
    same ids with different actions/surfaces are different contexts (and
    different ContextCache keys), so merging them would score one user's
    candidates against the other's context."""
    rng = np.random.RandomState(12)
    a, b = _mk_request(1, rng), _mk_request(1, rng)
    b.seq_actions = (b.seq_actions + 1) % 6
    plan = build_plan([a, b], BucketLadder(8), BucketLadder(32, 4))
    assert plan.n_unique == 2
    assert len(set(plan.user_keys)) == 2
    c = _mk_request(1, rng)                     # identical identity to a
    plan = build_plan([a, c], BucketLadder(8), BucketLadder(32, 4))
    assert plan.n_unique == 1


def test_split_requests_respects_maxima():
    rng = np.random.RandomState(0)
    reqs = [_mk_request(s % 4, rng, n_cand=3) for s in range(10)]
    chunks = split_requests(reqs, max_unique=2, max_candidates=7)
    assert sorted(i for c in chunks for i in c) == list(range(10))
    for c in chunks:
        assert sum(len(reqs[i].cand_ids) for i in c) <= 7
        assert len({reqs[i].seq_ids.tobytes() for i in c}) <= 2
    with pytest.raises(ValueError):
        split_requests([_mk_request(0, rng, n_cand=9)], 4, 8)


# ---------------------------------------------------------------------------
# engine equivalence
# ---------------------------------------------------------------------------

def test_engine_matches_single_request_scoring(early_model):
    model, params = early_model
    rng = np.random.RandomState(1)
    reqs = [_mk_request(s, rng) for s in (1, 2, 1, 3)]
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16)
    batched = engine.score(reqs)
    solo_engine = ServingEngine(model, params, max_unique=4,
                                max_candidates=16)
    for r, b in zip(reqs, batched):
        solo = solo_engine.score([r])[0]
        np.testing.assert_allclose(b, solo, atol=1e-5)


def test_engine_matches_direct_forward(early_model):
    model, params = early_model
    rng = np.random.RandomState(2)
    reqs = [_mk_request(s, rng) for s in (1, 2, 1)]
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16)
    out = engine.score(reqs)
    plan = build_plan(reqs, engine.ladder_u, engine.ladder_c)
    logits, _, _ = model.forward(params, jax.tree.map(jnp.asarray, plan.batch),
                                 train=False)
    ref = np.asarray(jax.nn.sigmoid(logits.astype(jnp.float32)))
    np.testing.assert_allclose(np.concatenate(out), ref[:plan.n_candidates],
                               atol=1e-5)


def test_oversized_single_request_is_split(early_model):
    """A request with more candidates than max_candidates is split by
    candidate slice and reassembled (the seed router padded it instead —
    unbounded shapes; the engine keeps shapes bucketed)."""
    import dataclasses
    model, params = early_model
    rng = np.random.RandomState(10)
    big = _mk_request(1, rng, n_cand=10)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=8)
    out = engine.score([big])
    assert out[0].shape == (10, 3)
    parts = [dataclasses.replace(big, cand_ids=big.cand_ids[s],
                                 cand_feats=big.cand_feats[s],
                                 graphsage=big.graphsage[s])
             for s in (slice(0, 8), slice(8, 10))]
    ref = np.concatenate([engine.score([p])[0] for p in parts])
    np.testing.assert_allclose(out[0], ref, atol=1e-6)


def test_oversized_request_list_is_chunked(early_model):
    model, params = early_model
    rng = np.random.RandomState(3)
    reqs = [_mk_request(s, rng) for s in range(9)]       # 9 users > max_unique
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16)
    out = engine.score(reqs)
    assert len(out) == 9 and all(o.shape == (3, 3) for o in out)
    assert len(engine.call_stats) >= 3                   # several chunks


# ---------------------------------------------------------------------------
# context-KV cache (early fusion)
# ---------------------------------------------------------------------------

def test_context_cache_hit_bitwise_identical(early_model):
    model, params = early_model
    rng = np.random.RandomState(4)
    reqs = [_mk_request(s, rng) for s in (1, 2, 3, 1)]
    cache = ContextCache(capacity=16)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=cache)
    miss_pass = engine.score(reqs)                       # populates the cache
    assert cache.misses == 3 and cache.hits == 0         # 3 unique users
    hit_pass = engine.score(reqs)                        # pure hits
    assert cache.misses == 3 and cache.hits == 3
    for a, b in zip(miss_pass, hit_pass):
        np.testing.assert_array_equal(a, b)              # bit-for-bit
    # and the cached path agrees with the uncached engine
    plain = ServingEngine(model, params, max_unique=4,
                          max_candidates=16).score(reqs)
    for a, b in zip(miss_pass, plain):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_context_cache_eviction_and_bytes(early_model):
    model, params = early_model
    rng = np.random.RandomState(5)
    cache = ContextCache(capacity=2)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=cache)
    engine.score([_mk_request(s, rng) for s in (1, 2, 3)])
    assert len(cache) == 2 and cache.nbytes > 0          # user 1 evicted
    engine.score([_mk_request(1, rng)])
    assert cache.misses == 4                             # re-encoded


def test_lite_cached_matches_uncached(lite_model):
    model, params = lite_model
    rng = np.random.RandomState(6)
    reqs = [_mk_request(s, rng, graphsage=False) for s in (1, 2, 1)]
    cache = ContextCache(capacity=16)
    cached = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=cache)
    out1 = cached.score(reqs)
    assert cache.misses == 2 and cache.hits == 0         # 2 unique users
    out2 = cached.score(reqs)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(a, b)
    plain = ServingEngine(model, params, max_unique=4,
                          max_candidates=16).score(reqs)
    for a, b in zip(out1, plain):
        np.testing.assert_allclose(a, b, atol=1e-4)


# ---------------------------------------------------------------------------
# depth-2 pipeline + pack memo + rotated-KV layout
# ---------------------------------------------------------------------------

def test_pipelined_bit_identical_to_sync(early_model):
    """The tentpole contract: the depth-2 pipeline feeds identical operands
    to identical executors in identical order, so scores match the
    pipeline_depth=1 escape hatch BIT-FOR-BIT across a multi-chunk,
    repeat-user stream — and neither path compiles anything after
    warmup()."""
    model, params = early_model
    kw = dict(max_unique=4, max_candidates=8, min_candidates=4)
    sync = ServingEngine(model, params, cache=ContextCache(32),
                         pipeline_depth=1, **kw)
    pipe = ServingEngine(model, params, cache=ContextCache(32),
                         pipeline_depth=2, **kw)
    assert sync.pipeline_depth == 1 and pipe.pipeline_depth == 2
    sync.warmup()
    pipe.warmup()
    rng = np.random.RandomState(21)
    for trial in range(3):                     # includes pure-repeat passes
        reqs = [_mk_request(s, rng, n_cand=2)
                for s in (1, 2, 3, 4, 5, 1, 2, 6, 7, 8)]
        a, b = sync.score(reqs), pipe.score(reqs)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
    assert sync.registry.compiles_after_warmup == 0
    assert pipe.registry.compiles_after_warmup == 0
    # telemetry: the overlap gauge is bounded and depth-1 never overlaps
    # (how MUCH overlaps is environmental — a fast device can finish before
    # the next prepare even starts, which the is_ready gate counts as 0)
    ps = pipe.pipeline_stats[-1]
    assert ps.depth == 2 and ps.chunks >= 3
    assert 0 <= ps.overlapped_ms <= ps.prepare_ms
    assert 0 <= ps.overlap_fraction <= 1
    assert ps.as_dict()["overlap_fraction"] == ps.overlap_fraction
    assert all(p.overlapped_ms == 0 for p in sync.pipeline_stats)


def test_pack_memo_skips_pack_on_exact_repeat(early_model):
    """An exact-repeat batch (same ordered unique-user tuple) is served
    from the device-side pack memo — no ctx_slice/ctx_pack/H2D — and is
    bit-identical because the executor consumes the very same device
    buffers."""
    model, params = early_model
    cache = ContextCache(capacity=16, memo_capacity=8)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=cache)
    rng = np.random.RandomState(22)
    reqs = [_mk_request(s, rng) for s in (1, 2, 3, 1)]
    first = engine.score(reqs)
    assert cache.memo_misses == 1 and cache.memo_hits == 0
    second = engine.score(reqs)
    assert cache.memo_hits == 1                # packed batch reused
    for a, b in zip(first, second):
        np.testing.assert_array_equal(a, b)
    # a PERMUTED repeat of the same unique-user SET is still a memo hit:
    # the engine relabels inverse_idx/user_feats into the memoized row
    # order on host (bit-identical — per-user rows are only ever consumed
    # through inverse_idx gathers), so no repack, no H2D
    reordered = [_mk_request(s, rng) for s in (2, 1, 3)]
    out3 = engine.score(reordered)
    assert cache.memo_hits == 2 and cache.memo_misses == 1
    assert engine.memo_perm_hits == 1
    solo = ServingEngine(model, params, max_unique=4,
                         max_candidates=16).score(reordered)
    for a, b in zip(out3, solo):
        np.testing.assert_allclose(a, b, atol=1e-5)
    # ... and bit-identical to scoring the same permutation uncached-memo
    fresh = ServingEngine(model, params, max_unique=4, max_candidates=16,
                          cache=ContextCache(capacity=16, memo_capacity=0))
    for a, b in zip(out3, fresh.score(reordered)):
        np.testing.assert_array_equal(a, b)


def test_pack_memo_eviction_drops_stale_batches(early_model):
    """No stale-KV scoring: once a user is evicted from the per-user LRU,
    every memoized packed batch containing that user must miss, and the
    re-encoded pass must agree with a fresh engine."""
    model, params = early_model
    cache = ContextCache(capacity=2, memo_capacity=8)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=cache)
    rng = np.random.RandomState(23)
    batch_a = [_mk_request(s, rng) for s in (1, 2)]
    first = engine.score(batch_a)              # memoizes (u1, u2)
    engine.score([_mk_request(s, rng) for s in (3, 4)])   # evicts u1+u2
    hits_before = cache.memo_hits
    again = engine.score(batch_a)              # must NOT hit the stale memo
    assert cache.memo_hits == hits_before
    for a, b in zip(first, again):
        np.testing.assert_array_equal(a, b)    # deterministic re-encode
    fresh = ServingEngine(model, params, max_unique=4,
                          max_candidates=16).score(batch_a)
    for a, b in zip(again, fresh):
        np.testing.assert_allclose(a, b, atol=1e-5)


@pytest.fixture(scope="module")
def rotate_model():
    return _make_model(
        "graphsage-lt",
        dcat=DCATOptions(rotate_replace=True, skip_last_self_attn=True))


def test_rotated_kv_layout_cached_path(rotate_model):
    """rotate_replace engines cache the PRE-ROTATED fixed-L KV layout
    (``ctx_rotate``), so the cross executor concats instead of rotating
    per call: hit == miss bit-for-bit, parity with the monolithic in-place
    rotation path, zero recompiles after warmup, and the cached KV is
    n_cand_tokens slots smaller per user."""
    model, params = rotate_model
    cache = ContextCache(capacity=16)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=cache)
    assert engine._ctx_rot and engine._ctx_tag == "rot"
    engine.warmup()
    rng = np.random.RandomState(24)
    reqs = [_mk_request(s, rng) for s in (1, 2, 3, 1)]
    miss_pass = engine.score(reqs)
    hit_pass = engine.score(reqs)
    for a, b in zip(miss_pass, hit_pass):
        np.testing.assert_array_equal(a, b)
    assert engine.registry.compiles_after_warmup == 0
    # the cached value is tagged and rotated: KV length L - n_cand_tokens
    tag, ctxs = cache.peek(next(iter(cache._d)))
    assert tag == "rot"
    kv = [l for l in jax.tree.leaves(ctxs) if l.ndim >= 3]
    assert all(l.shape[-3] == L - model.n_cand_tokens for l in kv)
    # parity with the uncached engine (per-call in-place rotation)
    plain = ServingEngine(model, params, max_unique=4,
                          max_candidates=16).score(reqs)
    for a, b in zip(miss_pass, plain):
        np.testing.assert_allclose(a, b, atol=2e-5)


# ---------------------------------------------------------------------------
# executor registry / warmup
# ---------------------------------------------------------------------------

def test_zero_recompiles_after_warmup(early_model):
    model, params = early_model
    cache = ContextCache(capacity=32)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           min_candidates=4, cache=cache)
    tel = engine.warmup()
    assert tel["compiles"] > 0 and tel["compiles_after_warmup"] == 0
    rng = np.random.RandomState(7)
    # mixed-shape stream: different request counts, candidate fanouts, and
    # repeat patterns hit several (b_u, b_c) buckets
    stream = [
        [_mk_request(1, rng, n_cand=2)],
        [_mk_request(s, rng, n_cand=3) for s in (1, 2, 3)],
        [_mk_request(s, rng, n_cand=5) for s in (2, 2, 4, 1)],
        [_mk_request(s, rng, n_cand=1) for s in (5, 6)],
    ]
    for batch in stream:                                 # first pass
        engine.score(batch)
    assert engine.registry.compiles_after_warmup == 0
    hits_before = engine.registry.hits
    for batch in stream:                                 # second pass
        engine.score(batch)
    assert engine.registry.compiles_after_warmup == 0
    assert engine.registry.hits > hits_before


def test_lite_cached_zero_recompiles_after_warmup(lite_model):
    """The score_emb executor is keyed by (b_u, b_c): user_feats is
    (b_u, F), so a b_u the warmup missed would silently retrace."""
    model, params = lite_model
    engine = ServingEngine(model, params, max_unique=4, max_candidates=8,
                           cache=ContextCache(16))
    engine.warmup()
    rng = np.random.RandomState(11)
    for seeds in ((1,), (1, 2), (1, 2, 3)):              # b_u = 1, 2, 4
        engine.score([_mk_request(s, rng, graphsage=False) for s in seeds])
    assert engine.registry.compiles_after_warmup == 0


def test_uncached_engine_warmup_covers_rank_executors(early_model):
    model, params = early_model
    engine = ServingEngine(model, params, max_unique=4, max_candidates=8,
                           min_candidates=8)
    engine.warmup()
    rng = np.random.RandomState(8)
    engine.score([_mk_request(1, rng), _mk_request(2, rng)])
    assert engine.registry.compiles_after_warmup == 0


# ---------------------------------------------------------------------------
# request scheduler, driven directly over the engine flush
# ---------------------------------------------------------------------------

def test_scheduler_coalesces(early_model):
    model, params = early_model
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=ContextCache(16))
    rng = np.random.RandomState(9)
    reqs = [_mk_request(s, rng) for s in (1, 2, 1, 3)]
    ref = ServingEngine(model, params, max_unique=4, max_candidates=16,
                        cache=ContextCache(16)).score(reqs)
    mb = _mk_scheduler(engine, max_requests=4)
    tickets = [mb.submit(r) for r in reqs]
    assert all(t.done() for t in tickets)                # auto-flushed at 4
    assert mb.flushes == 1 and mb.coalesced == 4
    for t, r in zip(tickets, ref):
        np.testing.assert_allclose(t.result(), r, atol=1e-6)
    # partial batch: result() forces the flush
    t = mb.submit(_mk_request(5, rng))
    assert not t.done()
    assert t.result().shape == (3, 3)
    assert mb.flushes == 2


def test_scheduler_propagates_engine_errors(early_model):
    """A failing engine.score must fail the tickets, not orphan them (a
    caller blocked in result() would hang forever)."""
    model, params = early_model
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16)
    mb = _mk_scheduler(engine, max_requests=8)
    rng = np.random.RandomState(13)
    t = mb.submit(_mk_request(1, rng, graphsage=False))  # variant needs gs
    with pytest.raises(ValueError, match="graphsage"):
        mb.flush()
    assert t.done()
    with pytest.raises(ValueError, match="graphsage"):
        t.result()


class _FakeEngine:
    """Deterministic stand-in for ServingEngine: each request scores to its
    own cand_ids (so a result can be attributed to exactly one request —
    the property the concurrency tests assert).  Optionally blocks inside
    score() until released, to hold a flush in flight."""

    def __init__(self, gate: "threading.Event" = None, delay: float = 0.0):
        self.max_candidates = 64
        self.calls = 0
        self._gate = gate
        self._delay = delay

    def score(self, requests):
        self.calls += 1
        if self._gate is not None:
            assert self._gate.wait(10.0)
        if self._delay:
            import time
            time.sleep(self._delay)
        return [np.asarray(r.cand_ids, np.float32) for r in requests]


def _tiny_request(uid: int, tag: int):
    ids = np.full(4, uid, np.int32)
    return RankRequest(seq_ids=ids, seq_actions=ids, seq_surfaces=ids,
                       cand_ids=np.array([tag], np.int32),
                       cand_feats=np.zeros((1, 2), np.float32),
                       user_feats=np.zeros(2, np.float32))


def test_ticket_result_no_redundant_flush_while_in_flight():
    """The double-flush race: a ticket whose request was picked up by an
    in-flight flush must WAIT on that batch from result(), not trigger a
    second engine call (which would prematurely flush whatever queued
    after it)."""
    gate = threading.Event()
    eng = _FakeEngine(gate=gate)
    mb = _mk_scheduler(eng, max_requests=64)
    t1 = mb.submit(_tiny_request(1, 101))
    flusher = threading.Thread(target=mb.flush)
    flusher.start()                    # picks t1 up, blocks inside score()
    deadline = time.time() + 10.0
    while eng.calls == 0:              # wait until the flush is in flight
        assert time.time() < deadline, "flush never reached engine.score"
        time.sleep(1e-4)
    t2 = mb.submit(_tiny_request(2, 202))      # queued AFTER the swap
    waiter_done = threading.Event()

    def waiter():
        assert t1.result() == [101.0]
        waiter_done.set()

    w = threading.Thread(target=waiter)
    w.start()
    w.join(0.2)
    # t1's result() saw its request in flight -> no second flush happened,
    # t2 is still pending, and the waiter is still blocked on the batch
    assert eng.calls == 1 and not t2.done() and not waiter_done.is_set()
    gate.set()
    flusher.join(10.0)
    assert waiter_done.wait(10.0)
    mb.flush()                         # t2 goes out in its own batch
    assert t2.result() == [202.0]
    assert eng.calls == 2 and mb.flushes == 2


def test_scheduler_threaded_submit_hammer():
    """8 threads hammer submit(); every ticket must resolve exactly once
    with ITS OWN request's result (no cross-wiring under concurrent
    flushes), and per-thread submission order is preserved in the
    tickets each thread holds."""
    eng = _FakeEngine(delay=0.001)
    mb = _mk_scheduler(eng, max_requests=8)
    n_threads, per_thread = 8, 25
    results = [None] * n_threads
    errors = []

    def worker(tid):
        try:
            tags = [tid * 1000 + i for i in range(per_thread)]
            tickets = [mb.submit(_tiny_request(tid, tag)) for tag in tags]
            results[tid] = (tags, [t.result() for t in tickets])
        except BaseException as e:     # pragma: no cover - diagnostic
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(tid,))
               for tid in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(60.0)
    mb.flush()                         # drain any final partial batch
    assert not errors
    for tid in range(n_threads):
        tags, outs = results[tid]
        # result-order: the i-th ticket of this thread carries the i-th
        # submitted request's score, in submission order
        assert [int(o[0]) for o in outs] == tags
    assert mb.coalesced == n_threads * per_thread
    assert mb.flushes == eng.calls <= n_threads * per_thread


def test_background_flusher_resolves_without_result(early_model):
    """With max_wait_ms set, a partial batch goes out on its own: the
    ticket resolves without anyone calling result()/flush()/poll(), and
    the scores match the synchronous engine."""
    model, params = early_model
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=ContextCache(16))
    rng = np.random.RandomState(14)
    reqs = [_mk_request(s, rng) for s in (1, 2)]
    ref = ServingEngine(model, params, max_unique=4,
                        max_candidates=16).score(reqs)
    with _mk_scheduler(engine, max_requests=32, max_wait_ms=5.0) as mb:
        tickets = [mb.submit(r) for r in reqs]
        assert all(t._done.wait(30.0) for t in tickets)   # no manual flush
        for t, r in zip(tickets, ref):
            np.testing.assert_allclose(t.result(), r, atol=1e-5)
        assert mb.flushes >= 1
    assert mb._flusher is None         # close() joined the thread


def test_background_flusher_survives_engine_errors():
    """A failing flush must not kill the flusher thread: subsequent
    batches still go out."""

    class _Flaky(_FakeEngine):
        def score(self, requests):
            self.calls += 1
            if self.calls == 1:
                raise RuntimeError("boom")
            return super().score(requests)

    eng = _Flaky()
    with _mk_scheduler(eng, max_requests=64, max_wait_ms=2.0) as mb:
        bad = mb.submit(_tiny_request(1, 7))
        assert bad._done.wait(30.0)
        with pytest.raises(RuntimeError, match="boom"):
            bad.result()
        good = mb.submit(_tiny_request(2, 8))
        assert good._done.wait(30.0)
        assert good.result() == [8.0]
