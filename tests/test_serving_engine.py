"""Serving engine (paper §4.3): batch planner, shape buckets, executor
registry, context-KV cache, micro-batcher.

Covers the acceptance points of the engine refactor:
  * vectorized Ψ/first_of in the planner == the naive per-unique argmax
    loop, on permuted and duplicate request orders (regression);
  * engine.score == per-request single scoring == direct model.forward;
  * cached early-fusion path (ContextCache hit) == uncached pass
    BIT-FOR-BIT on the same bucket;
  * zero fresh compiles on a mixed-shape request stream after warmup().
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.core.dcat import DCATOptions, dedup_with_first
from repro.core.finetune import FinetuneConfig, PinFMRankingModel
from repro.core.losses import LossConfig
from repro.core.pretrain import PinFMConfig, PinFMPretrain
from repro.models.config import get_config
from repro.serving.context_cache import ContextCache
from repro.serving.engine import ServingEngine
from repro.serving.microbatch import MicroBatcher
from repro.serving.plan import (BucketLadder, RankRequest, build_plan,
                                split_requests)

L = 16


def _make_model(variant, **fkw):
    pcfg = PinFMConfig(rows=512, n_tables=2, sub_dim=8, seq_len=L,
                       loss=LossConfig(window=4, downstream_len=8,
                                       n_negatives=0))
    bb = smoke_config(get_config("pinfm-20b")).replace(n_layers=2,
                                                       d_model=64, d_ff=128)
    cfg = FinetuneConfig(variant=variant, seq_len=L, **fkw)
    model = PinFMRankingModel.__new__(PinFMRankingModel)
    model.__init__(pcfg, cfg)
    from repro.core.dcat import DCAT
    model.pinfm = PinFMPretrain(pcfg, bb)
    model.dcat = DCAT(model.pinfm.body, cfg.dcat)
    return model, model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def early_model():
    return _make_model(
        "graphsage-lt",
        dcat=DCATOptions(rotate_replace=False, skip_last_self_attn=True))


@pytest.fixture(scope="module")
def lite_model():
    return _make_model("lite-last")


def _mk_request(user_seed, cand_rng, n_cand=3, graphsage=True):
    r = np.random.RandomState(user_seed)
    return RankRequest(
        seq_ids=r.randint(0, 1000, L),
        seq_actions=r.randint(0, 6, L),
        seq_surfaces=r.randint(0, 3, L),
        cand_ids=cand_rng.randint(0, 1000, n_cand),
        cand_feats=cand_rng.randn(n_cand, 32).astype(np.float32),
        user_feats=r.randn(32).astype(np.float32),
        graphsage=(cand_rng.randn(n_cand, 64).astype(np.float32)
                   if graphsage else None))


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_bucket_ladder():
    lad = BucketLadder(64, 8)
    assert lad.sizes() == (8, 16, 32, 64)
    assert lad.fit(1) == 8 and lad.fit(9) == 16 and lad.fit(64) == 64
    with pytest.raises(ValueError):
        lad.fit(65)
    assert BucketLadder(6, 1).sizes() == (1, 2, 4, 6)


def test_first_of_vectorized_matches_argmax_loop():
    """Regression for the O(B_u*B_c) per-unique np.argmax loop the seed
    router used: the vectorized first_of/inverse must agree on permuted and
    duplicate-heavy request orders."""
    rng = np.random.RandomState(0)
    for trial in range(20):
        n_req = rng.randint(1, 12)
        pattern = rng.randint(0, 5, n_req)            # duplicate-heavy
        rows = np.stack([np.full(L, v) + np.arange(L) for v in pattern])
        uniq, inv, first_of = dedup_with_first(rows)
        # naive reference (the seed implementation)
        ref_first = np.array([np.argmax(inv == u) for u in range(len(uniq))])
        np.testing.assert_array_equal(first_of, ref_first)
        np.testing.assert_array_equal(rows[first_of], uniq)
        np.testing.assert_array_equal(uniq[inv], rows)        # Ψ⁻¹ inverts
        # first-occurrence order is preserved under permutation
        assert (np.diff(first_of) > 0).all()


def test_build_plan_layout():
    rng = np.random.RandomState(0)
    reqs = [_mk_request(s, rng, n_cand=n)
            for s, n in ((1, 3), (2, 2), (1, 4), (3, 1), (1, 2))]
    plan = build_plan(reqs, BucketLadder(8), BucketLadder(32, 4))
    assert plan.n_unique == 3 and plan.b_u == 4
    assert plan.n_candidates == 12 and plan.b_c == 16
    assert plan.counts == [3, 2, 4, 1, 2]
    # candidates of requests 0, 2 and 4 share unique row 0 (same user seed)
    inv = plan.batch["inverse_idx"][:plan.n_candidates]
    np.testing.assert_array_equal(
        inv, [0, 0, 0, 1, 1, 0, 0, 0, 0, 2, 0, 0])
    # padding rows are zero / invalid
    assert not plan.batch["seq_valid"][plan.n_unique:].any()
    assert (plan.batch["cand_ids"][plan.n_candidates:] == 0).all()
    assert len(plan.user_keys) == plan.n_unique
    assert plan.dedup_ratio == pytest.approx(4.0)


def test_plan_dedups_on_full_identity():
    """Ψ may only merge requests whose ENTIRE context input matches —
    same ids with different actions/surfaces are different contexts (and
    different ContextCache keys), so merging them would score one user's
    candidates against the other's context."""
    rng = np.random.RandomState(12)
    a, b = _mk_request(1, rng), _mk_request(1, rng)
    b.seq_actions = (b.seq_actions + 1) % 6
    plan = build_plan([a, b], BucketLadder(8), BucketLadder(32, 4))
    assert plan.n_unique == 2
    assert len(set(plan.user_keys)) == 2
    c = _mk_request(1, rng)                     # identical identity to a
    plan = build_plan([a, c], BucketLadder(8), BucketLadder(32, 4))
    assert plan.n_unique == 1


def test_split_requests_respects_maxima():
    rng = np.random.RandomState(0)
    reqs = [_mk_request(s % 4, rng, n_cand=3) for s in range(10)]
    chunks = split_requests(reqs, max_unique=2, max_candidates=7)
    assert sorted(i for c in chunks for i in c) == list(range(10))
    for c in chunks:
        assert sum(len(reqs[i].cand_ids) for i in c) <= 7
        assert len({reqs[i].seq_ids.tobytes() for i in c}) <= 2
    with pytest.raises(ValueError):
        split_requests([_mk_request(0, rng, n_cand=9)], 4, 8)


# ---------------------------------------------------------------------------
# engine equivalence
# ---------------------------------------------------------------------------

def test_engine_matches_single_request_scoring(early_model):
    model, params = early_model
    rng = np.random.RandomState(1)
    reqs = [_mk_request(s, rng) for s in (1, 2, 1, 3)]
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16)
    batched = engine.score(reqs)
    solo_engine = ServingEngine(model, params, max_unique=4,
                                max_candidates=16)
    for r, b in zip(reqs, batched):
        solo = solo_engine.score([r])[0]
        np.testing.assert_allclose(b, solo, atol=1e-5)


def test_engine_matches_direct_forward(early_model):
    model, params = early_model
    rng = np.random.RandomState(2)
    reqs = [_mk_request(s, rng) for s in (1, 2, 1)]
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16)
    out = engine.score(reqs)
    plan = build_plan(reqs, engine.ladder_u, engine.ladder_c)
    logits, _, _ = model.forward(params, jax.tree.map(jnp.asarray, plan.batch),
                                 train=False)
    ref = np.asarray(jax.nn.sigmoid(logits.astype(jnp.float32)))
    np.testing.assert_allclose(np.concatenate(out), ref[:plan.n_candidates],
                               atol=1e-5)


def test_oversized_single_request_is_split(early_model):
    """A request with more candidates than max_candidates is split by
    candidate slice and reassembled (the seed router padded it instead —
    unbounded shapes; the engine keeps shapes bucketed)."""
    import dataclasses
    model, params = early_model
    rng = np.random.RandomState(10)
    big = _mk_request(1, rng, n_cand=10)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=8)
    out = engine.score([big])
    assert out[0].shape == (10, 3)
    parts = [dataclasses.replace(big, cand_ids=big.cand_ids[s],
                                 cand_feats=big.cand_feats[s],
                                 graphsage=big.graphsage[s])
             for s in (slice(0, 8), slice(8, 10))]
    ref = np.concatenate([engine.score([p])[0] for p in parts])
    np.testing.assert_allclose(out[0], ref, atol=1e-6)


def test_oversized_request_list_is_chunked(early_model):
    model, params = early_model
    rng = np.random.RandomState(3)
    reqs = [_mk_request(s, rng) for s in range(9)]       # 9 users > max_unique
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16)
    out = engine.score(reqs)
    assert len(out) == 9 and all(o.shape == (3, 3) for o in out)
    assert len(engine.stats) >= 3                        # several chunks


# ---------------------------------------------------------------------------
# context-KV cache (early fusion)
# ---------------------------------------------------------------------------

def test_context_cache_hit_bitwise_identical(early_model):
    model, params = early_model
    rng = np.random.RandomState(4)
    reqs = [_mk_request(s, rng) for s in (1, 2, 3, 1)]
    cache = ContextCache(capacity=16)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=cache)
    miss_pass = engine.score(reqs)                       # populates the cache
    assert cache.misses == 3 and cache.hits == 0         # 3 unique users
    hit_pass = engine.score(reqs)                        # pure hits
    assert cache.misses == 3 and cache.hits == 3
    for a, b in zip(miss_pass, hit_pass):
        np.testing.assert_array_equal(a, b)              # bit-for-bit
    # and the cached path agrees with the uncached engine
    plain = ServingEngine(model, params, max_unique=4,
                          max_candidates=16).score(reqs)
    for a, b in zip(miss_pass, plain):
        np.testing.assert_allclose(a, b, atol=1e-5)


def test_context_cache_eviction_and_bytes(early_model):
    model, params = early_model
    rng = np.random.RandomState(5)
    cache = ContextCache(capacity=2)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=cache)
    engine.score([_mk_request(s, rng) for s in (1, 2, 3)])
    assert len(cache) == 2 and cache.nbytes > 0          # user 1 evicted
    engine.score([_mk_request(1, rng)])
    assert cache.misses == 4                             # re-encoded


def test_lite_cached_matches_uncached(lite_model):
    model, params = lite_model
    rng = np.random.RandomState(6)
    reqs = [_mk_request(s, rng, graphsage=False) for s in (1, 2, 1)]
    cache = ContextCache(capacity=16)
    cached = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=cache)
    out1 = cached.score(reqs)
    assert cache.misses == 2 and cache.hits == 0         # 2 unique users
    out2 = cached.score(reqs)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(a, b)
    plain = ServingEngine(model, params, max_unique=4,
                          max_candidates=16).score(reqs)
    for a, b in zip(out1, plain):
        np.testing.assert_allclose(a, b, atol=1e-4)


# ---------------------------------------------------------------------------
# executor registry / warmup
# ---------------------------------------------------------------------------

def test_zero_recompiles_after_warmup(early_model):
    model, params = early_model
    cache = ContextCache(capacity=32)
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           min_candidates=4, cache=cache)
    tel = engine.warmup()
    assert tel["compiles"] > 0 and tel["compiles_after_warmup"] == 0
    rng = np.random.RandomState(7)
    # mixed-shape stream: different request counts, candidate fanouts, and
    # repeat patterns hit several (b_u, b_c) buckets
    stream = [
        [_mk_request(1, rng, n_cand=2)],
        [_mk_request(s, rng, n_cand=3) for s in (1, 2, 3)],
        [_mk_request(s, rng, n_cand=5) for s in (2, 2, 4, 1)],
        [_mk_request(s, rng, n_cand=1) for s in (5, 6)],
    ]
    for batch in stream:                                 # first pass
        engine.score(batch)
    assert engine.registry.compiles_after_warmup == 0
    hits_before = engine.registry.hits
    for batch in stream:                                 # second pass
        engine.score(batch)
    assert engine.registry.compiles_after_warmup == 0
    assert engine.registry.hits > hits_before


def test_lite_cached_zero_recompiles_after_warmup(lite_model):
    """The score_emb executor is keyed by (b_u, b_c): user_feats is
    (b_u, F), so a b_u the warmup missed would silently retrace."""
    model, params = lite_model
    engine = ServingEngine(model, params, max_unique=4, max_candidates=8,
                           cache=ContextCache(16))
    engine.warmup()
    rng = np.random.RandomState(11)
    for seeds in ((1,), (1, 2), (1, 2, 3)):              # b_u = 1, 2, 4
        engine.score([_mk_request(s, rng, graphsage=False) for s in seeds])
    assert engine.registry.compiles_after_warmup == 0


def test_uncached_engine_warmup_covers_rank_executors(early_model):
    model, params = early_model
    engine = ServingEngine(model, params, max_unique=4, max_candidates=8,
                           min_candidates=8)
    engine.warmup()
    rng = np.random.RandomState(8)
    engine.score([_mk_request(1, rng), _mk_request(2, rng)])
    assert engine.registry.compiles_after_warmup == 0


# ---------------------------------------------------------------------------
# micro-batcher
# ---------------------------------------------------------------------------

def test_microbatcher_coalesces(early_model):
    model, params = early_model
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16,
                           cache=ContextCache(16))
    rng = np.random.RandomState(9)
    reqs = [_mk_request(s, rng) for s in (1, 2, 1, 3)]
    ref = ServingEngine(model, params, max_unique=4, max_candidates=16,
                        cache=ContextCache(16)).score(reqs)
    mb = MicroBatcher(engine, max_requests=4)
    tickets = [mb.submit(r) for r in reqs]
    assert all(t.done() for t in tickets)                # auto-flushed at 4
    assert mb.flushes == 1 and mb.coalesced == 4
    for t, r in zip(tickets, ref):
        np.testing.assert_allclose(t.result(), r, atol=1e-6)
    # partial batch: result() forces the flush
    t = mb.submit(_mk_request(5, rng))
    assert not t.done()
    assert t.result().shape == (3, 3)
    assert mb.flushes == 2


def test_microbatcher_propagates_engine_errors(early_model):
    """A failing engine.score must fail the tickets, not orphan them (a
    caller blocked in result() would hang forever)."""
    model, params = early_model
    engine = ServingEngine(model, params, max_unique=4, max_candidates=16)
    mb = MicroBatcher(engine, max_requests=8)
    rng = np.random.RandomState(13)
    t = mb.submit(_mk_request(1, rng, graphsage=False))  # variant needs gs
    with pytest.raises(ValueError, match="graphsage"):
        mb.flush()
    assert t.done()
    with pytest.raises(ValueError, match="graphsage"):
        t.result()
