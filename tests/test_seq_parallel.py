"""Sequence-parallel attention (§Perf iteration 5) — numeric equivalence on
a virtual 8-device mesh.  Runs in a subprocess because the device count must
be set before jax initializes."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, r"%s")
import jax, jax.numpy as jnp
from repro.distributed.sharding import (activation_constraints,
                                        seq_parallel_attention)
from repro.nn.attention import attend5

mesh = jax.make_mesh((2, 4), ("data", "model"))
policy = {"_batch": "data", "_attn_seq": True}
B, S, K, G, D = 2, 32, 2, 2, 16
key = jax.random.PRNGKey(0)
q = jax.random.normal(key, (B, S, K, G, D))
k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, K, D))
v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, K, D))
pos = jnp.broadcast_to(jnp.arange(S), (B, S))
for window in (None, 8):
    ref = attend5(q, k, v, q_pos=pos, k_pos=pos, causal=True, window=window)
    with mesh, activation_constraints(mesh, policy):
        out = jax.jit(lambda q, k, v, p: seq_parallel_attention(
            q, k, v, p, causal=True, window=window,
            attend_fn=attend5))(q, k, v, pos)
    err = float(jnp.max(jnp.abs(out - ref)))
    assert err < 1e-5, (window, err)
print("OK")
"""


@pytest.mark.slow
def test_seq_parallel_attention_8dev():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", _SCRIPT % src],
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
